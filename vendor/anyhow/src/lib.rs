//! Minimal vendored stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate, covering exactly the API surface this workspace uses:
//!
//! * [`Error`] — a context-chain error value (`{}` prints the outermost
//!   message, `{:#}` the full `a: b: c` chain, like real anyhow).
//! * [`Result<T>`] — `Result` with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any `Result`
//!   whose error converts into [`Error`].
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the usual macros.
//!
//! The build environment for this repository is fully offline, so the real
//! crates-io dependency cannot be fetched; this shim keeps the crate's
//! source code byte-compatible with the real `anyhow` for the subset it
//! exercises (see DESIGN.md §3 on the vendored-substrate policy).

use std::fmt;

/// A boxed-free error value: an outermost message plus the `Display`
/// renderings of every underlying source, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (like `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `Display` renderings of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, exactly like real anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// The same coherence trick real anyhow uses: `Error` deliberately does NOT
// implement `std::error::Error`, so this blanket impl cannot overlap with
// core's reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "inner")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("value {x}");
        assert_eq!(e.to_string(), "value 3");
        let e = anyhow!("value {}", 4);
        assert_eq!(e.to_string(), "value 4");
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was false");
            bail!("always bails with {}", 7)
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "always bails with 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "inner");
    }
}
