//! Table 1 regeneration: {ZO-SGD, ZO-AdaMM, JAGUAR} x {Gaussian 2-fwd,
//! Gaussian 6-fwd, Algorithm 2} x {FT, LoRA} x {roberta_mini, opt_mini}.
//!
//!     cargo run --release --example table1 [-- --budget 6000 --models roberta_mini]
//!
//! Absolute accuracies are testbed-specific (mini models on a synthetic
//! corpus); the claims under test are the paper's *orderings*:
//!   Algorithm 2 > Gaussian 2-fwd >= Gaussian 6-fwd   per cell.
//! Results land in reports/table1.md + reports/table1.json.

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::coordinator::{run_grid, TrialSpec};
use zo_ldsd::report::{jnum, jobj, jstr, write_json, Table};
use zo_ldsd::train::TrainConfig;

/// Per-(optimizer, mode) base learning rates, scaled for the mini models
/// (the paper's Table 2 serves the same role for the full-size models).
fn lr_for(optimizer: &str, mode: TrainMode) -> f32 {
    // calibrated on roberta_mini at a short probe budget (see
    // EXPERIMENTS.md); FT rates are ~d_lora/d_ft smaller because the
    // rank-1 ZO step norm scales with d * lr
    match (optimizer, mode) {
        ("zo_sgd", TrainMode::Ft) => 2e-6,
        ("zo_sgd", TrainMode::Lora) => 1e-4,
        ("zo_adamm", TrainMode::Ft) => 1e-4,
        ("zo_adamm", TrainMode::Lora) => 1e-3,
        ("jaguar", TrainMode::Ft) => 2e-6,
        ("jaguar", TrainMode::Lora) => 5e-5,
        _ => 1e-4,
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let budget = args.get_u64("budget", 6000)?;
    let workers = args.get_usize("workers", 2)?;
    let models: Vec<String> = args
        .get_or("models", "roberta_mini,opt_mini")
        .split(',')
        .map(String::from)
        .collect();
    let manifest = Manifest::load(&dir)?;

    let mut specs = Vec::new();
    for model in &models {
        manifest.model(model)?; // validate early
        for mode in [TrainMode::Ft, TrainMode::Lora] {
            for optimizer in ["zo_sgd", "zo_adamm", "jaguar"] {
                let lr = lr_for(optimizer, mode);
                for (method, cfg) in [
                    ("gauss_2fwd", TrainConfig::gaussian_2fwd(optimizer, lr, budget)),
                    ("gauss_6fwd", TrainConfig::gaussian_6fwd(optimizer, lr, budget)),
                    ("alg2", TrainConfig::algorithm2(optimizer, lr, budget)),
                ] {
                    specs.push(TrialSpec {
                        id: format!("{model}/{}/{optimizer}/{method}", mode.as_str()),
                        model: model.clone(),
                        mode,
                        config: cfg,
                        eval_batches: 8,
                        probe_dispatch: None,
                        probe_storage: None,
                        checkpoint: None,
                        oracle: zo_ldsd::coordinator::OracleSpec::Pjrt,
                    });
                }
            }
        }
    }

    println!("running {} trials (budget {budget} forwards each, {workers} workers)", specs.len());
    let t0 = std::time::Instant::now();
    let results = run_grid(&dir, specs, &zo_ldsd::exec::ExecContext::new(workers));

    let mut table = Table::new(
        &format!("Table 1 (budget {budget} forwards)"),
        &["model", "mode", "optimizer", "sampling", "accuracy", "probe MiB"],
    );
    let mut json_rows = Vec::new();
    for r in &results {
        match r {
            Ok(tr) => {
                let parts: Vec<&str> = tr.spec_id.split('/').collect();
                table.row(vec![
                    parts[0].into(), parts[1].into(), parts[2].into(),
                    parts[3].into(),
                    format!("{:.3}", tr.outcome.final_accuracy),
                    // probe-state peak (grid-wide upper bound when the
                    // grid runs trials concurrently; see TrialResult)
                    format!("{:.1}", tr.probe_peak_bytes as f64 / (1 << 20) as f64),
                ]);
                json_rows.push(jobj(vec![
                    ("id", jstr(&tr.spec_id)),
                    ("accuracy", jnum(tr.outcome.final_accuracy)),
                    ("steps", jnum(tr.outcome.steps as f64)),
                    ("wall_seconds", jnum(tr.outcome.wall_seconds)),
                    ("probe_peak_bytes", jnum(tr.probe_peak_bytes as f64)),
                ]));
            }
            Err(e) => eprintln!("trial failed: {e:#}"),
        }
    }
    table.print();
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/table1.md", table.to_markdown())?;
    write_json(
        std::path::Path::new("reports/table1.json"),
        &zo_ldsd::jsonio::Json::Arr(json_rows),
    )?;
    println!("wrote reports/table1.md + .json in {:.0}s", t0.elapsed().as_secs_f64());
    Ok(())
}
