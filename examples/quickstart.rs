//! Quickstart: zero-order fine-tune a mini RoBERTa with ZO-LDSD (Alg. 2).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Loads the AOT-compiled model, runs a short budget of Algorithm 2 with
//! ZO-SGD, and prints the accuracy trajectory.  Python is not involved.

use anyhow::Result;

use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::data::Corpus;
use zo_ldsd::eval::Evaluator;
use zo_ldsd::oracle::PjrtOracle;
use zo_ldsd::runtime::Runtime;
use zo_ldsd::train::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    let manifest = Manifest::load(&dir)?;
    let model = manifest.model("roberta_mini")?;
    println!(
        "model {}: d_ft = {}, d_lora = {}, pretrained acc = {:?}",
        model.name, model.d_ft, model.d_lora, model.pretrain_accuracy
    );

    // LoRA fine-tuning with the paper's Algorithm 2 defaults
    let oracle = PjrtOracle::new(&rt, model, TrainMode::Lora)?;
    let evaluator = Evaluator::new(&rt, model, TrainMode::Lora)?;
    let corpus = Corpus::new(manifest.corpus("roberta_mini")?.clone())?;

    let mut cfg = TrainConfig::algorithm2("zo_sgd", 1e-4, 3000);
    cfg.eval_every = 600;
    let mut trainer = Trainer::new(cfg, oracle, corpus)?;
    println!("training: {} ...", trainer.cfg.estimator.label());
    let out = trainer.run(Some(&evaluator))?;

    for (calls, acc) in &out.acc_curve {
        println!("  {calls:>6} forwards   accuracy {acc:.4}");
    }
    println!(
        "{} steps, {} forwards, final accuracy {:.4} ({:.1}s)",
        out.steps, out.oracle_calls, out.final_accuracy, out.wall_seconds
    );
    Ok(())
}
