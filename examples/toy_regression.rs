//! Fig. 2 toy experiment: LDSD vs baseline DGD on a9a-like linear
//! regression, with access to directional derivatives (§3.6).
//!
//!     cargo run --release --example toy_regression [-- --steps 800]
//!
//! Emits reports/fig2_toy.csv with the two series the paper plots:
//! cos(g_x, grad f) and ||grad f||.  Drop a real `a9a` LIBSVM file next to
//! the binary and pass --a9a PATH to run on the actual dataset.

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::data::{parse_libsvm, SyntheticRegression};
use zo_ldsd::optim::{DgdConfig, DgdRunner};
use zo_ldsd::oracle::{LinRegOracle, Oracle};
use zo_ldsd::report::write_csv;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let steps = args.get_usize("steps", 800)?;
    let seed = args.get_u64("seed", 1)?;

    let make_oracle = || -> Result<LinRegOracle> {
        if let Some(path) = args.get("a9a") {
            let text = std::fs::read_to_string(path)?;
            let ds = parse_libsvm(&text, 123).map_err(anyhow::Error::msg)?;
            let d = ds.x.cols;
            println!("loaded real a9a: {} rows", ds.x.rows);
            Ok(LinRegOracle::new(ds.x, ds.y, vec![0.0; d]))
        } else {
            let ds = SyntheticRegression::a9a_like(2048, 0xA9A);
            Ok(LinRegOracle::new(ds.x, ds.y, vec![0.0; 123]))
        }
    };

    // Baseline DGD (v ~ N(0, I)); gamma_x rescaled to this conditioning
    let mut o_base = make_oracle()?;
    let mut cfg_base = DgdConfig::paper_baseline(steps, seed);
    cfg_base.gamma_x = 2.0;
    let mut base = DgdRunner::new(cfg_base, o_base.dim());
    let t_base = base.run(&mut o_base)?;

    // LDSD (learnable mu); the paper's gamma_x ratio (40x smaller) kept
    let mut o_ldsd = make_oracle()?;
    let mut cfg_ldsd = DgdConfig::paper_ldsd(steps, seed);
    cfg_ldsd.gamma_x = 0.05;
    cfg_ldsd.gamma_mu = 0.05;
    cfg_ldsd.eps = 0.05;
    let mut ldsd = DgdRunner::new(cfg_ldsd, o_ldsd.dim());
    let t_ldsd = ldsd.run(&mut o_ldsd)?;

    let xs: Vec<f64> = (0..steps).map(|i| i as f64).collect();
    let col = |v: &[f32]| -> Vec<f64> { v.iter().map(|x| *x as f64).collect() };
    write_csv(
        std::path::Path::new("reports/fig2_toy.csv"),
        &[
            "step",
            "baseline_alignment", "ldsd_alignment",
            "baseline_grad_norm", "ldsd_grad_norm",
            "baseline_loss", "ldsd_loss",
        ],
        &[
            &xs,
            &col(&t_base.alignment), &col(&t_ldsd.alignment),
            &col(&t_base.grad_norm), &col(&t_ldsd.grad_norm),
            &t_base.loss, &t_ldsd.loss,
        ],
    )?;

    let tail = |v: &[f32]| -> f32 {
        let s = &v[v.len().saturating_sub(50)..];
        s.iter().sum::<f32>() / s.len() as f32
    };
    println!("wrote reports/fig2_toy.csv ({steps} steps)");
    println!(
        "alignment tail:  baseline {:.3}   LDSD {:.3}   (paper: ~1/sqrt(d) vs ~1)",
        tail(&t_base.alignment), tail(&t_ldsd.alignment)
    );
    println!(
        "final loss:      baseline {:.4}   LDSD {:.4}",
        t_base.loss.last().unwrap(), t_ldsd.loss.last().unwrap()
    );
    Ok(())
}
