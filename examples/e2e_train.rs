//! End-to-end driver (EXPERIMENTS.md §E2E): full-parameter zero-order
//! fine-tuning of a transformer for a few hundred steps, logging the loss
//! curve and accuracy — proving all three layers compose: rust coordinator
//! -> PJRT -> AOT HLO containing the Pallas kernels.
//!
//!     cargo run --release --example e2e_train [-- --model roberta_mini --steps 300]

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::data::Corpus;
use zo_ldsd::eval::Evaluator;
use zo_ldsd::oracle::PjrtOracle;
use zo_ldsd::report::write_csv;
use zo_ldsd::runtime::Runtime;
use zo_ldsd::train::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let model_name = args.get_or("model", "roberta_mini").to_string();
    let steps = args.get_u64("steps", 300)?;
    let mode = TrainMode::parse(args.get_or("mode", "ft"))?;

    let rt = Runtime::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let model = manifest.model(&model_name)?;
    let corpus = Corpus::new(manifest.corpus(&model_name)?.clone())?;

    println!(
        "e2e: {} {} ({} trainable params), {} ZO steps (Algorithm 2, K = {})",
        model.name,
        mode.as_str(),
        model.d_trainable(mode),
        steps,
        model.shapes.k
    );

    let oracle = PjrtOracle::new(&rt, model, mode)?;
    let evaluator = Evaluator::new(&rt, model, mode)?;
    let calls_per_step = model.shapes.k as u64 + 1;
    let lr = args.get_f64("lr", if mode == TrainMode::Ft { 2e-6 } else { 1e-4 })? as f32;
    let mut cfg = TrainConfig::algorithm2("zo_sgd", lr, steps * calls_per_step);
    cfg.eval_every = (steps / 6).max(1) * calls_per_step;
    cfg.eval_batches = 8;

    let pre_acc = evaluator.accuracy(
        zo_ldsd::oracle::Oracle::params(
            &PjrtOracle::new(&rt, model, mode)?
        ),
        &corpus,
        8,
    )?;
    println!("pre-fine-tuning accuracy: {pre_acc:.4}");

    let mut trainer = Trainer::new(cfg, oracle, corpus)?;
    let out = trainer.run(Some(&evaluator))?;

    println!("loss curve (training-loss proxy every ~{} steps):", (steps / 20).max(1));
    let stride = (out.loss_curve.len() / 20).max(1);
    for (calls, loss) in out.loss_curve.iter().step_by(stride) {
        println!("  calls {calls:>7}  loss {loss:.4}");
    }
    for (calls, acc) in &out.acc_curve {
        println!("  calls {calls:>7}  accuracy {acc:.4}");
    }
    println!(
        "e2e done: {} steps, {} forwards, acc {:.4} -> {:.4} ({:.1}s, {:.1} steps/s)",
        out.steps,
        out.oracle_calls,
        pre_acc,
        out.final_accuracy,
        out.wall_seconds,
        out.steps as f64 / out.wall_seconds
    );

    let xs: Vec<f64> = out.loss_curve.iter().map(|(c, _)| *c as f64).collect();
    let ls: Vec<f64> = out.loss_curve.iter().map(|(_, l)| *l).collect();
    write_csv(
        std::path::Path::new(&format!("reports/e2e_{}_{}.csv", model.name, mode.as_str())),
        &["oracle_calls", "loss"],
        &[&xs, &ls],
    )?;
    println!("wrote reports/e2e_{}_{}.csv", model.name, mode.as_str());
    Ok(())
}
