//! End-to-end forward-only MLP fine-tuning (DESIGN.md §12): train the MLP
//! classifier on the synthetic corpus with Algorithm 2 (LDSD best-of-K)
//! under streamed probes and epoch-shuffled minibatches, logging the loss
//! curve and test accuracy.  No artifacts or PJRT runtime needed.
//!
//!     cargo run --release --example mlp_e2e [-- --hidden 64,64 --budget 6000]

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::eval::{AccuracyEval, MlpEvaluator};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::{Activation, MlpSpec};
use zo_ldsd::oracle::{MlpOracle, Oracle};
use zo_ldsd::train::{ProbeStorage, ShuffleSpec, TrainConfig, Trainer};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let hidden = MlpSpec::parse_hidden(args.get_or("hidden", "64,64"))?;
    let activation = Activation::parse(args.get_or("activation", "tanh"))?;
    let in_dim = args.get_usize("in-dim", 128)?;
    let budget = args.get_u64("budget", 6000)?;
    let seed = args.get_u64("seed", 0)?;
    let n_train = args.get_u64("train-examples", 4096)?;
    let threads = args.get_usize("threads", 0)?;

    let corpus_spec = CorpusSpec::default_mini();
    let spec = MlpSpec::new(in_dim, hidden, corpus_spec.n_classes as usize, activation)?;
    let corpus = Corpus::new(corpus_spec)?;
    let oracle = MlpOracle::from_seed(spec.clone(), seed);
    let evaluator = MlpEvaluator::new(spec.clone(), 32);

    let mut cfg = TrainConfig::algorithm2("zo_sgd", 0.02, budget);
    cfg.seed = seed;
    cfg.eval_every = (budget / 6).max(1);
    cfg.probe_storage = ProbeStorage::Streamed;
    // --train-examples 0 keeps the sequential stream (same convention as
    // the CLI)
    if n_train > 0 {
        cfg.shuffle = Some(ShuffleSpec { n_train });
    }

    let exec = if threads == 0 {
        ExecContext::from_env()
    } else {
        ExecContext::new(threads)
    };
    let ordering = if n_train > 0 {
        format!("epoch-shuffled over {n_train} examples")
    } else {
        "sequential stream".to_string()
    };
    println!(
        "mlp e2e: {} (d = {}, in_dim {in_dim}), budget {budget} forwards, {} threads, \
         {ordering}",
        spec.label(),
        spec.dim(),
        exec.threads()
    );

    let pre_acc = evaluator.accuracy(oracle.params(), &corpus, 8)?;
    println!("pre-training accuracy: {pre_acc:.4}");

    let mut trainer = Trainer::with_exec(cfg, oracle, corpus, exec)?;
    let out = trainer.run(Some(&evaluator))?;

    let stride = (out.loss_curve.len() / 20).max(1);
    println!("loss curve (best-probe training loss):");
    for (calls, loss) in out.loss_curve.iter().step_by(stride) {
        println!("  calls {calls:>7}  loss {loss:.4}");
    }
    for (calls, acc) in &out.acc_curve {
        println!("  calls {calls:>7}  accuracy {acc:.4}");
    }
    println!(
        "mlp e2e done: {} steps, {} forwards, acc {pre_acc:.4} -> {:.4} ({:.1}s)",
        out.steps, out.oracle_calls, out.final_accuracy, out.wall_seconds
    );
    Ok(())
}
