//! Memory-footprint comparison (the paper's §1 motivation): first-order
//! fine-tuning vs zero-order variants, from first-principles byte
//! accounting on our model stand-ins.
//!
//!     cargo run --release --example memory_report

use anyhow::Result;

use zo_ldsd::config::Manifest;
use zo_ldsd::metrics::MemoryReport;
use zo_ldsd::report::Table;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&dir)?;

    for (name, m) in &manifest.models {
        for (mode_label, d_trainable) in [("FT", m.d_ft), ("LoRA", m.d_lora)] {
            let report = MemoryReport::build(
                d_trainable, m.d_ft, m.shapes.batch, m.shapes.seq, m.d_model,
                4 * m.d_model, 4, m.n_layers, m.shapes.k,
            );
            let mut t = Table::new(
                &format!("{name} ({mode_label}, d_trainable = {d_trainable})"),
                &["method", "weights", "grads", "acts", "opt state", "method", "total MiB", "x inference"],
            );
            let mib = |b: usize| format!("{:.1}", b as f64 / (1 << 20) as f64);
            for r in &report {
                t.row(vec![
                    r.method.clone(),
                    mib(r.weights),
                    mib(r.gradients),
                    mib(r.activations_backward + r.activations_forward),
                    mib(r.optimizer_state),
                    mib(r.method_state),
                    mib(r.total()),
                    format!("{:.2}", r.over_inference()),
                ]);
            }
            t.print();
            println!();
        }
    }
    println!("(paper's claim: backprop fine-tuning needs ~12x inference memory at scale;");
    println!(" ZO rows stay within a small constant of inference.)");
    Ok(())
}
