//! Fig. 3 ablations: accuracy of ZO-SGD + Algorithm 2 sampling on
//! roberta_mini + LoRA as a function of (a) K, (b) gamma_mu, (c) epsilon.
//!
//!     cargo run --release --example ablations [-- --budget 4800 --sweep k]
//!
//! `--sweep k|gamma-mu|epsilon|all` selects the panel.  Results go to
//! reports/fig3_<sweep>.csv with the Gaussian-baseline reference line.

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::coordinator::{run_grid, TrialSpec};
use zo_ldsd::report::write_csv;
use zo_ldsd::sampler::LdsdConfig;
use zo_ldsd::train::{EstimatorKind, SamplerKind, TrainConfig};

const MODEL: &str = "roberta_mini";
const LR: f32 = 5e-4;

fn alg2_cfg(k: usize, gamma_mu: f32, eps: f32, budget: u64) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k,
            sampler: SamplerKind::Ldsd(LdsdConfig {
                eps,
                gamma_mu,
                ..Default::default()
            }),
        },
        ..TrainConfig::algorithm2("zo_sgd", LR, budget)
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let budget = args.get_u64("budget", 4800)?;
    let workers = args.get_usize("workers", 2)?;
    let sweep = args.get_or("sweep", "all").to_string();
    Manifest::load(&dir)?.model(MODEL)?;

    let mut specs: Vec<TrialSpec> = Vec::new();
    let spec = |id: String, config: TrainConfig| TrialSpec {
        id,
        model: MODEL.into(),
        mode: TrainMode::Lora,
        config,
        eval_batches: 8,
        probe_dispatch: None,
        probe_storage: None,
        checkpoint: None,
        oracle: zo_ldsd::coordinator::OracleSpec::Pjrt,
    };

    if sweep == "k" || sweep == "all" {
        for k in [1usize, 2, 5, 7, 10] {
            specs.push(spec(format!("k/{k}"), alg2_cfg(k, 1e-3, 1.0, budget)));
        }
    }
    if sweep == "gamma-mu" || sweep == "all" {
        for gm in [0.0f32, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1] {
            specs.push(spec(format!("gamma_mu/{gm}"), alg2_cfg(5, gm, 1.0, budget)));
        }
    }
    if sweep == "epsilon" || sweep == "all" {
        for eps in [0.05f32, 0.2, 0.5, 1.0, 2.0, 5.0] {
            specs.push(spec(format!("epsilon/{eps}"), alg2_cfg(5, 1e-3, eps, budget)));
        }
    }
    // design-choice ablations beyond the paper's three panels (DESIGN.md
    // §8b): the literal printed sign of the mu-update, and the ||mu|| = 1
    // renormalization the paper suggests in §3.5
    if sweep == "design" || sweep == "all" {
        for (label, reward_sign, renorm) in [
            ("descend_renorm", -1.0f32, true),  // our default
            ("descend_free", -1.0, false),
            ("paper_sign_renorm", 1.0, true),   // literal Algorithm 2
        ] {
            let mut cfg = alg2_cfg(5, 1e-3, 1.0, budget);
            if let EstimatorKind::BestOfK { sampler: SamplerKind::Ldsd(l), .. } =
                &mut cfg.estimator
            {
                l.reward_sign = reward_sign;
                l.renormalize = renorm;
            }
            specs.push(spec(format!("design/{label}"), cfg));
        }
    }
    // the Gaussian reference line shown in every Fig. 3 panel
    specs.push(spec(
        "reference/gaussian_2fwd".into(),
        TrainConfig::gaussian_2fwd("zo_sgd", LR, budget),
    ));

    println!("running {} ablation trials (budget {budget})", specs.len());
    let results = run_grid(&dir, specs, &zo_ldsd::exec::ExecContext::new(workers));

    let mut by_panel: std::collections::BTreeMap<String, Vec<(f64, f64)>> =
        Default::default();
    let mut reference = f64::NAN;
    for r in &results {
        let Ok(tr) = r else {
            eprintln!("trial failed: {:#}", r.as_ref().err().unwrap());
            continue;
        };
        let (panel, x) = tr.spec_id.split_once('/').unwrap();
        if panel == "reference" {
            reference = tr.outcome.final_accuracy;
            continue;
        }
        let xv: f64 = x.parse().unwrap_or(f64::NAN);
        by_panel
            .entry(panel.to_string())
            .or_default()
            .push((xv, tr.outcome.final_accuracy));
        println!("  {}: acc {:.4}", tr.spec_id, tr.outcome.final_accuracy);
    }
    println!("gaussian 2fwd reference: {reference:.4}");

    std::fs::create_dir_all("reports").ok();
    for (panel, rows) in by_panel {
        let xs: Vec<f64> = rows.iter().map(|(x, _)| *x).collect();
        let accs: Vec<f64> = rows.iter().map(|(_, a)| *a).collect();
        let refs: Vec<f64> = vec![reference; rows.len()];
        write_csv(
            std::path::Path::new(&format!("reports/fig3_{panel}.csv")),
            &[&panel, "accuracy", "gaussian_reference"],
            &[&xs, &accs, &refs],
        )?;
        println!("wrote reports/fig3_{panel}.csv");
    }
    Ok(())
}
