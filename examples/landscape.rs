//! Fig. 1 regeneration: the alignment landscape E[C | F] as a function of
//! mu in d = 2 with grad f = (1, 0).
//!
//!     cargo run --release --example landscape [-- --grid 61 --eps 0.25]
//!
//! Writes reports/fig1_landscape.csv (mu_x, mu_y, E[C]); the saddle at
//! mu = 0 and the ridges along +-grad are the paper's Figure 1.

use anyhow::Result;

use zo_ldsd::cli::Args;
use zo_ldsd::report::write_csv;
use zo_ldsd::sampler::expected_alignment_mc;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let grid = args.get_usize("grid", 61)?;
    let eps = args.get_f64("eps", 0.25)? as f32;
    let samples = args.get_usize("samples", 8000)?;
    let gradient = [1.0f32, 0.0];

    let mut mx_col = Vec::new();
    let mut my_col = Vec::new();
    let mut c_col = Vec::new();
    for i in 0..grid {
        for j in 0..grid {
            let mx = -3.0 + 6.0 * i as f32 / (grid - 1) as f32;
            let my = -3.0 + 6.0 * j as f32 / (grid - 1) as f32;
            let c = expected_alignment_mc(&[mx, my], &gradient, eps, samples, 99);
            mx_col.push(mx as f64);
            my_col.push(my as f64);
            c_col.push(c);
        }
    }
    write_csv(
        std::path::Path::new("reports/fig1_landscape.csv"),
        &["mu_x", "mu_y", "expected_alignment"],
        &[&mx_col, &my_col, &c_col],
    )?;

    // sanity summary: saddle at the origin, ridge along the gradient
    let at = |x: f32, y: f32| expected_alignment_mc(&[x, y], &gradient, eps, samples, 7);
    println!("E[C] at mu=(0,0):   {:.3}  (saddle: 1/d = 0.5)", at(0.0, 0.0));
    println!("E[C] at mu=(2,0):   {:.3}  (aligned ridge -> 1)", at(2.0, 0.0));
    println!("E[C] at mu=(-2,0):  {:.3}  (symmetric ridge)", at(-2.0, 0.0));
    println!("E[C] at mu=(0,2):   {:.3}  (orthogonal valley -> 0)", at(0.0, 2.0));
    println!("wrote reports/fig1_landscape.csv ({grid}x{grid})");
    Ok(())
}
