//! Determinism of the shard-parallel execution engine (DESIGN.md §9):
//! the same configuration must produce bit-for-bit identical results on
//! 1 and 8 threads — shard boundaries are fixed by `shard_len`, per-shard
//! reductions combine in shard order, and sampler RNG substreams are
//! keyed per (step, shard) cell.

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::oracle::{Oracle, QuadraticOracle};
use zo_ldsd::sampler::{
    CoordinateSampler, DirectionSampler, GaussianSampler, LdsdConfig, LdsdSampler,
    SphereSampler,
};
use zo_ldsd::train::{TrainConfig, Trainer};

fn ctx(threads: usize, shard_len: usize) -> ExecContext {
    ExecContext::new(threads).with_shard_len(shard_len)
}

/// The headline acceptance test: a full Algorithm-2 training run on a
/// closed-form oracle walks the *identical* trajectory under `--threads 1`
/// and `--threads 8` — bitwise-equal loss curve and final parameters.
#[test]
fn train_loop_bitwise_identical_threads_1_vs_8() {
    let d = 4096;
    let run = |threads: usize| {
        let cfg = TrainConfig {
            cosine_schedule: false,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 600)
        };
        let oracle = QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let mut t = Trainer::with_exec(cfg, oracle, corpus, ctx(threads, 512)).unwrap();
        let out = t.run(None).unwrap();
        (out.steps, out.loss_curve, t.oracle().params().to_vec())
    };
    let (s1, curve1, params1) = run(1);
    let (s8, curve8, params8) = run(8);
    assert_eq!(s1, s8, "step counts diverged");
    assert_eq!(curve1.len(), curve8.len());
    for (i, ((c1, l1), (c8, l8))) in curve1.iter().zip(curve8.iter()).enumerate() {
        assert_eq!(c1, c8, "call axis diverged at step {i}");
        assert_eq!(
            l1.to_bits(),
            l8.to_bits(),
            "loss trajectory diverged at step {i}: {l1} vs {l8}"
        );
    }
    for (i, (p1, p8)) in params1.iter().zip(params8.iter()).enumerate() {
        assert_eq!(
            p1.to_bits(),
            p8.to_bits(),
            "final parameters diverged at coordinate {i}: {p1} vs {p8}"
        );
    }
}

/// Every sampler's probe-matrix fill is a pure function of
/// (seed, step, shard geometry): 1-thread and 8-thread contexts with the
/// same shard length draw bit-identical direction matrices, step after
/// step.
#[test]
fn sampler_fills_bitwise_identical_across_thread_counts() {
    let d = 777; // odd length: shards and rows misalign on purpose
    let k = 5;
    let steps = 3;
    let sample_all = |threads: usize| -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        let mut samplers: Vec<Box<dyn DirectionSampler>> = vec![
            Box::new(GaussianSampler::new(d, 42)),
            Box::new(SphereSampler::new(d, 42)),
            Box::new(CoordinateSampler::new(d, 42)),
            Box::new(LdsdSampler::new(d, 42, LdsdConfig::default())),
        ];
        for s in samplers.iter_mut() {
            s.set_exec(ctx(threads, 128));
            let mut dirs = vec![0.0f32; k * d];
            for _ in 0..steps {
                s.sample(&mut dirs, k);
                out.push(dirs.clone());
            }
        }
        out
    };
    let serial = sample_all(1);
    let parallel = sample_all(8);
    assert_eq!(serial.len(), parallel.len());
    for (which, (a, b)) in serial.iter().zip(parallel.iter()).enumerate() {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "fill {which} diverged at element {i}: {x} vs {y}"
            );
        }
    }
}

/// The LDSD policy update (shard-parallel scale + fused axpy_k reduce)
/// keeps the learned mean bitwise identical across thread counts.
#[test]
fn ldsd_policy_updates_bitwise_identical_across_thread_counts() {
    let d = 2000;
    let k = 6;
    let run = |threads: usize| -> Vec<f32> {
        let mut s = LdsdSampler::new(d, 9, LdsdConfig::default());
        s.set_exec(ctx(threads, 256));
        let mut dirs = vec![0.0f32; k * d];
        for step in 0..10 {
            s.sample(&mut dirs, k);
            let losses: Vec<f64> =
                (0..k).map(|i| ((i * 7 + step) % 5) as f64 * 0.25).collect();
            s.observe(&dirs, &losses, k);
        }
        s.policy_mean().unwrap().to_vec()
    };
    let mu1 = run(1);
    let mu8 = run(8);
    for (i, (a, b)) in mu1.iter().zip(mu8.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "mu diverged at {i}: {a} vs {b}");
    }
}

/// The streamed probe engine rides the same determinism contract: a full
/// Algorithm-2 run with seed-replay probes walks the identical trajectory
/// on 1 and 8 threads — and matches the materialized run bit for bit
/// (the PR 3 acceptance property; see DESIGN.md §10).
#[test]
fn streamed_train_loop_bitwise_identical_threads_1_vs_8() {
    use zo_ldsd::train::ProbeStorage;
    let d = 4096;
    let run = |threads: usize, storage: ProbeStorage| {
        let cfg = TrainConfig {
            cosine_schedule: false,
            probe_storage: storage,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 600)
        };
        let oracle = QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let mut t = Trainer::with_exec(cfg, oracle, corpus, ctx(threads, 512)).unwrap();
        let out = t.run(None).unwrap();
        (out.steps, out.loss_curve, t.oracle().params().to_vec())
    };
    let (s1, curve1, params1) = run(1, ProbeStorage::Streamed);
    let (s8, curve8, params8) = run(8, ProbeStorage::Streamed);
    let (sm, curve_m, params_m) = run(8, ProbeStorage::Materialized);
    assert_eq!(s1, s8, "streamed step counts diverged across threads");
    assert_eq!(s1, sm, "streamed and materialized step counts diverged");
    for (i, ((c1, l1), ((c8, l8), (cm, lm)))) in curve1
        .iter()
        .zip(curve8.iter().zip(curve_m.iter()))
        .enumerate()
    {
        assert_eq!(c1, c8, "streamed call axis diverged at step {i}");
        assert_eq!(c1, cm, "storage call axis diverged at step {i}");
        assert_eq!(
            l1.to_bits(),
            l8.to_bits(),
            "streamed loss diverged at step {i}: {l1} vs {l8}"
        );
        assert_eq!(
            l1.to_bits(),
            lm.to_bits(),
            "storage loss diverged at step {i}: {l1} vs {lm}"
        );
    }
    for (i, (p1, (p8, pm))) in
        params1.iter().zip(params8.iter().zip(params_m.iter())).enumerate()
    {
        assert_eq!(p1.to_bits(), p8.to_bits(), "streamed params diverged at {i}");
        assert_eq!(p1.to_bits(), pm.to_bits(), "storage params diverged at {i}");
    }
}

/// Thread count must not change oracle-call accounting either — the
/// budget-fair protocol is schedule-independent.
#[test]
fn budget_accounting_independent_of_thread_count() {
    let d = 1024;
    let run = |threads: usize| {
        let cfg = TrainConfig {
            cosine_schedule: false,
            ..TrainConfig::gaussian_6fwd("zo_sgd_plain", 0.02, 180)
        };
        let oracle = QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let mut t = Trainer::with_exec(cfg, oracle, corpus, ctx(threads, 200)).unwrap();
        let out = t.run(None).unwrap();
        (out.steps, out.oracle_calls)
    };
    assert_eq!(run(1), run(4));
    assert_eq!(run(1), run(8));
}
