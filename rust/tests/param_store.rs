//! Quantized parameter storage (DESIGN.md §14) at the oracle and trainer
//! level: a quantized store must behave exactly like an f32 oracle
//! holding the dequantized image — bitwise, at any thread count and under
//! both probe-storage modes — and a quantized training run must survive
//! snapshot → restore → continue bit for bit (restore requantizes the
//! dequantized snapshot exactly, because requantization is idempotent on
//! the dequant image).

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::{Activation, MlpSpec};
use zo_ldsd::oracle::{MlpOracle, Oracle};
use zo_ldsd::probe::{BoxedSampler, ProbeLayout, ProbeSource, StreamedProbes};
use zo_ldsd::sampler::{LdsdConfig, LdsdSampler};
use zo_ldsd::train::{
    CheckpointConfig, EstimatorKind, GemmMode, ParamStoreMode, ProbeStorage, SamplerKind,
    ShuffleSpec, TrainConfig, Trainer,
};

const QUANT_MODES: [ParamStoreMode; 2] = [ParamStoreMode::F16, ParamStoreMode::Int8];

fn mini_corpus() -> Corpus {
    Corpus::new(CorpusSpec::default_mini()).unwrap()
}

fn mlp_oracle(seed: u64) -> MlpOracle {
    let spec = MlpSpec::new(32, vec![16], 2, Activation::Tanh).unwrap();
    MlpOracle::from_seed(spec, seed)
}

fn train_cfg(store: ParamStoreMode, storage: ProbeStorage, seed: u64) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k: 5,
            sampler: SamplerKind::Ldsd(LdsdConfig::default()),
        },
        optimizer: "zo_sgd_plain".into(),
        lr: 0.05,
        tau: 1e-3,
        budget: 120,
        eval_every: 0,
        eval_batches: 2,
        cosine_schedule: false,
        seed,
        probe_dispatch: Default::default(),
        probe_storage: storage,
        checkpoint: CheckpointConfig::default(),
        shuffle: Some(ShuffleSpec { n_train: 24 }),
        param_store: store,
        gemm: GemmMode::Blocked,
    }
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The f32-vs-quantized-dequant contract at the oracle level: a quantized
/// MLP oracle returns bitwise the losses of an f32 oracle holding the
/// dequantized parameter image — for every quantized mode, at 1 and 8
/// threads, through the materialized (`loss_k`) and the streamed
/// (seed-replay `loss_probes`) evaluation paths.
#[test]
fn mlp_quantized_matches_dequant_f32_across_threads_and_storage() {
    let batch = mini_corpus().train_batch(3, 8);
    let k = 5usize;
    let tau = 1e-2f32;
    for qm in QUANT_MODES {
        // quantized oracle + its dequantized image in a plain f32 oracle
        let mut q = mlp_oracle(11);
        q.set_param_store(qm).unwrap();
        let mut deq = Vec::new();
        q.params_into(&mut deq);
        let mut f = mlp_oracle(11);
        f.update_params(&mut |w| w.copy_from_slice(&deq)).unwrap();

        let d = q.dim();
        let mut rng = zo_ldsd::rng::Rng::new(23);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);

        for threads in [1usize, 8] {
            let ctx = ExecContext::new(threads).with_shard_len(37);
            for o in [&mut q, &mut f] {
                o.set_exec(ctx.clone());
                o.set_batch(&batch).unwrap();
            }
            // materialized slice path
            let lq = q.loss_k(&dirs, k, tau).unwrap();
            let lf = f.loss_k(&dirs, k, tau).unwrap();
            for (i, (a, b)) in lq.iter().zip(lf.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} loss_k probe {i} (threads {threads}): {a} vs {b}",
                    qm.label()
                );
            }
            // streamed (seed-replay) path
            let sampler = |seed| -> BoxedSampler {
                Box::new(LdsdSampler::new(d, seed, LdsdConfig::default()))
            };
            let run_streamed = |o: &mut MlpOracle| {
                let mut st = StreamedProbes::new(sampler(9), ProbeLayout::Direct, k);
                st.set_exec(ctx.clone());
                st.advance();
                let mut losses = Vec::new();
                o.loss_probes(&st, k, tau, &mut losses).unwrap();
                losses
            };
            let sq = run_streamed(&mut q);
            let sf = run_streamed(&mut f);
            for (i, (a, b)) in sq.iter().zip(sf.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} streamed probe {i} (threads {threads}): {a} vs {b}",
                    qm.label()
                );
            }
        }
    }
}

/// A quantized training run keeps the engine's determinism contract: the
/// trajectory is bitwise identical at 1 vs 8 threads and under
/// materialized vs streamed probe storage, for both quantized modes.
#[test]
fn quantized_train_bitwise_identical_across_threads_and_storage() {
    for qm in QUANT_MODES {
        let run = |threads: usize, storage: ProbeStorage| {
            let mut t = Trainer::with_exec(
                train_cfg(qm, storage, 13),
                mlp_oracle(13),
                mini_corpus(),
                ExecContext::new(threads).with_shard_len(64),
            )
            .unwrap();
            let out = t.run(None).unwrap();
            let mut p = Vec::new();
            t.oracle().params_into(&mut p);
            (out.loss_curve, p)
        };
        let (c1, p1) = run(1, ProbeStorage::Streamed);
        let (c8, p8) = run(8, ProbeStorage::Streamed);
        let (cm, pm) = run(8, ProbeStorage::Materialized);
        assert_eq!(c1.len(), c8.len());
        assert_eq!(c1.len(), cm.len());
        for (i, ((a1, l1), ((a8, l8), (am, lm)))) in
            c1.iter().zip(c8.iter().zip(cm.iter())).enumerate()
        {
            assert_eq!(a1, a8, "{}: call axis diverged at step {i}", qm.label());
            assert_eq!(a1, am, "{}: storage call axis diverged at {i}", qm.label());
            assert_eq!(l1.to_bits(), l8.to_bits(), "{}: thread loss at {i}", qm.label());
            assert_eq!(l1.to_bits(), lm.to_bits(), "{}: storage loss at {i}", qm.label());
        }
        assert!(bits_eq(&p1, &p8), "{}: thread params diverged", qm.label());
        assert!(bits_eq(&p1, &pm), "{}: storage params diverged", qm.label());
    }
}

/// Snapshot → restore → continue under a quantized store, bit for bit:
/// the snapshot persists the *dequantized* f32 image, and restore
/// requantizes it exactly (requantization is idempotent on the dequant
/// image), so the resumed trajectory is the uninterrupted one.
#[test]
fn quantized_snapshot_restore_continue_is_bitwise_identical() {
    for qm in QUANT_MODES {
        let dir = std::env::temp_dir().join(format!(
            "zo_param_store_resume_{}_{}",
            qm.label(),
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let ctx = || ExecContext::new(4).with_shard_len(64);

        let mut full = Trainer::with_exec(
            train_cfg(qm, ProbeStorage::Auto, 29),
            mlp_oracle(29),
            mini_corpus(),
            ctx(),
        )
        .unwrap();
        let full_out = full.run(None).unwrap();
        assert!(full_out.completed);

        let ck = |resume: bool, max_run_steps: u64| CheckpointConfig {
            dir: Some(dir.to_string_lossy().into_owned()),
            every: 2,
            resume,
            max_run_steps,
        };
        let mut first = Trainer::with_exec(
            TrainConfig { checkpoint: ck(false, 4), ..train_cfg(qm, ProbeStorage::Auto, 29) },
            mlp_oracle(29),
            mini_corpus(),
            ctx(),
        )
        .unwrap();
        let partial = first.run(None).unwrap();
        assert!(!partial.completed, "{}: interrupt must preempt", qm.label());
        assert_eq!(partial.steps, 4);
        drop(first);

        let mut second = Trainer::with_exec(
            TrainConfig { checkpoint: ck(true, 0), ..train_cfg(qm, ProbeStorage::Auto, 29) },
            mlp_oracle(29),
            mini_corpus(),
            ctx(),
        )
        .unwrap();
        let resumed = second.run(None).unwrap();
        assert!(resumed.completed);
        assert_eq!(resumed.steps, full_out.steps);
        assert_eq!(resumed.loss_curve.len(), full_out.loss_curve.len());
        for ((ca, la), (cb, lb)) in full_out.loss_curve.iter().zip(resumed.loss_curve.iter()) {
            assert_eq!(ca, cb, "{}: oracle-call axis diverged", qm.label());
            assert_eq!(la.to_bits(), lb.to_bits(), "{}: {la} vs {lb}", qm.label());
        }
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        full.oracle().params_into(&mut pa);
        second.oracle().params_into(&mut pb);
        assert!(bits_eq(&pa, &pb), "{}: resumed params diverged", qm.label());
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// The param-store mode is part of the snapshot fingerprint: resuming an
/// int8 run with an f16 configuration must fail loudly, not silently
/// continue on a different storage grid.  (Skipped when `ZO_PARAM_STORE`
/// is set: the env override legitimately forces both sessions onto one
/// mode, so no mismatch exists.)
#[test]
fn quantized_fingerprint_guards_resume_across_modes() {
    if std::env::var("ZO_PARAM_STORE").is_ok() {
        return;
    }
    let dir = std::env::temp_dir().join(format!(
        "zo_param_store_mismatch_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let ck = |resume: bool| CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 1,
        resume,
        max_run_steps: if resume { 0 } else { 2 },
    };
    let ctx = || ExecContext::new(1).with_shard_len(64);
    let int8 = train_cfg(ParamStoreMode::Int8, ProbeStorage::Auto, 7);
    let mut first = Trainer::with_exec(
        TrainConfig { checkpoint: ck(false), ..int8 },
        mlp_oracle(7),
        mini_corpus(),
        ctx(),
    )
    .unwrap();
    first.run(None).unwrap();

    let f16 = train_cfg(ParamStoreMode::F16, ProbeStorage::Auto, 7);
    let mut wrong = Trainer::with_exec(
        TrainConfig { checkpoint: ck(true), ..f16 },
        mlp_oracle(7),
        mini_corpus(),
        ctx(),
    )
    .unwrap();
    let err = wrong.run(None).unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
