//! The uniform env/flag precedence contract (DESIGN.md §17), pinned in
//! one place: for every `ZO_*` knob, an explicit configuration beats the
//! environment, and a process-wide FORCE (test/bench override) beats
//! both.  This lives in its own integration binary — env mutation is
//! process-global, so it must not share a process with suites that read
//! these variables — and in ONE test function, because the test harness
//! runs `#[test]`s concurrently in threads.
//!
//! Ordering inside the test matters: `lane_mode()` / `gemm_mode()` cache
//! their env read on first call, so the lanes/GEMM sections run before
//! anything that touches a kernel.

use zo_ldsd::config::TrainMode;
use zo_ldsd::coordinator::{run_local_trial, MlpTrial, OracleSpec, TrialSpec};
use zo_ldsd::data::CorpusSpec;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::Activation;
use zo_ldsd::snapshot::{self, CheckpointConfig};
use zo_ldsd::tensor::gemm::{
    effective_gemm_mode, force_gemm_mode, gemm_mode, set_run_mode, GemmMode,
};
use zo_ldsd::tensor::lanes::{effective_mode, force_mode, lane_mode, LaneMode};
use zo_ldsd::train::{
    requested_param_store, ParamStoreMode, ProbeStorage, TrainConfig,
};

/// A tiny artifact-free MLP trial for end-to-end resolution checks.
fn mlp_spec(id: &str, storage: Option<ProbeStorage>) -> TrialSpec {
    let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", 0.02, 40);
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    let oracle = OracleSpec::Mlp(MlpTrial {
        hidden: vec![8],
        activation: Activation::Tanh,
        in_dim: 16,
        corpus: CorpusSpec::default_mini(),
        init_seed: 1,
        eval_batch: 8,
    });
    let mut spec = TrialSpec::new(id, "mlp", TrainMode::Ft, cfg, oracle);
    spec.probe_storage = storage;
    spec
}

#[test]
fn forced_beats_configured_beats_env_for_every_knob() {
    // --- ZO_LANES: FORCED > ENV > CPU detection.  Must run before any
    // kernel call caches the env read.
    std::env::set_var("ZO_LANES", "scalar");
    assert_eq!(lane_mode(), LaneMode::Scalar, "env picked up on first read");
    assert_eq!(effective_mode(), LaneMode::Scalar);
    force_mode(Some(LaneMode::Wide));
    assert_eq!(effective_mode(), LaneMode::Wide, "force beats env");
    force_mode(None);
    assert_eq!(effective_mode(), LaneMode::Scalar, "un-forcing restores env");
    std::env::remove_var("ZO_LANES");

    // --- ZO_GEMM (kernel layer): FORCED > trainer-installed run mode
    // (the configured tier) > ENV.
    std::env::set_var("ZO_GEMM", "reference");
    assert_eq!(gemm_mode(), GemmMode::Reference, "env picked up on first read");
    assert_eq!(effective_gemm_mode(), GemmMode::Reference);
    set_run_mode(Some(GemmMode::Blocked));
    assert_eq!(effective_gemm_mode(), GemmMode::Blocked, "configured run mode beats env");
    force_gemm_mode(Some(GemmMode::Reference));
    assert_eq!(effective_gemm_mode(), GemmMode::Reference, "force beats configured");
    force_gemm_mode(None);
    set_run_mode(None);
    assert_eq!(effective_gemm_mode(), GemmMode::Reference, "back to the cached env read");
    std::env::remove_var("ZO_GEMM");

    // --- ZO_THREADS: --threads N > env > core-count default.
    std::env::set_var("ZO_THREADS", "3");
    assert_eq!(ExecContext::resolve(2).threads(), 2, "configured beats env");
    assert_eq!(ExecContext::resolve(0).threads(), 3, "unconfigured defers to env");
    std::env::set_var("ZO_THREADS", "not-a-number");
    assert!(ExecContext::resolve(0).threads() >= 1, "junk env falls back to cores");
    std::env::remove_var("ZO_THREADS");
    assert_eq!(ExecContext::resolve(5).threads(), 5);
    assert!(ExecContext::resolve(0).threads() >= 1);

    // --- ZO_PARAM_STORE: an off-default config beats the env; the env
    // forces only unconfigured (f32-default) runs.
    let mut cfg = TrainConfig::algorithm2("zo_sgd", 0.02, 40);
    std::env::set_var("ZO_PARAM_STORE", "int8");
    cfg.param_store = ParamStoreMode::F16;
    assert_eq!(requested_param_store(&cfg), ParamStoreMode::F16, "configured beats env");
    cfg.param_store = ParamStoreMode::F32;
    assert_eq!(requested_param_store(&cfg), ParamStoreMode::Int8, "env forces the default");
    std::env::remove_var("ZO_PARAM_STORE");
    assert_eq!(requested_param_store(&cfg), ParamStoreMode::F32);

    // --- ZO_STORE_DIR: CheckpointConfig::store_dir > env > <dir>/store.
    // (tests/store_env.rs drives a full checkpointed run through this;
    // here we pin just the ordering.)
    let ck = CheckpointConfig {
        dir: Some("ckbase".into()),
        every: 0,
        resume: false,
        max_run_steps: 0,
        store_dir: Some("cfgstore".into()),
    };
    std::env::set_var("ZO_STORE_DIR", "envstore");
    assert_eq!(
        snapshot::resolve_store_dir(&ck).unwrap(),
        std::path::PathBuf::from("cfgstore"),
        "configured beats env"
    );
    let unconfigured = CheckpointConfig { store_dir: None, ..ck.clone() };
    assert_eq!(
        snapshot::resolve_store_dir(&unconfigured).unwrap(),
        std::path::PathBuf::from("envstore"),
        "env beats the <dir>/store default"
    );
    std::env::remove_var("ZO_STORE_DIR");
    assert_eq!(
        snapshot::resolve_store_dir(&unconfigured).unwrap(),
        std::path::Path::new("ckbase").join("store")
    );

    // --- ZO_PROBE_STORAGE, end to end through a real run: an explicit
    // --probe-storage pin survives the suite-wide env forcing; the env
    // moves only unconfigured (auto) runs.  Both paths are bitwise
    // identical, so only the resolved label differs.
    std::env::set_var("ZO_PROBE_STORAGE", "streamed");
    let exec = ExecContext::new(1);
    let pinned = run_local_trial(
        "artifacts",
        &mlp_spec("prec/pinned", Some(ProbeStorage::Materialized)),
        &exec,
    )
    .unwrap();
    assert_eq!(pinned.probe_storage, "materialized", "configured beats env");
    let forced = run_local_trial("artifacts", &mlp_spec("prec/forced", None), &exec).unwrap();
    assert_eq!(forced.probe_storage, "streamed", "env forces the auto default");
    std::env::remove_var("ZO_PROBE_STORAGE");
    assert_eq!(
        pinned.outcome.final_accuracy.to_bits(),
        forced.outcome.final_accuracy.to_bits(),
        "storage modes are bitwise identical"
    );
}
