//! Probe-storage equivalence (DESIGN.md §10): the streamed seed-replay
//! engine must be a *bitwise* drop-in for the materialized K x d matrix —
//! identical `Estimate`s, identical parameter trajectories — across random
//! (d, K, shard_len, threads) configurations, and it must never allocate a
//! K x d probe buffer (the memory claim the refactor exists for).

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::metrics::probe_tracker;
use zo_ldsd::optim::{GradEstimator, LdsdEstimator};
use zo_ldsd::oracle::{Oracle, QuadraticOracle};
use zo_ldsd::probe::ProbeStorage;
use zo_ldsd::proptest::{check, Gen, U64Range};
use zo_ldsd::sampler::{GaussianSampler, LdsdConfig, LdsdSampler};
use zo_ldsd::train::{EstimatorKind, GemmMode, ParamStoreMode, SamplerKind, TrainConfig, Trainer};

/// One random probe-storage configuration to cross-check.
#[derive(Debug, Clone)]
struct StorageCase {
    d: usize,
    k: usize,
    shard_len: usize,
    threads: usize,
    seed: u64,
}

struct StorageCaseGen;

impl Gen<StorageCase> for StorageCaseGen {
    fn generate(&self, rng: &mut zo_ldsd::rng::Rng) -> StorageCase {
        StorageCase {
            d: 16 + rng.below(1200) as usize,
            k: 1 + rng.below(7) as usize,
            shard_len: 4 + rng.below(300) as usize,
            threads: 1 + rng.below(8) as usize,
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, value: &StorageCase) -> Vec<StorageCase> {
        let mut out = Vec::new();
        if value.d > 16 {
            out.push(StorageCase { d: (value.d / 2).max(16), ..value.clone() });
        }
        if value.k > 1 {
            out.push(StorageCase { k: value.k / 2, ..value.clone() });
        }
        out
    }
}

fn quad(d: usize) -> QuadraticOracle {
    let diag: Vec<f32> = (0..d).map(|i| 1.0 + 0.2 * (i % 4) as f32).collect();
    let center: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin()).collect();
    QuadraticOracle::new(diag, center, vec![0.0; d])
}

/// Randomized sweep: materialized and streamed trainers with the same
/// seed and shard geometry walk bit-identical trajectories at any thread
/// count.
#[test]
fn prop_streamed_and_materialized_trajectories_bitwise_equal() {
    check("probe_storage_equivalence", &StorageCaseGen, 12, |case| {
        let run = |storage: ProbeStorage| {
            let cfg = TrainConfig {
                estimator: EstimatorKind::BestOfK {
                    k: case.k,
                    sampler: SamplerKind::Ldsd(LdsdConfig::default()),
                },
                optimizer: "zo_sgd_plain".into(),
                lr: 0.02,
                tau: 1e-3,
                budget: (case.k as u64 + 1) * 6, // six steps
                eval_every: 0,
                eval_batches: 1,
                cosine_schedule: false,
                seed: case.seed,
                probe_dispatch: Default::default(),
                probe_storage: storage,
                checkpoint: Default::default(),
                shuffle: None,
                param_store: ParamStoreMode::F32,
                gemm: GemmMode::Blocked,
            };
            let ctx = ExecContext::new(case.threads).with_shard_len(case.shard_len);
            let mut t = Trainer::with_exec(
                cfg,
                quad(case.d),
                Corpus::new(CorpusSpec::default_mini()).unwrap(),
                ctx,
            )
            .unwrap();
            let out = t.run(None).unwrap();
            (out.loss_curve, t.oracle().params().to_vec())
        };
        let (curve_m, params_m) = run(ProbeStorage::Materialized);
        let (curve_s, params_s) = run(ProbeStorage::Streamed);
        curve_m.len() == curve_s.len()
            && curve_m
                .iter()
                .zip(curve_s.iter())
                .all(|((cm, lm), (cs, ls))| cm == cs && lm.to_bits() == ls.to_bits())
            && params_m
                .iter()
                .zip(params_s.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    });
}

/// Same property at the raw estimator level, where the `Estimate` scalars
/// (selection, fd coefficient, losses) are directly visible.
#[test]
fn prop_streamed_estimates_bitwise_equal() {
    check("probe_estimate_equivalence", &U64Range(0, u64::MAX / 2), 10, |seed| {
        let d = 64 + (seed % 700) as usize;
        let k = 2 + (seed % 5) as usize;
        let shard_len = 8 + (seed % 120) as usize;
        let mk = |storage: ProbeStorage, threads: usize| {
            let mut est = LdsdEstimator::with_storage(
                LdsdSampler::new(d, *seed, LdsdConfig::default()),
                1e-3,
                k,
                storage,
            )
            .unwrap();
            est.set_exec(ExecContext::new(threads).with_shard_len(shard_len));
            est
        };
        let mut em = mk(ProbeStorage::Materialized, 1);
        let mut es = mk(ProbeStorage::Streamed, 5);
        let mut om = quad(d);
        let mut os = quad(d);
        os.set_exec(ExecContext::new(5).with_shard_len(shard_len));
        let mut gm = vec![0.0f32; d];
        let mut gs = vec![0.0f32; d];
        for _ in 0..3 {
            let a = em.estimate(&mut om, &mut gm).unwrap();
            let b = es.estimate(&mut os, &mut gs).unwrap();
            if a.selected != b.selected
                || a.calls != b.calls
                || a.loss.to_bits() != b.loss.to_bits()
                || a.fd_coeff.to_bits() != b.fd_coeff.to_bits()
            {
                return false;
            }
            if gm.iter().zip(gs.iter()).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return false;
            }
        }
        om.oracle_calls() == os.oracle_calls()
    });
}

/// The memory acceptance criterion: when streaming, no K x d probe buffer
/// is ever allocated — the measured peak probe state stays at the
/// O(K * shard_len)-per-worker scale, orders of magnitude below the
/// matrix the materialized path holds.
#[test]
fn streamed_path_never_allocates_kd_probe_buffer() {
    let d = 1 << 20; // 4 MiB per row: a K x d buffer would be >= 20 MiB
    let k = 5;
    let threads = 4;
    let shard_len = 1 << 14;
    let kd_bytes = k * d * 4;

    // streamed: measured peak must stay far below K x d (worker scratch is
    // threads * (K + 1) * shard_len floats, plus slack for concurrently
    // running tests that also touch the global tracker)
    {
        let mut est = LdsdEstimator::with_storage(
            GaussianSampler::new(d, 3),
            1e-3,
            k,
            ProbeStorage::Streamed,
        )
        .unwrap();
        est.set_exec(ExecContext::new(threads).with_shard_len(shard_len));
        let mut oracle = QuadraticOracle::isotropic(vec![0.5; d]);
        oracle.set_exec(ExecContext::new(threads).with_shard_len(shard_len));
        let mut g = vec![0.0f32; d];
        probe_tracker().reset();
        for _ in 0..2 {
            est.estimate(&mut oracle, &mut g).unwrap();
        }
        let peak = probe_tracker().peak();
        assert!(peak > 0, "streaming scratch must be tracked");
        assert!(
            peak < kd_bytes / 4,
            "streamed peak {peak} B is not O(K * shard_len) (K x d = {kd_bytes} B)"
        );
        assert_eq!(est.state_bytes(), 0, "gaussian streamed estimator holds no probe state");
    }

    // materialized reference: the tracker does see the K x d matrix
    {
        probe_tracker().reset();
        let mut est = LdsdEstimator::with_storage(
            GaussianSampler::new(d, 3),
            1e-3,
            k,
            ProbeStorage::Materialized,
        )
        .unwrap();
        est.set_exec(ExecContext::new(threads).with_shard_len(shard_len));
        let mut oracle = QuadraticOracle::isotropic(vec![0.5; d]);
        let mut g = vec![0.0f32; d];
        est.estimate(&mut oracle, &mut g).unwrap();
        assert!(
            probe_tracker().peak() >= kd_bytes,
            "materialized path must hold the K x d matrix"
        );
        assert_eq!(est.state_bytes(), kd_bytes);
    }
}

/// Auto-selection picks streaming exactly when the matrix would blow the
/// budget (and the pipeline supports replay).
#[test]
fn auto_selects_streamed_only_over_budget() {
    let budget = zo_ldsd::probe::auto_budget_bytes();
    let small = 1024usize;
    assert_eq!(ProbeStorage::Auto.resolve(small, 5, true), ProbeStorage::Materialized);
    let big = budget / 4 + 1;
    assert_eq!(ProbeStorage::Auto.resolve(big, 1, true), ProbeStorage::Streamed);
    assert_eq!(ProbeStorage::Auto.resolve(big, 1, false), ProbeStorage::Materialized);
}
