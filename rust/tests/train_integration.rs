//! Integration: the full training stack against closed-form oracles and
//! (when artifacts exist) against the PJRT-backed models.

use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::data::SyntheticRegression;
use zo_ldsd::eval::Evaluator;
use zo_ldsd::oracle::{LinRegOracle, Oracle, PjrtOracle, QuadraticOracle};
use zo_ldsd::runtime::Runtime;
use zo_ldsd::train::{
    EstimatorKind, GemmMode, ParamStoreMode, ProbeDispatch, ProbeStorage, SamplerKind, TrainConfig,
    Trainer,
};

fn mini_corpus() -> Corpus {
    Corpus::new(CorpusSpec::default_mini()).unwrap()
}

fn have_artifacts() -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists()
}

/// Budget-fair comparison on a known objective: all three Table-1 schemes
/// must make progress on a quadratic, and the oracle-call accounting must
/// be exact.
#[test]
fn all_methods_descend_quadratic_within_budget() {
    let budget = 1800u64;
    for (name, cfg) in [
        ("2fwd", TrainConfig::gaussian_2fwd("zo_sgd_plain", 0.02, budget)),
        ("6fwd", TrainConfig::gaussian_6fwd("zo_sgd_plain", 0.02, budget)),
        ("alg2", TrainConfig::algorithm2("zo_sgd_plain", 0.02, budget)),
    ] {
        let d = 32;
        let oracle = QuadraticOracle::new(
            vec![1.0; d],
            vec![2.0; d],
            vec![0.0; d],
        );
        let mut trainer = Trainer::new(cfg, oracle, mini_corpus()).unwrap();
        let out = trainer.run(None).unwrap();
        assert!(out.oracle_calls <= budget, "{name}: budget exceeded");
        let first = out.loss_curve.first().unwrap().1;
        let last = out.loss_curve.last().unwrap().1;
        assert!(
            last < first * 0.7,
            "{name}: no descent ({first} -> {last})"
        );
    }
}

/// Budget-fair accounting (§5.1 / DESIGN.md §5): at the same total budget,
/// CentralK1 (2 calls/step) and BestOfK with K=5 (6 calls/step) must
/// consume *identical* total oracle calls — the cheaper estimator just
/// takes proportionally more steps.  This is the invariant every Table-1
/// comparison rests on.
#[test]
fn central_and_bestofk_consume_identical_budget() {
    let budget = 600u64; // divisible by both 2 and 6
    let d = 16;
    let mk = |est: EstimatorKind| TrainConfig {
        estimator: est,
        optimizer: "zo_sgd_plain".into(),
        lr: 0.02,
        tau: 1e-3,
        budget,
        eval_every: 0,
        eval_batches: 1,
        cosine_schedule: false,
        seed: 5,
        probe_dispatch: ProbeDispatch::Batched,
        probe_storage: ProbeStorage::Auto,
        checkpoint: Default::default(),
        shuffle: None,
        param_store: ParamStoreMode::F32,
        gemm: GemmMode::Blocked,
    };
    let oracle = || QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);

    let mut central = Trainer::new(
        mk(EstimatorKind::CentralK1(SamplerKind::Gaussian)),
        oracle(),
        mini_corpus(),
    )
    .unwrap();
    let mut bestofk = Trainer::new(
        mk(EstimatorKind::BestOfK { k: 5, sampler: SamplerKind::Gaussian }),
        oracle(),
        mini_corpus(),
    )
    .unwrap();
    let oc = central.run(None).unwrap();
    let ob = bestofk.run(None).unwrap();

    // identical totals, exactly the budget...
    assert_eq!(oc.oracle_calls, budget);
    assert_eq!(ob.oracle_calls, budget);
    // ...reached through the per-step cost ratio in iterations
    assert_eq!(oc.steps, 300);
    assert_eq!(ob.steps, 100);
    assert_eq!(oc.steps * 2, budget);
    assert_eq!(ob.steps * 6, budget);
    // the trainer never lets a method overdraw the shared budget
    assert_eq!(central.oracle().oracle_calls(), budget);
    assert_eq!(bestofk.oracle().oracle_calls(), budget);
}

/// The paper's headline mechanism on a controllable objective: on a
/// quadratic whose gradient direction is *persistent* (x0 -> center along
/// a fixed ray — the regime where a learnable mean pays off, cf. Lemma 2's
/// tracking argument), Algorithm 2 with a learnable policy must beat the
/// same best-of-K scheme with a frozen Gaussian policy at equal budget.
#[test]
fn learnable_policy_beats_frozen_on_persistent_direction_quadratic() {
    let d = 96;
    let budget = 4200u64;
    let center: Vec<f32> =
        (0..d).map(|i| if i % 3 == 0 { 2.0 } else { -1.0 }).collect();
    let run = |sampler: SamplerKind, seed: u64| -> f64 {
        let cfg = TrainConfig {
            estimator: EstimatorKind::BestOfK { k: 5, sampler },
            optimizer: "zo_sgd_plain".into(),
            lr: 0.01, // ZO stability on a quadratic needs lr ~ 1/d
            tau: 0.05,
            budget,
            eval_every: 0,
            eval_batches: 1,
            cosine_schedule: false,
            seed,
            probe_dispatch: ProbeDispatch::Batched,
            probe_storage: ProbeStorage::Auto,
            checkpoint: Default::default(),
            shuffle: None,
            param_store: ParamStoreMode::F32,
            gemm: GemmMode::Blocked,
        };
        let oracle =
            QuadraticOracle::new(vec![1.0; d], center.clone(), vec![0.0; d]);
        let mut t = Trainer::new(cfg, oracle, mini_corpus()).unwrap();
        t.run(None).unwrap().loss_curve.last().unwrap().1
    };
    let mut ldsd_wins = 0;
    let trials = 5;
    for seed in 0..trials {
        let frozen = run(SamplerKind::Gaussian, seed);
        let learned = run(
            SamplerKind::Ldsd(zo_ldsd::sampler::LdsdConfig {
                eps: 0.5,
                gamma_mu: 0.5,
                renormalize: true,
                ..Default::default()
            }),
            seed,
        );
        if learned < frozen {
            ldsd_wins += 1;
        }
    }
    assert!(
        ldsd_wins * 2 > trials,
        "LDSD won only {ldsd_wins}/{trials} persistent-direction trials"
    );
}

/// Alignment claim end-to-end on linreg: the estimator produced by the
/// Algorithm-2 path should align with the true gradient far better than
/// chance (~1/sqrt(d)).
#[test]
fn alg2_estimate_aligns_with_true_gradient() {
    use zo_ldsd::optim::{GradEstimator, LdsdEstimator};
    use zo_ldsd::oracle::GradOracle;
    use zo_ldsd::sampler::{LdsdConfig, LdsdSampler};
    use zo_ldsd::tensor::cosine;

    let ds = SyntheticRegression::a9a_like(512, 3);
    let mut oracle = LinRegOracle::new(ds.x, ds.y, vec![0.0; 123]);
    // tau sets the policy-learning signal scale: loss advantages across
    // probes are O(tau * ||grad|| * ||v||), so tau must be large enough
    // for the REINFORCE weights to rise above batch noise
    let sampler = LdsdSampler::new(
        123,
        7,
        LdsdConfig { eps: 0.3, gamma_mu: 1.0, ..Default::default() },
    );
    let mut est = LdsdEstimator::new(sampler, 0.05, 5);
    let mut g = vec![0.0f32; 123];
    let mut true_g = vec![0.0f32; 123];
    let mut tail = Vec::new();
    for step in 0..150 {
        est.estimate(&mut oracle, &mut g).unwrap();
        oracle.grad(&mut true_g).unwrap();
        // |cos|: g may point up or down hill; the optimizer step uses the
        // signed fd coefficient so either sign is informative
        let c = cosine(&g, &true_g).abs();
        if step >= 100 {
            tail.push(c);
        }
        // follow the estimate downhill a little so the trajectory is real
        oracle
            .update_params(&mut |x| {
                for (xi, gi) in x.iter_mut().zip(g.iter()) {
                    *xi -= 0.02 * gi;
                }
            })
            .unwrap();
    }
    let mean_tail: f32 = tail.iter().sum::<f32>() / tail.len() as f32;
    let chance = 1.0 / (123.0f32).sqrt();
    assert!(
        mean_tail > 2.5 * chance,
        "tail alignment {mean_tail} vs chance {chance}"
    );
}

/// PJRT end-to-end smoke: a short LoRA run on the real artifacts must not
/// degrade accuracy by more than noise, and accounting must hold.
#[test]
fn pjrt_short_lora_run_trains() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let entry = manifest.model("roberta_mini").unwrap();
    let corpus = Corpus::new(manifest.corpus("roberta_mini").unwrap().clone()).unwrap();
    let oracle = PjrtOracle::new(&rt, entry, TrainMode::Lora).unwrap();
    let evaluator = Evaluator::new(&rt, entry, TrainMode::Lora).unwrap();

    let pre = evaluator.accuracy(oracle.params(), &corpus, 4).unwrap();
    let mut cfg = TrainConfig::algorithm2("zo_sgd", 5e-4, 360);
    cfg.eval_batches = 4;
    let mut trainer = Trainer::new(cfg, oracle, corpus).unwrap();
    let out = trainer.run(Some(&evaluator)).unwrap();
    assert_eq!(out.steps, 60);
    assert_eq!(out.oracle_calls, 360);
    assert!(
        out.final_accuracy >= pre - 0.05,
        "short run should not wreck the model: {pre} -> {}",
        out.final_accuracy
    );
}
