//! `ZO_STORE_DIR` resolution under the uniform CONFIGURED > ENV
//! precedence contract (DESIGN.md §17): an explicit
//! `CheckpointConfig::store_dir` beats the environment, the environment
//! beats the `<dir>/store` default, and a checkpointed run writes every
//! blob to the resolved store.  This lives in its own integration binary
//! — env mutation is process-global, so it must not share a process with
//! the rest of the store suite.

use std::path::PathBuf;

use zo_ldsd::exec::ExecContext;
use zo_ldsd::oracle::QuadraticOracle;
use zo_ldsd::sampler::LdsdConfig;
use zo_ldsd::snapshot::{self, CheckpointConfig};
use zo_ldsd::store::Store;
use zo_ldsd::train::{EstimatorKind, SamplerKind, TrainConfig, Trainer};

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zo_store_env_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn config_store_dir_beats_env_which_beats_default() {
    let ck_dir = tmp("ck");
    let cfg_store = tmp("cfg_store");
    let env_store = tmp("env_store");

    // precedence without the env var: config beats the <dir>/store default
    let ck = CheckpointConfig {
        dir: Some(ck_dir.to_string_lossy().into_owned()),
        every: 1,
        resume: false,
        max_run_steps: 0,
        store_dir: Some(cfg_store.to_string_lossy().into_owned()),
    };
    assert_eq!(snapshot::resolve_store_dir(&ck), Some(cfg_store.clone()));
    let default_ck = CheckpointConfig { store_dir: None, ..ck.clone() };
    assert_eq!(
        snapshot::resolve_store_dir(&default_ck),
        Some(ck_dir.join("store"))
    );

    // with the env var set (process-global: this binary holds only this
    // test): the explicit config still wins, the env replaces only the
    // <dir>/store default
    std::env::set_var("ZO_STORE_DIR", &env_store);
    assert_eq!(snapshot::resolve_store_dir(&ck), Some(cfg_store.clone()));
    assert_eq!(
        snapshot::resolve_store_dir(&default_ck),
        Some(env_store.clone())
    );
    // an empty/whitespace env value un-forces cleanly
    std::env::set_var("ZO_STORE_DIR", "  ");
    assert_eq!(
        snapshot::resolve_store_dir(&default_ck),
        Some(ck_dir.join("store"))
    );
    std::env::set_var("ZO_STORE_DIR", &env_store);

    // a real checkpointed run with no configured store_dir lands every
    // blob in the env-chosen store
    let d = 24usize;
    let mut cfg = TrainConfig::algorithm2("zo_sgd", 0.02, 60);
    cfg.estimator = EstimatorKind::BestOfK {
        k: 3,
        sampler: SamplerKind::Ldsd(LdsdConfig::default()),
    };
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.seed = 11;
    cfg.checkpoint = default_ck;
    let diag: Vec<f32> = (0..d).map(|i| 1.0 + 0.1 * (i % 3) as f32).collect();
    let oracle = QuadraticOracle::new(diag, vec![1.0; d], vec![0.0; d]);
    let corpus = zo_ldsd::data::Corpus::new(zo_ldsd::data::CorpusSpec::default_mini()).unwrap();
    let mut t = Trainer::with_exec(cfg, oracle, corpus, ExecContext::new(1)).unwrap();
    let out = t.run(None).unwrap();
    assert!(out.completed);

    let env = Store::open(&env_store);
    assert!(env.object_count() > 0, "blobs must land in ZO_STORE_DIR");
    assert!(
        Store::open(&cfg_store).object_count() == 0
            && Store::open(ck_dir.join("store")).object_count() == 0,
        "nothing may leak into the unconfigured store locations"
    );
    // and the manifests resolve against the env store
    let snap = snapshot::load_latest(&ck_dir, Some(&env)).unwrap();
    assert!(snap.step > 0);

    std::env::remove_var("ZO_STORE_DIR");
    for dir in [&ck_dir, &cfg_store, &env_store] {
        std::fs::remove_dir_all(dir).ok();
    }
}
