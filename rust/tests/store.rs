//! The content-addressed persistence layer end to end (DESIGN.md §16):
//! corrupt objects fail loudly on read and verify, mark-and-sweep GC
//! keeps everything reachable from manifests while sweeping junk, a
//! re-run grid warm-starts by canonical spec hash with zero training
//! steps and byte-identical outcomes, an edited config misses the cache
//! exactly, and pre-store (v2) trial records migrate: they warm-start
//! through the legacy field comparison and are backfilled into
//! `grid.lock.json` as store objects.

use std::path::{Path, PathBuf};

use zo_ldsd::config::TrainMode;
use zo_ldsd::coordinator::{
    resolved_spec_hash, run_grid, run_local_trial, MlpTrial, OracleSpec, TrialResult, TrialSpec,
};
use zo_ldsd::data::CorpusSpec;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::Activation;
use zo_ldsd::optim::OptimizerState;
use zo_ldsd::snapshot::{self, CheckpointConfig, SnapshotFingerprint, TrainerSnapshot};
use zo_ldsd::store::{GridLock, Store};
use zo_ldsd::train::{TrainConfig, TrainOutcome};

const BUDGET: u64 = 120;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zo_store_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A tiny MLP trial checkpointing under `base` with resume on — the
/// cheapest real training run the coordinator schedules.
fn grid_spec(id: &str, seed: u64, lr: f32, base: &Path) -> TrialSpec {
    let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", lr, BUDGET);
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.seed = seed;
    let oracle = OracleSpec::Mlp(MlpTrial {
        hidden: vec![8],
        activation: Activation::Tanh,
        in_dim: 16,
        corpus: CorpusSpec::default_mini(),
        init_seed: 1,
        eval_batch: 8,
    });
    let mut spec = TrialSpec::new(id, "mlp", TrainMode::Ft, cfg, oracle);
    spec.checkpoint = Some(CheckpointConfig {
        dir: Some(base.to_string_lossy().into_owned()),
        every: 0,
        resume: true,
        max_run_steps: 0,
        store_dir: None,
    });
    spec
}

/// The hash the coordinator keys this spec under: overrides resolved the
/// same way `run_trial` resolves them before hashing (re-exported as
/// [`resolved_spec_hash`] — the service leases under the same identity).
fn resolved_hash(spec: &TrialSpec) -> String {
    resolved_spec_hash(spec)
}

fn outcomes_bitwise_equal(a: &TrainOutcome, b: &TrainOutcome) {
    assert_eq!(a.label, b.label);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.oracle_calls, b.oracle_calls);
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
    assert_eq!(a.loss_curve.len(), b.loss_curve.len());
    for ((ca, la), (cb, lb)) in a.loss_curve.iter().zip(b.loss_curve.iter()) {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits());
    }
    assert_eq!(a.acc_curve.len(), b.acc_curve.len());
    for ((ca, la), (cb, lb)) in a.acc_curve.iter().zip(b.acc_curve.iter()) {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits());
    }
}

/// A bit-flipped object must fail its re-hash on `get` and be reported by
/// `verify`, while intact objects keep reading fine.
#[test]
fn corrupt_object_detected_on_read_and_verify() {
    let root = tmp("corrupt");
    let store = Store::open(&root);
    let good = store.put(b"alpha").unwrap();
    let bad = store.put(b"beta-object").unwrap();

    let path = store.object_path(&bad);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[0] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    assert_eq!(store.get(&good).unwrap(), b"alpha");
    assert!(store.get(&bad).is_err(), "corrupt object must not read back");
    let report = store.verify();
    assert_eq!(report.ok, 1);
    assert_eq!(report.corrupt, vec![bad]);
    std::fs::remove_dir_all(&root).ok();
}

/// GC over randomized snapshot/outcome graphs: everything reachable from
/// the retained manifests survives (and still loads bitwise), junk
/// objects are swept, and a second GC finds nothing left to do.
#[test]
fn gc_sweeps_junk_keeps_reachable_snapshot_graphs() {
    let mut rng = zo_ldsd::rng::Rng::new(0x5EED);
    for round in 0..3 {
        let base = tmp(&format!("gc{round}"));
        let store = Store::open(base.join("store"));
        let tdir = base.join("trial");
        let d = 8 + rng.below(64) as usize;
        let gens = 3 + rng.below(3);

        let mut snap = TrainerSnapshot {
            version: snapshot::SNAPSHOT_VERSION,
            fingerprint: SnapshotFingerprint {
                label: "bestofk5/ldsd+zo_sgd".into(),
                seed: rng.next_u64(),
                budget: 6000,
                dim: d,
            },
            step: 0,
            oracle_calls_used: 0,
            next_eval: 1200,
            data_cursor: 0,
            sampler_step: 0,
            best_accuracy: 0.25,
            params: vec![0.0f32; d],
            optimizer: OptimizerState {
                scalars: vec![0],
                // constant across generations: the blob every retained
                // manifest shares (the dedup edge GC must not break)
                buffers: vec![vec![0.5f32; d]],
            },
            policy_mean: Some(vec![0.125f32; d]),
            loss_curve: vec![(6, 0.75)],
            acc_curve: vec![(12, 0.5)],
        };
        for step in 1..=gens {
            snap.step = step;
            snap.oracle_calls_used = step * 6;
            rng.fill_normal(&mut snap.params);
            snapshot::write_snapshot(&tdir, &store, &snap).unwrap();
        }
        let rec = snapshot::OutcomeRecord {
            outcome: TrainOutcome {
                loss_curve: vec![(6, 0.9), (12, 0.7)],
                acc_curve: vec![(12, 0.6)],
                final_accuracy: 0.6,
                best_accuracy: 0.6,
                steps: gens,
                oracle_calls: gens * 6,
                wall_seconds: 0.0,
                label: "bestofk5/ldsd+zo_sgd".into(),
                completed: true,
            },
            probe_storage: "streamed".into(),
            seed: snap.fingerprint.seed,
            budget: 6000,
            spec_hash: Some("ab".repeat(32)),
        };
        snapshot::write_outcome(&tdir, &store, &rec).unwrap();

        // junk: objects nothing references (a crashed run's leftovers)
        let mut junk = Vec::new();
        for j in 0u8..3 {
            let mut noise = vec![0.0f32; 16];
            rng.fill_normal(&mut noise);
            let bytes: Vec<u8> = noise.iter().flat_map(|v| v.to_le_bytes()).chain([j]).collect();
            junk.push(store.put(&bytes).unwrap());
        }

        let before = store.object_count();
        let report = store.gc(&[base.clone()]).unwrap();
        assert!(
            report.swept >= junk.len(),
            "round {round}: swept {} < {} junk objects",
            report.swept,
            junk.len()
        );
        assert_eq!(report.live + report.swept, before);
        for h in &junk {
            assert!(!store.contains(h), "round {round}: junk survived GC");
        }

        // everything the retained manifests reference still loads bitwise
        let snaps = snapshot::list_snapshots(&tdir);
        assert!(!snaps.is_empty());
        for (_, path) in &snaps {
            snapshot::load_snapshot(path, Some(&store)).unwrap();
        }
        let latest = snapshot::load_latest(&tdir, Some(&store)).unwrap();
        assert_eq!(latest.step, gens);
        for (a, b) in latest.params.iter().zip(snap.params.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let loaded = snapshot::load_outcome(&tdir, Some(&store)).unwrap();
        outcomes_bitwise_equal(&loaded.outcome, &rec.outcome);
        assert_eq!(loaded.spec_hash, rec.spec_hash);

        let post = store.verify();
        assert!(post.corrupt.is_empty(), "round {round}: {:?}", post.corrupt);
        assert_eq!(post.ok, report.live);
        let again = store.gc(&[base.clone()]).unwrap();
        assert_eq!(again.swept, 0, "round {round}: second GC must be a no-op");
        std::fs::remove_dir_all(&base).ok();
    }
}

/// The warm-start acceptance path: a re-run grid is served entirely from
/// `grid.lock.json` — zero training-session oracle calls, bitwise-equal
/// outcomes, no new store objects — and a *reordered* re-run still hits,
/// because the cache keys on hash identity, not trial position.
#[test]
fn grid_warm_start_is_cached_bitwise_and_deduped() {
    let base = tmp("warm");
    let mk = |seed: u64| grid_spec(&format!("mlp/s{seed}"), seed, 0.05, &base);
    let exec = ExecContext::new(2);

    let cold: Vec<TrialResult> = run_grid("no-artifacts", vec![mk(1), mk(2)], &exec)
        .into_iter()
        .map(Result::unwrap)
        .collect();
    for tr in &cold {
        assert!(!tr.cached, "{}: first run cannot be cached", tr.spec_id);
        assert!(tr.outcome.completed);
        assert!(tr.session_oracle_calls >= tr.outcome.oracle_calls);
        assert!(tr.session_oracle_calls > 0);
    }
    let store = Store::open(base.join("store"));
    let objects_after_cold = store.object_count();
    assert!(objects_after_cold > 0, "cold run must populate the store");

    let warm: Vec<TrialResult> = run_grid("no-artifacts", vec![mk(1), mk(2)], &exec)
        .into_iter()
        .map(Result::unwrap)
        .collect();
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.spec_id, w.spec_id);
        assert!(w.cached, "{}: re-run must warm-start", w.spec_id);
        assert_eq!(w.session_oracle_calls, 0, "{}: cached trials train zero steps", w.spec_id);
        outcomes_bitwise_equal(&c.outcome, &w.outcome);
    }
    assert_eq!(
        store.object_count(),
        objects_after_cold,
        "a fully-cached re-run must add no objects (content-addressed dedup)"
    );

    // reordered grid: position-independent hits
    let rev: Vec<TrialResult> = run_grid("no-artifacts", vec![mk(2), mk(1)], &exec)
        .into_iter()
        .map(Result::unwrap)
        .collect();
    assert_eq!(rev[0].spec_id, "mlp/s2");
    assert_eq!(rev[1].spec_id, "mlp/s1");
    for r in &rev {
        assert!(r.cached, "{}: reordered re-run must still hit", r.spec_id);
        let original = cold.iter().find(|c| c.spec_id == r.spec_id).unwrap();
        outcomes_bitwise_equal(&original.outcome, &r.outcome);
    }
    std::fs::remove_dir_all(&base).ok();
}

/// Exact staleness: editing a field the legacy label/seed/budget triple
/// cannot see (the learning rate) must miss the cache and re-run, while
/// the unchanged spec keeps hitting its own pin afterwards.
#[test]
fn edited_config_misses_cache_and_reruns() {
    let base = tmp("stale");
    let exec = ExecContext::new(2);
    let spec = grid_spec("mlp/edit", 5, 0.05, &base);
    let cold = run_local_trial("no-artifacts", &spec, &exec).unwrap();
    assert!(!cold.cached);

    // same id, seed, budget, and method label — only lr differs, which
    // the pre-hash freshness check was blind to
    let edited = grid_spec("mlp/edit", 5, 0.1, &base);
    assert_ne!(resolved_hash(&spec), resolved_hash(&edited));
    let rerun = run_local_trial("no-artifacts", &edited, &exec).unwrap();
    assert!(!rerun.cached, "edited lr must invalidate the cached outcome");
    assert!(rerun.session_oracle_calls > 0, "stale hit must actually re-train");

    // the original spec's pin is still intact alongside the new one
    let hit = run_local_trial("no-artifacts", &spec, &exec).unwrap();
    assert!(hit.cached);
    outcomes_bitwise_equal(&cold.outcome, &hit.outcome);
    let lock = GridLock::load(&base);
    assert!(lock.get(&resolved_hash(&spec)).is_some());
    assert!(lock.get(&resolved_hash(&edited)).is_some());
    std::fs::remove_dir_all(&base).ok();
}

/// Migration: a per-trial `completed/` record written by a pre-store
/// build (v2: sibling curve blobs, no spec hash, no lockfile) must
/// warm-start through the legacy field comparison, bitwise-identically —
/// and the hit must backfill `grid.lock.json` with a store object so the
/// next resume pins by hash directly.
#[test]
fn legacy_v2_outcome_record_warm_starts_and_backfills_lock() {
    let exec = ExecContext::new(2);
    let base = tmp("legacy");

    // reference outcome from an uncheckpointed run of the same config —
    // exactly what the old build would have recorded on completion
    let mut reference_spec = grid_spec("mlp/legacy", 9, 0.05, &base);
    reference_spec.checkpoint = Some(CheckpointConfig::default());
    let reference = run_local_trial("no-artifacts", &reference_spec, &exec).unwrap();
    assert!(!reference.cached);

    let spec = grid_spec("mlp/legacy", 9, 0.05, &base);
    let tdir = base.join(snapshot::sanitize_id(&spec.id));
    snapshot::write_outcome_legacy(
        &tdir,
        &reference.outcome,
        reference.probe_storage,
        spec.config.seed,
        spec.config.budget,
    )
    .unwrap();
    let hash = resolved_hash(&spec);
    assert!(
        GridLock::load(&base).get(&hash).is_none(),
        "fabricated legacy tree must start without a lockfile pin"
    );

    let warm = run_local_trial("no-artifacts", &spec, &exec).unwrap();
    assert!(warm.cached, "legacy record must warm-start");
    assert_eq!(warm.session_oracle_calls, 0);
    outcomes_bitwise_equal(&reference.outcome, &warm.outcome);

    // the hit upgraded the record: pinned in the lockfile as a store
    // object that carries the canonical spec hash
    let entry = GridLock::load(&base)
        .get(&hash)
        .cloned()
        .expect("legacy hit must backfill grid.lock.json");
    assert_eq!(entry.id, spec.id);
    let store = Store::open(base.join("store"));
    let rec = snapshot::outcome_from_store(&store, &entry.outcome).unwrap();
    assert_eq!(rec.spec_hash.as_deref(), Some(hash.as_str()));
    outcomes_bitwise_equal(&reference.outcome, &rec.outcome);

    // second resume hits the pin directly
    let again = run_local_trial("no-artifacts", &spec, &exec).unwrap();
    assert!(again.cached);
    assert_eq!(again.session_oracle_calls, 0);
    std::fs::remove_dir_all(&base).ok();
}
