//! Integration: AOT artifacts -> PJRT runtime -> golden parity.
//!
//! These tests pin the two language boundaries:
//! 1. the rust corpus port generates byte-identical batches to python
//!    (golden.json carries python-generated batches), and
//! 2. the PJRT-executed artifacts reproduce the python-side loss values
//!    and the pretrain-time eval accuracy.
//!
//! They require `make artifacts` to have run; they are skipped (with a
//! loud message) when artifacts/ is missing so `cargo test` stays green
//! on a fresh checkout.

use std::path::{Path, PathBuf};

use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::data::Corpus;
use zo_ldsd::eval::Evaluator;
use zo_ldsd::jsonio::{parse, Json};
use zo_ldsd::oracle::{read_params_bin, Oracle, PjrtOracle};
use zo_ldsd::rng::SplitMix64;
use zo_ldsd::runtime::{ArgValue, Runtime};

fn artifact_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the pjrt feature (stub runtime)");
        return None;
    }
    let candidates = ["artifacts", "../artifacts"];
    for c in candidates {
        let p = Path::new(c);
        if p.join("manifest.json").exists() {
            return Some(p.to_path_buf());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

fn load_golden(dir: &Path) -> Json {
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    parse(&text).unwrap()
}

#[test]
fn corpus_matches_python_golden() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let golden = load_golden(&dir);
    for entry in golden.get("corpus").unwrap().as_arr().unwrap() {
        let model = entry.get("model").unwrap().as_str().unwrap();
        let spec = manifest.corpus(model).unwrap().clone();
        let corpus = Corpus::new(spec).unwrap();
        let b = manifest.model(model).unwrap().shapes.batch;

        let train = corpus.train_batch(0, b);
        let test = corpus.test_batch(0, b);
        let gids = entry.get("train_ids").unwrap().to_i32_vec_nested().unwrap();
        assert_eq!(train.ids, gids, "{model}: train ids diverge from python");
        let gmask = entry.get("train_mask").unwrap().to_f32_vec_nested().unwrap();
        assert_eq!(train.mask, gmask, "{model}: train mask diverges");
        let glab = entry.get("train_labels").unwrap().to_i32_vec_nested().unwrap();
        assert_eq!(train.labels, glab, "{model}: train labels diverge");
        let tids = entry.get("test_ids").unwrap().to_i32_vec_nested().unwrap();
        assert_eq!(test.ids, tids, "{model}: test ids diverge");
        let tlab = entry.get("test_labels").unwrap().to_i32_vec_nested().unwrap();
        assert_eq!(test.labels, tlab, "{model}: test labels diverge");
    }
}

#[test]
fn pjrt_losses_match_python_golden() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let golden = load_golden(&dir);
    let losses = golden.get("losses").unwrap();
    let rt = Runtime::new(&dir).unwrap();

    for (model, g) in losses.as_obj().unwrap() {
        let entry = manifest.model(model).unwrap();
        let corpus = Corpus::new(manifest.corpus(model).unwrap().clone()).unwrap();
        let batch = corpus.train_batch(0, entry.shapes.batch);

        // FT loss at the pretrained checkpoint
        let mut ft = PjrtOracle::new(&rt, entry, TrainMode::Ft).unwrap();
        ft.set_batch(&batch).unwrap();
        let loss_ft = ft.loss_base().unwrap();
        let want_ft = g.get("ft_loss_batch0").unwrap().as_f64().unwrap();
        assert!(
            (loss_ft - want_ft).abs() < 1e-4 * (1.0 + want_ft.abs()),
            "{model} ft loss: rust {loss_ft} vs python {want_ft}"
        );

        // LoRA loss at init (B = 0 adapters + copied head): must equal FT
        let mut lora = PjrtOracle::new(&rt, entry, TrainMode::Lora).unwrap();
        lora.set_batch(&batch).unwrap();
        let loss_lora = lora.loss_base().unwrap();
        let want_lora = g.get("lora_loss_batch0").unwrap().as_f64().unwrap();
        assert!(
            (loss_lora - want_lora).abs() < 1e-4 * (1.0 + want_lora.abs()),
            "{model} lora loss: rust {loss_lora} vs python {want_lora}"
        );

        // perturbed loss along the deterministic sin direction
        let d = entry.d_ft;
        let dir_vec: Vec<f32> =
            (0..d).map(|i| (0.5 * (i as f64).sin()) as f32).collect();
        let loss_dir = ft.loss_dir(&dir_vec, 1e-3).unwrap();
        let want_dir = g
            .get("ft_loss_dir_batch0_sin_tau1e-3")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(
            (loss_dir - want_dir).abs() < 1e-4 * (1.0 + want_dir.abs()),
            "{model} loss_dir: rust {loss_dir} vs python {want_dir}"
        );
        // the perturbation must actually change the loss
        assert!((loss_dir - loss_ft).abs() > 1e-7);
    }
}

#[test]
fn loss_k_matches_k_loss_dir_calls() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::new(&dir).unwrap();
    let entry = manifest.model("roberta_mini").unwrap();
    let corpus = Corpus::new(manifest.corpus("roberta_mini").unwrap().clone()).unwrap();
    let batch = corpus.train_batch(3, entry.shapes.batch);

    let mut oracle = PjrtOracle::new(&rt, entry, TrainMode::Lora).unwrap();
    oracle.set_batch(&batch).unwrap();
    let d = oracle.dim();
    let k = entry.shapes.k;
    let mut sm = SplitMix64::new(42);
    let dirs: Vec<f32> = (0..k * d)
        .map(|_| (sm.next_f64() as f32 - 0.5) * 2.0)
        .collect();
    let fused = oracle.loss_k(&dirs, k, 1e-3).unwrap();
    let looped: Vec<f64> = (0..k)
        .map(|i| oracle.loss_dir(&dirs[i * d..(i + 1) * d], 1e-3).unwrap())
        .collect();
    for i in 0..k {
        assert!(
            (fused[i] - looped[i]).abs() < 1e-5 * (1.0 + looped[i].abs()),
            "probe {i}: fused {} vs looped {}",
            fused[i],
            looped[i]
        );
    }
}

#[test]
fn evaluator_reproduces_python_eval_accuracy() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::new(&dir).unwrap();
    for (name, entry) in &manifest.models {
        // python measured this on the shipped checkpoint (head re-init)
        let Some(want) = entry.init_accuracy.or(entry.pretrain_accuracy) else {
            continue;
        };
        let corpus = Corpus::new(manifest.corpus(name).unwrap().clone()).unwrap();
        let evaluator = Evaluator::new(&rt, entry, TrainMode::Ft).unwrap();
        let params =
            read_params_bin(&dir.join(&entry.params_file), entry.d_ft).unwrap();
        // python evaluated 4 batches of 64 test examples — same stream
        let acc = evaluator.accuracy(&params, &corpus, 4).unwrap();
        assert!(
            (acc - want).abs() < 0.02,
            "{name}: rust eval acc {acc} vs python {want}"
        );
    }
}

#[test]
fn toy_artifact_matches_golden() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let golden = load_golden(&dir);
    let toy = golden.get("toy").unwrap();
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("toy_linreg_grad").unwrap();

    // regenerate w, X, y from the same SplitMix64(0xA9A) stream as aot.py
    let (d, n) = (manifest.toy_d, manifest.toy_n);
    let mut sm = SplitMix64::new(0xA9A);
    let w: Vec<f32> = (0..d).map(|_| sm.next_f64() as f32 - 0.5).collect();
    let x: Vec<f32> = (0..n * d).map(|_| sm.next_f64() as f32 - 0.5).collect();
    let y: Vec<f32> = (0..n).map(|_| sm.next_f64() as f32 - 0.5).collect();

    let out = exe
        .run(&[
            ArgValue::F32(&w, &[d]),
            ArgValue::F32(&x, &[n, d]),
            ArgValue::F32(&y, &[n]),
        ])
        .unwrap();
    let grad = &out[0];
    let loss = out[1][0] as f64;

    let want_loss = toy.get("loss").unwrap().as_f64().unwrap();
    assert!((loss - want_loss).abs() < 1e-5 * (1.0 + want_loss.abs()));
    let want_head = toy.get("grad_head").unwrap().to_f32_vec().unwrap();
    for (i, w_i) in want_head.iter().enumerate() {
        assert!(
            (grad[i] - w_i).abs() < 1e-5,
            "grad[{i}]: rust {} vs python {w_i}",
            grad[i]
        );
    }
    let norm: f64 = grad.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt();
    let want_norm = toy.get("grad_norm").unwrap().as_f64().unwrap();
    assert!((norm - want_norm).abs() < 1e-4 * (1.0 + want_norm));
}

#[test]
fn update_params_invalidate_device_copy() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::new(&dir).unwrap();
    let entry = manifest.model("roberta_mini").unwrap();
    let corpus = Corpus::new(manifest.corpus("roberta_mini").unwrap().clone()).unwrap();
    let batch = corpus.train_batch(0, entry.shapes.batch);
    let mut oracle = PjrtOracle::new(&rt, entry, TrainMode::Lora).unwrap();
    oracle.set_batch(&batch).unwrap();
    let l0 = oracle.loss_base().unwrap();
    // scramble the classifier head (shipped checkpoints zero it, so write
    // nonzero values): loss must change on the next call
    oracle
        .update_params(&mut |x| {
            let n = x.len();
            for (i, v) in x[n - 258..].iter_mut().enumerate() {
                *v = 0.05 * ((i as f32 * 0.7).sin() + 0.1);
            }
        })
        .unwrap();
    let l1 = oracle.loss_base().unwrap();
    assert!((l0 - l1).abs() > 1e-6, "device param copy was not refreshed");
}
