//! The distributed seed-sync service end to end over loopback HTTP
//! (DESIGN.md §17): a grid farmed across two workers merges to a report
//! byte-identical to the single-process run, a worker killed mid-trial
//! only costs a lease timeout (the trial re-queues and the merged report
//! is still byte-identical), a restarted coordinator serves the whole
//! grid from its result cache with zero training steps, loss-evaluation
//! shards merge bitwise to the unsharded evaluation, and malformed
//! requests answer 4xx without killing the listener.

use std::path::PathBuf;
use std::time::Duration;

use zo_ldsd::config::TrainMode;
use zo_ldsd::coordinator::{deterministic_report, run_grid, MlpTrial, OracleSpec, TrialSpec};
use zo_ldsd::data::CorpusSpec;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::jsonio::{parse, to_string_canonical};
use zo_ldsd::model::mlp::MlpSpec;
use zo_ldsd::model::Activation;
use zo_ldsd::service::http::http_request;
use zo_ldsd::service::proto::{self, LeaseReply};
use zo_ldsd::service::{
    eval_shard_losses, run_worker, Coordinator, CoordinatorConfig, WorkerConfig,
};
use zo_ldsd::train::TrainConfig;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zo_service_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A tiny artifact-free MLP trial — the cheapest real training run the
/// coordinator can farm out.  No checkpoint policy: workers pin their
/// own, and the spec hash is identical either way.
fn trial(id: &str, seed: u64, lr: f32) -> TrialSpec {
    let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", lr, 120);
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.seed = seed;
    let oracle = OracleSpec::Mlp(MlpTrial {
        hidden: vec![8],
        activation: Activation::Tanh,
        in_dim: 16,
        corpus: CorpusSpec::default_mini(),
        init_seed: 1,
        eval_batch: 8,
    });
    TrialSpec::new(id, "mlp", TrainMode::Ft, cfg, oracle)
}

/// A grid farmed over two loopback workers produces a merged report
/// byte-identical to the single-process `run_grid`, and a coordinator
/// restarted on the same directory re-serves every trial from the
/// result cache with zero training-session oracle calls.
#[test]
fn farmed_grid_is_byte_identical_and_warm_restart_serves_cache() {
    let base = tmp("farm");
    let grid = || {
        vec![
            trial("svc/a", 1, 0.02),
            trial("svc/b", 2, 0.02),
            trial("svc/c", 3, 0.03),
        ]
    };
    let single = run_grid("no-artifacts", grid(), &ExecContext::new(1));
    let want = deterministic_report(&single);

    let mut coordinator =
        Coordinator::bind(CoordinatorConfig::loopback(base.join("coord"))).unwrap();
    let addr = coordinator.addr().to_string();
    assert_eq!(
        coordinator.enqueue(grid()).unwrap(),
        0,
        "a cold queue has nothing cached"
    );

    let workers: Vec<_> = (0..2)
        .map(|w| {
            let cfg = WorkerConfig::new(addr.clone(), base.join(format!("w{w}")));
            std::thread::spawn(move || run_worker(&cfg).unwrap())
        })
        .collect();
    let farmed = coordinator.run_until_done(Duration::from_millis(20)).unwrap();
    let mut trials_run = 0;
    for h in workers {
        let report = h.join().unwrap();
        assert_eq!(report.errors, 0);
        trials_run += report.trials_run;
    }
    assert_eq!(trials_run, 3, "the two workers drained the queue exactly once");
    assert_eq!(
        deterministic_report(&farmed),
        want,
        "farmed grid must be byte-identical to the single-process run"
    );
    for r in &farmed {
        let tr = r.as_ref().unwrap();
        assert!(!tr.cached, "cold trials train for real");
        assert!(tr.outcome.completed);
    }
    let stats = coordinator.stats();
    assert_eq!(stats.outcomes_accepted, 3);
    assert_eq!(stats.cached_on_enqueue, 0);
    coordinator.shutdown().unwrap();
    drop(coordinator);

    // restart on the same directory: queue.json restores the grid, and
    // grid.lock.json + the store answer every trial without training
    let warm_coordinator =
        Coordinator::bind(CoordinatorConfig::loopback(base.join("coord"))).unwrap();
    let warm = warm_coordinator
        .run_until_done(Duration::from_millis(5))
        .unwrap();
    assert_eq!(warm.len(), 3, "the persisted queue restored the full grid");
    for r in &warm {
        let tr = r.as_ref().unwrap();
        assert!(tr.cached, "warm trials come from the result cache");
        assert_eq!(tr.session_oracle_calls, 0, "warm start does no training");
    }
    assert_eq!(deterministic_report(&warm), want, "warm report is byte-identical too");
    assert_eq!(warm_coordinator.stats().cached_on_enqueue, 3);
    std::fs::remove_dir_all(&base).ok();
}

/// A worker killed mid-trial (a lease taken and never submitted) only
/// costs the lease timeout: the trial re-queues, a live worker finishes
/// the grid, and the merged report is still byte-identical to the
/// single-process run.
#[test]
fn killed_worker_lease_expires_and_the_grid_still_merges_clean() {
    let base = tmp("kill");
    let grid = || vec![trial("kill/a", 11, 0.02), trial("kill/b", 12, 0.025)];
    let single = run_grid("no-artifacts", grid(), &ExecContext::new(1));
    let want = deterministic_report(&single);

    let mut coordinator = Coordinator::bind(CoordinatorConfig {
        addr: "127.0.0.1:0".to_string(),
        dir: base.join("coord"),
        lease_timeout: Duration::from_millis(250),
    })
    .unwrap();
    let addr = coordinator.addr().to_string();
    coordinator.enqueue(grid()).unwrap();

    // the doomed worker: takes a trial lease over raw HTTP, then dies
    // without ever submitting
    let body = format!("{}\n", to_string_canonical(&proto::message(vec![])));
    let (status, reply) =
        http_request(&addr, "POST", proto::P_LEASE, "application/json", body.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let j = parse(std::str::from_utf8(&reply).unwrap()).unwrap();
    match LeaseReply::from_json(&j).unwrap() {
        LeaseReply::Trial { .. } => {}
        other => panic!("expected a trial lease, got {other:?}"),
    }

    // a live worker drains the queue; the dead lease expires, re-queues,
    // and the same worker picks the orphaned trial back up
    let report = run_worker(&WorkerConfig::new(addr, base.join("w0"))).unwrap();
    assert_eq!(report.errors, 0);
    assert!(
        report.trials_run >= 2,
        "the live worker ran both trials (got {})",
        report.trials_run
    );
    let farmed = coordinator.run_until_done(Duration::from_millis(10)).unwrap();
    assert!(
        coordinator.stats().requeues >= 1,
        "the dead worker's lease must have expired and re-queued"
    );
    assert_eq!(
        deterministic_report(&farmed),
        want,
        "a mid-trial kill must not perturb the merged report"
    );
    coordinator.shutdown().unwrap();
    std::fs::remove_dir_all(&base).ok();
}

/// Loss-evaluation shards farmed through the service merge bitwise to
/// the unsharded local evaluation of the same parameter image.
#[test]
fn eval_shards_merge_bitwise_to_the_local_evaluation() {
    let base = tmp("eval");
    let spec = trial("eval/a", 21, 0.02);
    let (mspec, init_seed) = match &spec.oracle {
        OracleSpec::Mlp(m) => (
            MlpSpec::new(
                m.in_dim,
                m.hidden.clone(),
                m.corpus.n_classes as usize,
                m.activation,
            )
            .unwrap(),
            m.init_seed,
        ),
        other => panic!("expected an MLP oracle, got {other:?}"),
    };
    // any deterministic parameter image of the right dimension (a
    // different seed than the oracle init, so the install is observable)
    let params = mspec.init_params(init_seed ^ 0xE7A1);
    let local = eval_shard_losses(&spec, &params, 0, 6).unwrap();
    assert_eq!(local.len(), 6);

    let coordinator =
        Coordinator::bind(CoordinatorConfig::loopback(base.join("coord"))).unwrap();
    let addr = coordinator.addr().to_string();
    let shards = coordinator.enqueue_eval(&spec, &params, 6, 2).unwrap();
    assert_eq!(shards, 3, "6 batches in chunks of 2");
    assert!(coordinator.eval_losses().is_none(), "nothing evaluated yet");

    let report = run_worker(&WorkerConfig::new(addr, base.join("w0"))).unwrap();
    assert_eq!(report.evals_run, 3);
    let merged = coordinator.eval_losses().expect("every shard is done");
    assert_eq!(merged.len(), local.len());
    for (a, b) in merged.iter().zip(local.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "sharded eval must merge bitwise");
    }
    std::fs::remove_dir_all(&base).ok();
}

/// Garbage on the wire answers 4xx with a JSON error body and leaves the
/// listener healthy.
#[test]
fn malformed_requests_answer_4xx_without_killing_the_service() {
    let base = tmp("bad");
    let coordinator =
        Coordinator::bind(CoordinatorConfig::loopback(base.join("coord"))).unwrap();
    let addr = coordinator.addr().to_string();

    // a body that is not JSON at all
    let (status, body) =
        http_request(&addr, "POST", proto::P_ENQUEUE, "application/json", b"this is not json")
            .unwrap();
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("error"));

    // valid JSON stamped with a wire schema from the future
    let stale = r#"{"schema":"00000000000000ff","kind":"trial"}"#;
    let (status, _) =
        http_request(&addr, "POST", proto::P_OUTCOME, "application/json", stale.as_bytes())
            .unwrap();
    assert_eq!(status, 400);

    // an outcome for a trial that was never queued
    let bogus = format!(
        r#"{{"schema":"{:016x}","kind":"eval","index":7,"losses":[]}}"#,
        zo_ldsd::coordinator::wire::WIRE_SCHEMA_VERSION
    );
    let (status, _) =
        http_request(&addr, "POST", proto::P_OUTCOME, "application/json", bogus.as_bytes())
            .unwrap();
    assert_eq!(status, 400);

    // unknown route, and a store object that does not exist
    let (status, _) = http_request(&addr, "GET", "/api/v1/nope", "text/plain", &[]).unwrap();
    assert_eq!(status, 404);
    let missing = format!("{}/{}", proto::P_STORE_OBJ, "ab".repeat(32));
    let (status, _) = http_request(&addr, "GET", &missing, "text/plain", &[]).unwrap();
    assert_eq!(status, 404);

    // the listener survived all of it
    let (status, body) =
        http_request(&addr, "GET", proto::P_PING, "application/json", &[]).unwrap();
    assert_eq!(status, 200);
    assert!(String::from_utf8_lossy(&body).contains("zo-coordinator"));
    std::fs::remove_dir_all(&base).ok();
}
