//! Property-based tests over coordinator invariants, using the in-repo
//! proptest substrate (DESIGN.md §3: the vendored set has no proptest).

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::data::{Batch, SyntheticRegression};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::{Activation, MlpSpec};
use zo_ldsd::optim::{BaseOptimizer, ZoAdaMM, ZoSgd};
use zo_ldsd::oracle::{LinRegOracle, LogRegOracle, MlpOracle, Oracle, QuadraticOracle};
use zo_ldsd::proptest::{check, Gen, U64Range, VecF32, VecPairF32};
use zo_ldsd::rng::Rng;
use zo_ldsd::sampler::{DirectionSampler, GaussianSampler, LdsdConfig, LdsdSampler};
use zo_ldsd::tensor::lanes::{fma_axpy_into, force_mode, LaneMode};
use zo_ldsd::tensor::{
    axpy_into, axpy_into_ctx, axpy_k, axpy_k_ctx, cosine, dot, normalize, nrm2,
    probe_combine, probe_combine_ctx, ParamStore, ParamStoreMode,
};

const VEC: VecF32 = VecF32 { min_len: 1, max_len: 256, scale: 10.0 };

#[test]
fn prop_normalize_idempotent_and_unit() {
    check("normalize_unit", &VEC, 300, |v| {
        let mut x = v.clone();
        let n = normalize(&mut x);
        if n == 0.0 {
            return x.iter().all(|&a| a == 0.0);
        }
        let n1 = nrm2(&x);
        let mut y = x.clone();
        normalize(&mut y);
        (n1 - 1.0).abs() < 1e-4 && x.iter().zip(y.iter()).all(|(a, b)| (a - b).abs() < 1e-5)
    });
}

#[test]
fn prop_cosine_bounded_and_symmetric() {
    check(
        "cosine_bounds",
        &VecPairF32(VEC),
        300,
        |(a, b)| {
            let c1 = cosine(a, b);
            let c2 = cosine(b, a);
            (-1.0..=1.0).contains(&c1) && (c1 - c2).abs() < 1e-6
        },
    );
}

#[test]
fn prop_cosine_scale_invariant() {
    check("cosine_scale_invariant", &VecPairF32(VEC), 200, |(a, b)| {
        let c1 = cosine(a, b);
        let a2: Vec<f32> = a.iter().map(|x| x * 3.5).collect();
        let c2 = cosine(&a2, b);
        (c1 - c2).abs() < 1e-4
    });
}

#[test]
fn prop_axpy_into_linear() {
    // f(x + s d) along s: axpy_into(s1+s2) == axpy_into applied twice
    check("axpy_linear", &VecPairF32(VEC), 200, |(x, d)| {
        let n = x.len();
        let mut once = vec![0.0f32; n];
        axpy_into(&mut once, x, 0.7, d);
        let mut twice = vec![0.0f32; n];
        axpy_into(&mut twice, x, 0.3, d);
        let t2 = twice.clone();
        axpy_into(&mut twice, &t2, 0.4, d);
        once.iter().zip(twice.iter()).all(|(a, b)| (a - b).abs() < 1e-3)
    });
}

#[test]
fn prop_dot_cauchy_schwarz() {
    check("cauchy_schwarz", &VecPairF32(VEC), 300, |(a, b)| {
        dot(a, b).abs() <= nrm2(a) * nrm2(b) * (1.0 + 1e-4) + 1e-6
    });
}

/// The shard-parallel kernels are bitwise identical to their serial
/// references for *arbitrary* shapes, shard lengths and thread counts —
/// the determinism contract of the sharded execution engine (DESIGN.md
/// §9).  One seeded case draws (d, k, shard_len, threads) plus random
/// contents and checks all three `_ctx` kernels at once.
#[test]
fn prop_parallel_kernels_bitwise_match_serial() {
    check("parallel_kernels_match", &U64Range(0, 1 << 20), 60, |&s| {
        let mut rng = Rng::new(s);
        let d = 1 + rng.below(3000) as usize;
        let k = 1 + rng.below(6) as usize;
        let shard_len = 1 + rng.below(700) as usize;
        let threads = 1 + rng.below(8) as usize;
        let ctx = ExecContext::new(threads).with_shard_len(shard_len);

        let mut rows = vec![0.0f32; k * d];
        rng.fill_normal(&mut rows);
        let mut w = vec![0.0f32; k];
        rng.fill_normal(&mut w);
        let mut base = vec![0.0f32; d];
        rng.fill_normal(&mut base);

        // axpy_k
        let mut y_serial = base.clone();
        axpy_k(&w, &rows, &mut y_serial);
        let mut y_par = base.clone();
        axpy_k_ctx(&ctx, &w, &rows, &mut y_par);
        if y_serial
            .iter()
            .zip(y_par.iter())
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return false;
        }

        // probe_combine (output is overwritten, so garbage in is fine)
        let mut g_serial = vec![7.0f32; d];
        probe_combine(&rows, d, &w, &mut g_serial);
        let mut g_par = vec![-3.0f32; d];
        probe_combine_ctx(&ctx, &rows, d, &w, &mut g_par);
        if g_serial
            .iter()
            .zip(g_par.iter())
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return false;
        }

        // axpy_into
        let mut o_serial = vec![0.0f32; d];
        axpy_into(&mut o_serial, &base, 0.37, &g_serial);
        let mut o_par = vec![0.0f32; d];
        axpy_into_ctx(&ctx, &mut o_par, &base, 0.37, &g_par);
        o_serial
            .iter()
            .zip(o_par.iter())
            .all(|(a, b)| a.to_bits() == b.to_bits())
    });
}

/// LDSD with gamma_mu = 0 must sample exactly like a frozen-mean Gaussian:
/// the policy update is the ONLY difference the learning rate controls.
#[test]
fn prop_ldsd_gamma_zero_policy_frozen() {
    check("ldsd_frozen_policy", &U64Range(0, 10_000), 50, |&seed| {
        let d = 64;
        let mut s = LdsdSampler::new(
            d,
            seed,
            LdsdConfig { gamma_mu: 0.0, ..Default::default() },
        );
        let mu0 = s.policy_mean().unwrap().to_vec();
        let mut dirs = vec![0.0f32; 5 * d];
        for round in 0..5 {
            s.sample(&mut dirs, 5);
            let losses: Vec<f64> = (0..5).map(|i| (i + round) as f64).collect();
            s.observe(&dirs, &losses, 5);
        }
        s.policy_mean().unwrap() == &mu0[..]
    });
}

/// Sampler state-size claims (the paper's O(d) memory argument) hold for
/// every d.
#[test]
fn prop_sampler_state_bytes() {
    check("state_bytes", &U64Range(1, 4096), 60, |&d| {
        let d = d as usize;
        let g = GaussianSampler::new(d, 1);
        let l = LdsdSampler::new(d, 1, LdsdConfig::default());
        g.state_bytes() == 0 && l.state_bytes() == 4 * d
    });
}

/// Optimizer updates are equivariant to permutations of coordinates
/// (no hidden coordinate coupling).
#[test]
fn prop_optimizer_permutation_equivariant() {
    check("optimizer_equivariance", &U64Range(0, 1000), 40, |&seed| {
        let d = 16;
        let mut rng = Rng::new(seed);
        let x0: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        // permutation = reversal
        let xr: Vec<f32> = x0.iter().rev().cloned().collect();
        let gr: Vec<f32> = g.iter().rev().cloned().collect();
        for mk in [0usize, 1] {
            let (mut o1, mut o2): (Box<dyn BaseOptimizer>, Box<dyn BaseOptimizer>) =
                match mk {
                    0 => (Box::new(ZoSgd::new(d, 0.9)), Box::new(ZoSgd::new(d, 0.9))),
                    _ => (
                        Box::new(ZoAdaMM::new(d, 0.9, 0.999)),
                        Box::new(ZoAdaMM::new(d, 0.9, 0.999)),
                    ),
                };
            let mut a = x0.clone();
            let mut b = xr.clone();
            for _ in 0..3 {
                o1.step(&mut a, &g, 0.01);
                o2.step(&mut b, &gr, 0.01);
            }
            let ok = a
                .iter()
                .zip(b.iter().rev())
                .all(|(p, q)| (p - q).abs() < 1e-5);
            if !ok {
                return false;
            }
        }
        true
    });
}

/// Corpus invariants hold for arbitrary indices, including the test range.
#[test]
fn prop_corpus_examples_well_formed() {
    let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
    check("corpus_wf", &U64Range(0, 1 << 22), 300, |&idx| {
        let ex = corpus.example(idx);
        let len = ex.mask.iter().filter(|&&m| m == 1.0).count();
        ex.ids[0] == 1
            && (corpus.spec.min_len as usize..corpus.spec.seq).contains(&len)
            && ex.ids[len..].iter().all(|&t| t == 0)
            && ex.ids[..len].iter().all(|&t| t >= 1 && (t as u64) < corpus.spec.vocab)
            && (ex.label == 0 || ex.label == 1)
    });
}

/// Determinism: the corpus is a pure function of (seed, index).
#[test]
fn prop_corpus_deterministic() {
    let a = Corpus::new(CorpusSpec::default_mini()).unwrap();
    let b = Corpus::new(CorpusSpec::default_mini()).unwrap();
    check("corpus_det", &U64Range(0, 1 << 30), 100, |&idx| {
        let x = a.example(idx);
        let y = b.example(idx);
        x.ids == y.ids && x.mask == y.mask && x.label == y.label
    });
}

/// A generator sanity property for the substrate itself: shrink produces
/// strictly smaller cases.
#[test]
fn prop_shrink_shrinks() {
    let gen = VecF32 { min_len: 2, max_len: 128, scale: 1.0 };
    let mut rng = Rng::new(7);
    for _ in 0..50 {
        let v = gen.generate(&mut rng);
        for s in gen.shrink(&v) {
            assert!(
                s.len() < v.len() || nrm2(&s) <= nrm2(&v) + 1e-6,
                "shrink must not grow"
            );
        }
    }
}

/// [`Oracle::loss_dir`] documents that `scale = 0` (or an all-zero
/// direction) gives f(x).  Pin the contract **bitwise** for every oracle
/// — closed-form substrates and the MLP alike — over random iterates and
/// directions: `loss_dir(v, 0)` must equal both `loss_dir(0, 0)` and
/// `loss_dir(0, 1)`.
#[test]
fn prop_loss_dir_scale_zero_is_f_of_x_for_every_oracle() {
    check("loss_dir_scale_zero", &U64Range(0, 1 << 20), 30, |&seed| {
        let mut rng = Rng::new(seed ^ 0x5CA1E0);
        let mut oracles: Vec<Box<dyn Oracle>> = Vec::new();
        // quadratic with random conditioning and iterate
        {
            let d = 8 + rng.below(40) as usize;
            let diag: Vec<f32> = (0..d).map(|_| 0.5 + rng.next_f64() as f32).collect();
            let center: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let x0: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            oracles.push(Box::new(QuadraticOracle::new(diag, center, x0)));
        }
        // linreg / logreg on an a9a-like draw
        {
            let ds = SyntheticRegression::a9a_like(32, seed);
            let w0: Vec<f32> = (0..123).map(|_| 0.05 * rng.normal() as f32).collect();
            oracles.push(Box::new(LinRegOracle::new(ds.x.clone(), ds.y.clone(), w0.clone())));
            let y: Vec<f32> =
                ds.y.iter().map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
            oracles.push(Box::new(LogRegOracle::new(ds.x, y, w0)));
        }
        // the MLP over a dense feature minibatch
        {
            let spec = MlpSpec::new(10, vec![6], 3, Activation::Tanh).unwrap();
            let mut o = MlpOracle::from_seed(spec.clone(), seed);
            let n = 4;
            let mut data = vec![0.0f32; n * spec.in_dim];
            rng.fill_normal(&mut data);
            let labels: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
            o.set_batch(&Batch::from_features(spec.in_dim, data, labels)).unwrap();
            oracles.push(Box::new(o));
        }
        for mut o in oracles {
            let d = o.dim();
            let mut dir = vec![0.0f32; d];
            rng.fill_normal(&mut dir);
            let zeros = vec![0.0f32; d];
            let at_zero_scale = o.loss_dir(&dir, 0.0).unwrap();
            let at_zero_dir = o.loss_dir(&zeros, 0.0).unwrap();
            let at_zero_dir_unit_scale = o.loss_dir(&zeros, 1.0).unwrap();
            if at_zero_scale.to_bits() != at_zero_dir.to_bits()
                || at_zero_dir.to_bits() != at_zero_dir_unit_scale.to_bits()
            {
                eprintln!(
                    "{}: scale-0 contract broken: {at_zero_scale} vs {at_zero_dir} vs \
                     {at_zero_dir_unit_scale}",
                    o.name()
                );
                return false;
            }
        }
        true
    });
}

/// The lane contract (DESIGN.md §14) at the ops layer: the scalar and the
/// wide (SIMD) kernel families return identical bits for *arbitrary*
/// shapes, shard lengths and thread counts — forcing the mode changes
/// speed, never results.  One seeded case draws (d, k, shard_len,
/// threads) plus random contents and runs the whole hot-path family —
/// serial and `_ctx` sharded forms — under both forced modes.
#[test]
fn prop_lane_modes_bitwise_identical_across_shapes() {
    check("lanes_bitwise", &U64Range(0, 1 << 20), 50, |&s| {
        let mut rng = Rng::new(s ^ 0xA5A5);
        let d = 1 + rng.below(3000) as usize;
        let k = 1 + rng.below(6) as usize;
        let shard_len = 1 + rng.below(700) as usize;
        let threads = 1 + rng.below(8) as usize;
        let ctx = ExecContext::new(threads).with_shard_len(shard_len);

        let mut rows = vec![0.0f32; k * d];
        rng.fill_normal(&mut rows);
        let mut w = vec![0.0f32; k];
        rng.fill_normal(&mut w);
        let mut base = vec![0.0f32; d];
        rng.fill_normal(&mut base);

        let run = |mode: LaneMode| {
            force_mode(Some(mode));
            let mut y = base.clone();
            axpy_k(&w, &rows, &mut y);
            let mut yc = base.clone();
            axpy_k_ctx(&ctx, &w, &rows, &mut yc);
            let mut g = vec![7.0f32; d];
            probe_combine(&rows, d, &w, &mut g);
            let mut gc = vec![-3.0f32; d];
            probe_combine_ctx(&ctx, &rows, d, &w, &mut gc);
            let mut o = vec![0.0f32; d];
            axpy_into(&mut o, &base, 0.37, &g);
            force_mode(None);
            (y, yc, g, gc, o)
        };
        let a = run(LaneMode::Scalar);
        let b = run(LaneMode::Wide);
        let eq = |x: &[f32], y: &[f32]| {
            x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        };
        eq(&a.0, &b.0) && eq(&a.1, &b.1) && eq(&a.2, &b.2) && eq(&a.3, &b.3) && eq(&a.4, &b.4)
    });
}

/// Quantized parameter stores (DESIGN.md §14) over random contents and
/// lengths, for every mode: requantizing the dequant image is an exact
/// round-trip (the property snapshot/restore relies on), the fused
/// `perturb_into` is bitwise the same as materializing the dequantized
/// f32 image and running the fma axpy kernel, and any window of
/// `perturb_range_into` agrees with the corresponding slice of the full
/// fused result.
#[test]
fn prop_param_store_requant_idempotent_and_perturb_fused() {
    check("param_store_roundtrip", &U64Range(0, 1 << 20), 40, |&s| {
        let mut rng = Rng::new(s ^ 0x9E37);
        let d = 1 + rng.below(800) as usize;
        let mut xs = vec![0.0f32; d];
        rng.fill_normal(&mut xs);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v);
        let tau = 1e-3f32;
        for mode in [ParamStoreMode::F32, ParamStoreMode::F16, ParamStoreMode::Int8] {
            let store = ParamStore::from_f32(mode, &xs);
            let mut deq = vec![0.0f32; d];
            store.dequant_into(&mut deq);

            // requant idempotence: quantizing the dequant image changes no bits
            let store2 = ParamStore::from_f32(mode, &deq);
            let mut deq2 = vec![0.0f32; d];
            store2.dequant_into(&mut deq2);
            if deq.iter().zip(deq2.iter()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return false;
            }

            // fused perturb == dequant-then-fma (the lane axpy kernel)
            let mut fused = vec![0.0f32; d];
            store.perturb_into(tau, &v, &mut fused);
            let mut reference = vec![0.0f32; d];
            fma_axpy_into(&mut reference, &deq, tau, &v);
            if fused.iter().zip(reference.iter()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return false;
            }

            // windowed perturb agrees with the full fused image
            let start = rng.below(d as u64) as usize;
            let m = 1 + rng.below((d - start) as u64) as usize;
            let mut win = vec![0.0f32; m];
            store.perturb_range_into(start, tau, &v[start..start + m], &mut win);
            if win
                .iter()
                .zip(fused[start..start + m].iter())
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return false;
            }
        }
        true
    });
}
