//! The forward-only MLP fine-tuning oracle end to end (DESIGN.md §12):
//! analytic gradients vs finite differences, 1-vs-8-thread and
//! materialized-vs-streamed bitwise determinism, layout/`.zock`
//! compatibility, and mid-epoch checkpoint/resume over the
//! epoch-shuffled minibatch stream.  CI runs this suite under both
//! `ZO_PROBE_STORAGE` modes.

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::data::Batch;
use zo_ldsd::eval::MlpEvaluator;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::{views, Activation, MlpSpec};
use zo_ldsd::oracle::{GradOracle, MlpOracle, Oracle};
use zo_ldsd::probe::{BoxedSampler, MaterializedProbes, ProbeLayout, ProbeSource, StreamedProbes};
use zo_ldsd::sampler::{LdsdConfig, LdsdSampler};
use zo_ldsd::train::{
    CheckpointConfig, EstimatorKind, GemmMode, ParamStoreMode, ProbeStorage, SamplerKind,
    ShuffleSpec, TrainConfig, Trainer,
};

fn mini_corpus() -> Corpus {
    Corpus::new(CorpusSpec::default_mini()).unwrap()
}

/// A dense random feature minibatch delivered through `Batch.features`
/// (the LIBSVM-style input path).
fn feature_batch(in_dim: usize, n: usize, n_classes: u64, seed: u64) -> Batch {
    let mut rng = zo_ldsd::rng::Rng::new(seed);
    let mut data = vec![0.0f32; n * in_dim];
    rng.fill_normal(&mut data);
    let labels: Vec<i32> = (0..n).map(|_| rng.below(n_classes) as i32).collect();
    Batch::from_features(in_dim, data, labels)
}

fn train_cfg(k: usize, budget: u64, seed: u64, storage: ProbeStorage) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k,
            sampler: SamplerKind::Ldsd(LdsdConfig::default()),
        },
        optimizer: "zo_sgd_plain".into(),
        lr: 0.05,
        tau: 1e-3,
        budget,
        eval_every: 0,
        eval_batches: 2,
        cosine_schedule: false,
        seed,
        probe_dispatch: Default::default(),
        probe_storage: storage,
        checkpoint: CheckpointConfig::default(),
        shuffle: Some(ShuffleSpec { n_train: 24 }),
        param_store: ParamStoreMode::F32,
        gemm: GemmMode::Blocked,
    }
}

fn mlp_oracle(seed: u64) -> MlpOracle {
    let spec = MlpSpec::new(32, vec![16], 2, Activation::Tanh).unwrap();
    MlpOracle::from_seed(spec, seed)
}

/// Analytic backprop vs central finite differences on a tiny
/// architecture — the correctness anchor for the forward core.
#[test]
fn mlp_grad_matches_finite_difference() {
    let spec = MlpSpec::new(9, vec![7, 5], 3, Activation::Tanh).unwrap();
    let mut o = MlpOracle::from_seed(spec.clone(), 2);
    o.set_batch(&feature_batch(9, 6, 3, 11)).unwrap();
    let d = o.dim();
    let mut g = vec![0.0f32; d];
    o.grad(&mut g).unwrap();
    let h = 1e-3f32;
    let mut checked = 0usize;
    for i in (0..d).step_by((d / 23).max(1)) {
        let mut e = vec![0.0f32; d];
        e[i] = 1.0;
        let fp = o.loss_dir(&e, h).unwrap();
        let fm = o.loss_dir(&e, -h).unwrap();
        let fd = (fp - fm) / (2.0 * h as f64);
        assert!(
            (fd - g[i] as f64).abs() < 2e-2 * (1.0 + g[i].abs() as f64),
            "coord {i}: fd {fd} vs grad {}",
            g[i]
        );
        checked += 1;
    }
    assert!(checked >= 10, "sampled too few coordinates ({checked})");
}

/// The vectorized batch path is bitwise `loss_dir`'s loop — same
/// perturbation expression, same forward — at any thread count.
#[test]
fn mlp_loss_k_bitwise_matches_loss_dir_at_any_thread_count() {
    let batch = mini_corpus().train_batch(3, 8);
    let mut reference = mlp_oracle(5);
    reference.set_batch(&batch).unwrap();
    let d = reference.dim();
    let k = 5;
    let mut rng = zo_ldsd::rng::Rng::new(21);
    let mut dirs = vec![0.0f32; k * d];
    rng.fill_normal(&mut dirs);
    let looped: Vec<f64> = (0..k)
        .map(|i| reference.loss_dir(&dirs[i * d..(i + 1) * d], 1e-2).unwrap())
        .collect();
    for threads in [1usize, 8] {
        let mut o = mlp_oracle(5);
        o.set_exec(ExecContext::new(threads).with_shard_len(64));
        o.set_batch(&batch).unwrap();
        let batched = o.loss_k(&dirs, k, 1e-2).unwrap();
        for (i, (b, l)) in batched.iter().zip(looped.iter()).enumerate() {
            assert_eq!(
                b.to_bits(),
                l.to_bits(),
                "threads {threads}, probe {i}: {b} vs {l}"
            );
        }
    }
}

/// Streamed (seed-replay) probe evaluation is bitwise the materialized
/// slice path, for 1 and 4 workers.
#[test]
fn mlp_streamed_loss_probes_bitwise_matches_materialized() {
    let batch = mini_corpus().train_batch(0, 8);
    let k = 4;
    let tau = 1e-2f32;
    let d = mlp_oracle(0).dim();
    for threads in [1usize, 4] {
        let ctx = ExecContext::new(threads).with_shard_len(37);
        let sampler = |seed| -> BoxedSampler {
            Box::new(LdsdSampler::new(d, seed, LdsdConfig::default()))
        };
        let mut mat = MaterializedProbes::new(sampler(9), ProbeLayout::Direct, k);
        mat.set_exec(ctx.clone());
        let mut st = StreamedProbes::new(sampler(9), ProbeLayout::Direct, k);
        st.set_exec(ctx.clone());
        mat.advance();
        st.advance();
        let mut o1 = mlp_oracle(7);
        o1.set_exec(ctx.clone());
        o1.set_batch(&batch).unwrap();
        let mut o2 = mlp_oracle(7);
        o2.set_exec(ctx);
        o2.set_batch(&batch).unwrap();
        let mut l1 = Vec::new();
        let mut l2 = Vec::new();
        o1.loss_probes(&mat, k, tau, &mut l1).unwrap();
        o2.loss_probes(&st, k, tau, &mut l2).unwrap();
        assert_eq!(o1.oracle_calls(), o2.oracle_calls());
        assert_eq!(l1.len(), k);
        for (a, b) in l1.iter().zip(l2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}: {a} vs {b}");
        }
    }
}

/// The acceptance run: LDSD over the MLP with streamed probes on the
/// shuffled stream walks a bitwise-identical trajectory on 1 and 8
/// threads — and matches the materialized run bit for bit.
#[test]
fn mlp_train_bitwise_identical_across_threads_and_storage() {
    let run = |threads: usize, storage: ProbeStorage| {
        let mut t = Trainer::with_exec(
            train_cfg(5, 120, 13, storage),
            mlp_oracle(13),
            mini_corpus(),
            ExecContext::new(threads).with_shard_len(64),
        )
        .unwrap();
        let out = t.run(None).unwrap();
        // params_into, not params(): agnostic to a ZO_PARAM_STORE-forced
        // quantized store (params() has no f32 slice to return there)
        let mut p = Vec::new();
        t.oracle().params_into(&mut p);
        (out.loss_curve, p)
    };
    let (c1, p1) = run(1, ProbeStorage::Streamed);
    let (c8, p8) = run(8, ProbeStorage::Streamed);
    let (cm, pm) = run(8, ProbeStorage::Materialized);
    assert_eq!(c1.len(), c8.len());
    assert_eq!(c1.len(), cm.len());
    for (i, ((a1, l1), ((a8, l8), (am, lm)))) in
        c1.iter().zip(c8.iter().zip(cm.iter())).enumerate()
    {
        assert_eq!(a1, a8, "call axis diverged at step {i}");
        assert_eq!(a1, am, "storage call axis diverged at step {i}");
        assert_eq!(l1.to_bits(), l8.to_bits(), "thread loss diverged at {i}");
        assert_eq!(l1.to_bits(), lm.to_bits(), "storage loss diverged at {i}");
    }
    for (i, (a, (b, c))) in p1.iter().zip(p8.iter().zip(pm.iter())).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "thread params diverged at {i}");
        assert_eq!(a.to_bits(), c.to_bits(), "storage params diverged at {i}");
    }
}

/// Mid-epoch interrupt + resume over the shuffled stream: with
/// `n_train = 24` and batch 8 an epoch is 3 steps, so preempting at step
/// 4 stops one step into epoch 2 — the resumed session must replay the
/// identical shuffled batches via the restored batch cursor.
#[test]
fn mlp_checkpoint_resume_mid_epoch_is_bitwise_identical() {
    let dir = std::env::temp_dir().join(format!(
        "zo_mlp_resume_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let ctx = || ExecContext::new(4).with_shard_len(64);
    let storage = ProbeStorage::Auto;

    let mut full =
        Trainer::with_exec(train_cfg(5, 120, 29, storage), mlp_oracle(29), mini_corpus(), ctx())
            .unwrap();
    let full_out = full.run(None).unwrap();
    assert!(full_out.completed);

    let ck = |resume: bool, max_run_steps: u64| CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 2,
        resume,
        max_run_steps,
    };
    let mut first = Trainer::with_exec(
        TrainConfig { checkpoint: ck(false, 4), ..train_cfg(5, 120, 29, storage) },
        mlp_oracle(29),
        mini_corpus(),
        ctx(),
    )
    .unwrap();
    let partial = first.run(None).unwrap();
    assert!(!partial.completed);
    assert_eq!(partial.steps, 4);
    assert_eq!(first.progress().data_cursor, 32, "mid-epoch cursor");
    drop(first);

    let mut second = Trainer::with_exec(
        TrainConfig { checkpoint: ck(true, 0), ..train_cfg(5, 120, 29, storage) },
        mlp_oracle(29),
        mini_corpus(),
        ctx(),
    )
    .unwrap();
    let resumed = second.run(None).unwrap();
    assert!(resumed.completed);
    assert_eq!(resumed.steps, full_out.steps);
    assert_eq!(resumed.loss_curve.len(), full_out.loss_curve.len());
    for ((ca, la), (cb, lb)) in
        full_out.loss_curve.iter().zip(resumed.loss_curve.iter())
    {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits(), "{la} vs {lb}");
    }
    let (mut pa, mut pb) = (Vec::new(), Vec::new());
    full.oracle().params_into(&mut pa);
    second.oracle().params_into(&mut pb);
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Training actually optimizes: the loss of a *fixed* probe batch —
/// evaluated at the initial and the trained parameters, so minibatch
/// noise cannot blur the comparison — drops over a 3000-forward LDSD
/// run, and the evaluator scores the trained parameters
/// deterministically.
#[test]
fn mlp_training_reduces_loss_end_to_end() {
    let spec = MlpSpec::new(16, vec![8], 2, Activation::Tanh).unwrap();
    let corpus = mini_corpus();
    let fixed = corpus.train_batch(0, 8);
    let zeros = vec![0.0f32; spec.dim()];

    let mut before_oracle = MlpOracle::from_seed(spec.clone(), 3);
    before_oracle.set_batch(&fixed).unwrap();
    let before = before_oracle.loss_dir(&zeros, 0.0).unwrap();

    let mut cfg = train_cfg(5, 3000, 3, ProbeStorage::Auto);
    cfg.lr = 0.02;
    cfg.shuffle = Some(ShuffleSpec { n_train: 64 });
    let mut t =
        Trainer::new(cfg, MlpOracle::from_seed(spec.clone(), 3), corpus).unwrap();
    let evaluator = MlpEvaluator::new(spec.clone(), 32);
    let out = t.run(Some(&evaluator)).unwrap();
    assert_eq!(out.oracle_calls, 3000);
    assert!(out.loss_curve.iter().all(|(_, l)| l.is_finite()));
    assert!((0.0..=1.0).contains(&out.final_accuracy));

    t.oracle_mut().set_batch(&fixed).unwrap();
    let after = t.oracle_mut().loss_dir(&zeros, 0.0).unwrap();
    assert!(
        after < before,
        "training must reduce the fixed-batch loss: {before} -> {after}"
    );

    // same run again: bitwise-identical outcome (everything is seeded)
    let mut cfg2 = train_cfg(5, 3000, 3, ProbeStorage::Auto);
    cfg2.lr = 0.02;
    cfg2.shuffle = Some(ShuffleSpec { n_train: 64 });
    let mut t2 = Trainer::new(
        cfg2,
        MlpOracle::from_seed(spec.clone(), 3),
        mini_corpus(),
    )
    .unwrap();
    let out2 = t2.run(Some(&MlpEvaluator::new(spec, 32))).unwrap();
    assert_eq!(out.final_accuracy.to_bits(), out2.final_accuracy.to_bits());
    for ((ca, la), (cb, lb)) in out.loss_curve.iter().zip(out2.loss_curve.iter()) {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits());
    }
}

/// The MLP's flat parameter vector rides the existing layout manifest
/// machinery: `model::views` slices it and `.zock` checkpoints
/// round-trip it unchanged.
#[test]
fn mlp_layout_views_and_zock_checkpoint_apply_unchanged() {
    let spec = MlpSpec::new(12, vec![6, 4], 3, Activation::Relu).unwrap();
    let params = spec.init_params(8);
    let layout = spec.layout();
    let v = views(&params, &layout).unwrap();
    assert_eq!(v.len(), 6); // (w, b) x 3 layers
    assert_eq!(v[0].name, "layer0.w");
    assert_eq!(v[0].shape, &[6, 12]);
    assert_eq!(v[4].shape, &[3, 4]);
    let total: usize = layout.iter().map(|l| l.len).sum();
    assert_eq!(total, spec.dim());

    let ck = zo_ldsd::model::Checkpoint {
        model: spec.label(),
        mode: "ft".into(),
        step: 5,
        oracle_calls: 30,
        data: params.clone(),
    };
    let dir = std::env::temp_dir().join(format!("zo_mlp_zock_{}", std::process::id()));
    let path = dir.join("mlp.zock");
    ck.save(&path).unwrap();
    let back = zo_ldsd::model::Checkpoint::load(&path).unwrap();
    assert_eq!(back.data.len(), spec.dim());
    for (a, b) in params.iter().zip(back.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}
