//! The transformer + LoRA oracle end to end (DESIGN.md §13): analytic
//! (JVP) directional derivatives vs finite differences on the LoRA
//! subspace, 1-vs-8-thread and materialized-vs-streamed bitwise
//! determinism, mid-run checkpoint/resume over the shuffled minibatch
//! stream, and LoRA layout/`.zock` compatibility — the same property
//! matrix `mlp_train.rs` pins for the MLP oracle.  CI runs this suite
//! under both `ZO_PROBE_STORAGE` modes.

use zo_ldsd::config::TrainMode;
use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::eval::TransformerEvaluator;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::{views, Pool, TransformerSpec};
use zo_ldsd::oracle::{Oracle, TransformerOracle};
use zo_ldsd::probe::{
    BoxedSampler, MaterializedProbes, ProbeLayout, ProbeSource, StreamedProbes,
};
use zo_ldsd::sampler::{LdsdConfig, LdsdSampler};
use zo_ldsd::train::{
    CheckpointConfig, EstimatorKind, GemmMode, ParamStoreMode, ProbeStorage, SamplerKind,
    ShuffleSpec, TrainConfig, Trainer,
};

/// A corpus small enough for the tiny architecture below (vocab 64,
/// sequences of 8 tokens).
fn tiny_corpus() -> Corpus {
    Corpus::new(CorpusSpec {
        vocab: 64,
        seq: 8,
        lexicon: 16,
        min_len: 4,
        signal_min: 1,
        signal_max: 3,
        ..CorpusSpec::default_mini()
    })
    .unwrap()
}

/// 2-layer, 2-head, d_model 16 decoder with rank-2 q/v adapters:
/// d_lora = 290 trainables against d_ft = 5666 frozen base weights.
fn tiny_spec() -> TransformerSpec {
    TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, Pool::Cls, 2).unwrap()
}

fn lora_oracle(seed: u64) -> TransformerOracle {
    TransformerOracle::from_seed(tiny_spec(), TrainMode::Lora, seed)
}

fn train_cfg(k: usize, budget: u64, seed: u64, storage: ProbeStorage) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k,
            sampler: SamplerKind::Ldsd(LdsdConfig::default()),
        },
        optimizer: "zo_sgd_plain".into(),
        lr: 0.05,
        tau: 1e-3,
        budget,
        eval_every: 0,
        eval_batches: 2,
        cosine_schedule: false,
        seed,
        probe_dispatch: Default::default(),
        probe_storage: storage,
        checkpoint: CheckpointConfig::default(),
        shuffle: Some(ShuffleSpec { n_train: 24 }),
        param_store: ParamStoreMode::F32,
        gemm: GemmMode::Blocked,
    }
}

/// The f64 forward-mode JVP vs central finite differences along random
/// directions of the LoRA subspace — the correctness anchor tying the
/// perturbation geometry to the actual loss surface.
#[test]
fn jvp_matches_finite_difference_on_the_lora_subspace() {
    let mut o = lora_oracle(2);
    o.set_batch(&tiny_corpus().train_batch(1, 6)).unwrap();
    let d = o.dim();
    assert_eq!(d, tiny_spec().d_lora());
    let mut rng = zo_ldsd::rng::Rng::new(17);
    for trial in 0..4 {
        let mut dir = vec![0.0f32; d];
        rng.fill_normal(&mut dir);
        let (loss, analytic) = o.dir_derivative(&dir).unwrap();
        assert!(loss.is_finite());
        let h = 1e-3f32;
        let fp = o.loss_dir(&dir, h).unwrap();
        let fm = o.loss_dir(&dir, -h).unwrap();
        let fd = (fp - fm) / (2.0 * h as f64);
        assert!(
            (fd - analytic).abs() < 2e-2 * (1.0 + analytic.abs()),
            "trial {trial}: fd {fd} vs analytic {analytic}"
        );
    }
}

/// FT mode exposes the full d_ft subspace through the same JVP.
#[test]
fn jvp_matches_finite_difference_in_ft_mode() {
    let mut o = TransformerOracle::from_seed(tiny_spec(), TrainMode::Ft, 4);
    o.set_batch(&tiny_corpus().train_batch(0, 4)).unwrap();
    let d = o.dim();
    assert_eq!(d, tiny_spec().d_ft());
    let mut dir = vec![0.0f32; d];
    zo_ldsd::rng::Rng::new(23).fill_normal(&mut dir);
    let (_, analytic) = o.dir_derivative(&dir).unwrap();
    let h = 1e-3f32;
    let fd = (o.loss_dir(&dir, h).unwrap() - o.loss_dir(&dir, -h).unwrap())
        / (2.0 * h as f64);
    assert!(
        (fd - analytic).abs() < 2e-2 * (1.0 + analytic.abs()),
        "fd {fd} vs analytic {analytic}"
    );
}

/// Streamed (seed-replay) probe evaluation is bitwise the materialized
/// slice path, for 1 and 4 workers, on the LoRA subspace (d = 290 is far
/// below — and misaligned with — the 64-element shard length).
#[test]
fn transformer_streamed_loss_probes_bitwise_matches_materialized() {
    let batch = tiny_corpus().train_batch(0, 6);
    let k = 4;
    let tau = 1e-2f32;
    let d = lora_oracle(0).dim();
    for threads in [1usize, 4] {
        let ctx = ExecContext::new(threads).with_shard_len(64);
        let sampler = |seed| -> BoxedSampler {
            Box::new(LdsdSampler::new(d, seed, LdsdConfig::default()))
        };
        let mut mat = MaterializedProbes::new(sampler(9), ProbeLayout::Direct, k);
        mat.set_exec(ctx.clone());
        let mut st = StreamedProbes::new(sampler(9), ProbeLayout::Direct, k);
        st.set_exec(ctx.clone());
        mat.advance();
        st.advance();
        let mut o1 = lora_oracle(7);
        o1.set_exec(ctx.clone());
        o1.set_batch(&batch).unwrap();
        let mut o2 = lora_oracle(7);
        o2.set_exec(ctx);
        o2.set_batch(&batch).unwrap();
        let mut l1 = Vec::new();
        let mut l2 = Vec::new();
        o1.loss_probes(&mat, k, tau, &mut l1).unwrap();
        o2.loss_probes(&st, k, tau, &mut l2).unwrap();
        assert_eq!(o1.oracle_calls(), o2.oracle_calls());
        assert_eq!(l1.len(), k);
        for (a, b) in l1.iter().zip(l2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}: {a} vs {b}");
        }
    }
}

/// The acceptance run: LDSD over the LoRA subspace with streamed probes
/// on the shuffled stream walks a bitwise-identical trajectory on 1 and
/// 8 threads — and matches the materialized run bit for bit.
#[test]
fn transformer_train_bitwise_identical_across_threads_and_storage() {
    let run = |threads: usize, storage: ProbeStorage| {
        let mut t = Trainer::with_exec(
            train_cfg(5, 60, 13, storage),
            lora_oracle(13),
            tiny_corpus(),
            ExecContext::new(threads).with_shard_len(64),
        )
        .unwrap();
        let out = t.run(None).unwrap();
        // params_into, not params(): agnostic to a ZO_PARAM_STORE-forced
        // quantized store (params() has no f32 slice to return there)
        let mut p = Vec::new();
        t.oracle().params_into(&mut p);
        (out.loss_curve, p)
    };
    let (c1, p1) = run(1, ProbeStorage::Streamed);
    let (c8, p8) = run(8, ProbeStorage::Streamed);
    let (cm, pm) = run(8, ProbeStorage::Materialized);
    assert_eq!(c1.len(), c8.len());
    assert_eq!(c1.len(), cm.len());
    for (i, ((a1, l1), ((a8, l8), (am, lm)))) in
        c1.iter().zip(c8.iter().zip(cm.iter())).enumerate()
    {
        assert_eq!(a1, a8, "call axis diverged at step {i}");
        assert_eq!(a1, am, "storage call axis diverged at step {i}");
        assert_eq!(l1.to_bits(), l8.to_bits(), "thread loss diverged at {i}");
        assert_eq!(l1.to_bits(), lm.to_bits(), "storage loss diverged at {i}");
    }
    for (i, (a, (b, c))) in p1.iter().zip(p8.iter().zip(pm.iter())).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "thread params diverged at {i}");
        assert_eq!(a.to_bits(), c.to_bits(), "storage params diverged at {i}");
    }
}

/// Mid-epoch interrupt + resume over the shuffled stream: with
/// `n_train = 24` and batch 8 an epoch is 3 steps, so preempting at step
/// 4 stops one step into epoch 2 — the resumed session must replay the
/// identical shuffled batches via the restored batch cursor.
#[test]
fn transformer_checkpoint_resume_mid_epoch_is_bitwise_identical() {
    let dir = std::env::temp_dir().join(format!(
        "zo_tfm_resume_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let ctx = || ExecContext::new(4).with_shard_len(64);
    let storage = ProbeStorage::Auto;

    let mut full = Trainer::with_exec(
        train_cfg(5, 60, 29, storage),
        lora_oracle(29),
        tiny_corpus(),
        ctx(),
    )
    .unwrap();
    let full_out = full.run(None).unwrap();
    assert!(full_out.completed);

    let ck = |resume: bool, max_run_steps: u64| CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 2,
        resume,
        max_run_steps,
    };
    let mut first = Trainer::with_exec(
        TrainConfig { checkpoint: ck(false, 4), ..train_cfg(5, 60, 29, storage) },
        lora_oracle(29),
        tiny_corpus(),
        ctx(),
    )
    .unwrap();
    let partial = first.run(None).unwrap();
    assert!(!partial.completed);
    assert_eq!(partial.steps, 4);
    assert_eq!(first.progress().data_cursor, 32, "mid-epoch cursor");
    drop(first);

    let mut second = Trainer::with_exec(
        TrainConfig { checkpoint: ck(true, 0), ..train_cfg(5, 60, 29, storage) },
        lora_oracle(29),
        tiny_corpus(),
        ctx(),
    )
    .unwrap();
    let resumed = second.run(None).unwrap();
    assert!(resumed.completed);
    assert_eq!(resumed.steps, full_out.steps);
    assert_eq!(resumed.loss_curve.len(), full_out.loss_curve.len());
    for ((ca, la), (cb, lb)) in
        full_out.loss_curve.iter().zip(resumed.loss_curve.iter())
    {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits(), "{la} vs {lb}");
    }
    let (mut pa, mut pb) = (Vec::new(), Vec::new());
    full.oracle().params_into(&mut pa);
    second.oracle().params_into(&mut pb);
    for (a, b) in pa.iter().zip(pb.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Training actually optimizes: the loss of a *fixed* batch — evaluated
/// at the initial and the trained adapters, so minibatch noise cannot
/// blur the comparison — drops over a 3000-forward LDSD run, and the
/// whole run repeats bitwise (everything is seeded).
#[test]
fn transformer_training_reduces_loss_end_to_end() {
    let corpus = tiny_corpus();
    let fixed = corpus.train_batch(0, 8);
    let zeros = vec![0.0f32; lora_oracle(3).dim()];

    let mut before_oracle = lora_oracle(3);
    before_oracle.set_batch(&fixed).unwrap();
    let before = before_oracle.loss_dir(&zeros, 0.0).unwrap();

    let run = || {
        let mut cfg = train_cfg(5, 3000, 3, ProbeStorage::Auto);
        cfg.lr = 0.02;
        cfg.shuffle = Some(ShuffleSpec { n_train: 64 });
        let mut t = Trainer::new(cfg, lora_oracle(3), tiny_corpus()).unwrap();
        let evaluator = TransformerEvaluator::new(
            tiny_spec(),
            TrainMode::Lora,
            lora_oracle(3).base().to_vec(),
            16,
        )
        .unwrap();
        let out = t.run(Some(&evaluator)).unwrap();
        (out, t)
    };
    let (out, mut t) = run();
    assert_eq!(out.oracle_calls, 3000);
    assert!(out.loss_curve.iter().all(|(_, l)| l.is_finite()));
    assert!((0.0..=1.0).contains(&out.final_accuracy));

    t.oracle_mut().set_batch(&fixed).unwrap();
    let after = t.oracle_mut().loss_dir(&zeros, 0.0).unwrap();
    assert!(
        after < before,
        "training must reduce the fixed-batch loss: {before} -> {after}"
    );

    let (out2, _) = run();
    assert_eq!(out.final_accuracy.to_bits(), out2.final_accuracy.to_bits());
    for ((ca, la), (cb, lb)) in out.loss_curve.iter().zip(out2.loss_curve.iter()) {
        assert_eq!(ca, cb);
        assert_eq!(la.to_bits(), lb.to_bits());
    }
}

/// The LoRA trainable vector rides the existing layout manifest
/// machinery: `model::views` slices it by the python ABI names and
/// `.zock` checkpoints round-trip it unchanged.
#[test]
fn lora_layout_views_and_zock_checkpoint_apply_unchanged() {
    let spec = tiny_spec();
    let base = spec.init_base(8);
    let lora = spec.init_lora(8, Some(&base));

    let layout = spec.lora_layout();
    let v = views(&lora, &layout).unwrap();
    // 4 adapter factors per layer (q and v, A and B each) + head.w/head.b
    assert_eq!(v.len(), spec.n_layers * 4 + 2);
    assert_eq!(v[0].name, "layer0.lora_q.a");
    assert_eq!(v[0].shape, &[spec.d_model, spec.lora_rank]);
    assert_eq!(v[1].name, "layer0.lora_q.b");
    assert_eq!(v[1].shape, &[spec.lora_rank, spec.d_model]);
    assert_eq!(v[v.len() - 2].name, "head.w");
    assert_eq!(v[v.len() - 2].shape, &[spec.d_model, spec.n_classes]);
    let total: usize = layout.iter().map(|l| l.len).sum();
    assert_eq!(total, spec.d_lora());

    // the FT layout covers the full base the same way
    let ft_total: usize = spec.ft_layout().iter().map(|l| l.len).sum();
    assert_eq!(ft_total, spec.d_ft());

    let ck = zo_ldsd::model::Checkpoint {
        model: spec.label(),
        mode: "lora".into(),
        step: 5,
        oracle_calls: 30,
        data: lora.clone(),
    };
    let dir = std::env::temp_dir().join(format!("zo_tfm_zock_{}", std::process::id()));
    let path = dir.join("tfm.zock");
    ck.save(&path).unwrap();
    let back = zo_ldsd::model::Checkpoint::load(&path).unwrap();
    assert_eq!(back.mode, "lora");
    assert_eq!(back.data.len(), spec.d_lora());
    for (a, b) in lora.iter().zip(back.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}
