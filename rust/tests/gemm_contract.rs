//! The GEMM tiling contract (DESIGN.md §15) end to end: the cache-blocked
//! packed kernel must produce *identical bits* to the row-at-a-time
//! reference loop — at any (m, k, n), any tile size, either lane mode,
//! any thread count — because both walk the same ascending-k term
//! sequence per output element.  The suite pins that equality at three
//! levels: the raw kernels on randomized shapes, the batched
//! transformer/MLP forwards (FT + LoRA, provided packs + per-worker
//! repacks), and whole training trajectories (threads x probe storage x
//! parameter store) forced onto each engine.  CI runs the GEMM-heavy
//! suites under both `ZO_GEMM` arms; this file carries the cross-engine
//! assertions themselves.

use zo_ldsd::config::TrainMode;
use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::model::transformer::batch_loss;
use zo_ldsd::model::{Activation, MlpSpec, Pool, TransformerSpec, TransformerState};
use zo_ldsd::oracle::{MlpOracle, Oracle, TransformerOracle};
use zo_ldsd::proptest::{check, U64Range};
use zo_ldsd::rng::Rng;
use zo_ldsd::sampler::LdsdConfig;
use zo_ldsd::tensor::gemm::{
    force_gemm_mode, gemm_blocked_narrow, gemm_blocked_with, gemm_reference, PackedB, MR, NR,
};
use zo_ldsd::tensor::lanes::{force_mode, LaneMode};
use zo_ldsd::tensor::{GemmMode, Matrix};
use zo_ldsd::train::{
    CheckpointConfig, EstimatorKind, ParamStoreMode, ProbeStorage, SamplerKind, ShuffleSpec,
    TrainConfig, Trainer,
};

/// The lane/GEMM mode overrides are process-global; tests that force them
/// serialize here so a concurrently running test never observes a
/// half-flipped configuration.  (Results would still be identical — the
/// contract — but the comparisons below are only meaningful when each
/// arm really ran the engine it claims.)
static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn mode_lock() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Randomized kernel-level equality: blocked == reference bitwise for
/// random (m, k, n), every tile-size combination (including degenerate
/// 1-wide panels and m-tiles larger than MR), the narrow unpacked path,
/// and both lane modes.
#[test]
fn prop_blocked_matches_reference_bitwise() {
    let _guard = mode_lock();
    check("gemm_blocked_bitwise", &U64Range(0, u64::MAX / 2), 40, |seed| {
        let mut rng = Rng::new(*seed);
        let m = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(40) as usize;
        let n = 1 + rng.below(2 * NR as u64 + 5) as usize;
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        rng.fill_normal(&mut bias);
        let biases: [Option<&[f32]>; 2] = [Some(&bias), None];

        let mut ok = true;
        for lane in [LaneMode::Scalar, LaneMode::Wide] {
            force_mode(Some(lane));
            for bias_opt in biases {
                let mut want = vec![0.0f32; m * n];
                gemm_reference(&a, m, k, &b, n, bias_opt, &mut want);
                for nr in [1usize, 3, 8, NR] {
                    let pb = PackedB::pack_with_nr(&b, k, n, nr);
                    for mr in [1usize, 2, MR, 11] {
                        let mut got = vec![0.0f32; m * n];
                        let mut ctile = vec![0.0f32; mr * nr];
                        gemm_blocked_with(&a, m, k, &pb, bias_opt, &mut got, mr, &mut ctile);
                        ok &= bits_eq(&got, &want);
                    }
                }
                if n <= NR {
                    let mut got = vec![0.0f32; m * n];
                    gemm_blocked_narrow(&a, m, k, &b, n, bias_opt, &mut got);
                    ok &= bits_eq(&got, &want);
                }
            }
        }
        force_mode(None);
        ok
    });
}

fn tiny_corpus() -> Corpus {
    Corpus::new(CorpusSpec {
        vocab: 64,
        seq: 8,
        lexicon: 16,
        min_len: 4,
        signal_min: 1,
        signal_max: 3,
        ..CorpusSpec::default_mini()
    })
    .unwrap()
}

fn tiny_spec() -> TransformerSpec {
    TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, Pool::Cls, 2).unwrap()
}

/// The transformer batched forward under the blocked engine returns the
/// per-example reference fold's exact bits — FT and LoRA, with the loss
/// compared as full f64 bit patterns, across repeated evaluations
/// through the same reused state (arena/pack reuse cannot leak bits).
#[test]
fn transformer_batch_loss_identical_bits_across_engines() {
    let _guard = mode_lock();
    let spec = tiny_spec();
    let mut rng = Rng::new(41);
    let mut base = vec![0.0f32; spec.d_ft()];
    let mut lora = vec![0.0f32; spec.d_lora()];
    rng.fill_normal(&mut base);
    rng.fill_normal(&mut lora);
    // keep the random base in a numerically sane regime for layernorm
    for v in base.iter_mut() {
        *v *= 0.05;
    }
    for v in lora.iter_mut() {
        *v *= 0.05;
    }
    let batch = tiny_corpus().train_batch(2, 6);

    let eval = |lora_opt: Option<&[f32]>| {
        let mut state = TransformerState::new(&spec);
        batch_loss(
            &spec, &base, lora_opt, &batch.ids, &batch.mask, batch.seq, &batch.labels,
            &mut state,
        )
    };
    for lora_opt in [None, Some(&lora[..])] {
        force_gemm_mode(Some(GemmMode::Reference));
        let want = eval(lora_opt);
        force_gemm_mode(Some(GemmMode::Blocked));
        let got = eval(lora_opt);
        // repeat through one reused state: arena growth and pack reuse
        // must not perturb the bits
        let again = {
            let mut state = TransformerState::new(&spec);
            let first = batch_loss(
                &spec, &base, lora_opt, &batch.ids, &batch.mask, batch.seq, &batch.labels,
                &mut state,
            );
            let second = batch_loss(
                &spec, &base, lora_opt, &batch.ids, &batch.mask, batch.seq, &batch.labels,
                &mut state,
            );
            assert_eq!(first.to_bits(), second.to_bits(), "state reuse changed bits");
            second
        };
        force_gemm_mode(None);
        assert!(want.is_finite());
        assert_eq!(
            want.to_bits(),
            got.to_bits(),
            "lora={}: blocked {got} vs reference {want}",
            lora_opt.is_some()
        );
        assert_eq!(want.to_bits(), again.to_bits());
    }
}

/// Oracle-level equality where the pack cache actually lives: the LoRA
/// oracle packs its frozen base once per run, the FT oracle repacks per
/// evaluation in each worker — both must match the reference engine
/// bitwise through the vectorized `loss_k`, at 1 and 8 threads.
#[test]
fn transformer_oracle_loss_k_identical_bits_across_engines_and_threads() {
    let _guard = mode_lock();
    let batch = tiny_corpus().train_batch(0, 6);
    let k = 4usize;
    let tau = 1e-2f32;
    for mode in [TrainMode::Lora, TrainMode::Ft] {
        let d = match mode {
            TrainMode::Lora => tiny_spec().d_lora(),
            TrainMode::Ft => tiny_spec().d_ft(),
        };
        let mut dirs = vec![0.0f32; k * d];
        Rng::new(29).fill_normal(&mut dirs);
        for threads in [1usize, 8] {
            let run = |gmode: GemmMode| {
                force_gemm_mode(Some(gmode));
                let mut o = TransformerOracle::from_seed(tiny_spec(), mode, 7);
                o.set_exec(ExecContext::new(threads).with_shard_len(64));
                o.set_batch(&batch).unwrap();
                let losses = o.loss_k(&dirs, k, tau).unwrap();
                force_gemm_mode(None);
                losses
            };
            let want = run(GemmMode::Reference);
            let got = run(GemmMode::Blocked);
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{mode:?} t{threads} probe {i}: blocked {b} vs reference {a}"
                );
            }
        }
    }
}

/// The MLP batched forward under the blocked engine preserves the
/// per-unit closed form (`bias[j] + dot_lanes(w_j, x)`) bitwise.
#[test]
fn mlp_batch_loss_identical_bits_across_engines() {
    let _guard = mode_lock();
    let spec = MlpSpec::new(24, vec![48, 40], 3, Activation::Tanh).unwrap();
    let mut rng = Rng::new(53);
    let mut params = vec![0.0f32; spec.dim()];
    rng.fill_normal(&mut params);
    let rows = 70usize; // spans multiple MB_LANES row blocks plus a tail
    let mut feats = Matrix::zeros(rows, 24);
    rng.fill_normal(&mut feats.data);
    let labels: Vec<i32> = (0..rows).map(|r| (r % 3) as i32).collect();

    let eval = |gmode: GemmMode| {
        force_gemm_mode(Some(gmode));
        let mut state = zo_ldsd::model::MlpState::new(&spec);
        let loss = zo_ldsd::model::mlp::batch_loss(&spec, &params, &feats, &labels, &mut state);
        force_gemm_mode(None);
        loss
    };
    let want = eval(GemmMode::Reference);
    let got = eval(GemmMode::Blocked);
    assert!(want.is_finite());
    assert_eq!(want.to_bits(), got.to_bits(), "blocked {got} vs reference {want}");
}

fn tfm_cfg(storage: ProbeStorage, seed: u64) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k: 5,
            sampler: SamplerKind::Ldsd(LdsdConfig::default()),
        },
        optimizer: "zo_sgd_plain".into(),
        lr: 0.05,
        tau: 1e-3,
        budget: 48,
        eval_every: 0,
        eval_batches: 2,
        cosine_schedule: false,
        seed,
        probe_dispatch: Default::default(),
        probe_storage: storage,
        checkpoint: CheckpointConfig::default(),
        shuffle: Some(ShuffleSpec { n_train: 24 }),
        param_store: ParamStoreMode::F32,
        gemm: GemmMode::Blocked,
    }
}

/// Whole training trajectories are engine-invariant: the LoRA
/// transformer run walks identical loss-curve and final-parameter bits
/// under each forced engine, across 1-vs-8 threads and both probe
/// storages.
#[test]
fn transformer_train_matrix_bitwise_identical_under_both_engines() {
    let _guard = mode_lock();
    let run = |gmode: GemmMode, threads: usize, storage: ProbeStorage| {
        force_gemm_mode(Some(gmode));
        let mut t = Trainer::with_exec(
            tfm_cfg(storage, 19),
            TransformerOracle::from_seed(tiny_spec(), TrainMode::Lora, 19),
            tiny_corpus(),
            ExecContext::new(threads).with_shard_len(64),
        )
        .unwrap();
        let out = t.run(None).unwrap();
        let mut p = Vec::new();
        t.oracle().params_into(&mut p);
        force_gemm_mode(None);
        (out.loss_curve, p)
    };
    let (c_ref, p_ref) = run(GemmMode::Reference, 1, ProbeStorage::Materialized);
    for (threads, storage) in [
        (1usize, ProbeStorage::Materialized),
        (8, ProbeStorage::Materialized),
        (1, ProbeStorage::Streamed),
        (8, ProbeStorage::Streamed),
    ] {
        let (c, p) = run(GemmMode::Blocked, threads, storage);
        assert_eq!(c_ref.len(), c.len());
        for (i, ((ca, la), (cb, lb))) in c_ref.iter().zip(c.iter()).enumerate() {
            assert_eq!(ca, cb, "t{threads} {storage:?}: call axis diverged at {i}");
            assert_eq!(
                la.to_bits(),
                lb.to_bits(),
                "t{threads} {storage:?}: loss diverged at step {i}"
            );
        }
        assert!(
            bits_eq(&p_ref, &p),
            "t{threads} {storage:?}: final params diverged from the reference engine"
        );
    }
}

/// The engine axis composes with quantized parameter storage: f32 and
/// f16 MLP runs each walk identical bits under either engine (the store
/// dequantizes to the same activations either way).
#[test]
fn mlp_train_engine_invariant_under_f32_and_f16_stores() {
    let _guard = mode_lock();
    let spec = MlpSpec::new(32, vec![16], 2, Activation::Tanh).unwrap();
    let run = |gmode: GemmMode, store: ParamStoreMode| {
        force_gemm_mode(Some(gmode));
        let cfg = TrainConfig {
            param_store: store,
            budget: 60,
            ..tfm_cfg(ProbeStorage::Materialized, 31)
        };
        let mut t = Trainer::with_exec(
            cfg,
            MlpOracle::from_seed(spec.clone(), 31),
            Corpus::new(CorpusSpec::default_mini()).unwrap(),
            ExecContext::new(4).with_shard_len(37),
        )
        .unwrap();
        let out = t.run(None).unwrap();
        let mut p = Vec::new();
        t.oracle().params_into(&mut p);
        force_gemm_mode(None);
        (out.loss_curve, p)
    };
    for store in [ParamStoreMode::F32, ParamStoreMode::F16] {
        let (c_ref, p_ref) = run(GemmMode::Reference, store);
        let (c_blk, p_blk) = run(GemmMode::Blocked, store);
        assert_eq!(c_ref.len(), c_blk.len());
        for (i, ((ca, la), (cb, lb))) in c_ref.iter().zip(c_blk.iter()).enumerate() {
            assert_eq!(ca, cb, "{}: call axis diverged at {i}", store.label());
            assert_eq!(
                la.to_bits(),
                lb.to_bits(),
                "{}: loss diverged at step {i}",
                store.label()
            );
        }
        assert!(bits_eq(&p_ref, &p_blk), "{}: final params diverged", store.label());
    }
}
