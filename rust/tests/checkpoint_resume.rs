//! Crash-safe checkpoint/resume (DESIGN.md §11): a run interrupted at any
//! step and resumed from its on-disk snapshot must reproduce the
//! uninterrupted run's trajectory **bitwise** — loss curve, oracle-call
//! axis, and final parameters — at any thread count and under both
//! probe-storage modes.  The per-(seed, step, shard) RNG cells make probe
//! streams pure functions of the restored step label, so nothing about
//! the probes themselves is (or needs to be) persisted.

use std::path::{Path, PathBuf};

use zo_ldsd::data::corpus::{Corpus, CorpusSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::oracle::{Oracle, QuadraticOracle};
use zo_ldsd::proptest::{check, Gen};
use zo_ldsd::sampler::LdsdConfig;
use zo_ldsd::snapshot;
use zo_ldsd::train::{
    CheckpointConfig, EstimatorKind, GemmMode, ParamStoreMode, ProbeStorage, SamplerKind,
    TrainConfig, Trainer,
};

fn mini_corpus() -> Corpus {
    Corpus::new(CorpusSpec::default_mini()).unwrap()
}

fn quad(d: usize) -> QuadraticOracle {
    let diag: Vec<f32> = (0..d).map(|i| 1.0 + 0.15 * (i % 5) as f32).collect();
    let center: Vec<f32> = (0..d).map(|i| (i as f32 * 0.41).cos()).collect();
    QuadraticOracle::new(diag, center, vec![0.0; d])
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zo_ck_resume_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// One random interrupt-resume configuration to cross-check.
#[derive(Debug, Clone)]
struct ResumeCase {
    d: usize,
    k: usize,
    threads: usize,
    shard_len: usize,
    seed: u64,
    /// Step the first session is preempted at (1..steps-1).
    interrupt: u64,
    /// Total optimizer steps of the full run.
    steps: u64,
    optimizer: &'static str,
    storage: ProbeStorage,
}

struct ResumeCaseGen;

impl Gen<ResumeCase> for ResumeCaseGen {
    fn generate(&self, rng: &mut zo_ldsd::rng::Rng) -> ResumeCase {
        let steps = 6 + rng.below(8);
        let optimizer = ["zo_sgd", "zo_adamm", "jaguar", "zo_sgd_plain"]
            [rng.below(4) as usize];
        let storage = if rng.below(2) == 0 {
            ProbeStorage::Materialized
        } else {
            ProbeStorage::Streamed
        };
        ResumeCase {
            d: 16 + rng.below(700) as usize,
            k: 2 + rng.below(5) as usize,
            threads: 1 + rng.below(8) as usize,
            shard_len: 4 + rng.below(250) as usize,
            seed: rng.next_u64(),
            interrupt: 1 + rng.below(steps - 1),
            steps,
            optimizer,
            storage,
        }
    }

    fn shrink(&self, value: &ResumeCase) -> Vec<ResumeCase> {
        let mut out = Vec::new();
        if value.d > 16 {
            out.push(ResumeCase { d: (value.d / 2).max(16), ..value.clone() });
        }
        if value.steps > 3 {
            let steps = value.steps / 2;
            out.push(ResumeCase {
                steps,
                interrupt: value.interrupt.min(steps - 1).max(1),
                ..value.clone()
            });
        }
        out
    }
}

fn cfg_for(case: &ResumeCase, checkpoint: CheckpointConfig) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k: case.k,
            sampler: SamplerKind::Ldsd(LdsdConfig::default()),
        },
        optimizer: case.optimizer.into(),
        lr: 0.02,
        tau: 1e-3,
        budget: (case.k as u64 + 1) * case.steps,
        eval_every: 0,
        eval_batches: 1,
        cosine_schedule: true, // exercises the schedule's step dependence
        seed: case.seed,
        probe_dispatch: Default::default(),
        probe_storage: case.storage,
        checkpoint,
        shuffle: None,
        param_store: ParamStoreMode::F32,
        gemm: GemmMode::Blocked,
    }
}

fn run_to_end(case: &ResumeCase, checkpoint: CheckpointConfig) -> (Vec<(u64, f64)>, Vec<f32>, u64) {
    let ctx = ExecContext::new(case.threads).with_shard_len(case.shard_len);
    let mut t = Trainer::with_exec(
        cfg_for(case, checkpoint),
        quad(case.d),
        mini_corpus(),
        ctx,
    )
    .unwrap();
    let out = t.run(None).unwrap();
    assert!(out.completed);
    (out.loss_curve, t.oracle().params().to_vec(), out.steps)
}

fn run_interrupted(case: &ResumeCase, dir: &Path) -> (Vec<(u64, f64)>, Vec<f32>, u64) {
    let ctx = ExecContext::new(case.threads).with_shard_len(case.shard_len);
    // session 1: snapshot every other step, preempt at `interrupt`
    let ck1 = CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 2,
        resume: false,
        max_run_steps: case.interrupt,
        store_dir: None,
    };
    let mut first =
        Trainer::with_exec(cfg_for(case, ck1), quad(case.d), mini_corpus(), ctx.clone())
            .unwrap();
    let partial = first.run(None).unwrap();
    assert!(!partial.completed, "interrupt must preempt before the budget");
    assert_eq!(partial.steps, case.interrupt);
    drop(first); // the first session's process is gone

    // session 2: fresh trainer, resume from disk, run to completion
    let ck2 = CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 2,
        resume: true,
        max_run_steps: 0,
        store_dir: None,
    };
    let mut second =
        Trainer::with_exec(cfg_for(case, ck2), quad(case.d), mini_corpus(), ctx).unwrap();
    let out = second.run(None).unwrap();
    assert!(out.completed);
    (out.loss_curve, t_params(&second), out.steps)
}

fn t_params<O: Oracle>(t: &Trainer<O>) -> Vec<f32> {
    t.oracle().params().to_vec()
}

fn curves_bitwise_equal(a: &[(u64, f64)], b: &[(u64, f64)]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b.iter())
            .all(|((ca, la), (cb, lb))| ca == cb && la.to_bits() == lb.to_bits())
}

fn params_bitwise_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The headline property: interrupt at a random step, resume from disk,
/// and the whole trajectory is bit-for-bit the uninterrupted one — across
/// random (d, K, threads, shard_len, optimizer, probe storage, interrupt
/// point) configurations.
#[test]
fn prop_interrupted_resume_is_bitwise_identical() {
    let case_no = std::cell::Cell::new(0usize);
    check("checkpoint_resume_bitwise", &ResumeCaseGen, 10, |case| {
        let n = case_no.get();
        case_no.set(n + 1);
        let dir = tmpdir(&format!("prop{n}"));
        let (curve_full, params_full, steps_full) =
            run_to_end(case, CheckpointConfig::default());
        let (curve_res, params_res, steps_res) = run_interrupted(case, &dir);
        std::fs::remove_dir_all(&dir).ok();
        steps_full == steps_res
            && curves_bitwise_equal(&curve_full, &curve_res)
            && params_bitwise_equal(&params_full, &params_res)
    });
}

/// The acceptance matrix pinned explicitly: 1 and 8 threads, materialized
/// and streamed probe storage — a mid-run kill + resume reproduces the
/// uninterrupted `TrainOutcome` bit for bit in every cell.
#[test]
fn resume_matrix_threads_x_storage() {
    for threads in [1usize, 8] {
        for storage in [ProbeStorage::Materialized, ProbeStorage::Streamed] {
            let case = ResumeCase {
                d: 257, // misaligned with the shard length on purpose
                k: 5,
                threads,
                shard_len: 64,
                seed: 0xC0FFEE,
                interrupt: 5,
                steps: 12,
                optimizer: "zo_adamm",
                storage,
            };
            let dir = tmpdir(&format!("matrix_t{threads}_{}", storage.label()));
            let (curve_full, params_full, _) =
                run_to_end(&case, CheckpointConfig::default());
            let (curve_res, params_res, _) = run_interrupted(&case, &dir);
            assert!(
                curves_bitwise_equal(&curve_full, &curve_res),
                "loss curve diverged (threads {threads}, {})",
                storage.label()
            );
            assert!(
                params_bitwise_equal(&params_full, &params_res),
                "params diverged (threads {threads}, {})",
                storage.label()
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// Two interruptions chained: kill at step 3, resume and kill again at
/// step 8, resume to the end — still bitwise identical.
#[test]
fn double_interruption_still_bitwise_identical() {
    let case = ResumeCase {
        d: 120,
        k: 3,
        threads: 4,
        shard_len: 48,
        seed: 77,
        interrupt: 3,
        steps: 14,
        optimizer: "zo_sgd",
        storage: ProbeStorage::Materialized,
    };
    let dir = tmpdir("double");
    let (curve_full, params_full, _) = run_to_end(&case, CheckpointConfig::default());

    let ctx = || ExecContext::new(case.threads).with_shard_len(case.shard_len);
    let ck = |resume: bool, max_run_steps: u64| CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 1,
        resume,
        max_run_steps,
        store_dir: None,
    };
    let mut s1 =
        Trainer::with_exec(cfg_for(&case, ck(false, 3)), quad(case.d), mini_corpus(), ctx())
            .unwrap();
    assert!(!s1.run(None).unwrap().completed);
    let mut s2 =
        Trainer::with_exec(cfg_for(&case, ck(true, 5)), quad(case.d), mini_corpus(), ctx())
            .unwrap();
    let mid = s2.run(None).unwrap();
    assert!(!mid.completed);
    assert_eq!(mid.steps, 8, "3 restored + 5 session steps");
    let mut s3 =
        Trainer::with_exec(cfg_for(&case, ck(true, 0)), quad(case.d), mini_corpus(), ctx())
            .unwrap();
    let fin = s3.run(None).unwrap();
    assert!(fin.completed);
    assert!(curves_bitwise_equal(&curve_full, &fin.loss_curve));
    assert!(params_bitwise_equal(&params_full, &t_params(&s3)));
    std::fs::remove_dir_all(&dir).ok();
}

/// Snapshot container round-trip at the trainer level + on-disk format
/// goldens: directory naming, manifest magic/fields, the content-addressed
/// blob inventory (v3: manifests name store objects by sha-256, the step
/// directory holds no sibling blob files).  The format is versioned; these
/// goldens are the compatibility contract.
#[test]
fn snapshot_format_roundtrip_and_golden() {
    let case = ResumeCase {
        d: 33,
        k: 2,
        threads: 1,
        shard_len: 16,
        seed: 5,
        interrupt: 4,
        steps: 6,
        optimizer: "zo_adamm",
        storage: ProbeStorage::Materialized,
    };
    let dir = tmpdir("golden");
    let ck = CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 2,
        resume: false,
        max_run_steps: case.interrupt,
        store_dir: None,
    };
    let mut t = Trainer::with_exec(
        cfg_for(&case, ck),
        quad(case.d),
        mini_corpus(),
        ExecContext::new(1).with_shard_len(16),
    )
    .unwrap();
    t.run(None).unwrap();

    // golden: zero-padded step directories, newest = the halt snapshot
    let snaps = snapshot::list_snapshots(&dir);
    let (last_step, last_path) = snaps.last().unwrap().clone();
    assert_eq!(last_step, 4);
    assert!(last_path.ends_with("step-0000000004"), "{last_path:?}");

    // golden: manifest magic + required fields + blob inventory
    let text = std::fs::read_to_string(last_path.join("manifest.json")).unwrap();
    let manifest = zo_ldsd::jsonio::parse(&text).unwrap();
    assert_eq!(
        manifest.get("magic").and_then(zo_ldsd::jsonio::Json::as_str),
        Some("zosnap1")
    );
    assert_eq!(
        manifest.get("version").and_then(zo_ldsd::jsonio::Json::as_str),
        Some("0000000000000003"),
        "new snapshots must be written in the store-backed v3 container"
    );
    for field in [
        "version", "label", "seed", "budget", "dim", "step",
        "oracle_calls_used", "next_eval", "data_cursor", "sampler_step",
        "best_accuracy_bits", "opt_scalars", "opt_buffers", "blobs",
    ] {
        assert!(manifest.get(field).is_some(), "manifest missing '{field}'");
    }
    // v3: blobs are content-addressed store objects, named by sha-256 —
    // the step directory holds ONLY the manifest
    let store = snapshot::open_store(&CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        ..Default::default()
    })
    .unwrap();
    let blobs = manifest.get("blobs").unwrap();
    for blob in ["params.bin", "opt-0.bin", "opt-1.bin", "policy_mean.bin",
                 "loss_curve.bin", "acc_curve.bin"] {
        let hash = blobs
            .get(blob)
            .and_then(zo_ldsd::jsonio::Json::as_str)
            .unwrap_or_else(|| panic!("inventory missing '{blob}'"));
        assert_eq!(hash.len(), 64, "'{blob}' must name a sha-256 object: {hash}");
        assert!(store.contains(hash), "store object missing for '{blob}'");
        assert!(
            !last_path.join(blob).exists(),
            "v3 step dirs must not carry sibling blob files ('{blob}')"
        );
    }
    let entries: Vec<_> = std::fs::read_dir(&last_path)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert_eq!(entries, vec!["manifest.json"], "{entries:?}");
    // no nulls anywhere in the manifest (non-finite leak guard)
    assert!(!text.contains("null"), "{text}");

    // round-trip: load == what the trainer would snapshot now
    let loaded = snapshot::load_latest(&dir, Some(&store)).unwrap();
    let live = t.snapshot();
    assert_eq!(loaded.step, live.step);
    assert_eq!(loaded.oracle_calls_used, live.oracle_calls_used);
    assert_eq!(loaded.sampler_step, live.sampler_step);
    assert_eq!(loaded.fingerprint, live.fingerprint);
    assert_eq!(loaded.params.len(), live.params.len());
    for (a, b) in loaded.params.iter().zip(live.params.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in loaded
        .policy_mean
        .as_deref()
        .unwrap()
        .iter()
        .zip(live.policy_mean.as_deref().unwrap())
    {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Migration: a checkpoint written by a pre-store build (the v2 container:
/// blobs as raw sibling files, no object store) must resume bit-for-bit on
/// the current build.  The legacy checkpoint is fabricated with the kept
/// v2 writer from a mid-run snapshot, so it is exactly what an older build
/// would have left on disk.
#[test]
fn legacy_v2_checkpoint_resumes_bitwise() {
    let case = ResumeCase {
        d: 95,
        k: 4,
        threads: 2,
        shard_len: 32,
        seed: 0xBEEF,
        interrupt: 4,
        steps: 11,
        optimizer: "zo_adamm",
        storage: ProbeStorage::Streamed,
    };
    let (curve_full, params_full, steps_full) =
        run_to_end(&case, CheckpointConfig::default());

    // session 1 on the CURRENT build, preempted mid-run: its halt
    // snapshot is the state an old build would also have reached
    let v3_dir = tmpdir("legacy_src");
    let ck1 = CheckpointConfig {
        dir: Some(v3_dir.to_string_lossy().into_owned()),
        every: 0,
        resume: false,
        max_run_steps: case.interrupt,
        store_dir: None,
    };
    let ctx = || ExecContext::new(case.threads).with_shard_len(case.shard_len);
    let mut first =
        Trainer::with_exec(cfg_for(&case, ck1.clone()), quad(case.d), mini_corpus(), ctx())
            .unwrap();
    assert!(!first.run(None).unwrap().completed);
    let store = snapshot::open_store(&ck1).unwrap();
    let snap = snapshot::load_latest(&v3_dir, Some(&store)).unwrap();

    // re-materialize that state as a v2 checkpoint: sibling blob files,
    // no store directory anywhere
    let v2_dir = tmpdir("legacy_dst");
    let written = snapshot::write_snapshot_legacy(&v2_dir, &snap).unwrap();
    let text = std::fs::read_to_string(written.join("manifest.json")).unwrap();
    let manifest = zo_ldsd::jsonio::parse(&text).unwrap();
    assert_eq!(
        manifest.get("version").and_then(zo_ldsd::jsonio::Json::as_str),
        Some("0000000000000002")
    );
    assert!(written.join("params.bin").exists(), "v2 carries sibling blobs");

    // session 2 resumes from the fabricated legacy checkpoint
    let ck2 = CheckpointConfig {
        dir: Some(v2_dir.to_string_lossy().into_owned()),
        every: 0,
        resume: true,
        max_run_steps: 0,
        store_dir: None,
    };
    let mut second =
        Trainer::with_exec(cfg_for(&case, ck2), quad(case.d), mini_corpus(), ctx())
            .unwrap();
    let out = second.run(None).unwrap();
    assert!(out.completed);
    assert_eq!(out.steps, steps_full);
    assert!(
        curves_bitwise_equal(&curve_full, &out.loss_curve),
        "legacy resume diverged from the uninterrupted trajectory"
    );
    assert!(params_bitwise_equal(&params_full, &t_params(&second)));
    std::fs::remove_dir_all(&v3_dir).ok();
    std::fs::remove_dir_all(&v2_dir).ok();
}

/// Resuming with a mismatched configuration must fail loudly, not walk a
/// silently different trajectory.
#[test]
fn resume_under_different_config_errors() {
    let case = ResumeCase {
        d: 24,
        k: 3,
        threads: 1,
        shard_len: 32,
        seed: 9,
        interrupt: 2,
        steps: 6,
        optimizer: "zo_sgd",
        storage: ProbeStorage::Materialized,
    };
    let dir = tmpdir("mismatch");
    let ck = |resume: bool| CheckpointConfig {
        dir: Some(dir.to_string_lossy().into_owned()),
        every: 1,
        resume,
        max_run_steps: if resume { 0 } else { 2 },
        store_dir: None,
    };
    let mut first = Trainer::with_exec(
        cfg_for(&case, ck(false)),
        quad(case.d),
        mini_corpus(),
        ExecContext::new(1).with_shard_len(32),
    )
    .unwrap();
    first.run(None).unwrap();

    // different seed -> fingerprint mismatch -> hard error on resume
    let other = ResumeCase { seed: 10, ..case.clone() };
    let mut wrong = Trainer::with_exec(
        cfg_for(&other, ck(true)),
        quad(case.d),
        mini_corpus(),
        ExecContext::new(1).with_shard_len(32),
    )
    .unwrap();
    let err = wrong.run(None).unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
