//! Bench: Fig. 3 ablations in miniature — a reduced sweep per panel so
//! `cargo bench` stays affordable (the full sweep is
//! `examples/ablations.rs`).
//!
//!     cargo bench --bench fig3_ablations           # all three panels
//!     cargo bench --bench fig3_ablations -- k      # one panel

use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::coordinator::{run_grid, TrialSpec};
use zo_ldsd::report::Table;
use zo_ldsd::sampler::LdsdConfig;
use zo_ldsd::train::{EstimatorKind, SamplerKind, TrainConfig};

fn cfg(k: usize, gamma_mu: f32, eps: f32, budget: u64) -> TrainConfig {
    TrainConfig {
        estimator: EstimatorKind::BestOfK {
            k,
            sampler: SamplerKind::Ldsd(LdsdConfig { eps, gamma_mu, ..Default::default() }),
        },
        ..TrainConfig::algorithm2("zo_sgd", 5e-4, budget)
    }
}

fn main() {
    let dir = "artifacts";
    if Manifest::load(dir).is_err() {
        eprintln!("SKIP fig3 bench: artifacts/ not built (run `make artifacts`)");
        return;
    }
    // skip harness-injected flags like `--bench` (cargo bench passes them)
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let budget = std::env::var("FIG3_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(900u64);

    let mut specs = Vec::new();
    // the presets carry eval_batches = 8; TrialSpec::new folds it in
    let spec = |id: String, c: TrainConfig| {
        TrialSpec::new(&id, "roberta_mini", TrainMode::Lora, c, zo_ldsd::coordinator::OracleSpec::Pjrt)
    };
    if filter.is_empty() || filter == "k" {
        for k in [1usize, 5, 10] {
            specs.push(spec(format!("k={k}"), cfg(k, 1e-3, 1.0, budget)));
        }
    }
    if filter.is_empty() || filter == "gamma-mu" {
        for gm in [0.0f32, 1e-3, 1e-1] {
            specs.push(spec(format!("gamma_mu={gm}"), cfg(5, gm, 1.0, budget)));
        }
    }
    if filter.is_empty() || filter == "epsilon" {
        for eps in [0.05f32, 1.0, 5.0] {
            specs.push(spec(format!("epsilon={eps}"), cfg(5, 1e-3, eps, budget)));
        }
    }

    let results = run_grid(dir, specs, &zo_ldsd::exec::ExecContext::new(3));
    let mut t = Table::new(
        &format!("Fig. 3 ablations (bench subset, budget {budget})"),
        &["point", "accuracy", "steps"],
    );
    for r in &results {
        match r {
            Ok(tr) => t.row(vec![
                tr.spec_id.clone(),
                format!("{:.4}", tr.outcome.final_accuracy),
                tr.outcome.steps.to_string(),
            ]),
            Err(e) => eprintln!("trial failed: {e:#}"),
        }
    }
    t.print();
    println!("paper shape: K peaks near 5 (3a); gamma_mu has an interior optimum (3b);");
    println!("epsilon is U-shaped with a peak where LDSD beats Gaussian (3c).");
}
