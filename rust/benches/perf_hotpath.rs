//! Bench: hot-path decomposition (§Perf of EXPERIMENTS.md).
//!
//! Times every stage of one ZO training step — sampling, the batched
//! K-probe dispatch vs K single dispatches (on both the closed-form and
//! the PJRT oracles), the central difference, the policy update, the
//! optimizer axpy — plus the pure-rust O(d) and O(K d) kernels, so
//! regressions localize immediately.
//!
//!     cargo bench --bench perf_hotpath

use zo_ldsd::bench::Bencher;
use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::data::Corpus;
use zo_ldsd::exec::ExecContext;
use zo_ldsd::optim::{GradEstimator, LdsdEstimator};
use zo_ldsd::oracle::{Oracle, PjrtOracle, QuadraticOracle};
use zo_ldsd::runtime::Runtime;
use zo_ldsd::sampler::{DirectionSampler, GaussianSampler, LdsdConfig, LdsdSampler};
use zo_ldsd::tensor::{
    axpy, axpy_into, axpy_k, axpy_k_ctx, dot, nrm2, probe_combine, probe_combine_ctx,
};

fn main() {
    let mut b = Bencher::new();
    b.max_seconds = 3.0;
    // shared mini corpus for the host-side (artifact-free) workloads
    let corpus_mini =
        Corpus::new(zo_ldsd::data::CorpusSpec::default_mini()).unwrap();

    // --- pure-rust O(d) kernels ------------------------------------------
    let d = 1_321_986usize; // roberta_mini d_ft
    let x = vec![0.5f32; d];
    let mut y = vec![0.25f32; d];
    let mut out = vec![0.0f32; d];
    b.bench("tensor/axpy_1.3M", d as f64, || axpy(0.1, &x, &mut y));
    b.bench("tensor/axpy_into_1.3M", d as f64, || {
        axpy_into(&mut out, &x, 0.1, &y)
    });
    b.bench("tensor/dot_1.3M", d as f64, || {
        std::hint::black_box(dot(&x, &y));
    });
    b.bench("tensor/nrm2_1.3M", d as f64, || {
        std::hint::black_box(nrm2(&x));
    });

    // --- blocked K x d probe-matrix kernels -------------------------------
    // (the combine step of the batched estimation path)
    {
        let dk = 262_144usize; // 256k floats per row
        let k = 5usize;
        let rows = vec![0.01f32; k * dk];
        let w = [0.3f32, -0.1, 0.2, 0.05, -0.4];
        let mut g = vec![0.0f32; dk];
        b.bench("tensor/probe_combine_k5_256k", (k * dk) as f64, || {
            probe_combine(&rows, dk, &w, &mut g)
        });
        b.bench("tensor/axpy_k_fused_k5_256k", (k * dk) as f64, || {
            axpy_k(&w, &rows, &mut g)
        });
        b.bench("tensor/axpy_k_looped_k5_256k", (k * dk) as f64, || {
            for i in 0..k {
                axpy(w[i], &rows[i * dk..(i + 1) * dk], &mut g);
            }
        });
    }

    // --- lane-vectorized kernels: scalar vs wide A/B (tensor::lanes) ------
    // The acceptance rows for the SIMD tentpole: the same axpy_k /
    // probe_combine calls forced onto the scalar and the wide lane path
    // within one run.  The bench gate's intra-run A/B check
    // (`--ab-max-ratio`) asserts wide ≤ ratio x scalar, so the speedup is
    // enforced by measurement, not by a stored anchor.  Both paths return
    // bitwise-identical results (the tensor::lanes contract).
    {
        use zo_ldsd::tensor::lanes::{force_mode, LaneMode};
        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let dm = 1usize << 20;
        let k = 5usize;
        let rows = vec![0.01f32; k * dm];
        let w: Vec<f32> = (0..k).map(|i| 0.1 * (i as f32 + 1.0)).collect();
        let mut g = vec![0.0f32; dm];
        for (mode, label) in [(LaneMode::Scalar, "scalar"), (LaneMode::Wide, "wide")] {
            force_mode(Some(mode));
            b.bench(&format!("lanes/axpy_k_k5_d1M_{label}"), (k * dm) as f64, || {
                axpy_k(&w, &rows, &mut g)
            });
            b.bench(
                &format!("lanes/probe_combine_k5_d1M_{label}"),
                (k * dm) as f64,
                || probe_combine(&rows, dm, &w, &mut g),
            );
        }
        force_mode(None);
        b.max_seconds = saved_max_seconds;
    }

    // --- blocked GEMM engine: reference vs blocked A/B (tensor::gemm) ------
    // The acceptance rows for the GEMM tentpole: the same batched matmul
    // (C = A x B + bias) through the row-at-a-time reference loop and the
    // cache-blocked packed kernel, at the transformer projection shape
    // and the MLP hidden-layer shape.  The bench gate's `--ab-specs`
    // check asserts blocked <= ratio x reference within this run, so the
    // speedup is enforced by measurement, not by a stored anchor.  Both
    // engines return identical bits (the DESIGN.md §15 tiling contract),
    // which the section re-asserts after timing.
    {
        use zo_ldsd::tensor::gemm::{gemm_blocked, gemm_reference, PackedB};
        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        for (m, kk, n, stem) in [
            (256usize, 768usize, 768usize, "tfm_qkv_256x768x768"),
            (256, 784, 256, "mlp_fc_256x784x256"),
        ] {
            let mut rng = zo_ldsd::rng::Rng::new(11);
            let mut a = vec![0.0f32; m * kk];
            let mut w = vec![0.0f32; kk * n];
            let mut bias = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut w);
            rng.fill_normal(&mut bias);
            let mut out = vec![0.0f32; m * n];
            let macs = (m * kk * n) as f64;
            b.bench(&format!("gemm/{stem}_reference"), macs, || {
                gemm_reference(&a, m, kk, &w, n, Some(&bias), &mut out)
            });
            // the weight-pack cache: packing happens once, outside the
            // timed loop, exactly as the oracles reuse packs across
            // rows/probes (LoRA base weights pack once per run)
            let pb = PackedB::pack(&w, kk, n);
            b.bench(&format!("gemm/{stem}_blocked"), macs, || {
                gemm_blocked(&a, m, kk, &pb, Some(&bias), &mut out)
            });
            let mut check = vec![0.0f32; m * n];
            gemm_reference(&a, m, kk, &w, n, Some(&bias), &mut check);
            gemm_blocked(&a, m, kk, &pb, Some(&bias), &mut out);
            assert!(
                out.iter().zip(check.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gemm/{stem}: blocked engine diverged from reference bits"
            );
        }
        b.max_seconds = saved_max_seconds;
    }

    // --- quantized parameter stores: fused dequant+perturb per mode --------
    // `qstore/*` rows time w = x + tau * v through each ParamStore mode at
    // d = 2^20 and record the store's resident parameter bytes as the
    // deterministic peak metric (f32 4 B/param, f16 2 B/param, int8
    // ~1.06 B/param) — the memory the quantized modes buy back.
    {
        use zo_ldsd::tensor::{ParamStore, ParamStoreMode};
        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let dm = 1usize << 20;
        let xs: Vec<f32> = (0..dm).map(|i| 0.25 + 0.001 * (i % 97) as f32).collect();
        let v = vec![0.01f32; dm];
        let mut w = vec![0.0f32; dm];
        for mode in [ParamStoreMode::F32, ParamStoreMode::F16, ParamStoreMode::Int8] {
            let store = ParamStore::from_f32(mode, &xs);
            let name = format!("qstore/perturb_into_d1M_{}", mode.label());
            b.bench(&name, dm as f64, || store.perturb_into(1e-3, &v, &mut w));
            b.annotate_peak_bytes(&name, store.resident_bytes());
        }
        b.max_seconds = saved_max_seconds;
    }

    // --- content-addressed snapshot persistence (the store tentpole) -------
    // `snapshot/*` rows time one steady-state checkpoint write and one
    // full load through the content-addressed store at d = 2^16.  The
    // write row measures the dedup fast path: every blob of the
    // generation already exists in the store, so the cost is hashing +
    // existence checks + the manifest commit — the per-step overhead a
    // long run actually pays once the store is warm.  The load row
    // measures the manifest parse + blob fetch + checksum path.
    {
        use zo_ldsd::optim::OptimizerState;
        use zo_ldsd::snapshot::{
            load_snapshot, write_snapshot, SnapshotFingerprint, TrainerSnapshot,
            SNAPSHOT_VERSION,
        };
        use zo_ldsd::store::Store;

        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let dm = 1usize << 16;
        let base = std::env::temp_dir()
            .join(format!("zo-bench-snapshot-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let dir = base.join("ck");
        let store = Store::open(base.join("store"));
        let snap = TrainerSnapshot {
            version: SNAPSHOT_VERSION,
            fingerprint: SnapshotFingerprint {
                label: "bestofk5/ldsd+zo_sgd".into(),
                seed: 7,
                budget: 1 << 20,
                dim: dm,
            },
            step: 40,
            oracle_calls_used: 240,
            next_eval: 1200,
            data_cursor: 320,
            sampler_step: 40,
            best_accuracy: 0.5,
            params: (0..dm).map(|i| 0.25 + 1e-4 * (i % 101) as f32).collect(),
            optimizer: OptimizerState {
                scalars: vec![40],
                buffers: vec![vec![0.5f32; dm]],
            },
            policy_mean: Some(vec![0.125f32; dm]),
            loss_curve: vec![(6, 0.75), (12, 0.6)],
            acc_curve: vec![(12, 0.5)],
        };
        // warm the store so the timed writes hit the dedup path only
        let last = write_snapshot(&dir, &store, &snap).unwrap();
        b.bench("snapshot/write_dedup", dm as f64, || {
            write_snapshot(&dir, &store, &snap).unwrap();
        });
        b.bench("snapshot/load_dedup", dm as f64, || {
            std::hint::black_box(load_snapshot(&last, Some(&store)).unwrap());
        });
        let _ = std::fs::remove_dir_all(&base);
        b.max_seconds = saved_max_seconds;
    }

    // --- RNG: scalar cached-spare path vs the pairwise hot loop -----------
    // (§Perf optimization #1: FT-mode LDSD draws K*d = 6.6M normals/step)
    {
        use zo_ldsd::rng::Rng;
        let n = 1_000_000usize;
        let mut buf = vec![0.0f32; n];
        let mut r1 = Rng::new(1);
        b.bench("rng/normal_scalar_1M", n as f64, || {
            for v in buf.iter_mut() {
                *v = r1.normal() as f32;
            }
        });
        let mut r2 = Rng::new(1);
        b.bench("rng/fill_normal_pairwise_1M", n as f64, || {
            r2.fill_normal(&mut buf);
        });
    }

    // --- samplers ----------------------------------------------------------
    let mut gauss = GaussianSampler::new(d, 1);
    let mut dirs = vec![0.0f32; d];
    b.bench("sampler/gaussian_1dir_1.3M", d as f64, || {
        gauss.sample(&mut dirs, 1)
    });
    let d_lora = 16_642usize;
    let mut ldsd = LdsdSampler::new(d_lora, 2, LdsdConfig::default());
    let mut dirs5 = vec![0.0f32; 5 * d_lora];
    b.bench("sampler/ldsd_5dirs_16k", (5 * d_lora) as f64, || {
        ldsd.sample(&mut dirs5, 5)
    });
    let losses = [0.5f64, 0.4, 0.6, 0.45, 0.55];
    b.bench("sampler/ldsd_observe_k5_16k", (5 * d_lora) as f64, || {
        ldsd.observe(&dirs5, &losses, 5)
    });

    // --- batched vs per-probe K-probe estimation (closed-form oracle) -----
    // The acceptance row for the batching refactor: one estimation step of
    // the best-of-K estimator, dispatched (a) through the fused vectorized
    // `loss_k` and (b) as K separate `loss_dir` calls, for K in {5, 10}.
    // Throughput is probes/second; no artifacts are needed.
    for k in [5usize, 10] {
        let dq = 16_384usize;
        let diag: Vec<f32> = (0..dq).map(|i| 1.0 + 0.5 * (i % 7) as f32).collect();
        let center = vec![1.0f32; dq];
        let mut oracle = QuadraticOracle::new(diag, center, vec![0.0; dq]);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(dq, 7, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g = vec![0.0f32; dq];
        b.bench(&format!("estimator/bestofk{k}_batched_16k"), (k + 1) as f64, || {
            est.estimate(&mut oracle, &mut g).unwrap();
        });
        b.bench(&format!("estimator/bestofk{k}_perprobe_16k"), (k + 1) as f64, || {
            let probe_losses: Vec<f64> = {
                let batch = est.propose().unwrap();
                let dirs = batch.dirs.expect("per-probe dispatch needs materialized probes");
                (0..batch.k)
                    .map(|i| {
                        oracle
                            .loss_dir(&dirs[i * dq..(i + 1) * dq], batch.tau)
                            .unwrap()
                    })
                    .collect()
            };
            est.consume(&mut oracle, &probe_losses, &mut g).unwrap();
        });
    }

    // --- probe storage: materialized vs streamed (the PR 3 tentpole) -------
    // `mem/*` rows time one full best-of-K estimation step per storage mode
    // and record the *measured* peak probe-state bytes (probe matrices +
    // streaming scratch, via metrics::probe_tracker).  Streamed peaks are
    // O(K * shard_len) per worker; materialized peaks are the K x d matrix,
    // which is why d = 2^24 runs streamed-only.  Smoke mode keeps one
    // d = 2^20 pair so CI always executes a mem row.
    {
        use zo_ldsd::metrics::probe_tracker;
        use zo_ldsd::probe::ProbeStorage;
        use zo_ldsd::report::Table;

        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let mut mem_table = Table::new(
            "probe-state peak memory (per estimate step)",
            &["row", "storage", "peak MiB"],
        );
        let dims: &[usize] = if b.is_smoke() { &[1 << 20] } else { &[1 << 20, 1 << 22, 1 << 24] };
        let ks: &[usize] = if b.is_smoke() { &[5] } else { &[5, 10] };
        for &dm in dims {
            for &k in ks {
                for storage in [ProbeStorage::Materialized, ProbeStorage::Streamed] {
                    // the K x d matrix alone is 320-640 MiB at 2^24:
                    // that's the allocation this PR removes, so the
                    // materialized arm stops at 2^22
                    if storage == ProbeStorage::Materialized && dm >= 1 << 24 {
                        continue;
                    }
                    let dlabel = match dm {
                        x if x == 1 << 20 => "1M",
                        x if x == 1 << 22 => "4M",
                        _ => "16M",
                    };
                    let name = format!("mem/bestofk{k}_d{dlabel}_{}", storage.label());
                    if !b.enabled(&name) {
                        continue;
                    }
                    let ctx = ExecContext::new(4);
                    let mut est = LdsdEstimator::with_storage(
                        GaussianSampler::new(dm, 7),
                        1e-3,
                        k,
                        storage,
                    )
                    .unwrap();
                    est.set_exec(ctx.clone());
                    let mut oracle = QuadraticOracle::new(
                        vec![1.0f32; dm],
                        vec![1.0f32; dm],
                        vec![0.0f32; dm],
                    );
                    oracle.set_exec(ctx);
                    let mut g = vec![0.0f32; dm];
                    probe_tracker().reset();
                    b.bench(&name, (k + 1) as f64, || {
                        est.estimate(&mut oracle, &mut g).unwrap();
                    });
                    // deterministic metric for the bench-regression gate
                    b.annotate_peak_bytes(&name, probe_tracker().peak());
                    mem_table.row(vec![
                        format!("bestofk{k}_d{dlabel}"),
                        storage.label().to_string(),
                        format!("{:.2}", probe_tracker().peak() as f64 / (1 << 20) as f64),
                    ]);
                }
            }
        }
        mem_table.print();
        b.max_seconds = saved_max_seconds;
    }

    // --- thread scaling: the shard-parallel execution engine ---------------
    // Acceptance rows for the sharded-execution refactor: the O(K d)
    // kernels and the closed-form `loss_k` at d = 2^20, for 1/2/4/8-thread
    // contexts and K in {5, 10}.  Results are bitwise identical across the
    // thread counts (pinned by tests/parallel_determinism.rs); these rows
    // pin the throughput side.
    {
        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let dm = 1usize << 20;
        for k in [5usize, 10] {
            let rows = vec![0.01f32; k * dm];
            let w: Vec<f32> = (0..k).map(|i| 0.1 * (i as f32 + 1.0)).collect();
            let mut g = vec![0.0f32; dm];
            let diag: Vec<f32> =
                (0..dm).map(|i| 1.0 + 0.5 * (i % 7) as f32).collect();
            for threads in [1usize, 2, 4, 8] {
                let ctx = ExecContext::new(threads);
                b.bench(
                    &format!("scale/axpy_k_k{k}_d1M_t{threads}"),
                    (k * dm) as f64,
                    || axpy_k_ctx(&ctx, &w, &rows, &mut g),
                );
                b.bench(
                    &format!("scale/probe_combine_k{k}_d1M_t{threads}"),
                    (k * dm) as f64,
                    || probe_combine_ctx(&ctx, &rows, dm, &w, &mut g),
                );
                let mut oracle = QuadraticOracle::new(
                    diag.clone(),
                    vec![1.0f32; dm],
                    vec![0.0f32; dm],
                );
                oracle.set_exec(ctx.clone());
                b.bench(
                    &format!("scale/loss_k_closed_form_k{k}_d1M_t{threads}"),
                    k as f64,
                    || {
                        std::hint::black_box(oracle.loss_k(&rows, k, 1e-3).unwrap());
                    },
                );
            }
        }
        b.max_seconds = saved_max_seconds;
    }

    // --- MLP forward-only oracle (the first network workload) --------------
    // `mlp/*` rows: the vectorized K-probe forward at 1 and 8 threads, a
    // full streamed best-of-K estimation step (LDSD policy + seed
    // replay), and the single-forward baseline.  All gated by the CI
    // bench-regression job alongside loss_k/axpy_k/probe_combine.
    {
        use zo_ldsd::metrics::probe_tracker;
        use zo_ldsd::model::{Activation, MlpSpec};
        use zo_ldsd::oracle::MlpOracle;
        use zo_ldsd::probe::ProbeStorage;

        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let spec = MlpSpec::new(128, vec![64, 64], 2, Activation::Tanh).unwrap();
        let dm = spec.dim();
        let batch = corpus_mini.train_batch(0, 8);
        let mut rng = zo_ldsd::rng::Rng::new(3);
        for k in [5usize, 10] {
            let mut dirs = vec![0.0f32; k * dm];
            rng.fill_normal(&mut dirs);
            for threads in [1usize, 8] {
                let ctx = ExecContext::new(threads);
                let mut oracle = MlpOracle::from_seed(spec.clone(), 7);
                oracle.set_exec(ctx);
                oracle.set_batch(&batch).unwrap();
                b.bench(
                    &format!("mlp/loss_k_h64x64_k{k}_t{threads}"),
                    k as f64,
                    || {
                        std::hint::black_box(oracle.loss_k(&dirs, k, 1e-3).unwrap());
                    },
                );
            }
        }
        // one full best-of-K estimation step on streamed (seed-replay)
        // probes: the acceptance workload of DESIGN.md §12
        {
            let k = 5usize;
            let ctx = ExecContext::new(4);
            let mut est = LdsdEstimator::with_storage(
                LdsdSampler::new(dm, 7, LdsdConfig::default()),
                1e-3,
                k,
                ProbeStorage::Streamed,
            )
            .unwrap();
            est.set_exec(ctx.clone());
            let mut oracle = MlpOracle::from_seed(spec.clone(), 7);
            oracle.set_exec(ctx);
            oracle.set_batch(&batch).unwrap();
            let mut g = vec![0.0f32; dm];
            let name = "mlp/estimate_bestofk5_streamed_t4";
            probe_tracker().reset();
            b.bench(name, (k + 1) as f64, || {
                est.estimate(&mut oracle, &mut g).unwrap();
            });
            b.annotate_peak_bytes(name, probe_tracker().peak());
        }
        {
            let mut dir1 = vec![0.0f32; dm];
            rng.fill_normal(&mut dir1);
            let mut oracle = MlpOracle::from_seed(spec.clone(), 7);
            oracle.set_batch(&batch).unwrap();
            b.bench("mlp/loss_dir_1fwd", 1.0, || {
                std::hint::black_box(oracle.loss_dir(&dir1, 1e-3).unwrap());
            });
        }
        b.max_seconds = saved_max_seconds;
    }

    // --- transformer + LoRA oracle (the Table 1 workload shape) ------------
    // `transformer/*` rows: the probe-parallel K-forward on the LoRA
    // subspace (d = adapter + head params) and on the full FT flat
    // vector, a full streamed best-of-K estimation step on the LoRA
    // subspace, and the single-forward baseline.  Gated by the CI
    // bench-regression job alongside the mlp/* rows.
    {
        use zo_ldsd::metrics::probe_tracker;
        use zo_ldsd::model::{Pool, TransformerSpec};
        use zo_ldsd::oracle::TransformerOracle;
        use zo_ldsd::probe::ProbeStorage;

        let saved_max_seconds = b.max_seconds;
        b.max_seconds = 1.5;
        let cspec = zo_ldsd::data::CorpusSpec {
            vocab: 64,
            seq: 8,
            lexicon: 16,
            min_len: 4,
            signal_min: 1,
            signal_max: 3,
            ..zo_ldsd::data::CorpusSpec::default_mini()
        };
        let corpus_tfm = Corpus::new(cspec).unwrap();
        let spec =
            TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, Pool::Cls, 2).unwrap();
        let batch = corpus_tfm.train_batch(0, 8);
        let mut rng = zo_ldsd::rng::Rng::new(5);
        let k = 5usize;
        for (mode, mlabel, threads_list) in [
            (TrainMode::Lora, "lora", &[1usize, 8][..]),
            (TrainMode::Ft, "ft", &[1usize][..]),
        ] {
            let dm = match mode {
                TrainMode::Lora => spec.d_lora(),
                TrainMode::Ft => spec.d_ft(),
            };
            let mut dirs = vec![0.0f32; k * dm];
            rng.fill_normal(&mut dirs);
            for &threads in threads_list {
                let ctx = ExecContext::new(threads);
                let mut oracle = TransformerOracle::from_seed(spec.clone(), mode, 7);
                oracle.set_exec(ctx);
                oracle.set_batch(&batch).unwrap();
                b.bench(
                    &format!("transformer/loss_k_tfm2x2d16_{mlabel}_k{k}_t{threads}"),
                    k as f64,
                    || {
                        std::hint::black_box(oracle.loss_k(&dirs, k, 1e-3).unwrap());
                    },
                );
            }
        }
        // one full best-of-K estimation step on streamed (seed-replay)
        // probes over the LoRA subspace: the Table 1 acceptance workload
        {
            let ctx = ExecContext::new(4);
            let mut est = LdsdEstimator::with_storage(
                LdsdSampler::new(spec.d_lora(), 7, LdsdConfig::default()),
                1e-3,
                k,
                ProbeStorage::Streamed,
            )
            .unwrap();
            est.set_exec(ctx.clone());
            let mut oracle =
                TransformerOracle::from_seed(spec.clone(), TrainMode::Lora, 7);
            oracle.set_exec(ctx);
            oracle.set_batch(&batch).unwrap();
            let mut g = vec![0.0f32; spec.d_lora()];
            let name = "transformer/estimate_bestofk5_lora_streamed_t4";
            probe_tracker().reset();
            b.bench(name, (k + 1) as f64, || {
                est.estimate(&mut oracle, &mut g).unwrap();
            });
            b.annotate_peak_bytes(name, probe_tracker().peak());
        }
        {
            let mut dir1 = vec![0.0f32; spec.d_lora()];
            rng.fill_normal(&mut dir1);
            let mut oracle =
                TransformerOracle::from_seed(spec.clone(), TrainMode::Lora, 7);
            oracle.set_batch(&batch).unwrap();
            b.bench("transformer/loss_dir_lora_1fwd", 1.0, || {
                std::hint::black_box(oracle.loss_dir(&dir1, 1e-3).unwrap());
            });
        }
        // the batched forward under each GEMM engine: one 8-example
        // evaluation through the per-example reference fold and through
        // the flattened [batch*seq, d] blocked path (identical bits;
        // DESIGN.md §15).  Coverage rows — the enforced reference-vs-
        // blocked speedup lives in the gemm/* A/B pairs above, at shapes
        // where the GEMM dominates.
        {
            use zo_ldsd::tensor::gemm::{force_gemm_mode, GemmMode};
            let mut dir1 = vec![0.0f32; spec.d_lora()];
            rng.fill_normal(&mut dir1);
            for (gmode, glabel) in
                [(GemmMode::Reference, "reference"), (GemmMode::Blocked, "blocked")]
            {
                force_gemm_mode(Some(gmode));
                let mut oracle =
                    TransformerOracle::from_seed(spec.clone(), TrainMode::Lora, 7);
                oracle.set_batch(&batch).unwrap();
                b.bench(&format!("transformer/forward_b8_{glabel}"), 8.0, || {
                    std::hint::black_box(oracle.loss_dir(&dir1, 1e-3).unwrap());
                });
            }
            force_gemm_mode(None);
        }
        b.max_seconds = saved_max_seconds;
    }

    // --- PJRT oracle -------------------------------------------------------
    if cfg!(not(feature = "pjrt")) {
        eprintln!("(skipping PJRT benches: built without the pjrt feature)");
        b.finish();
        return;
    }
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("(skipping PJRT benches: artifacts/ not built)");
        b.finish();
        return;
    };
    let rt = Runtime::new("artifacts").unwrap();
    let entry = manifest.model("roberta_mini").unwrap();
    let corpus = Corpus::new(manifest.corpus("roberta_mini").unwrap().clone()).unwrap();
    let batch = corpus.train_batch(0, entry.shapes.batch);

    for (mode, label) in [(TrainMode::Lora, "lora"), (TrainMode::Ft, "ft")] {
        let mut oracle = PjrtOracle::new(&rt, entry, mode).unwrap();
        oracle.set_batch(&batch).unwrap();
        let dt = oracle.dim();
        let k = entry.shapes.k;
        let dir: Vec<f32> = vec![0.01; dt];
        let dirs: Vec<f32> = vec![0.01; k * dt];

        b.bench(&format!("pjrt/{label}_loss_dir_1fwd"), 1.0, || {
            oracle.loss_dir(&dir, 1e-3).unwrap();
        });
        b.bench(&format!("pjrt/{label}_loss_k_fused_{k}fwd"), k as f64, || {
            oracle.loss_k(&dirs, k, 1e-3).unwrap();
        });
        b.bench(&format!("pjrt/{label}_loss_k_looped_{k}fwd"), k as f64, || {
            for i in 0..k {
                oracle.loss_dir(&dirs[i * dt..(i + 1) * dt], 1e-3).unwrap();
            }
        });
        // param re-upload cost after an optimizer step
        b.bench(&format!("pjrt/{label}_step_with_param_upload"), 1.0, || {
            oracle.update_params(&mut |x| x[0] += 1e-7).unwrap();
            oracle.loss_dir(&dir, 1e-3).unwrap();
        });
    }
    b.finish();
}
