//! Bench: the ZO-vs-first-order memory table (paper §1 motivation),
//! from first-principles byte accounting on the manifest's models.
//!
//!     cargo bench --bench memory_table

use zo_ldsd::config::Manifest;
use zo_ldsd::metrics::{param_tracker, MemoryReport};
use zo_ldsd::report::Table;
use zo_ldsd::tensor::{ParamStore, ParamStoreMode};

/// Resident parameter bytes per storage mode (DESIGN.md §14), measured —
/// each store is actually built and its bytes read back from the global
/// parameter tracker, so the table reports what a Trainer would hold
/// resident, not a formula.
fn param_store_table() {
    const MODES: [ParamStoreMode; 3] =
        [ParamStoreMode::F32, ParamStoreMode::F16, ParamStoreMode::Int8];
    for exp in [20u32, 24] {
        let d = 1usize << exp;
        let xs: Vec<f32> = (0..d).map(|i| 0.25 + 0.001 * (i % 97) as f32).collect();
        let mut t = Table::new(
            &format!("param store residency (d = 2^{exp} = {d})"),
            &["mode", "resident MiB", "bytes/param", "vs f32", "tracker peak MiB"],
        );
        let f32_bytes = 4 * d;
        for mode in MODES {
            // nothing else holds a store in this process, so a per-mode
            // reset makes the tracker peak THIS store's annotated peak
            param_tracker().reset();
            let store = ParamStore::from_f32(mode, &xs);
            let bytes = store.resident_bytes();
            // the tracker's registered bytes must match the store's own
            // accounting — the peak the trainer's memory rows report
            assert_eq!(param_tracker().current(), bytes, "tracker drift ({})", mode.label());
            let peak_mib = param_tracker().peak() as f64 / (1 << 20) as f64;
            t.row(vec![
                mode.label().to_string(),
                format!("{:.2}", bytes as f64 / (1 << 20) as f64),
                format!("{:.3}", bytes as f64 / d as f64),
                format!("{:.2}x", bytes as f64 / f32_bytes as f64),
                format!("{peak_mib:.2}"),
            ]);
        }
        t.print();
        println!();
    }
}

fn main() {
    // artifact-free: the quantized-store residency table needs no manifest
    param_store_table();
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("SKIP memory bench: artifacts/ not built");
        return;
    };
    for (name, m) in &manifest.models {
        let report = MemoryReport::build(
            m.d_ft, m.d_ft, m.shapes.batch, m.shapes.seq, m.d_model,
            4 * m.d_model, 4, m.n_layers, m.shapes.k,
        );
        let mut t = Table::new(
            &format!("memory: {name} full fine-tuning (d = {})", m.d_ft),
            &["method", "total MiB", "x inference"],
        );
        let mut fo_adam = 0.0f64;
        let mut zo_sgd = 0.0f64;
        for r in &report {
            let mib = r.total() as f64 / (1 << 20) as f64;
            if r.method == "fo_adam" {
                fo_adam = mib;
            }
            if r.method.starts_with("zo_sgd (") {
                zo_sgd = mib;
            }
            t.row(vec![
                r.method.clone(),
                format!("{mib:.1}"),
                format!("{:.2}", r.over_inference()),
            ]);
        }
        t.print();
        println!("zo_sgd saves {:.1}x over fo_adam\n", fo_adam / zo_sgd);
        assert!(fo_adam > zo_sgd, "ZO must beat FO Adam on memory");
    }
}
