//! Bench: the ZO-vs-first-order memory table (paper §1 motivation),
//! from first-principles byte accounting on the manifest's models.
//!
//!     cargo bench --bench memory_table

use zo_ldsd::config::Manifest;
use zo_ldsd::metrics::MemoryReport;
use zo_ldsd::report::Table;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("SKIP memory bench: artifacts/ not built");
        return;
    };
    for (name, m) in &manifest.models {
        let report = MemoryReport::build(
            m.d_ft, m.d_ft, m.shapes.batch, m.shapes.seq, m.d_model,
            4 * m.d_model, 4, m.n_layers, m.shapes.k,
        );
        let mut t = Table::new(
            &format!("memory: {name} full fine-tuning (d = {})", m.d_ft),
            &["method", "total MiB", "x inference"],
        );
        let mut fo_adam = 0.0f64;
        let mut zo_sgd = 0.0f64;
        for r in &report {
            let mib = r.total() as f64 / (1 << 20) as f64;
            if r.method == "fo_adam" {
                fo_adam = mib;
            }
            if r.method.starts_with("zo_sgd (") {
                zo_sgd = mib;
            }
            t.row(vec![
                r.method.clone(),
                format!("{mib:.1}"),
                format!("{:.2}", r.over_inference()),
            ]);
        }
        t.print();
        println!("zo_sgd saves {:.1}x over fo_adam\n", fo_adam / zo_sgd);
        assert!(fo_adam > zo_sgd, "ZO must beat FO Adam on memory");
    }
}
