//! Bench: regenerate Fig. 2 (toy DGD experiment) and report the series'
//! summary plus the runner's throughput.
//!
//!     cargo bench --bench fig2_toy

use zo_ldsd::bench::Bencher;
use zo_ldsd::data::SyntheticRegression;
use zo_ldsd::optim::{DgdConfig, DgdRunner, DgdVariant};
use zo_ldsd::oracle::{LinRegOracle, Oracle};
use zo_ldsd::report::Table;

fn run(variant: DgdVariant, steps: usize, seed: u64) -> (f32, f32, f64) {
    let ds = SyntheticRegression::a9a_like(2048, 0xA9A);
    let mut oracle = LinRegOracle::new(ds.x, ds.y, vec![0.0; 123]);
    let cfg = match variant {
        DgdVariant::Baseline => {
            let mut c = DgdConfig::paper_baseline(steps, seed);
            c.gamma_x = 2.0;
            c
        }
        DgdVariant::Ldsd => {
            let mut c = DgdConfig::paper_ldsd(steps, seed);
            c.gamma_x = 0.05;
            c.gamma_mu = 0.05;
            c.eps = 0.05;
            c
        }
    };
    let mut runner = DgdRunner::new(cfg, oracle.dim());
    let t = runner.run(&mut oracle).unwrap();
    let tail = |v: &[f32]| -> f32 {
        let s = &v[v.len().saturating_sub(50)..];
        s.iter().sum::<f32>() / s.len() as f32
    };
    (tail(&t.alignment), tail(&t.grad_norm), *t.loss.last().unwrap())
}

fn main() {
    let steps = 800;
    let mut table = Table::new(
        "Fig. 2: LDSD vs baseline DGD on a9a-like regression",
        &["variant", "seed", "alignment (tail)", "grad norm (tail)", "final loss"],
    );
    for seed in [1u64, 2, 3] {
        for (name, variant) in
            [("baseline", DgdVariant::Baseline), ("ldsd", DgdVariant::Ldsd)]
        {
            let (align, gnorm, loss) = run(variant, steps, seed);
            table.row(vec![
                name.into(),
                seed.to_string(),
                format!("{align:.3}"),
                format!("{gnorm:.4}"),
                format!("{loss:.4}"),
            ]);
        }
    }
    table.print();
    println!("paper shape: baseline alignment ~ O(1/sqrt(d)) ~ 0.1-0.2;");
    println!("LDSD alignment rises then oscillates near 1 (Lemma 2).\n");

    let mut b = Bencher::new();
    b.max_seconds = 3.0;
    b.bench("dgd_baseline_100steps", 100.0, || {
        let _ = run(DgdVariant::Baseline, 100, 9);
    });
    b.bench("dgd_ldsd_100steps", 100.0, || {
        let _ = run(DgdVariant::Ldsd, 100, 9);
    });
    b.finish();
}
