//! Bench: regenerate Fig. 1 (the alignment landscape) and verify its
//! qualitative structure: saddle at mu = 0, ridges along +-grad f,
//! valleys orthogonal to it.
//!
//!     cargo bench --bench fig1_landscape

use zo_ldsd::bench::Bencher;
use zo_ldsd::report::Table;
use zo_ldsd::sampler::expected_alignment_mc;

fn main() {
    let eps = 0.25f32;
    let grad = [1.0f32, 0.0];
    let at = |x: f32, y: f32| expected_alignment_mc(&[x, y], &grad, eps, 20_000, 7);

    let mut t = Table::new(
        "Fig. 1 landmarks: E[C] over mu (d = 2, grad f = (1,0), eps = 0.25)",
        &["mu", "E[C]", "paper structure"],
    );
    let saddle = at(0.0, 0.0);
    let ridge_p = at(2.0, 0.0);
    let ridge_n = at(-2.0, 0.0);
    let valley = at(0.0, 2.0);
    let diag = at(1.5, 1.5);
    t.row(vec!["(0, 0)".into(), format!("{saddle:.3}"), "saddle = 1/d = 0.5".into()]);
    t.row(vec!["(2, 0)".into(), format!("{ridge_p:.3}"), "aligned ridge -> 1".into()]);
    t.row(vec!["(-2, 0)".into(), format!("{ridge_n:.3}"), "mirror ridge (mu -> -mu symmetry)".into()]);
    t.row(vec!["(0, 2)".into(), format!("{valley:.3}"), "orthogonal valley -> 0".into()]);
    t.row(vec!["(1.5, 1.5)".into(), format!("{diag:.3}"), "diagonal = 1/2 (cos^2 45deg)".into()]);
    t.print();

    // structural assertions (the figure's whole point)
    assert!((saddle - 0.5).abs() < 0.02, "saddle should be 1/d");
    assert!(ridge_p > 0.95 && ridge_n > 0.95, "ridges should approach 1");
    assert!(valley < 0.05, "valley should approach 0");
    assert!((ridge_p - ridge_n).abs() < 0.02, "mu -> -mu symmetry");
    println!("\nstructure checks passed (saddle/ridge/valley/symmetry)\n");

    let mut b = Bencher::new();
    b.max_seconds = 3.0;
    b.bench("alignment_mc_4000_samples_d2", 4000.0, || {
        let _ = expected_alignment_mc(&[1.0, 0.5], &grad, eps, 4000, 3);
    });
    let big_mu = vec![0.1f32; 4096];
    let mut big_g = vec![0.0f32; 4096];
    big_g[0] = 1.0;
    b.bench("alignment_mc_200_samples_d4096", 200.0, || {
        let _ = expected_alignment_mc(&big_mu, &big_g, eps, 200, 3);
    });
    b.finish();
}
