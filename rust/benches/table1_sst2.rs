//! Bench: a scaled-down Table 1 — the three sampling schemes under one
//! base optimizer per run, fixed oracle budget, on the PJRT-backed models.
//! (The full grid lives in `examples/table1.rs`; this bench keeps `cargo
//! bench` affordable while still exercising the ordering claim.)
//!
//!     cargo bench --bench table1_sst2            # zo_sgd, roberta_mini/LoRA
//!     cargo bench --bench table1_sst2 -- full    # all optimizers

use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::coordinator::{run_grid, TrialSpec};
use zo_ldsd::report::Table;
use zo_ldsd::train::TrainConfig;

fn main() {
    let dir = "artifacts";
    if Manifest::load(dir).is_err() {
        eprintln!("SKIP table1 bench: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let full = std::env::args().any(|a| a == "full");
    let budget = std::env::var("T1_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200u64);

    // calibrated LoRA learning rates (see EXPERIMENTS.md / examples/table1.rs)
    let optimizers: &[(&str, f32)] = if full {
        &[("zo_sgd", 1e-4), ("zo_adamm", 1e-3), ("jaguar", 5e-5)]
    } else {
        &[("zo_sgd", 1e-4)]
    };

    let mut specs = Vec::new();
    for (optimizer, lr) in optimizers {
        for (method, cfg) in [
            ("gauss_2fwd", TrainConfig::gaussian_2fwd(optimizer, *lr, budget)),
            ("gauss_6fwd", TrainConfig::gaussian_6fwd(optimizer, *lr, budget)),
            ("alg2", TrainConfig::algorithm2(optimizer, *lr, budget)),
        ] {
            specs.push(TrialSpec {
                id: format!("roberta_mini/lora/{optimizer}/{method}"),
                model: "roberta_mini".into(),
                mode: TrainMode::Lora,
                config: cfg,
                eval_batches: 8,
                probe_dispatch: None,
                probe_storage: None,
                checkpoint: None,
                oracle: zo_ldsd::coordinator::OracleSpec::Pjrt,
            });
        }
    }

    let t0 = std::time::Instant::now();
    let results = run_grid(dir, specs, &zo_ldsd::exec::ExecContext::new(3));
    let mut table = Table::new(
        &format!("Table 1 (bench subset, budget {budget} forwards)"),
        &["trial", "accuracy", "steps", "secs", "probe MiB"],
    );
    let mut accs = std::collections::BTreeMap::new();
    for r in &results {
        match r {
            Ok(tr) => {
                table.row(vec![
                    tr.spec_id.clone(),
                    format!("{:.4}", tr.outcome.final_accuracy),
                    tr.outcome.steps.to_string(),
                    format!("{:.1}", tr.outcome.wall_seconds),
                    // probe-state peak (grid-wide upper bound when the
                    // grid runs trials concurrently; see TrialResult)
                    format!("{:.1}", tr.probe_peak_bytes as f64 / (1 << 20) as f64),
                ]);
                let method = tr.spec_id.rsplit('/').next().unwrap().to_string();
                accs.entry(method).or_insert(tr.outcome.final_accuracy);
            }
            Err(e) => eprintln!("trial failed: {e:#}"),
        }
    }
    table.print();
    if let (Some(a2), Some(g2), Some(g6)) =
        (accs.get("alg2"), accs.get("gauss_2fwd"), accs.get("gauss_6fwd"))
    {
        println!(
            "\nordering check (paper: alg2 best, 6fwd <= 2fwd): alg2 {a2:.4}, 2fwd {g2:.4}, 6fwd {g6:.4}"
        );
    }
    println!("total {:.0}s", t0.elapsed().as_secs_f64());
}
