//! Bench: a scaled-down Table 1 on the paper's workload shape — the
//! three sampling schemes under one base optimizer per run, fixed oracle
//! budget, on the host-side transformer + LoRA oracle.  Artifact-free:
//! the grid runs through the coordinator with no PJRT runtime, so it
//! executes everywhere the test suite does (the PJRT variant of the full
//! grid lives in `examples/table1.rs`).
//!
//!     cargo bench --bench table1_sst2              # zo_sgd, LoRA rank 4
//!     cargo bench --bench table1_sst2 -- full      # all optimizers
//!     cargo bench --bench table1_sst2 -- --smoke   # CI: tiny budget
//!
//! The grid itself is [`table1_grid`] — the same spec builder behind
//! `zo grid emit --preset table1*` and the service byte-identity tests,
//! so every consumer schedules the identical trials through the one
//! wire constructor path.
//!
//! `T1_BUDGET` overrides the per-trial forward budget; `BENCH_JSON=<path>`
//! serializes one row per trial (`ns_per_op` = wall ns per oracle call,
//! plus accuracy/steps/peak probe bytes) — the `table1-smoke` CI job
//! uploads that file as its artifact.
//!
//! Warm-start hooks (the `store-smoke` CI job; DESIGN.md §16):
//! `T1_CHECKPOINT_DIR=<dir>` checkpoints every trial under `<dir>` with
//! resume on, so a re-run against the same directory short-circuits each
//! trial through the grid's `grid.lock.json` result cache.
//! `T1_REPORT=<path>` writes the deterministic canonical report
//! ([`deterministic_report`]: trial id, accuracy bits, steps, oracle
//! calls, label, completed — no wall times or peaks), byte-comparable
//! across cold and warm runs and against a service-farmed grid.
//! `T1_EXPECT_CACHED=1` asserts every trial was served from the cache
//! with zero training-session oracle calls — the proof that the warm run
//! did no training.

use std::collections::BTreeMap;

use zo_ldsd::coordinator::{deterministic_report, run_grid, table1_grid, OracleSpec};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::jsonio::Json;
use zo_ldsd::report::Table;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let full = argv.iter().any(|a| a == "full");
    let smoke = argv.iter().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
    let budget = std::env::var("T1_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 120u64 } else { 2400 });
    let ck_dir = std::env::var("T1_CHECKPOINT_DIR").ok().filter(|v| !v.is_empty());
    let report_path = std::env::var("T1_REPORT").ok().filter(|v| !v.is_empty());
    let expect_cached = std::env::var("T1_EXPECT_CACHED")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);

    // The SST-2 stand-in grid (see table1_grid for the architecture:
    // small causal decoder, rank-4 q/v adapters — the paper's LoRA
    // fine-tuning shape).  The bench only layers its warm-start
    // checkpoint policy on top.
    let mut specs = table1_grid(budget, full, smoke);
    if let Some(d) = &ck_dir {
        for spec in &mut specs {
            spec.checkpoint = Some(zo_ldsd::snapshot::CheckpointConfig {
                dir: Some(d.clone()),
                every: 0,
                resume: true,
                max_run_steps: 0,
                store_dir: None,
            });
        }
    }
    if let OracleSpec::Transformer(trial) = &specs[0].oracle {
        let tspec = trial.model_spec().unwrap();
        println!(
            "table1 bench: {} lora (d = {} of {} ft params), budget {budget} forwards",
            tspec.label(),
            tspec.d_lora(),
            tspec.d_ft()
        );
    }

    let t0 = std::time::Instant::now();
    let results = run_grid("artifacts", specs, &ExecContext::new(3));
    let mut table = Table::new(
        &format!("Table 1 (bench subset, budget {budget} forwards)"),
        &["trial", "accuracy", "steps", "secs", "probe KiB"],
    );
    let mut accs = BTreeMap::new();
    let mut json_rows: Vec<Json> = Vec::new();
    let mut cache_misses: Vec<String> = Vec::new();
    for r in &results {
        match r {
            Ok(tr) => {
                if expect_cached && !(tr.cached && tr.session_oracle_calls == 0) {
                    cache_misses.push(format!(
                        "{} (cached {}, session oracle calls {})",
                        tr.spec_id, tr.cached, tr.session_oracle_calls
                    ));
                }
                table.row(vec![
                    tr.spec_id.clone(),
                    format!("{:.4}", tr.outcome.final_accuracy),
                    tr.outcome.steps.to_string(),
                    format!("{:.1}", tr.outcome.wall_seconds),
                    // probe-state peak (grid-wide upper bound when the
                    // grid runs trials concurrently; see TrialResult)
                    format!("{:.1}", tr.probe_peak_bytes as f64 / 1024.0),
                ]);
                let method = tr.spec_id.rsplit('/').next().unwrap().to_string();
                accs.entry(method).or_insert(tr.outcome.final_accuracy);
                let mut row = BTreeMap::new();
                row.insert(
                    "name".to_string(),
                    Json::Str(format!("table1/{}", tr.spec_id)),
                );
                row.insert(
                    "ns_per_op".to_string(),
                    Json::Num(tr.outcome.wall_seconds * 1e9 / budget.max(1) as f64),
                );
                row.insert("accuracy".to_string(), Json::Num(tr.outcome.final_accuracy));
                row.insert("steps".to_string(), Json::Num(tr.outcome.steps as f64));
                row.insert(
                    "peak_bytes".to_string(),
                    Json::Num(tr.probe_peak_bytes as f64),
                );
                json_rows.push(Json::Obj(row));
            }
            Err(e) => eprintln!("trial failed: {e:#}"),
        }
    }
    table.print();
    if let Some(path) = &report_path {
        match std::fs::write(path, deterministic_report(&results)) {
            Ok(()) => eprintln!("bench: wrote deterministic report to {path}"),
            Err(e) => {
                eprintln!("bench: failed writing report {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    if expect_cached {
        if !cache_misses.is_empty() {
            eprintln!("T1_EXPECT_CACHED=1 but trials ran cold:");
            for m in &cache_misses {
                eprintln!("  {m}");
            }
            std::process::exit(1);
        }
        println!("warm start: all {} trials served from the result cache", results.len());
    }
    if let (Some(a2), Some(g2), Some(g6)) =
        (accs.get("alg2"), accs.get("gauss_2fwd"), accs.get("gauss_6fwd"))
    {
        println!(
            "\nordering check (paper: alg2 best, 6fwd <= 2fwd): alg2 {a2:.4}, 2fwd {g2:.4}, 6fwd {g6:.4}"
        );
    }
    if let Ok(path) = std::env::var("BENCH_JSON") {
        if !path.is_empty() {
            let mut root = BTreeMap::new();
            root.insert("rows".to_string(), Json::Arr(json_rows));
            match zo_ldsd::report::write_json(std::path::Path::new(&path), &Json::Obj(root))
            {
                Ok(()) => eprintln!("bench: wrote trial rows to {path}"),
                Err(e) => eprintln!("bench: failed writing {path}: {e:#}"),
            }
        }
    }
    println!("total {:.0}s", t0.elapsed().as_secs_f64());
}
