//! Bench harness substrate (replaces criterion; vendored set lacks it).
//!
//! `rust/benches/*.rs` are `harness = false` binaries built on this:
//! warmup, timed iterations, and a markdown summary via [`Bencher`].
//! Filters come from argv so `cargo bench -- <filter>` keeps working.
//!
//! Smoke mode (`cargo bench -- --smoke`, or `BENCH_SMOKE=1`) clamps every
//! benchmark to exactly one untimed-warmup-free iteration: `make
//! bench-smoke` uses it so CI compiles and executes every bench without
//! paying for stable timings — the benches cannot silently rot.
//!
//! JSON serialization (`BENCH_JSON=<path>`): [`Bencher::finish`] writes
//! every timed row (ns/op plus any annotated peak bytes) to the given
//! file; [`regression`] parses those files and diffs a current run
//! against the committed baseline within a threshold — the CI
//! bench-regression gate (see `make bench-gate` and the `bench-gate`
//! binary).

pub mod regression;

use std::time::Instant;

use crate::metrics::Summary;
use crate::report::Table;

/// One serialized bench row: the payload of the `BENCH_JSON` file the
/// regression gate consumes.
struct JsonRow {
    name: String,
    ns_per_op: f64,
    peak_bytes: Option<usize>,
}

/// Times closures and accumulates a result table.
pub struct Bencher {
    /// Untimed iterations before sampling starts.
    pub warmup_iters: usize,
    /// Minimum timed iterations per benchmark.
    pub min_iters: usize,
    /// Maximum timed iterations per benchmark.
    pub max_iters: usize,
    /// Time budget per benchmark (soft; checked between iterations).
    pub max_seconds: f64,
    filter: Option<String>,
    table: Table,
    json_rows: Vec<JsonRow>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// New harness with default limits; the filter comes from argv.
    /// `--smoke` (or `BENCH_SMOKE=1`) clamps every bench to one iteration.
    pub fn new() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let smoke = argv.iter().any(|a| a == "--smoke")
            || std::env::var("BENCH_SMOKE")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
        let filter = argv.into_iter().find(|a| !a.starts_with('-'));
        let mut b = Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            max_seconds: 5.0,
            filter,
            table: Table::new(
                "bench results",
                &["name", "iters", "mean", "p50", "p95", "throughput"],
            ),
            json_rows: Vec::new(),
        };
        if smoke {
            b.warmup_iters = 0;
            b.min_iters = 1;
            b.max_iters = 1;
        }
        b
    }

    /// True when smoke mode clamps this harness to single iterations
    /// (benches can use it to shrink auxiliary workloads too).
    pub fn is_smoke(&self) -> bool {
        self.max_iters == 1
    }

    /// Honour `cargo bench -- <filter>`.
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f`; `work_units` scales the throughput column (e.g. oracle
    /// calls per invocation).  Returns per-iteration seconds.
    pub fn bench<F: FnMut()>(&mut self, name: &str, work_units: f64, mut f: F) -> Option<Summary> {
        if !self.enabled(name) {
            return None;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.max_seconds)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = Summary::of(&samples);
        if s.is_empty() {
            // unreachable with min_iters >= 1, but never emit a row of
            // NaNs (which jsonio would render as null) if limits are
            // misconfigured
            return Some(s);
        }
        let throughput = if s.mean > 0.0 { work_units / s.mean } else { 0.0 };
        self.json_rows.push(JsonRow {
            name: name.to_string(),
            ns_per_op: s.mean * 1e9,
            peak_bytes: None,
        });
        self.table.row(vec![
            name.to_string(),
            format!("{}", s.n),
            format_seconds(s.mean),
            format_seconds(s.p50),
            format_seconds(s.p95),
            format!("{throughput:.1}/s"),
        ]);
        Some(s)
    }

    /// Attach measured peak bytes to the named row (latest occurrence):
    /// the regression gate diffs bytes with the same threshold as
    /// timings, and — unlike timings — peaks are deterministic, so they
    /// gate exactly.
    pub fn annotate_peak_bytes(&mut self, name: &str, bytes: usize) {
        if let Some(row) = self.json_rows.iter_mut().rev().find(|r| r.name == name) {
            row.peak_bytes = Some(bytes);
        }
    }

    /// Print the accumulated table (call once at the end of main) and,
    /// when `BENCH_JSON=<path>` is set, serialize the rows for the
    /// bench-regression gate ([`regression`]).
    pub fn finish(&self) {
        if !self.table.rows.is_empty() {
            self.table.print();
        }
        if let Ok(path) = std::env::var("BENCH_JSON") {
            if !path.is_empty() && !self.json_rows.is_empty() {
                if let Err(e) = self.write_json(std::path::Path::new(&path)) {
                    eprintln!("bench: failed writing {path}: {e:#}");
                } else {
                    eprintln!("bench: wrote {} rows to {path}", self.json_rows.len());
                }
            }
        }
    }

    fn write_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use crate::jsonio::Json;
        use std::collections::BTreeMap;
        let rows: Vec<Json> = self
            .json_rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(r.name.clone()));
                m.insert("ns_per_op".to_string(), Json::Num(r.ns_per_op));
                if let Some(b) = r.peak_bytes {
                    m.insert("peak_bytes".to_string(), Json::Num(b as f64));
                }
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("rows".to_string(), Json::Arr(rows));
        crate::report::write_json(path, &Json::Obj(root))
    }
}

/// Human-readable duration with an auto-selected unit (s/ms/us/ns).
pub fn format_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::new();
        b.max_seconds = 0.05;
        b.min_iters = 3;
        let mut count = 0usize;
        let s = b.bench("noop", 1.0, || count += 1);
        // filter from argv may disable in `cargo test` context; tolerate None
        if let Some(s) = s {
            assert!(s.n >= 3);
            assert!(count >= 3 + b.warmup_iters);
        }
    }

    #[test]
    fn json_rows_record_timing_and_annotated_bytes() {
        let mut b = Bencher::new();
        b.max_seconds = 0.01;
        b.min_iters = 1;
        b.warmup_iters = 0;
        let r = b.bench("gate/row", 1.0, || {});
        // the argv-derived filter may disable the row under `cargo test`
        if r.is_some() {
            let row = b.json_rows.last().unwrap();
            assert_eq!(row.name, "gate/row");
            assert!(row.ns_per_op >= 0.0);
            assert_eq!(row.peak_bytes, None);
            b.annotate_peak_bytes("gate/row", 1234);
            assert_eq!(b.json_rows.last().unwrap().peak_bytes, Some(1234));
            // annotating an unknown row is a no-op
            b.annotate_peak_bytes("gate/absent", 1);
        }
    }

    #[test]
    fn second_formatting() {
        assert_eq!(format_seconds(2.0), "2.000s");
        assert_eq!(format_seconds(0.002), "2.000ms");
        assert_eq!(format_seconds(2e-6), "2.000us");
        assert!(format_seconds(2e-9).ends_with("ns"));
    }
}
