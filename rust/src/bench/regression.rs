//! Benchmark-regression gate: parse the `BENCH_*.json` row files written
//! by [`crate::bench::Bencher::finish`] and diff a current run against
//! the committed baseline within a fractional threshold.
//!
//! The gate is *one-sided*: only getting slower (or allocating more peak
//! probe-state bytes) than `baseline * (1 + threshold)` fails; getting
//! faster silently passes (and is the cue to re-run `make
//! bench-baseline`).  Timings and bytes gate with *separate* thresholds:
//! peak bytes are deterministic (exact allocation sizes), so they can be
//! held tight, while smoke-mode single-iteration timings are noisy and
//! need headroom.  A gated baseline row missing from the current run
//! also fails — renaming a row must update the baseline, not silently
//! drop coverage.  The `bench-gate` binary wraps this for the CI job
//! (`.github/workflows/ci.yml`) and `make bench-gate`.

use anyhow::{anyhow, Result};

use crate::jsonio::{parse, Json};

/// One benchmark row as serialized under the `rows` key of a
/// `BENCH_*.json` file.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    /// Bench row name (e.g. "scale/loss_k_closed_form_k5_d1M_t4").
    pub name: String,
    /// Mean nanoseconds per timed iteration.
    pub ns_per_op: f64,
    /// Measured peak probe-state bytes, when the bench annotated one.
    pub peak_bytes: Option<f64>,
}

/// Parse a bench JSON file's text into rows.
pub fn parse_rows(text: &str) -> Result<Vec<BenchRow>> {
    let root = parse(text).map_err(|e| anyhow!("bench json: {e}"))?;
    let rows = root
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bench json: missing 'rows' array"))?;
    rows.iter()
        .map(|r| {
            Ok(BenchRow {
                name: r
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("bench json: row without a name"))?
                    .to_string(),
                ns_per_op: r
                    .get("ns_per_op")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("bench json: row without ns_per_op"))?,
                peak_bytes: r.get("peak_bytes").and_then(Json::as_f64),
            })
        })
        .collect()
}

/// One gated comparison that exceeded the threshold.
#[derive(Clone, Debug)]
pub struct Regression {
    /// The offending row name.
    pub name: String,
    /// Which metric regressed ("ns_per_op" | "peak_bytes").
    pub metric: &'static str,
    /// The committed baseline value.
    pub baseline: f64,
    /// The current run's value.
    pub current: f64,
    /// current / baseline.
    pub ratio: f64,
}

/// Outcome of diffing a current bench run against the baseline.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    /// Gated rows found in both files and compared.
    pub compared: usize,
    /// Gated baseline rows with no counterpart in the current run.
    pub missing: Vec<String>,
    /// Comparisons beyond the threshold (slower/larger than baseline).
    pub regressions: Vec<Regression>,
}

impl GateReport {
    /// True when nothing regressed and no gated row went missing.
    pub fn is_green(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Diff `current` against `baseline`: every baseline row whose name
/// contains one of the `gates` substrings must exist in `current` and
/// stay within `ns_threshold` (fractional: 0.2 = +20%) on ns/op — and
/// within `bytes_threshold` on peak bytes when both runs recorded one.
/// Non-gated rows are ignored.
pub fn gate(
    baseline: &[BenchRow],
    current: &[BenchRow],
    ns_threshold: f64,
    bytes_threshold: f64,
    gates: &[&str],
) -> GateReport {
    let mut report = GateReport::default();
    for b in baseline {
        if !gates.iter().any(|g| b.name.contains(g)) {
            continue;
        }
        let cur = match current.iter().find(|c| c.name == b.name) {
            Some(c) => c,
            None => {
                report.missing.push(b.name.clone());
                continue;
            }
        };
        report.compared += 1;
        let metrics = [
            ("ns_per_op", Some(b.ns_per_op), Some(cur.ns_per_op), ns_threshold),
            ("peak_bytes", b.peak_bytes, cur.peak_bytes, bytes_threshold),
        ];
        for (metric, bv, cv, threshold) in metrics {
            let (bv, cv) = match (bv, cv) {
                (Some(bv), Some(cv)) => (bv, cv),
                _ => continue,
            };
            if bv <= 0.0 {
                // a zero/negative baseline cannot anchor a ratio; skip
                continue;
            }
            let ratio = cv / bv;
            if ratio > 1.0 + threshold {
                report.regressions.push(Regression {
                    name: b.name.clone(),
                    metric,
                    baseline: bv,
                    current: cv,
                    ratio,
                });
            }
        }
    }
    report
}

/// One intra-run A/B pair whose vectorized arm missed the required
/// speedup (or lost its counterpart row).
#[derive(Clone, Debug)]
pub struct AbViolation {
    /// The scalar-arm row name.
    pub scalar: String,
    /// The wide-arm row name.
    pub wide: String,
    /// Scalar-arm ns/op.
    pub scalar_ns: f64,
    /// Wide-arm ns/op (NaN when the wide row is missing).
    pub wide_ns: f64,
    /// wide / scalar (NaN when the wide row is missing).
    pub ratio: f64,
}

/// Outcome of the intra-run A/B check ([`ab_gate`]).
#[derive(Clone, Debug, Default)]
pub struct AbReport {
    /// A/B pairs found and compared.
    pub compared: usize,
    /// Pairs whose ratio exceeded the bound, or whose wide row vanished.
    pub violations: Vec<AbViolation>,
}

impl AbReport {
    /// True when every pair met the required ratio.
    pub fn is_green(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Intra-run A/B speedup check: for every `current` row named
/// `<prefix><stem>_scalar`, the sibling `<prefix><stem>_wide` must exist
/// and satisfy `wide_ns <= max_ratio * scalar_ns`.  Both arms come from
/// the *same* run on the same hardware, so — unlike the stored-baseline
/// timing gate — the ratio bound is portable: it enforces the vectorized
/// kernels' speedup by measurement wherever the gate runs.
pub fn ab_gate(current: &[BenchRow], prefix: &str, max_ratio: f64) -> AbReport {
    let mut report = AbReport::default();
    for c in current {
        let stem = match c
            .name
            .strip_prefix(prefix)
            .and_then(|rest| rest.strip_suffix("_scalar"))
        {
            Some(stem) => stem,
            None => continue,
        };
        let wide_name = format!("{prefix}{stem}_wide");
        let violation = match current.iter().find(|r| r.name == wide_name) {
            Some(wide) => {
                report.compared += 1;
                let ratio = wide.ns_per_op / c.ns_per_op;
                (c.ns_per_op > 0.0 && ratio > max_ratio).then(|| AbViolation {
                    scalar: c.name.clone(),
                    wide: wide_name.clone(),
                    scalar_ns: c.ns_per_op,
                    wide_ns: wide.ns_per_op,
                    ratio,
                })
            }
            None => Some(AbViolation {
                scalar: c.name.clone(),
                wide: wide_name.clone(),
                scalar_ns: c.ns_per_op,
                wide_ns: f64::NAN,
                ratio: f64::NAN,
            }),
        };
        report.violations.extend(violation);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str, ns: f64, bytes: Option<f64>) -> BenchRow {
        BenchRow { name: name.into(), ns_per_op: ns, peak_bytes: bytes }
    }

    #[test]
    fn parse_roundtrips_bencher_format() {
        let text = r#"{
          "rows": [
            {"name": "scale/loss_k_k5", "ns_per_op": 1200.5},
            {"name": "mem/bestofk5", "ns_per_op": 3.0, "peak_bytes": 4096}
          ]
        }"#;
        let rows = parse_rows(text).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "scale/loss_k_k5");
        assert_eq!(rows[0].peak_bytes, None);
        assert_eq!(rows[1].peak_bytes, Some(4096.0));
        assert!(parse_rows("{}").is_err());
        assert!(parse_rows(r#"{"rows": [{"ns_per_op": 1}]}"#).is_err());
    }

    #[test]
    fn gate_passes_within_threshold_and_on_improvement() {
        let base = [row("scale/loss_k", 1000.0, None), row("mlp/loss_k", 500.0, None)];
        let cur = [
            row("scale/loss_k", 1150.0, None), // +15% < +20%
            row("mlp/loss_k", 200.0, None),    // faster: never fails
        ];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "mlp"]);
        assert_eq!(rep.compared, 2);
        assert!(rep.is_green(), "{rep:?}");
    }

    #[test]
    fn gate_fails_on_regression_missing_row_and_byte_growth() {
        let base = [
            row("scale/loss_k", 1000.0, None),
            row("mem/mlp_peak", 100.0, Some(1000.0)),
            row("scale/axpy_k", 10.0, None),
        ];
        let cur = [
            row("scale/loss_k", 1300.0, None),      // +30% ns: fails
            row("mem/mlp_peak", 100.0, Some(1500.0)), // +50% bytes: fails
                                                      // axpy_k missing: fails
        ];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "axpy_k", "mlp"]);
        assert!(!rep.is_green());
        assert_eq!(rep.missing, vec!["scale/axpy_k".to_string()]);
        assert_eq!(rep.regressions.len(), 2);
        let metrics: Vec<&str> = rep.regressions.iter().map(|r| r.metric).collect();
        assert!(metrics.contains(&"ns_per_op"));
        assert!(metrics.contains(&"peak_bytes"));
        let r0 = rep
            .regressions
            .iter()
            .find(|r| r.metric == "ns_per_op")
            .unwrap();
        assert!((r0.ratio - 1.3).abs() < 1e-9);
    }

    #[test]
    fn non_gated_rows_are_ignored() {
        let base = [row("rng/normal", 100.0, None)];
        let cur = [row("rng/normal", 900.0, None)];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "axpy_k", "probe_combine", "mlp"]);
        assert_eq!(rep.compared, 0);
        assert!(rep.is_green());
    }

    #[test]
    fn thresholds_apply_per_metric() {
        // +30% ns but a loose ns threshold passes, while the same +30%
        // on deterministic bytes under a tight bytes threshold fails
        let base = [row("mem/mlp_peak", 100.0, Some(1000.0))];
        let cur = [row("mem/mlp_peak", 130.0, Some(1300.0))];
        let rep = gate(&base, &cur, 0.50, 0.05, &["mem/"]);
        assert_eq!(rep.regressions.len(), 1, "{rep:?}");
        assert_eq!(rep.regressions[0].metric, "peak_bytes");
    }

    #[test]
    fn byte_gate_skipped_when_either_side_lacks_bytes() {
        let base = [row("mlp/loss_k", 100.0, Some(100.0))];
        let cur = [row("mlp/loss_k", 100.0, None)];
        let rep = gate(&base, &cur, 0.20, 0.20, &["mlp"]);
        assert!(rep.is_green(), "bytes gate needs both sides: {rep:?}");
    }

    #[test]
    fn ab_gate_enforces_intra_run_speedup() {
        let cur = [
            row("lanes/axpy_k_k5_d1M_scalar", 1000.0, None),
            row("lanes/axpy_k_k5_d1M_wide", 300.0, None), // 0.3 <= 0.67
            row("lanes/probe_combine_k5_d1M_scalar", 1000.0, None),
            row("lanes/probe_combine_k5_d1M_wide", 900.0, None), // 0.9: fails
            row("tensor/axpy_1.3M", 50.0, None),                 // no prefix: ignored
        ];
        let rep = ab_gate(&cur, "lanes/", 0.67);
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.violations.len(), 1, "{rep:?}");
        assert_eq!(rep.violations[0].wide, "lanes/probe_combine_k5_d1M_wide");
        assert!((rep.violations[0].ratio - 0.9).abs() < 1e-9);
    }

    #[test]
    fn ab_gate_flags_missing_wide_counterpart() {
        let cur = [row("lanes/axpy_k_k5_d1M_scalar", 1000.0, None)];
        let rep = ab_gate(&cur, "lanes/", 0.67);
        assert_eq!(rep.compared, 0);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].wide_ns.is_nan());
    }
}
