//! Benchmark-regression gate: parse the `BENCH_*.json` row files written
//! by [`crate::bench::Bencher::finish`] and diff a current run against
//! the committed baseline within a fractional threshold.
//!
//! The gate is *one-sided*: only getting slower (or allocating more peak
//! probe-state bytes) than `baseline * (1 + threshold)` fails; getting
//! faster silently passes (and is the cue to re-run `make
//! bench-baseline`).  Timings and bytes gate with *separate* thresholds:
//! peak bytes are deterministic (exact allocation sizes), so they can be
//! held tight, while smoke-mode single-iteration timings are noisy and
//! need headroom.  A gated baseline row missing from the current run
//! also fails — renaming a row must update the baseline, not silently
//! drop coverage.  The `bench-gate` binary wraps this for the CI job
//! (`.github/workflows/ci.yml`) and `make bench-gate`.

use anyhow::{anyhow, bail, Context, Result};

use crate::cli::Args;
use crate::jsonio::{parse, Json};
use crate::report::Table;

/// One benchmark row as serialized under the `rows` key of a
/// `BENCH_*.json` file.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    /// Bench row name (e.g. "scale/loss_k_closed_form_k5_d1M_t4").
    pub name: String,
    /// Mean nanoseconds per timed iteration.
    pub ns_per_op: f64,
    /// Measured peak probe-state bytes, when the bench annotated one.
    pub peak_bytes: Option<f64>,
}

/// Parse a bench JSON file's text into rows.
pub fn parse_rows(text: &str) -> Result<Vec<BenchRow>> {
    let root = parse(text).map_err(|e| anyhow!("bench json: {e}"))?;
    let rows = root
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bench json: missing 'rows' array"))?;
    rows.iter()
        .map(|r| {
            Ok(BenchRow {
                name: r
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("bench json: row without a name"))?
                    .to_string(),
                ns_per_op: r
                    .get("ns_per_op")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("bench json: row without ns_per_op"))?,
                peak_bytes: r.get("peak_bytes").and_then(Json::as_f64),
            })
        })
        .collect()
}

/// One gated comparison that exceeded the threshold.
#[derive(Clone, Debug)]
pub struct Regression {
    /// The offending row name.
    pub name: String,
    /// Which metric regressed ("ns_per_op" | "peak_bytes").
    pub metric: &'static str,
    /// The committed baseline value.
    pub baseline: f64,
    /// The current run's value.
    pub current: f64,
    /// current / baseline.
    pub ratio: f64,
}

/// Outcome of diffing a current bench run against the baseline.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    /// Gated rows found in both files and compared.
    pub compared: usize,
    /// Gated baseline rows with no counterpart in the current run.
    pub missing: Vec<String>,
    /// Comparisons beyond the threshold (slower/larger than baseline).
    pub regressions: Vec<Regression>,
}

impl GateReport {
    /// True when nothing regressed and no gated row went missing.
    pub fn is_green(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Diff `current` against `baseline`: every baseline row whose name
/// contains one of the `gates` substrings must exist in `current` and
/// stay within `ns_threshold` (fractional: 0.2 = +20%) on ns/op — and
/// within `bytes_threshold` on peak bytes when both runs recorded one.
/// Non-gated rows are ignored.
pub fn gate(
    baseline: &[BenchRow],
    current: &[BenchRow],
    ns_threshold: f64,
    bytes_threshold: f64,
    gates: &[&str],
) -> GateReport {
    let mut report = GateReport::default();
    for b in baseline {
        if !gates.iter().any(|g| b.name.contains(g)) {
            continue;
        }
        let cur = match current.iter().find(|c| c.name == b.name) {
            Some(c) => c,
            None => {
                report.missing.push(b.name.clone());
                continue;
            }
        };
        report.compared += 1;
        let metrics = [
            ("ns_per_op", Some(b.ns_per_op), Some(cur.ns_per_op), ns_threshold),
            ("peak_bytes", b.peak_bytes, cur.peak_bytes, bytes_threshold),
        ];
        for (metric, bv, cv, threshold) in metrics {
            let (bv, cv) = match (bv, cv) {
                (Some(bv), Some(cv)) => (bv, cv),
                _ => continue,
            };
            if bv <= 0.0 {
                // a zero/negative baseline cannot anchor a ratio; skip
                continue;
            }
            let ratio = cv / bv;
            if ratio > 1.0 + threshold {
                report.regressions.push(Regression {
                    name: b.name.clone(),
                    metric,
                    baseline: bv,
                    current: cv,
                    ratio,
                });
            }
        }
    }
    report
}

/// One intra-run A/B pair whose fast arm missed the required speedup
/// (or lost its counterpart row).
#[derive(Clone, Debug)]
pub struct AbViolation {
    /// The slow-arm row name (e.g. `lanes/axpy_k_..._scalar`).
    pub scalar: String,
    /// The fast-arm row name (e.g. `lanes/axpy_k_..._wide`).
    pub wide: String,
    /// Slow-arm ns/op.
    pub scalar_ns: f64,
    /// Fast-arm ns/op (NaN when the fast row is missing).
    pub wide_ns: f64,
    /// fast / slow (NaN when the fast row is missing).
    pub ratio: f64,
}

/// Outcome of the intra-run A/B check ([`ab_gate`]).
#[derive(Clone, Debug, Default)]
pub struct AbReport {
    /// A/B pairs found and compared.
    pub compared: usize,
    /// Pairs whose ratio exceeded the bound, or whose wide row vanished.
    pub violations: Vec<AbViolation>,
}

impl AbReport {
    /// True when every pair met the required ratio.
    pub fn is_green(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Intra-run A/B speedup check with configurable arm suffixes: for every
/// `current` row named `<prefix><stem><slow_suffix>`, the sibling
/// `<prefix><stem><fast_suffix>` must exist and satisfy
/// `fast_ns <= max_ratio * slow_ns`.  Both arms come from the *same* run
/// on the same hardware, so — unlike the stored-baseline timing gate —
/// the ratio bound is portable: it enforces the fast arm's speedup by
/// measurement wherever the gate runs.  The lane gate pairs
/// `_scalar`/`_wide` rows; the GEMM gate pairs `_reference`/`_blocked`
/// rows (DESIGN.md §15).
pub fn ab_gate_suffixed(
    current: &[BenchRow],
    prefix: &str,
    slow_suffix: &str,
    fast_suffix: &str,
    max_ratio: f64,
) -> AbReport {
    let mut report = AbReport::default();
    for c in current {
        let stem = match c
            .name
            .strip_prefix(prefix)
            .and_then(|rest| rest.strip_suffix(slow_suffix))
        {
            Some(stem) => stem,
            None => continue,
        };
        let fast_name = format!("{prefix}{stem}{fast_suffix}");
        let violation = match current.iter().find(|r| r.name == fast_name) {
            Some(fast) => {
                report.compared += 1;
                let ratio = fast.ns_per_op / c.ns_per_op;
                (c.ns_per_op > 0.0 && ratio > max_ratio).then(|| AbViolation {
                    scalar: c.name.clone(),
                    wide: fast_name.clone(),
                    scalar_ns: c.ns_per_op,
                    wide_ns: fast.ns_per_op,
                    ratio,
                })
            }
            None => Some(AbViolation {
                scalar: c.name.clone(),
                wide: fast_name.clone(),
                scalar_ns: c.ns_per_op,
                wide_ns: f64::NAN,
                ratio: f64::NAN,
            }),
        };
        report.violations.extend(violation);
    }
    report
}

/// [`ab_gate_suffixed`] specialized to the original `_scalar`/`_wide`
/// lane-kernel pairing.
pub fn ab_gate(current: &[BenchRow], prefix: &str, max_ratio: f64) -> AbReport {
    ab_gate_suffixed(current, prefix, "_scalar", "_wide", max_ratio)
}

/// One parsed `--ab-specs` entry: which row family to pair and the
/// required intra-run speedup.
#[derive(Clone, Debug, PartialEq)]
pub struct AbSpec {
    /// Row-name prefix selecting the family (e.g. "lanes/", "gemm/").
    pub prefix: String,
    /// Slow-arm suffix (e.g. "_scalar", "_reference").
    pub slow_suffix: String,
    /// Fast-arm suffix (e.g. "_wide", "_blocked").
    pub fast_suffix: String,
    /// Required bound: `fast_ns <= max_ratio * slow_ns`.
    pub max_ratio: f64,
}

/// Parse a comma-separated `--ab-specs` value.  Each entry is
/// `prefix:slow:fast:ratio` — e.g.
/// `lanes/:scalar:wide:0.67,gemm/:reference:blocked:0.5` — where the
/// suffixes are given without their leading underscore.
pub fn parse_ab_specs(raw: &str) -> Result<Vec<AbSpec>> {
    raw.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|entry| {
            let parts: Vec<&str> = entry.split(':').collect();
            let [prefix, slow, fast, ratio] = parts.as_slice() else {
                return Err(anyhow!(
                    "ab spec '{entry}' (expected prefix:slow:fast:ratio)"
                ));
            };
            let max_ratio: f64 = ratio
                .parse()
                .map_err(|_| anyhow!("ab spec '{entry}': bad ratio '{ratio}'"))?;
            if max_ratio <= 0.0 {
                return Err(anyhow!("ab spec '{entry}': ratio must be > 0"));
            }
            Ok(AbSpec {
                prefix: prefix.to_string(),
                slow_suffix: format!("_{slow}"),
                fast_suffix: format!("_{fast}"),
                max_ratio,
            })
        })
        .collect()
}

/// The bench-gate driver: everything behind the `bench-gate` binary and
/// the `zo bench-gate` subcommand (both parse argv into [`Args`] and
/// delegate here).  Diffs `--current` against `--baseline` within the
/// gated row families, enforces the intra-run A/B speedup bounds, prints
/// every violation, and — on a green gate with `--store-dir` — archives
/// the exact report bytes into the content-addressed store under
/// `--store-label` (DESIGN.md §12, §16).
pub fn gate_cli(args: &Args) -> Result<()> {
    let baseline_path = args.require("baseline")?.to_string();
    let current_path = args.require("current")?.to_string();
    let threshold = args.get_f64("threshold", 0.20)?;
    let bytes_threshold = args.get_f64("bytes-threshold", threshold)?;
    let ab_max_ratio = args.get_f64("ab-max-ratio", 0.0)?;
    let ab_prefix = args.get_or("ab-prefix", "lanes/").to_string();
    let ab_specs = parse_ab_specs(args.get_or("ab-specs", ""))?;
    let gates_raw = args
        .get_or("gate", "loss_k,axpy_k,probe_combine,mlp,mem/")
        .to_string();
    let gates: Vec<&str> = gates_raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();

    let baseline = parse_rows(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )?;
    let current_text = std::fs::read_to_string(&current_path)
        .with_context(|| format!("reading current {current_path}"))?;
    let current = parse_rows(&current_text)?;

    let report = gate(&baseline, &current, threshold, bytes_threshold, &gates);
    println!(
        "bench-gate: {} gated row(s) compared against {baseline_path} \
         (ns +{:.0}%, bytes +{:.0}%, gates: {gates_raw})",
        report.compared,
        threshold * 100.0,
        bytes_threshold * 100.0
    );
    for m in &report.missing {
        println!("  MISSING from current run: {m}");
    }
    if !report.regressions.is_empty() {
        let mut t = Table::new(
            "bench regressions",
            &["row", "metric", "baseline", "current", "ratio", "limit"],
        );
        for r in &report.regressions {
            let limit = match r.metric {
                "peak_bytes" => bytes_threshold,
                _ => threshold,
            };
            t.row(vec![
                r.name.clone(),
                r.metric.to_string(),
                format!("{:.1}", r.baseline),
                format!("{:.1}", r.current),
                format!("{:.2}x", r.ratio),
                format!("<= {:.2}x", 1.0 + limit),
            ]);
        }
        t.print();
    }

    // intra-run scalar-vs-wide speedup (hardware-portable: both arms are
    // measured in the same run, so no stored anchor is involved)
    let ab = if ab_max_ratio > 0.0 {
        let ab = ab_gate(&current, &ab_prefix, ab_max_ratio);
        println!(
            "bench-gate: {} A/B pair(s) checked (prefix {ab_prefix}, wide <= {lim:.2}x scalar)",
            ab.compared,
            lim = ab_max_ratio
        );
        if !ab.violations.is_empty() {
            let mut t = Table::new(
                "A/B speedup violations",
                &["scalar row", "scalar ns", "wide ns", "ratio", "limit"],
            );
            for v in &ab.violations {
                t.row(vec![
                    v.scalar.clone(),
                    format!("{:.1}", v.scalar_ns),
                    if v.wide_ns.is_nan() {
                        "MISSING".to_string()
                    } else {
                        format!("{:.1}", v.wide_ns)
                    },
                    format!("{:.2}x", v.ratio),
                    format!("<= {ab_max_ratio:.2}x"),
                ]);
            }
            t.print();
        }
        ab
    } else {
        Default::default()
    };

    // suffixed A/B families (--ab-specs): same intra-run portability as
    // the lane pairing, with per-family suffixes and bounds
    let mut spec_violations = 0usize;
    for spec in &ab_specs {
        let rep = ab_gate_suffixed(
            &current,
            &spec.prefix,
            &spec.slow_suffix,
            &spec.fast_suffix,
            spec.max_ratio,
        );
        println!(
            "bench-gate: {} A/B pair(s) checked (prefix {}, *{} <= {:.2}x *{})",
            rep.compared, spec.prefix, spec.fast_suffix, spec.max_ratio, spec.slow_suffix,
        );
        if !rep.violations.is_empty() {
            let mut t = Table::new(
                "A/B speedup violations",
                &["slow row", "slow ns", "fast ns", "ratio", "limit"],
            );
            for v in &rep.violations {
                t.row(vec![
                    v.scalar.clone(),
                    format!("{:.1}", v.scalar_ns),
                    if v.wide_ns.is_nan() {
                        "MISSING".to_string()
                    } else {
                        format!("{:.1}", v.wide_ns)
                    },
                    format!("{:.2}x", v.ratio),
                    format!("<= {:.2}x", spec.max_ratio),
                ]);
            }
            t.print();
        }
        spec_violations += rep.violations.len();
    }

    if !report.is_green() || !ab.is_green() || spec_violations > 0 {
        bail!(
            "{} regression(s), {} missing gated row(s), {} A/B violation(s)",
            report.regressions.len(),
            report.missing.len(),
            ab.violations.len() + spec_violations
        );
    }
    println!("bench-gate: green");
    // archive the exact report bytes that passed: store object + lockfile
    // pin, so the audit trail dedups across identical re-runs
    if let Some(dir) = args.get("store-dir") {
        let store = crate::store::Store::open(dir);
        let hash = store.put(current_text.as_bytes())?;
        let label = args.get_or("store-label", "current");
        crate::store::BenchLock::record(store.root(), label, &hash)?;
        println!("bench-gate: archived gated report as {hash} (label '{label}')");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str, ns: f64, bytes: Option<f64>) -> BenchRow {
        BenchRow { name: name.into(), ns_per_op: ns, peak_bytes: bytes }
    }

    #[test]
    fn parse_roundtrips_bencher_format() {
        let text = r#"{
          "rows": [
            {"name": "scale/loss_k_k5", "ns_per_op": 1200.5},
            {"name": "mem/bestofk5", "ns_per_op": 3.0, "peak_bytes": 4096}
          ]
        }"#;
        let rows = parse_rows(text).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "scale/loss_k_k5");
        assert_eq!(rows[0].peak_bytes, None);
        assert_eq!(rows[1].peak_bytes, Some(4096.0));
        assert!(parse_rows("{}").is_err());
        assert!(parse_rows(r#"{"rows": [{"ns_per_op": 1}]}"#).is_err());
    }

    #[test]
    fn gate_passes_within_threshold_and_on_improvement() {
        let base = [row("scale/loss_k", 1000.0, None), row("mlp/loss_k", 500.0, None)];
        let cur = [
            row("scale/loss_k", 1150.0, None), // +15% < +20%
            row("mlp/loss_k", 200.0, None),    // faster: never fails
        ];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "mlp"]);
        assert_eq!(rep.compared, 2);
        assert!(rep.is_green(), "{rep:?}");
    }

    #[test]
    fn gate_fails_on_regression_missing_row_and_byte_growth() {
        let base = [
            row("scale/loss_k", 1000.0, None),
            row("mem/mlp_peak", 100.0, Some(1000.0)),
            row("scale/axpy_k", 10.0, None),
        ];
        let cur = [
            row("scale/loss_k", 1300.0, None),      // +30% ns: fails
            row("mem/mlp_peak", 100.0, Some(1500.0)), // +50% bytes: fails
                                                      // axpy_k missing: fails
        ];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "axpy_k", "mlp"]);
        assert!(!rep.is_green());
        assert_eq!(rep.missing, vec!["scale/axpy_k".to_string()]);
        assert_eq!(rep.regressions.len(), 2);
        let metrics: Vec<&str> = rep.regressions.iter().map(|r| r.metric).collect();
        assert!(metrics.contains(&"ns_per_op"));
        assert!(metrics.contains(&"peak_bytes"));
        let r0 = rep
            .regressions
            .iter()
            .find(|r| r.metric == "ns_per_op")
            .unwrap();
        assert!((r0.ratio - 1.3).abs() < 1e-9);
    }

    #[test]
    fn non_gated_rows_are_ignored() {
        let base = [row("rng/normal", 100.0, None)];
        let cur = [row("rng/normal", 900.0, None)];
        let rep = gate(&base, &cur, 0.20, 0.20, &["loss_k", "axpy_k", "probe_combine", "mlp"]);
        assert_eq!(rep.compared, 0);
        assert!(rep.is_green());
    }

    #[test]
    fn thresholds_apply_per_metric() {
        // +30% ns but a loose ns threshold passes, while the same +30%
        // on deterministic bytes under a tight bytes threshold fails
        let base = [row("mem/mlp_peak", 100.0, Some(1000.0))];
        let cur = [row("mem/mlp_peak", 130.0, Some(1300.0))];
        let rep = gate(&base, &cur, 0.50, 0.05, &["mem/"]);
        assert_eq!(rep.regressions.len(), 1, "{rep:?}");
        assert_eq!(rep.regressions[0].metric, "peak_bytes");
    }

    #[test]
    fn byte_gate_skipped_when_either_side_lacks_bytes() {
        let base = [row("mlp/loss_k", 100.0, Some(100.0))];
        let cur = [row("mlp/loss_k", 100.0, None)];
        let rep = gate(&base, &cur, 0.20, 0.20, &["mlp"]);
        assert!(rep.is_green(), "bytes gate needs both sides: {rep:?}");
    }

    #[test]
    fn ab_gate_enforces_intra_run_speedup() {
        let cur = [
            row("lanes/axpy_k_k5_d1M_scalar", 1000.0, None),
            row("lanes/axpy_k_k5_d1M_wide", 300.0, None), // 0.3 <= 0.67
            row("lanes/probe_combine_k5_d1M_scalar", 1000.0, None),
            row("lanes/probe_combine_k5_d1M_wide", 900.0, None), // 0.9: fails
            row("tensor/axpy_1.3M", 50.0, None),                 // no prefix: ignored
        ];
        let rep = ab_gate(&cur, "lanes/", 0.67);
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.violations.len(), 1, "{rep:?}");
        assert_eq!(rep.violations[0].wide, "lanes/probe_combine_k5_d1M_wide");
        assert!((rep.violations[0].ratio - 0.9).abs() < 1e-9);
    }

    #[test]
    fn ab_gate_flags_missing_wide_counterpart() {
        let cur = [row("lanes/axpy_k_k5_d1M_scalar", 1000.0, None)];
        let rep = ab_gate(&cur, "lanes/", 0.67);
        assert_eq!(rep.compared, 0);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].wide_ns.is_nan());
    }

    #[test]
    fn ab_gate_suffixed_pairs_reference_blocked() {
        let cur = [
            row("gemm/tfm_qkv_256x768x768_reference", 1000.0, None),
            row("gemm/tfm_qkv_256x768x768_blocked", 400.0, None), // 0.4 <= 0.5
            row("gemm/mlp_256x784x256_reference", 1000.0, None),
            row("gemm/mlp_256x784x256_blocked", 700.0, None), // 0.7: fails
            row("lanes/axpy_k_k5_d1M_scalar", 10.0, None),    // other family
        ];
        let rep = ab_gate_suffixed(&cur, "gemm/", "_reference", "_blocked", 0.5);
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.violations.len(), 1, "{rep:?}");
        assert_eq!(rep.violations[0].wide, "gemm/mlp_256x784x256_blocked");
        // a reference row with no blocked sibling is itself a violation
        let orphan = [row("gemm/tfm_wo_reference", 1000.0, None)];
        let rep = ab_gate_suffixed(&orphan, "gemm/", "_reference", "_blocked", 0.5);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].wide_ns.is_nan());
    }

    #[test]
    fn ab_specs_parse_and_reject_malformed() {
        let specs =
            parse_ab_specs("lanes/:scalar:wide:0.67, gemm/:reference:blocked:0.5").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].prefix, "lanes/");
        assert_eq!(specs[0].slow_suffix, "_scalar");
        assert_eq!(specs[0].fast_suffix, "_wide");
        assert!((specs[0].max_ratio - 0.67).abs() < 1e-12);
        assert_eq!(specs[1].prefix, "gemm/");
        assert_eq!(specs[1].slow_suffix, "_reference");
        assert_eq!(specs[1].fast_suffix, "_blocked");
        assert!(parse_ab_specs("").unwrap().is_empty());
        assert!(parse_ab_specs("gemm/:reference:blocked").is_err());
        assert!(parse_ab_specs("gemm/:reference:blocked:fast").is_err());
        assert!(parse_ab_specs("gemm/:reference:blocked:0").is_err());
    }
}
