//! Content-addressed artifact store (DESIGN.md §16).
//!
//! Every persisted artifact — snapshot blobs, completed-trial outcome
//! records, archived bench baselines, materialized corpora — lives in one
//! pacm-style object store: a blob is written once under
//! `objects/<hh>/<sha256-hex>` (the first two hex chars shard the
//! directory) and referenced everywhere else *by hash*.  Identical
//! content is therefore stored exactly once: retained `step-<N>`
//! snapshot generations that share an unchanged parameter vector, LDSD
//! policy mean, or curve prefix all point at the same object, and a
//! re-run grid's outcome records dedup against the previous run's.
//!
//! * **Writes are atomic**: object bytes land in a `.tmp-<hash>-<pid>`
//!   sibling that is `rename`d into place; a crash mid-write leaves only
//!   ignorable staging debris, never a half object.  An object that
//!   already exists is never rewritten (content addressing makes the
//!   write idempotent).
//! * **Reads re-hash**: [`Store::get`] recomputes the digest and refuses
//!   an object whose bytes no longer match its name, so corruption is
//!   detected at the first read, not propagated into a resumed run.
//! * **GC is refcount-free mark-and-sweep** ([`Store::gc`]): the roots
//!   are manifests — `manifest.json` files under the caller's root
//!   directories plus lockfiles (`grid.lock.json`, `bench.lock.json`,
//!   `corpora.json`) — and marking follows hash references *through*
//!   stored objects (an outcome record referenced by the grid lock keeps
//!   its curve blobs live).  Everything unmarked is swept.  Pruning a
//!   snapshot directory or dropping a lock entry is all it takes to
//!   unroot its objects.
//! * **[`Store::verify`]** re-hashes every object and reports mismatches
//!   — the `zo-ldsd store verify` CLI pass.
//!
//! The store location resolves under the uniform precedence contract
//! (DESIGN.md §17e): [`crate::snapshot::CheckpointConfig::store_dir`]
//! (`--store-dir`, configured) → `ZO_STORE_DIR` (environment) →
//! `<checkpoint-dir>/store` (the default, so a grid's trials share one
//! store under the grid base and dedup across trials).

mod lock;
mod sha256;

pub use lock::{BenchLock, GridLock, LockEntry, BENCH_LOCK_FILE, GRID_LOCK_FILE};
pub use sha256::{sha256, sha256_hex};

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jsonio::{parse, Json};

/// A content-addressed blob store rooted at one directory.
///
/// Opening is cheap (no I/O); directories are created lazily on the
/// first write, so read paths against a store that was never written
/// (e.g. a legacy checkpoint tree) touch nothing.
#[derive(Clone, Debug)]
pub struct Store {
    root: PathBuf,
}

/// What [`Store::verify`] found.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// Objects whose bytes re-hashed to their name.
    pub ok: usize,
    /// Object hashes whose bytes did NOT re-hash to their name.
    pub corrupt: Vec<String>,
}

/// What [`Store::gc`] did.
#[derive(Clone, Debug, Default)]
pub struct GcReport {
    /// Objects reachable from the roots (kept).
    pub live: usize,
    /// Unreachable objects deleted.
    pub swept: usize,
    /// Total bytes reclaimed.
    pub swept_bytes: u64,
}

fn is_hex64(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

impl Store {
    /// Open (lazily) the store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where the object named `hash` lives (whether or not it exists).
    pub fn object_path(&self, hash: &str) -> PathBuf {
        let shard = if hash.len() >= 2 { &hash[..2] } else { hash };
        self.root.join("objects").join(shard).join(hash)
    }

    /// True if the object named `hash` is present.
    pub fn contains(&self, hash: &str) -> bool {
        self.object_path(hash).is_file()
    }

    /// Store `bytes` under their content hash and return it.  Idempotent:
    /// an object that already exists is left untouched (dedup), otherwise
    /// the bytes are staged in a `.tmp-*` sibling and renamed into place
    /// (atomic commit — readers never see a partial object).
    pub fn put(&self, bytes: &[u8]) -> Result<String> {
        let hash = sha256_hex(bytes);
        let path = self.object_path(&hash);
        if path.is_file() {
            return Ok(hash);
        }
        let dir = path.parent().expect("object path has a shard dir");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let tmp = dir.join(format!(".tmp-{hash}-{}", std::process::id()));
        std::fs::write(&tmp, bytes)
            .with_context(|| format!("staging {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        Ok(hash)
    }

    /// Read the object named `hash`, re-hashing the bytes to detect
    /// corruption: a flipped bit anywhere in the object fails loudly here
    /// rather than silently resuming a training run from bad state.
    pub fn get(&self, hash: &str) -> Result<Vec<u8>> {
        let path = self.object_path(hash);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading object {}", path.display()))?;
        let got = sha256_hex(&bytes);
        if got != hash {
            bail!(
                "object {}: content hashes to {got} (corrupt object)",
                path.display()
            );
        }
        Ok(bytes)
    }

    /// Every object hash in the store, sorted.  Staging debris and
    /// foreign files are ignored.
    pub fn objects(&self) -> Vec<String> {
        let mut out = Vec::new();
        let objects = self.root.join("objects");
        let shards = match std::fs::read_dir(&objects) {
            Ok(rd) => rd,
            Err(_) => return out,
        };
        for shard in shards.flatten() {
            if let Ok(rd) = std::fs::read_dir(shard.path()) {
                for entry in rd.flatten() {
                    let name = entry.file_name().to_string_lossy().into_owned();
                    if is_hex64(&name) {
                        out.push(name);
                    }
                }
            }
        }
        out.sort();
        out
    }

    /// Number of objects in the store (the dedup-assertion counter).
    pub fn object_count(&self) -> usize {
        self.objects().len()
    }

    /// Re-hash every object against its name.
    pub fn verify(&self) -> VerifyReport {
        let mut report = VerifyReport::default();
        for hash in self.objects() {
            match self.get(&hash) {
                Ok(_) => report.ok += 1,
                Err(_) => report.corrupt.push(hash),
            }
        }
        report
    }

    /// Mark-and-sweep garbage collection.  Marking starts from every
    /// `*.json` file under the given root directories (recursively;
    /// snapshot/outcome `manifest.json`s, `grid.lock.json`,
    /// report files) plus the store root's own lockfiles
    /// (`bench.lock.json`, `corpora.json`), collects every 64-hex string
    /// that names a present object, and follows references *through*
    /// stored JSON objects to a fixpoint — an outcome record pinned by
    /// the grid lock keeps its curve blobs, a corpus manifest keeps its
    /// token blobs.  Unmarked objects are deleted; `.tmp-*` staging
    /// debris in the object tree is swept too.
    pub fn gc(&self, roots: &[PathBuf]) -> Result<GcReport> {
        let mut pending: Vec<String> = Vec::new();
        let objects_dir = self.root.join("objects");
        // the store root's own lockfiles are always roots, so corpora and
        // archived bench baselines survive even when the caller only
        // passes checkpoint trees
        let mut scan_roots: Vec<PathBuf> = vec![self.root.clone()];
        scan_roots.extend(roots.iter().cloned());
        for root in &scan_roots {
            collect_root_refs(root, &objects_dir, self, &mut pending);
        }
        // transitive closure through stored JSON objects
        let mut marked: BTreeSet<String> = BTreeSet::new();
        while let Some(hash) = pending.pop() {
            if !marked.insert(hash.clone()) {
                continue;
            }
            if let Ok(bytes) = self.get(&hash) {
                if let Ok(text) = std::str::from_utf8(&bytes) {
                    if let Ok(json) = parse(text) {
                        collect_json_refs(&json, self, &mut pending);
                    }
                }
            }
        }
        // sweep
        let mut report = GcReport { live: marked.len(), ..Default::default() };
        if let Ok(shards) = std::fs::read_dir(&objects_dir) {
            for shard in shards.flatten() {
                let mut emptied = true;
                if let Ok(rd) = std::fs::read_dir(shard.path()) {
                    for entry in rd.flatten() {
                        let name = entry.file_name().to_string_lossy().into_owned();
                        let stale_tmp = name.starts_with(".tmp-");
                        if (is_hex64(&name) && !marked.contains(&name)) || stale_tmp {
                            let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
                            if std::fs::remove_file(entry.path()).is_ok() && !stale_tmp {
                                report.swept += 1;
                                report.swept_bytes += len;
                            }
                        } else {
                            emptied = false;
                        }
                    }
                }
                if emptied {
                    std::fs::remove_dir(shard.path()).ok();
                }
            }
        }
        Ok(report)
    }
}

/// Recursively scan `root` for `*.json` files (skipping the store's
/// object tree itself) and collect candidate object references.
fn collect_root_refs(root: &Path, objects_dir: &Path, store: &Store, out: &mut Vec<String>) {
    if root == objects_dir {
        return;
    }
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "json") {
            if let Ok(text) = std::fs::read_to_string(root) {
                if let Ok(json) = parse(&text) {
                    collect_json_refs(&json, store, out);
                }
            }
        }
        return;
    }
    if let Ok(rd) = std::fs::read_dir(root) {
        for entry in rd.flatten() {
            collect_root_refs(&entry.path(), objects_dir, store, out);
        }
    }
}

/// Collect every string in `json` that is 64 hex chars *and* names a
/// present object.  Conservative by construction: a stray hex string can
/// only over-retain, never free a live blob.
fn collect_json_refs(json: &Json, store: &Store, out: &mut Vec<String>) {
    match json {
        Json::Str(s) => {
            if is_hex64(s) && store.contains(s) {
                out.push(s.clone());
            }
        }
        Json::Arr(items) => {
            for item in items {
                collect_json_refs(item, store, out);
            }
        }
        Json::Obj(map) => {
            for val in map.values() {
                collect_json_refs(val, store, out);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("zo_store_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn put_get_roundtrip_and_dedup() {
        let dir = tmpdir("roundtrip");
        let store = Store::open(dir.join("store"));
        let h1 = store.put(b"hello").unwrap();
        let h2 = store.put(b"hello").unwrap();
        assert_eq!(h1, h2, "identical content must share one object");
        assert_eq!(store.object_count(), 1);
        assert_eq!(store.get(&h1).unwrap(), b"hello");
        let h3 = store.put(b"world").unwrap();
        assert_ne!(h1, h3);
        assert_eq!(store.object_count(), 2);
        assert_eq!(store.objects().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_detects_corruption() {
        let dir = tmpdir("corrupt");
        let store = Store::open(dir.join("store"));
        let h = store.put(b"precious bits").unwrap();
        let path = store.object_path(&h);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.get(&h).unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
        let report = store.verify();
        assert_eq!(report.ok, 0);
        assert_eq!(report.corrupt, vec![h]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn verify_green_on_intact_store() {
        let dir = tmpdir("verify");
        let store = Store::open(dir.join("store"));
        for i in 0..5u8 {
            store.put(&[i; 9]).unwrap();
        }
        let report = store.verify();
        assert_eq!(report.ok, 5);
        assert!(report.corrupt.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_keeps_rooted_sweeps_unrooted() {
        let dir = tmpdir("gc");
        let store = Store::open(dir.join("store"));
        let live = store.put(b"live blob").unwrap();
        let nested = store.put(b"nested blob").unwrap();
        // a stored JSON object referencing `nested` — reachable through
        // one dereference, the outcome-record shape
        let manifest = format!("{{\"blobs\":{{\"curve\":{{\"hash\":\"{nested}\"}}}}}}");
        let mhash = store.put(manifest.as_bytes()).unwrap();
        let dead = store.put(b"dead blob").unwrap();
        // root: a manifest.json on disk referencing `live` + `mhash`
        let rootdir = dir.join("trial");
        std::fs::create_dir_all(&rootdir).unwrap();
        std::fs::write(
            rootdir.join("manifest.json"),
            format!("{{\"a\":\"{live}\",\"outcome\":\"{mhash}\"}}"),
        )
        .unwrap();
        let report = store.gc(&[dir.clone()]).unwrap();
        assert_eq!(report.live, 3);
        assert_eq!(report.swept, 1);
        assert!(store.contains(&live));
        assert!(store.contains(&mhash));
        assert!(store.contains(&nested), "transitively referenced blob must survive");
        assert!(!store.contains(&dead));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_sweeps_staging_debris() {
        let dir = tmpdir("gc_tmp");
        let store = Store::open(dir.join("store"));
        let h = store.put(b"keep me").unwrap();
        let shard = store.object_path(&h).parent().unwrap().to_path_buf();
        std::fs::write(shard.join(".tmp-deadbeef-123"), b"half-written").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!("{{\"k\":\"{h}\"}}"),
        )
        .unwrap();
        store.gc(&[dir.clone()]).unwrap();
        assert!(store.contains(&h));
        assert!(!shard.join(".tmp-deadbeef-123").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hex64_filter() {
        assert!(is_hex64(&"a".repeat(64)));
        assert!(!is_hex64(&"A".repeat(64)));
        assert!(!is_hex64(&"a".repeat(63)));
        assert!(!is_hex64(&"g".repeat(64)));
    }
}
