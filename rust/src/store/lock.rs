//! Lockfile-style pinning of canonical spec hashes to store objects.
//!
//! `grid.lock.json` lives at a grid's checkpoint base and maps each
//! trial's canonical spec hash (SHA-256 over the canonical-JSON identity
//! of the resolved spec, [`crate::coordinator`]) to the store hash of its
//! completed outcome record.  The coordinator's warm-start short-circuit
//! keys on this map: hash identity replaces the old
//! sanitized-directory-name + label/seed/budget convention, so stale
//! detection is exact (any identity field change changes the hash) and a
//! reordered or partially-overlapping re-run grid still hits.
//!
//! `bench.lock.json` lives at the store root and pins bench-baseline
//! labels to archived report objects ([`crate::bench`]'s regression gate
//! archives its gated `BENCH_*.json` there).
//!
//! Both files are read-modify-written under a process-wide mutex and
//! committed with the same tmp+rename discipline as snapshot manifests,
//! so concurrent grid workers in one process never tear an update and a
//! crash never leaves a half lockfile.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::jsonio::{parse, to_string_pretty, Json};

/// File name of the per-grid lockfile (at the grid's checkpoint base).
pub const GRID_LOCK_FILE: &str = "grid.lock.json";
/// File name of the bench-baseline lockfile (at the store root).
pub const BENCH_LOCK_FILE: &str = "bench.lock.json";

const GRID_LOCK_MAGIC: &str = "zogrid1";
const BENCH_LOCK_MAGIC: &str = "zobench1";
const LOCK_VERSION: u64 = 1;

/// Serializes read-modify-write cycles on lockfiles across grid workers.
static LOCK_IO: Mutex<()> = Mutex::new(());

/// One pinned trial in a [`GridLock`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LockEntry {
    /// Store hash of the trial's outcome-record object.
    pub outcome: String,
    /// The trial's human-readable spec id (diagnostic only — identity is
    /// the spec hash keying this entry).
    pub id: String,
    /// The trial's training label (diagnostic only).
    pub label: String,
}

/// In-memory view of a `grid.lock.json`.
#[derive(Clone, Debug, Default)]
pub struct GridLock {
    trials: BTreeMap<String, LockEntry>,
}

fn lock_path(base: &Path) -> PathBuf {
    base.join(GRID_LOCK_FILE)
}

fn commit_json(path: &Path, json: &Json) -> Result<()> {
    let dir = path.parent().context("lockfile path has no parent")?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let tmp = dir.join(format!(
        ".tmp-{}-{}",
        path.file_name().map(|n| n.to_string_lossy()).unwrap_or_default(),
        std::process::id()
    ));
    std::fs::write(&tmp, to_string_pretty(json))
        .with_context(|| format!("staging {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("committing {}", path.display()))?;
    Ok(())
}

impl GridLock {
    /// Load the lockfile at `base`, tolerating a missing or unreadable
    /// file (→ empty lock: the grid simply runs cold).
    pub fn load(base: &Path) -> GridLock {
        let mut out = GridLock::default();
        let text = match std::fs::read_to_string(lock_path(base)) {
            Ok(t) => t,
            Err(_) => return out,
        };
        let json = match parse(&text) {
            Ok(j) => j,
            Err(_) => return out,
        };
        if json.get("magic").and_then(Json::as_str) != Some(GRID_LOCK_MAGIC) {
            return out;
        }
        if let Some(trials) = json.get("trials").and_then(Json::as_obj) {
            for (spec_hash, entry) in trials {
                let (Some(outcome), Some(id), Some(label)) = (
                    entry.get("outcome").and_then(Json::as_str),
                    entry.get("id").and_then(Json::as_str),
                    entry.get("label").and_then(Json::as_str),
                ) else {
                    continue;
                };
                out.trials.insert(
                    spec_hash.clone(),
                    LockEntry {
                        outcome: outcome.to_string(),
                        id: id.to_string(),
                        label: label.to_string(),
                    },
                );
            }
        }
        out
    }

    /// Look up the pinned outcome for a canonical spec hash.
    pub fn get(&self, spec_hash: &str) -> Option<&LockEntry> {
        self.trials.get(spec_hash)
    }

    /// Number of pinned trials.
    pub fn len(&self) -> usize {
        self.trials.len()
    }

    /// True if no trial is pinned.
    pub fn is_empty(&self) -> bool {
        self.trials.is_empty()
    }

    /// Pin `spec_hash → entry` in the lockfile at `base`, preserving all
    /// other entries (read-modify-write under the process-wide lock).
    pub fn record(base: &Path, spec_hash: &str, entry: &LockEntry) -> Result<()> {
        let _guard = LOCK_IO.lock().unwrap_or_else(|e| e.into_inner());
        let mut lock = GridLock::load(base);
        lock.trials.insert(spec_hash.to_string(), entry.clone());
        let mut trials = BTreeMap::new();
        for (hash, e) in &lock.trials {
            let mut obj = BTreeMap::new();
            obj.insert("outcome".to_string(), Json::Str(e.outcome.clone()));
            obj.insert("id".to_string(), Json::Str(e.id.clone()));
            obj.insert("label".to_string(), Json::Str(e.label.clone()));
            trials.insert(hash.clone(), Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("magic".to_string(), Json::Str(GRID_LOCK_MAGIC.to_string()));
        root.insert("version".to_string(), Json::Num(LOCK_VERSION as f64));
        root.insert("trials".to_string(), Json::Obj(trials));
        commit_json(&lock_path(base), &Json::Obj(root))
    }
}

/// In-memory view of a `bench.lock.json` (label → archived report hash).
#[derive(Clone, Debug, Default)]
pub struct BenchLock {
    entries: BTreeMap<String, String>,
}

fn bench_lock_path(store_root: &Path) -> PathBuf {
    store_root.join(BENCH_LOCK_FILE)
}

impl BenchLock {
    /// Load the bench lockfile at the store root, tolerating absence.
    pub fn load(store_root: &Path) -> BenchLock {
        let mut out = BenchLock::default();
        let text = match std::fs::read_to_string(bench_lock_path(store_root)) {
            Ok(t) => t,
            Err(_) => return out,
        };
        let json = match parse(&text) {
            Ok(j) => j,
            Err(_) => return out,
        };
        if json.get("magic").and_then(Json::as_str) != Some(BENCH_LOCK_MAGIC) {
            return out;
        }
        if let Some(entries) = json.get("entries").and_then(Json::as_obj) {
            for (label, hash) in entries {
                if let Some(h) = hash.as_str() {
                    out.entries.insert(label.clone(), h.to_string());
                }
            }
        }
        out
    }

    /// The archived report hash pinned for `label`, if any.
    pub fn get(&self, label: &str) -> Option<&str> {
        self.entries.get(label).map(String::as_str)
    }

    /// Number of pinned labels.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no label is pinned.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Pin `label → hash` in the lockfile at `store_root`, preserving all
    /// other entries.
    pub fn record(store_root: &Path, label: &str, hash: &str) -> Result<()> {
        let _guard = LOCK_IO.lock().unwrap_or_else(|e| e.into_inner());
        let mut lock = BenchLock::load(store_root);
        lock.entries.insert(label.to_string(), hash.to_string());
        let mut entries = BTreeMap::new();
        for (l, h) in &lock.entries {
            entries.insert(l.clone(), Json::Str(h.clone()));
        }
        let mut root = BTreeMap::new();
        root.insert("magic".to_string(), Json::Str(BENCH_LOCK_MAGIC.to_string()));
        root.insert("version".to_string(), Json::Num(LOCK_VERSION as f64));
        root.insert("entries".to_string(), Json::Obj(entries));
        commit_json(&bench_lock_path(store_root), &Json::Obj(root))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("zo_lock_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn grid_lock_roundtrip_preserves_entries() {
        let dir = tmpdir("grid");
        let e1 = LockEntry {
            outcome: "a".repeat(64),
            id: "trial-1".into(),
            label: "ldsd+sgd".into(),
        };
        let e2 = LockEntry {
            outcome: "b".repeat(64),
            id: "trial-2".into(),
            label: "gaussian+adam".into(),
        };
        GridLock::record(&dir, &"1".repeat(64), &e1).unwrap();
        GridLock::record(&dir, &"2".repeat(64), &e2).unwrap();
        let lock = GridLock::load(&dir);
        assert_eq!(lock.len(), 2);
        assert_eq!(lock.get(&"1".repeat(64)), Some(&e1));
        assert_eq!(lock.get(&"2".repeat(64)), Some(&e2));
        assert_eq!(lock.get(&"3".repeat(64)), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_lock_record_overwrites_same_hash() {
        let dir = tmpdir("grid_ow");
        let old = LockEntry { outcome: "a".repeat(64), id: "t".into(), label: "l".into() };
        let new = LockEntry { outcome: "c".repeat(64), id: "t".into(), label: "l".into() };
        GridLock::record(&dir, &"1".repeat(64), &old).unwrap();
        GridLock::record(&dir, &"1".repeat(64), &new).unwrap();
        let lock = GridLock::load(&dir);
        assert_eq!(lock.len(), 1);
        assert_eq!(lock.get(&"1".repeat(64)), Some(&new));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_or_corrupt_lock_loads_empty() {
        let dir = tmpdir("grid_missing");
        assert!(GridLock::load(&dir).is_empty());
        std::fs::write(dir.join(GRID_LOCK_FILE), "not json {").unwrap();
        assert!(GridLock::load(&dir).is_empty());
        std::fs::write(dir.join(GRID_LOCK_FILE), "{\"magic\":\"wrong\"}").unwrap();
        assert!(GridLock::load(&dir).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_lock_roundtrip() {
        let dir = tmpdir("bench");
        BenchLock::record(&dir, "main", &"d".repeat(64)).unwrap();
        BenchLock::record(&dir, "pr", &"e".repeat(64)).unwrap();
        let lock = BenchLock::load(&dir);
        assert_eq!(lock.len(), 2);
        assert_eq!(lock.get("main"), Some("d".repeat(64).as_str()));
        assert_eq!(lock.get("pr"), Some("e".repeat(64).as_str()));
        assert!(lock.get("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
