//! Thread-pool execution substrate (replaces tokio for this workload).
//!
//! The coordinator's parallelism is coarse-grained — independent training
//! trials, sweep points, eval batches — so a fixed worker pool with a
//! simple channel-fed queue is the right tool.  [`ThreadPool::scope_map`]
//! is the primary API: run a closure over a list of inputs in parallel and
//! collect results in order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (at least one).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("zo-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self { tx: Some(tx), workers, size }
    }

    /// Pool sized to the machine (leaving one core for the main thread).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map `f` over `inputs` in parallel; results come back in input order.
    /// Panics in `f` are isolated per item and surfaced as `Err`.
    pub fn scope_map<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, Result<R, String>)>();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    move || f(input),
                ))
                .map_err(|e| panic_message(e.as_ref()));
                let _ = rtx.send((i, result));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result channel closed early");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("missing result slot")).collect()
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scope_map((0..100).collect(), |x: i32| x * x);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), (i * i) as i32);
        }
    }

    #[test]
    fn panics_are_isolated() {
        let pool = ThreadPool::new(2);
        let out = pool.scope_map(vec![1, 2, 3], |x: i32| {
            if x == 2 {
                panic!("boom {x}");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(out[2], Ok(3));
    }

    #[test]
    fn all_workers_participate() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let out = pool.scope_map((0..64).collect(), move |_x: i32| {
            c2.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
            0
        });
        assert_eq!(out.len(), 64);
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }
}
