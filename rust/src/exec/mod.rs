//! Execution substrate: a fixed worker pool for coarse task-level work and
//! a shard-parallel [`ExecContext`] for the O(K d) kernel hot path.
//!
//! Two levels of parallelism live here (DESIGN.md §9):
//!
//! * **Task level** — independent training trials, sweep points, eval
//!   batches.  [`ThreadPool::scope_map`] runs a `'static` closure over a
//!   list of inputs on a fixed worker pool and collects results in order.
//! * **Shard level** — the per-step O(K d) work inside one trial:
//!   probe-matrix fills, blocked axpy/combine kernels, vectorized
//!   `loss_k` rows.  These need *borrowing* closures (they touch the
//!   probe matrix and parameter slices in place), so [`ExecContext`]
//!   drives them with `std::thread::scope` workers instead of the pool.
//!
//! Shard geometry is deterministic: boundaries are fixed by
//! [`ExecContext::shard_len`], never by worker count or schedule, and all
//! per-shard reductions are combined in shard order — so every result is
//! bitwise identical for 1 and N threads.  `ZO_THREADS` overrides the
//! default worker budget (see [`ExecContext::from_env`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool (task-level parallelism).
pub struct ThreadPool {
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (at least one).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("zo-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self { tx: Some(Mutex::new(tx)), workers, size }
    }

    /// Pool sized to the machine (leaving one core for the main thread).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool is shut down")
            .lock()
            .unwrap()
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map `f` over `inputs` in parallel; results come back in input order.
    /// Panics in `f` are isolated per item and surfaced as `Err`.
    pub fn scope_map<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, Result<R, String>)>();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    move || f(input),
                ))
                .map_err(|e| panic_message(e.as_ref()));
                let _ = rtx.send((i, result));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result channel closed early");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("missing result slot")).collect()
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default shard length (f32 elements) for shard-parallel kernels: a
/// multiple of the tensor kernels' cache block, large enough that a shard
/// amortizes a scoped-thread handoff.  Shard boundaries are part of the
/// deterministic sampling scheme (RNG substreams are keyed per shard), so
/// this is a fixed constant, not a tuning knob derived from the machine.
pub const DEFAULT_SHARD_LEN: usize = 1 << 16;

/// Shard-parallel execution context: a lazily-built shared [`ThreadPool`]
/// for task-level work plus a scoped-worker budget and fixed shard
/// geometry for the kernel hot path.
///
/// Cloning is cheap and shares the pool.  Determinism contract: for a
/// fixed `shard_len`, every operation driven through this context returns
/// bitwise-identical results regardless of `threads` — shard boundaries
/// depend only on `shard_len`, per-shard work is combined in shard order,
/// and RNG substreams are keyed by (seed, step, shard).
pub struct ExecContext {
    pool: Arc<Mutex<Option<Arc<ThreadPool>>>>,
    threads: usize,
    shard_len: usize,
}

impl Clone for ExecContext {
    fn clone(&self) -> Self {
        Self {
            pool: Arc::clone(&self.pool),
            threads: self.threads,
            shard_len: self.shard_len,
        }
    }
}

impl std::fmt::Debug for ExecContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecContext")
            .field("threads", &self.threads)
            .field("shard_len", &self.shard_len)
            .finish()
    }
}

impl ExecContext {
    /// Context with a worker budget of `threads` (at least one) and the
    /// default shard length.  No threads are spawned until used.
    pub fn new(threads: usize) -> Self {
        Self {
            pool: Arc::new(Mutex::new(None)),
            threads: threads.max(1),
            shard_len: DEFAULT_SHARD_LEN,
        }
    }

    /// Single-threaded context: every operation runs inline on the caller.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Context sized from the environment: `ZO_THREADS` if set (and a
    /// positive integer), else one worker per core minus one for the main
    /// thread.
    pub fn from_env() -> Self {
        let threads = std::env::var("ZO_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(ThreadPool::default_size);
        Self::new(threads)
    }

    /// Resolve a worker budget under the uniform CONFIGURED > ENV
    /// precedence contract (DESIGN.md §17): an explicit configuration
    /// (`--threads N`, N > 0) wins; `configured == 0` means unconfigured
    /// and defers to `ZO_THREADS`, then the core-count default
    /// ([`ExecContext::from_env`]).  The CLI threads every `--threads`
    /// flag through here so all subcommands resolve identically.
    pub fn resolve(configured: usize) -> Self {
        if configured > 0 {
            Self::new(configured)
        } else {
            Self::from_env()
        }
    }

    /// Override the shard length (element count per shard; must be > 0).
    /// Changing it changes sampler substream keying, so runs are only
    /// reproducible at a fixed shard length.
    pub fn with_shard_len(mut self, shard_len: usize) -> Self {
        assert!(shard_len > 0, "shard_len must be positive");
        self.shard_len = shard_len;
        self
    }

    /// Worker budget for shard-level work.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fixed shard length (f32 elements).
    pub fn shard_len(&self) -> usize {
        self.shard_len
    }

    /// Number of shards covering a buffer of `len` elements.
    pub fn shard_count(&self, len: usize) -> usize {
        // manual div_ceil: keeps the MSRV below the std stabilization
        (len + self.shard_len - 1) / self.shard_len
    }

    /// The shared task-level pool, created on first use with `threads`
    /// workers.  Reused by every clone of this context — callers must not
    /// build their own pools per grid (that oversubscribes the machine).
    pub fn pool(&self) -> Arc<ThreadPool> {
        let mut guard = self.pool.lock().unwrap();
        guard
            .get_or_insert_with(|| Arc::new(ThreadPool::new(self.threads)))
            .clone()
    }

    /// Derive the shard-level context for workers of a task-level section
    /// running `concurrent` tasks at once: the worker budget is divided so
    /// total concurrency stays at this context's level.  Shard length is
    /// unchanged, so determinism keying is unchanged.  The derived context
    /// gets its own (empty) pool slot: shard-level work runs on scoped
    /// threads, and sharing the parent's lazy slot would let a partitioned
    /// clone create the shared pool undersized.
    pub fn partition(&self, concurrent: usize) -> ExecContext {
        ExecContext {
            pool: Arc::new(Mutex::new(None)),
            threads: (self.threads / concurrent.max(1)).max(1),
            shard_len: self.shard_len,
        }
    }

    /// Scoped dynamic scheduler: run `task(i)` for `i in 0..n_tasks` on up
    /// to `threads` borrowing workers.  Assignment order is arbitrary;
    /// callers keep determinism by indexing all effects by `i`.
    fn run_tasks(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        self.run_tasks_local(n_tasks, &|| (), &|_, i| task(i));
    }

    /// Scoped dynamic scheduler with worker-local state: like
    /// [`ExecContext::run_tasks`], but each worker builds one `init()`
    /// value at start-up and threads it through every task it executes.
    /// The streamed probe engine uses this to give each worker its shard
    /// regeneration scratch without allocating per shard.
    fn run_tasks_local<S>(
        &self,
        n_tasks: usize,
        init: &(dyn Fn() -> S + Sync),
        task: &(dyn Fn(&mut S, usize) + Sync),
    ) {
        let workers = self.threads.min(n_tasks);
        if workers <= 1 {
            let mut scratch = init();
            for i in 0..n_tasks {
                task(&mut scratch, i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    let mut scratch = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_tasks {
                            break;
                        }
                        task(&mut scratch, i);
                    }
                });
            }
        });
    }

    /// [`ExecContext::for_each_shard_mut`] with worker-local scratch:
    /// `f(scratch, shard_index, start_offset, chunk)` where each worker's
    /// `scratch` comes from one `mk_scratch()` call and is reused across
    /// all shards that worker processes.  Shard geometry (and therefore
    /// the write pattern) is identical to the scratch-free variant.
    pub fn for_each_shard_mut_scratch<S, M, F>(&self, data: &mut [f32], mk_scratch: M, f: F)
    where
        M: Fn() -> S + Sync,
        F: Fn(&mut S, usize, usize, &mut [f32]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        let sl = self.shard_len;
        // serial fast path (see for_each_shard_mut): one scratch, shards
        // in order
        if self.threads <= 1 || data.len() <= sl {
            let mut scratch = mk_scratch();
            for (i, chunk) in data.chunks_mut(sl).enumerate() {
                f(&mut scratch, i, i * sl, chunk);
            }
            return;
        }
        let chunks: Vec<Mutex<Option<(usize, &mut [f32])>>> = data
            .chunks_mut(sl)
            .enumerate()
            .map(|(i, c)| Mutex::new(Some((i * sl, c))))
            .collect();
        let n = chunks.len();
        self.run_tasks_local(n, &mk_scratch, &|scratch, i| {
            let (start, chunk) =
                chunks[i].lock().unwrap().take().expect("shard visited twice");
            f(scratch, i, start, chunk);
        });
    }

    /// Borrowing parallel-for over disjoint `shard_len` chunks of `data`:
    /// `f(shard_index, start_offset, chunk)` runs once per shard, shards
    /// possibly concurrent.  Boundaries depend only on `shard_len`, so the
    /// write pattern is identical for any worker count.
    pub fn for_each_shard_mut<F>(&self, data: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        let sl = self.shard_len;
        // serial fast path: no staging Vec, no mutexes — same shard
        // geometry and call order, so numerics are unchanged
        if self.threads <= 1 || data.len() <= sl {
            for (i, chunk) in data.chunks_mut(sl).enumerate() {
                f(i, i * sl, chunk);
            }
            return;
        }
        let chunks: Vec<Mutex<Option<(usize, &mut [f32])>>> = data
            .chunks_mut(sl)
            .enumerate()
            .map(|(i, c)| Mutex::new(Some((i * sl, c))))
            .collect();
        let n = chunks.len();
        self.run_tasks(n, &|i| {
            let (start, chunk) =
                chunks[i].lock().unwrap().take().expect("shard visited twice");
            f(i, start, chunk);
        });
    }

    /// Borrowing parallel-for over contiguous rows of a row-major matrix:
    /// `f(row_index, row)` with `row.len() == row_len` (the final chunk may
    /// be shorter if `data` is ragged — callers pass exact K x d buffers).
    pub fn for_each_row_mut<F>(&self, data: &mut [f32], row_len: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert!(row_len > 0, "row_len must be positive");
        if data.is_empty() {
            return;
        }
        // serial fast path (see for_each_shard_mut)
        if self.threads <= 1 || data.len() <= row_len {
            for (i, row) in data.chunks_mut(row_len).enumerate() {
                f(i, row);
            }
            return;
        }
        let rows: Vec<Mutex<Option<(usize, &mut [f32])>>> = data
            .chunks_mut(row_len)
            .enumerate()
            .map(|(i, c)| Mutex::new(Some((i, c))))
            .collect();
        let n = rows.len();
        self.run_tasks(n, &|i| {
            let (idx, row) = rows[i].lock().unwrap().take().expect("row visited twice");
            f(idx, row);
        });
    }

    /// Map `f` over `0..n` work items (one item = one probe row, one
    /// trial); results come back in item order.  Each item's computation is
    /// self-contained, so numerics are identical for any worker count.
    pub fn map_items<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads <= 1 || n <= 1 {
            return (0..n).map(&f).collect();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.run_tasks(n, &|i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("missing item result"))
            .collect()
    }

    /// [`ExecContext::map_items`] gated by per-item work: items smaller
    /// than one shard run inline (scoped-thread handoff would dominate).
    /// The gate only picks the schedule — numerics are identical.
    pub fn map_items_sized<R, F>(&self, n: usize, per_item_work: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if per_item_work < self.shard_len {
            (0..n).map(&f).collect()
        } else {
            self.map_items(n, f)
        }
    }

    /// [`ExecContext::map_items`] with worker-local scratch: each worker
    /// builds one `mk_scratch()` value and reuses it across every item it
    /// processes (`f(scratch, item_index)`), so per-item state (streaming
    /// cursors, projection accumulators) is allocated once per worker per
    /// dispatch instead of once per item.
    pub fn map_items_scratch<S, R, M, F>(&self, n: usize, mk_scratch: M, f: F) -> Vec<R>
    where
        R: Send,
        M: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        if self.threads <= 1 || n <= 1 {
            let mut scratch = mk_scratch();
            return (0..n).map(|i| f(&mut scratch, i)).collect();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.run_tasks_local(n, &mk_scratch, &|scratch, i| {
            *slots[i].lock().unwrap() = Some(f(scratch, i));
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("missing item result"))
            .collect()
    }

    /// [`ExecContext::map_items_scratch`] gated by per-item work, like
    /// [`ExecContext::map_items_sized`].  The gate only picks the schedule
    /// — numerics are identical.
    pub fn map_items_sized_scratch<S, R, M, F>(
        &self,
        n: usize,
        per_item_work: usize,
        mk_scratch: M,
        f: F,
    ) -> Vec<R>
    where
        R: Send,
        M: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        if per_item_work < self.shard_len {
            let mut scratch = mk_scratch();
            (0..n).map(|i| f(&mut scratch, i)).collect()
        } else {
            self.map_items_scratch(n, mk_scratch, f)
        }
    }
}

impl Default for ExecContext {
    fn default() -> Self {
        Self::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scope_map((0..100).collect(), |x: i32| x * x);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), (i * i) as i32);
        }
    }

    #[test]
    fn panics_are_isolated() {
        let pool = ThreadPool::new(2);
        let out = pool.scope_map(vec![1, 2, 3], |x: i32| {
            if x == 2 {
                panic!("boom {x}");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(out[2], Ok(3));
    }

    #[test]
    fn all_workers_participate() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let out = pool.scope_map((0..64).collect(), move |_x: i32| {
            c2.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
            0
        });
        assert_eq!(out.len(), 64);
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn shard_boundaries_fixed_by_shard_len_not_threads() {
        for threads in [1usize, 2, 7] {
            let ctx = ExecContext::new(threads).with_shard_len(10);
            assert_eq!(ctx.shard_count(0), 0);
            assert_eq!(ctx.shard_count(9), 1);
            assert_eq!(ctx.shard_count(10), 1);
            assert_eq!(ctx.shard_count(11), 2);
            assert_eq!(ctx.shard_count(100), 10);
        }
    }

    #[test]
    fn for_each_shard_mut_covers_every_element_once() {
        for threads in [1usize, 4] {
            let ctx = ExecContext::new(threads).with_shard_len(7);
            let mut data = vec![0.0f32; 50];
            ctx.for_each_shard_mut(&mut data, |_, start, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += (start + i) as f32 + 1.0;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as f32 + 1.0, "element {i} touched wrongly");
            }
        }
    }

    #[test]
    fn for_each_row_mut_sees_whole_rows() {
        let ctx = ExecContext::new(4).with_shard_len(3);
        let mut data = vec![0.0f32; 6 * 5]; // 6 rows x 5
        ctx.for_each_row_mut(&mut data, 5, |row, chunk| {
            assert_eq!(chunk.len(), 5);
            for v in chunk.iter_mut() {
                *v = row as f32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 5) as f32);
        }
    }

    #[test]
    fn map_items_ordered_for_any_thread_count() {
        for threads in [1usize, 2, 8] {
            let ctx = ExecContext::new(threads);
            let out = ctx.map_items(37, |i| i * 3);
            assert_eq!(out, (0..37).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn partition_divides_worker_budget() {
        let ctx = ExecContext::new(8).with_shard_len(99);
        let shard = ctx.partition(4);
        assert_eq!(shard.threads(), 2);
        assert_eq!(shard.shard_len(), 99);
        // never below one worker
        assert_eq!(ctx.partition(100).threads(), 1);
    }

    #[test]
    fn pool_is_shared_across_clones() {
        let ctx = ExecContext::new(2);
        let p1 = ctx.pool();
        let p2 = ctx.clone().pool();
        assert!(Arc::ptr_eq(&p1, &p2), "clones must reuse one pool");
        assert_eq!(p1.size(), 2);
    }

    #[test]
    fn empty_buffers_are_noops() {
        let ctx = ExecContext::new(4);
        let mut empty: Vec<f32> = Vec::new();
        ctx.for_each_shard_mut(&mut empty, |_, _, _| panic!("no shards expected"));
        ctx.for_each_row_mut(&mut empty, 3, |_, _| panic!("no rows expected"));
        assert!(ctx.map_items(0, |i| i).is_empty());
        ctx.for_each_shard_mut_scratch(
            &mut empty,
            || (),
            |_, _, _, _| panic!("no shards expected"),
        );
    }

    #[test]
    fn scratch_variant_covers_every_element_once() {
        for threads in [1usize, 4] {
            let ctx = ExecContext::new(threads).with_shard_len(7);
            let mut data = vec![0.0f32; 50];
            ctx.for_each_shard_mut_scratch(
                &mut data,
                || vec![0.0f32; 7],
                |scratch, _, start, chunk| {
                    // scratch is writable and at least shard-sized
                    scratch[..chunk.len()].copy_from_slice(chunk);
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v += (start + i) as f32 + 1.0;
                    }
                },
            );
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as f32 + 1.0, "element {i} touched wrongly");
            }
        }
    }

    #[test]
    fn scratch_allocated_once_per_worker() {
        let made = Arc::new(AtomicUsize::new(0));
        let ctx = ExecContext::new(3).with_shard_len(4);
        let mut data = vec![0.0f32; 64]; // 16 shards
        let m2 = Arc::clone(&made);
        ctx.for_each_shard_mut_scratch(
            &mut data,
            move || m2.fetch_add(1, Ordering::SeqCst),
            |_, _, _, _| {},
        );
        let n = made.load(Ordering::SeqCst);
        assert!(n >= 1 && n <= 3, "one scratch per worker, got {n}");
    }
}
