//! Forward-only MLP fine-tuning oracle (DESIGN.md §12).
//!
//! The first workload where *forward evaluation* — not probe algebra — is
//! the per-step hot path the execution engine was built for.  One oracle
//! call is one full minibatch forward of the
//! [`crate::model::mlp`] classifier at `x + scale * v`; the K-probe batch
//! paths parallelize **over probes** (each worker owns a perturbed
//! parameter buffer and an activation scratch), never inside one forward,
//! so losses are bitwise identical for any worker count.
//!
//! Streamed probes: unlike the linear substrates, an MLP loss is not a
//! function of the scalar projections `<X_r, v>`, so the streamed path
//! cannot fold probe shards through running projection accumulators.
//! Instead each worker *materializes the perturbed parameter vector* —
//! O(d) per worker, still independent of K — by visiting the probe row's
//! regenerated column shards and applying the identical fused
//! `w[i] = tau.mul_add(v[i], x[i])` kernel the slice path uses
//! ([`crate::tensor::ParamStore::perturb_range_into`]).  Same floats in,
//! same fixed-order forward after: bitwise-equal losses across storage
//! modes (pinned by `tests/mlp_train.rs`).
//!
//! Minibatches arrive through [`Oracle::set_batch`] either as corpus
//! token batches — hashed into bag-of-token features by
//! [`hash_features`] — or as dense [`crate::data::Features`] rows
//! (LIBSVM-style inputs).

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::exec::ExecContext;
use crate::model::mlp::{batch_grad, batch_loss, MlpSpec, MlpState};
use crate::probe::ProbeSource;
use crate::tensor::{Matrix, ParamStore, ParamStoreMode};

use super::{GradOracle, Oracle};

/// Deterministic hashed bag-of-tokens featurizer: every valid token of an
/// example is multiplicatively hashed into one of `in_dim` buckets and
/// the bucket counts are normalized by the example's valid length.  A
/// pure function of (ids, mask, in_dim) — identical on every platform
/// and thread count.
pub fn hash_features(ids: &[i32], mask: &[f32], in_dim: usize, out_row: &mut [f32]) {
    debug_assert_eq!(ids.len(), mask.len());
    debug_assert_eq!(out_row.len(), in_dim);
    out_row.iter_mut().for_each(|v| *v = 0.0);
    let mut valid = 0u32;
    for (t, m) in ids.iter().zip(mask.iter()) {
        if *m == 0.0 {
            continue;
        }
        valid += 1;
        let h = (*t as u64)
            .wrapping_add(1)
            .wrapping_mul(crate::rng::GOLDEN_GAMMA);
        out_row[(h >> 32) as usize % in_dim] += 1.0;
    }
    if valid > 0 {
        let inv = 1.0 / valid as f32;
        for v in out_row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Forward-only MLP classifier oracle: softmax cross-entropy of a
/// configurable multi-layer perceptron over hashed token (or dense
/// feature) minibatches.  Implements the full batched `Oracle` surface —
/// vectorized [`Oracle::loss_k`], streamed [`Oracle::loss_probes`],
/// shard/row parallelism via [`Oracle::set_exec`] — with exact call
/// accounting.
pub struct MlpOracle {
    spec: MlpSpec,
    /// The flat trainable vector (layout: [`MlpSpec::layout`]), resident
    /// in the configured [`ParamStoreMode`] — quantized modes hold *only*
    /// the compressed representation (the memory saving is real) and
    /// every evaluation dequantizes on the fly inside the fused perturb
    /// kernels, which is bitwise identical to materializing first.
    store: ParamStore,
    /// Current minibatch features (B x in_dim).
    feats: Matrix,
    /// Current minibatch labels (length B).
    labels: Vec<i32>,
    /// Perturbed-parameter scratch for `loss_dir`.
    wtmp: Vec<f32>,
    /// Activation scratch for the serial evaluation paths.
    state: MlpState,
    exec: ExecContext,
    calls: u64,
    name: String,
}

impl MlpOracle {
    /// Build from an architecture and an explicit parameter vector
    /// (length must equal [`MlpSpec::dim`]).
    pub fn new(spec: MlpSpec, params: Vec<f32>) -> Result<Self> {
        if params.len() != spec.dim() {
            bail!(
                "mlp oracle: params hold {} f32, spec wants {}",
                params.len(),
                spec.dim()
            );
        }
        let d = params.len();
        let state = MlpState::new(&spec);
        let name = format!("mlp:{}", spec.label());
        Ok(Self {
            spec,
            store: ParamStore::from_f32(ParamStoreMode::F32, &params),
            feats: Matrix::zeros(0, 0),
            labels: Vec::new(),
            wtmp: vec![0.0; d],
            state,
            exec: ExecContext::serial(),
            calls: 0,
            name,
        })
    }

    /// Build with the deterministic [`MlpSpec::init_params`] init.
    pub fn from_seed(spec: MlpSpec, seed: u64) -> Self {
        let params = spec.init_params(seed);
        Self::new(spec, params).expect("init_params sizes the vector")
    }

    /// The oracle's architecture.
    pub fn spec(&self) -> &MlpSpec {
        &self.spec
    }

    fn ensure_batch(&self) -> Result<()> {
        if self.feats.rows == 0 {
            bail!("{}: set_batch must be called before evaluation", self.name);
        }
        Ok(())
    }

    /// Shared `loss_k`/`loss_k_into` core: the K probes are evaluated
    /// independently (row-parallel on the installed context); each worker
    /// forms `w = x + tau * v_j` elementwise into its own O(d) buffer and
    /// runs the fixed-order minibatch forward.  Per probe the arithmetic
    /// is exactly `loss_dir`'s, so the batched and looped paths agree
    /// bit for bit.
    fn loss_k_impl(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.store.len();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        self.ensure_batch()?;
        self.calls += k as u64;
        let spec = &self.spec;
        let store = &self.store;
        let feats = &self.feats;
        let labels = &self.labels;
        let per_item_work = d.saturating_mul(feats.rows.max(1));
        let vals = self.exec.map_items_sized_scratch(
            k,
            per_item_work,
            || (vec![0.0f32; d], MlpState::new(spec)),
            |scratch, j| {
                let (w, st) = scratch;
                store.perturb_into(tau, &dirs[j * d..(j + 1) * d], w);
                batch_loss(spec, w, feats, labels, st)
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }
}

impl Oracle for MlpOracle {
    fn dim(&self) -> usize {
        self.store.len()
    }

    fn set_batch(&mut self, batch: &Batch) -> Result<()> {
        let in_dim = self.spec.in_dim;
        match &batch.features {
            Some(f) => {
                if f.dim != in_dim {
                    bail!(
                        "{}: feature dim {} != spec in_dim {in_dim}",
                        self.name,
                        f.dim
                    );
                }
                if f.data.len() != batch.batch * f.dim {
                    bail!(
                        "{}: features hold {} f32, batch wants {}",
                        self.name,
                        f.data.len(),
                        batch.batch * f.dim
                    );
                }
                self.feats = Matrix::from_vec(batch.batch, f.dim, f.data.clone());
            }
            None => {
                if self.feats.rows != batch.batch || self.feats.cols != in_dim {
                    self.feats = Matrix::zeros(batch.batch, in_dim);
                }
                for b in 0..batch.batch {
                    let row =
                        &mut self.feats.data[b * in_dim..(b + 1) * in_dim];
                    hash_features(
                        &batch.ids[b * batch.seq..(b + 1) * batch.seq],
                        &batch.mask[b * batch.seq..(b + 1) * batch.seq],
                        in_dim,
                        row,
                    );
                }
            }
        }
        self.labels.clear();
        for &l in &batch.labels {
            if l < 0 || l as usize >= self.spec.n_classes {
                bail!(
                    "{}: label {l} outside 0..{}",
                    self.name,
                    self.spec.n_classes
                );
            }
            self.labels.push(l);
        }
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.ensure_batch()?;
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        self.store.perturb_into(scale, dir, &mut wtmp);
        let v = batch_loss(&self.spec, &wtmp, &self.feats, &self.labels, &mut self.state);
        self.wtmp = wtmp;
        Ok(v)
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(k);
        self.loss_k_impl(dirs, k, tau, &mut out)?;
        Ok(out)
    }

    fn loss_k_into(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        self.loss_k_impl(dirs, k, tau, out)
    }

    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let Some(dirs) = probes.dirs() {
            return self.loss_k_impl(dirs, k, tau, out);
        }
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.store.len();
        assert_eq!(probes.dim(), d, "probe rows must be length d");
        self.ensure_batch()?;
        self.calls += k as u64;
        // per probe: materialize w = x + tau * v from the row's
        // regenerated column shards through the store's fused
        // perturb-window kernel — the same `tau.mul_add(v, x)` the slice
        // path applies, so the forward sees identical floats and the
        // losses are bitwise equal.  Cursor, w and the activation scratch
        // are per worker, reused across that worker's probes.
        let spec = &self.spec;
        let store = &self.store;
        let feats = &self.feats;
        let labels = &self.labels;
        let per_item_work = d.saturating_mul(feats.rows.max(1));
        let vals = self.exec.map_items_sized_scratch(
            k,
            per_item_work,
            || (probes.cursor(), vec![0.0f32; d], MlpState::new(spec)),
            |scratch, j| {
                let (cur, w, st) = scratch;
                cur.visit_row(j, &mut |c0, piece| {
                    store.perturb_range_into(c0, tau, piece, &mut w[c0..c0 + piece.len()]);
                });
                batch_loss(spec, w, feats, labels, st)
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }

    fn supports_streamed_probes(&self) -> bool {
        true
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn params(&self) -> &[f32] {
        self.store.as_f32()
    }

    fn params_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.store.len(), 0.0);
        self.store.dequant_into(out);
    }

    fn set_param_store(&mut self, mode: ParamStoreMode) -> Result<()> {
        if mode != self.store.mode() {
            self.store = self.store.convert(mode);
        }
        Ok(())
    }

    fn supports_param_store(&self) -> bool {
        true
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        if self.store.mode() == ParamStoreMode::F32 {
            f(self.store.as_f32_mut());
            return Ok(());
        }
        // dequant -> mutate -> requant; exact round-trip when f is the
        // identity, so restores reproduce the store bit-for-bit
        let mut tmp = std::mem::take(&mut self.wtmp);
        self.store.dequant_into(&mut tmp);
        f(&mut tmp);
        self.store.store_from(&tmp);
        self.wtmp = tmp;
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        &self.name
    }
}

impl GradOracle for MlpOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        self.ensure_batch()?;
        // diagnostics path: f32 storage only (as_f32 panics otherwise)
        Ok(batch_grad(
            &self.spec,
            self.store.as_f32(),
            &self.feats,
            &self.labels,
            out,
            &mut self.state,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusSpec};
    use crate::model::mlp::Activation;

    fn small_spec() -> MlpSpec {
        MlpSpec::new(16, vec![8], 2, Activation::Tanh).unwrap()
    }

    fn corpus_batch() -> Batch {
        Corpus::new(CorpusSpec::default_mini()).unwrap().train_batch(0, 4)
    }

    #[test]
    fn rejects_mismatched_params() {
        assert!(MlpOracle::new(small_spec(), vec![0.0; 3]).is_err());
    }

    #[test]
    fn evaluation_requires_a_batch() {
        let mut o = MlpOracle::from_seed(small_spec(), 1);
        let zeros = vec![0.0f32; o.dim()];
        let err = o.loss_dir(&zeros, 0.0).unwrap_err();
        assert!(err.to_string().contains("set_batch"), "{err}");
        assert_eq!(o.oracle_calls(), 0, "a rejected call must not be charged");
    }

    #[test]
    fn hash_features_is_normalized_and_deterministic() {
        let ids = [1, 5, 9, 5, 0, 0];
        let mask = [1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        hash_features(&ids, &mask, 8, &mut a);
        hash_features(&ids, &mask, 8, &mut b);
        assert_eq!(a, b);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "bucket mass must sum to 1, got {sum}");
        // padded positions must not contribute
        let mut c = vec![0.0f32; 8];
        hash_features(&ids[..4], &mask[..4], 8, &mut c);
        assert_eq!(a, c);
    }

    #[test]
    fn loss_at_init_is_near_chance_level() {
        // near-zero init => logits near zero => loss ~ ln(n_classes)
        let mut o = MlpOracle::from_seed(small_spec(), 3);
        o.set_batch(&corpus_batch()).unwrap();
        let zeros = vec![0.0f32; o.dim()];
        let loss = o.loss_dir(&zeros, 0.0).unwrap();
        assert!(
            (loss - std::f64::consts::LN_2).abs() < 0.5,
            "chance-level CE should be near ln 2, got {loss}"
        );
        assert_eq!(o.oracle_calls(), 1);
    }

    #[test]
    fn feature_batches_flow_through_set_batch() {
        let spec = small_spec();
        let mut o = MlpOracle::from_seed(spec.clone(), 4);
        let n = 3;
        let mut rng = crate::rng::Rng::new(8);
        let mut data = vec![0.0f32; n * spec.in_dim];
        rng.fill_normal(&mut data);
        let batch = Batch::from_features(spec.in_dim, data, vec![0, 1, 0]);
        o.set_batch(&batch).unwrap();
        let zeros = vec![0.0f32; o.dim()];
        assert!(o.loss_dir(&zeros, 0.0).unwrap().is_finite());
        // wrong feature dim is rejected
        let bad = Batch::from_features(
            spec.in_dim + 1,
            vec![0.0; 2 * (spec.in_dim + 1)],
            vec![0, 1],
        );
        assert!(o.set_batch(&bad).is_err());
        // out-of-range labels are rejected
        let bad_label =
            Batch::from_features(spec.in_dim, vec![0.0; spec.in_dim], vec![2]);
        assert!(o.set_batch(&bad_label).is_err());
    }

    #[test]
    fn loss_k_charges_k_calls_and_rejects_zero() {
        let mut o = MlpOracle::from_seed(small_spec(), 5);
        o.set_batch(&corpus_batch()).unwrap();
        let d = o.dim();
        let mut rng = crate::rng::Rng::new(11);
        let mut dirs = vec![0.0f32; 3 * d];
        rng.fill_normal(&mut dirs);
        let before = o.oracle_calls();
        let losses = o.loss_k(&dirs, 3, 1e-3).unwrap();
        assert_eq!(losses.len(), 3);
        assert_eq!(o.oracle_calls() - before, 3);
        assert!(o.loss_k(&[], 0, 1e-3).is_err());
    }

    #[test]
    fn loss_k_matches_loss_dir_bitwise() {
        let mut o = MlpOracle::from_seed(small_spec(), 6);
        o.set_batch(&corpus_batch()).unwrap();
        let d = o.dim();
        let k = 4;
        let mut rng = crate::rng::Rng::new(12);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);
        let batched = o.loss_k(&dirs, k, 1e-2).unwrap();
        for (i, b) in batched.iter().enumerate() {
            let l = o.loss_dir(&dirs[i * d..(i + 1) * d], 1e-2).unwrap();
            assert_eq!(b.to_bits(), l.to_bits(), "probe {i}: {b} vs {l}");
        }
    }

    #[test]
    fn quantized_store_matches_materialized_dequant_bitwise() {
        // the qstore contract at the oracle level: evaluating through the
        // fused on-the-fly dequant kernels equals materializing the
        // dequantized f32 vector and evaluating that, bit for bit
        let spec = small_spec();
        let batch = corpus_batch();
        let d = spec.dim();
        let k = 3;
        let mut rng = crate::rng::Rng::new(21);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);
        for mode in [ParamStoreMode::F16, ParamStoreMode::Int8] {
            let mut q = MlpOracle::from_seed(spec.clone(), 9);
            q.set_param_store(mode).unwrap();
            let mut deq = Vec::new();
            q.params_into(&mut deq);
            let mut r = MlpOracle::new(spec.clone(), deq).unwrap();
            q.set_batch(&batch).unwrap();
            r.set_batch(&batch).unwrap();
            let a = q.loss_k(&dirs, k, 1e-2).unwrap();
            let b = r.loss_k(&dirs, k, 1e-2).unwrap();
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?}");
            }
            // identity update must leave the store bitwise intact
            let before = a.clone();
            q.update_params(&mut |_| {}).unwrap();
            let after = q.loss_k(&dirs, k, 1e-2).unwrap();
            for (x, y) in before.iter().zip(after.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?} identity update");
            }
        }
    }

    #[test]
    fn loss_k_parallel_bitwise_matches_serial() {
        let spec = small_spec();
        let batch = corpus_batch();
        let d = spec.dim();
        let k = 5;
        let mut rng = crate::rng::Rng::new(13);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);
        let mut serial = MlpOracle::from_seed(spec.clone(), 7);
        serial.set_batch(&batch).unwrap();
        let mut par = MlpOracle::from_seed(spec, 7);
        par.set_exec(ExecContext::new(8).with_shard_len(16));
        par.set_batch(&batch).unwrap();
        let a = serial.loss_k(&dirs, k, 1e-3).unwrap();
        let b = par.loss_k(&dirs, k, 1e-3).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }
}
