//! Transformer + LoRA fine-tuning oracle (DESIGN.md §13).
//!
//! The paper's empirical setting: ZO fine-tuning of a decoder-transformer
//! classifier through a LoRA-restricted trainable subspace.  One oracle
//! call is one minibatch forward of the [`crate::model::transformer`]
//! core at the perturbed trainable vector; the K-probe paths parallelize
//! **over probes** (each worker owns a perturbed trainable buffer and an
//! activation scratch), never inside one forward, so losses are bitwise
//! identical for any worker count.
//!
//! Two train modes share one oracle:
//! * [`TrainMode::Ft`] — the full base vector (d_ft parameters) is
//!   trainable and perturbed.
//! * [`TrainMode::Lora`] — only the rank-r adapter factors + classifier
//!   head (d_lora parameters) are trainable; the base stays frozen.  This
//!   is the small-`d` regime where LDSD's learned sampler and the
//!   streamed probe engine compound (the pairing studied in
//!   arXiv 2402.11592).
//!
//! Streamed probes: a transformer loss is not a function of scalar
//! projections, so — exactly like the MLP oracle — each worker
//! *materializes the perturbed trainable vector* (O(d) per worker,
//! independent of K) by visiting the probe row's regenerated column
//! shards and applying the identical fused `w[i] = tau.mul_add(v[i],
//! x[i])` kernel the slice path uses
//! ([`crate::tensor::ParamStore::perturb_range_into`]).  Same floats in,
//! same fixed-order forward after: bitwise-equal losses across storage
//! modes (pinned by `tests/transformer_train.rs`).
//!
//! The trainable vector lives in a [`ParamStore`] (DESIGN.md §14): in
//! quantized (f16/int8) modes only the compressed representation is
//! resident — in LoRA mode the frozen base stays f32 (it feeds every
//! forward unperturbed), while the adapter vector quantizes; in FT mode
//! the whole base quantizes.

use anyhow::{bail, Result};

use crate::config::TrainMode;
use crate::data::Batch;
use crate::exec::ExecContext;
use crate::model::transformer::{
    batch_dir_derivative, batch_loss_packed, BasePacks, TransformerSpec, TransformerState,
};
use crate::probe::ProbeSource;
use crate::tensor::{ParamStore, ParamStoreMode};

use super::Oracle;

/// Decoder-transformer classifier oracle with a LoRA-restricted (or full)
/// trainable subspace.  Implements the full batched `Oracle` surface —
/// probe-parallel [`Oracle::loss_k`], streamed [`Oracle::loss_probes`],
/// worker dispatch via [`Oracle::set_exec`] — with exact call accounting.
pub struct TransformerOracle {
    spec: TransformerSpec,
    mode: TrainMode,
    /// Trainable vector: the full base (layout
    /// [`TransformerSpec::ft_layout`]) in FT mode, the LoRA vector
    /// (layout [`TransformerSpec::lora_layout`]) in LoRA mode.  In
    /// quantized modes only the compressed representation is resident.
    store: ParamStore,
    /// Frozen base vector in LoRA mode (always f32 — it feeds every
    /// forward unperturbed); empty in FT mode, where the base *is* the
    /// trainable and lives in `store`.
    frozen_base: Vec<f32>,
    /// LoRA-mode weight-pack cache (DESIGN.md §15): the frozen base's
    /// GEMM operands packed tile-major **once per run** at construction
    /// and shared read-only by every probe worker — ZO never mutates the
    /// base in LoRA mode, so the pack never invalidates.  `None` in FT
    /// mode, where the trainable vector is the base and each perturbed
    /// evaluation repacks into worker scratch.
    base_packs: Option<BasePacks>,
    /// Current minibatch token ids (B x seq).
    ids: Vec<i32>,
    /// Current minibatch key-padding mask (B x seq).
    mask: Vec<f32>,
    /// Current minibatch labels (length B).
    labels: Vec<i32>,
    /// Current minibatch sequence length.
    seq: usize,
    /// Perturbed-trainable scratch for `loss_dir`.
    wtmp: Vec<f32>,
    /// Activation scratch for the serial evaluation paths.
    state: TransformerState,
    exec: ExecContext,
    calls: u64,
    name: String,
}

impl TransformerOracle {
    /// Build from an architecture, mode and explicit vectors.  `base`
    /// must hold [`TransformerSpec::d_ft`] f32; in LoRA mode `lora` must
    /// hold [`TransformerSpec::d_lora`] (in FT mode it must be empty).
    pub fn new(
        spec: TransformerSpec,
        mode: TrainMode,
        base: Vec<f32>,
        lora: Vec<f32>,
    ) -> Result<Self> {
        if base.len() != spec.d_ft() {
            bail!(
                "transformer oracle: base holds {} f32, spec wants d_ft {}",
                base.len(),
                spec.d_ft()
            );
        }
        match mode {
            TrainMode::Lora => {
                if lora.len() != spec.d_lora() {
                    bail!(
                        "transformer oracle: lora holds {} f32, spec wants d_lora {}",
                        lora.len(),
                        spec.d_lora()
                    );
                }
            }
            TrainMode::Ft => {
                if !lora.is_empty() {
                    bail!("transformer oracle: FT mode takes no lora vector");
                }
            }
        }
        let (store, frozen_base, d) = match mode {
            TrainMode::Ft => {
                let d = base.len();
                (ParamStore::from_f32(ParamStoreMode::F32, &base), Vec::new(), d)
            }
            TrainMode::Lora => {
                let d = lora.len();
                (ParamStore::from_f32(ParamStoreMode::F32, &lora), base, d)
            }
        };
        let state = TransformerState::new(&spec);
        let base_packs = match mode {
            TrainMode::Ft => None,
            TrainMode::Lora => Some(BasePacks::pack(&spec, &frozen_base)),
        };
        let name = format!("transformer:{}:{}", spec.label(), mode.as_str());
        Ok(Self {
            spec,
            mode,
            store,
            frozen_base,
            base_packs,
            ids: Vec::new(),
            mask: Vec::new(),
            labels: Vec::new(),
            seq: 0,
            wtmp: vec![0.0; d],
            state,
            exec: ExecContext::serial(),
            calls: 0,
            name,
        })
    }

    /// Build with the deterministic reference init: base from
    /// [`TransformerSpec::init_base`], and in LoRA mode adapters from
    /// [`TransformerSpec::init_lora`] (head copied from the base).
    pub fn from_seed(spec: TransformerSpec, mode: TrainMode, seed: u64) -> Self {
        let base = spec.init_base(seed);
        let lora = match mode {
            TrainMode::Ft => Vec::new(),
            TrainMode::Lora => spec.init_lora(seed, Some(&base)),
        };
        Self::new(spec, mode, base, lora).expect("reference init sizes the vectors")
    }

    /// The oracle's architecture.
    pub fn spec(&self) -> &TransformerSpec {
        &self.spec
    }

    /// The oracle's train mode.
    pub fn mode(&self) -> TrainMode {
        self.mode
    }

    /// The frozen/full base vector (FT mode: the trainable itself).
    ///
    /// In FT mode this reads the resident f32 image and therefore panics
    /// under a quantized store — callers (evaluator construction, the
    /// diagnostics paths) run before or without
    /// [`Oracle::set_param_store`].
    pub fn base(&self) -> &[f32] {
        match self.mode {
            TrainMode::Ft => self.store.as_f32(),
            TrainMode::Lora => &self.frozen_base,
        }
    }

    fn ensure_batch(&self) -> Result<()> {
        if self.labels.is_empty() {
            bail!("{}: set_batch must be called before evaluation", self.name);
        }
        Ok(())
    }

    /// Analytic directional derivative of the current-batch loss along
    /// `dir` on the trainable subspace, via the f64 forward-mode JVP
    /// ([`batch_dir_derivative`]).  Returns `(loss, dloss/dtau)`.
    /// Diagnostics only — the fd-vs-analytic cross-checks in
    /// `tests/transformer_train.rs`; the training path never calls it.
    /// Reads the resident f32 image, so it panics under a quantized
    /// store (the diagnostics tests run f32 storage only).
    pub fn dir_derivative(&self, dir: &[f32]) -> Result<(f64, f64)> {
        self.ensure_batch()?;
        let (base, lora) = match self.mode {
            TrainMode::Ft => (self.store.as_f32(), None),
            TrainMode::Lora => (&self.frozen_base[..], Some(self.store.as_f32())),
        };
        Ok(batch_dir_derivative(
            &self.spec,
            base,
            lora,
            dir,
            &self.ids,
            &self.mask,
            self.seq,
            &self.labels,
        ))
    }

    /// Shared `loss_k`/`loss_k_into` core: the K probes are evaluated
    /// independently (probe-parallel on the installed context); each
    /// worker forms `w = x + tau * v_j` into its own O(d) buffer via
    /// [`ParamStore::perturb_into`] (fused dequant+axpy in quantized
    /// modes) and runs the fixed-order minibatch forward.  Per probe the
    /// arithmetic is exactly `loss_dir`'s, so the batched and looped
    /// paths agree bit for bit.
    fn loss_k_impl(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.dim();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        self.ensure_batch()?;
        self.calls += k as u64;
        let spec = &self.spec;
        let store = &self.store;
        let frozen_base = &self.frozen_base;
        let base_packs = self.base_packs.as_ref();
        let lora_mode = self.mode == TrainMode::Lora;
        let ids = &self.ids;
        let mask = &self.mask;
        let labels = &self.labels;
        let seq = self.seq;
        let per_item_work = spec.forward_work(seq).saturating_mul(labels.len().max(1));
        let vals = self.exec.map_items_sized_scratch(
            k,
            per_item_work,
            || (vec![0.0f32; d], TransformerState::new(spec)),
            |scratch, j| {
                let (w, st) = scratch;
                store.perturb_into(tau, &dirs[j * d..(j + 1) * d], w);
                if lora_mode {
                    batch_loss_packed(
                        spec, frozen_base, Some(w), ids, mask, seq, labels, st, base_packs,
                    )
                } else {
                    batch_loss_packed(spec, w, None, ids, mask, seq, labels, st, None)
                }
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }
}

impl Oracle for TransformerOracle {
    fn dim(&self) -> usize {
        self.store.len()
    }

    fn set_batch(&mut self, batch: &Batch) -> Result<()> {
        if batch.features.is_some() || batch.seq == 0 {
            bail!(
                "{}: needs token minibatches (feature batches have no sequence)",
                self.name
            );
        }
        if batch.seq > self.spec.max_seq {
            bail!(
                "{}: batch seq {} exceeds max_seq {}",
                self.name,
                batch.seq,
                self.spec.max_seq
            );
        }
        if batch.ids.len() != batch.batch * batch.seq
            || batch.mask.len() != batch.batch * batch.seq
            || batch.labels.len() != batch.batch
        {
            bail!("{}: inconsistent batch geometry", self.name);
        }
        for &id in &batch.ids {
            if id < 0 || id as usize >= self.spec.vocab {
                bail!(
                    "{}: token id {id} outside vocab {}",
                    self.name,
                    self.spec.vocab
                );
            }
        }
        for &l in &batch.labels {
            if l < 0 || l as usize >= self.spec.n_classes {
                bail!(
                    "{}: label {l} outside 0..{}",
                    self.name,
                    self.spec.n_classes
                );
            }
        }
        self.ids.clear();
        self.ids.extend_from_slice(&batch.ids);
        self.mask.clear();
        self.mask.extend_from_slice(&batch.mask);
        self.labels.clear();
        self.labels.extend_from_slice(&batch.labels);
        self.seq = batch.seq;
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.ensure_batch()?;
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        let mut state = std::mem::replace(&mut self.state, TransformerState::new(&self.spec));
        self.store.perturb_into(scale, dir, &mut wtmp);
        let v = match self.mode {
            TrainMode::Ft => batch_loss_packed(
                &self.spec,
                &wtmp,
                None,
                &self.ids,
                &self.mask,
                self.seq,
                &self.labels,
                &mut state,
                None,
            ),
            TrainMode::Lora => batch_loss_packed(
                &self.spec,
                &self.frozen_base,
                Some(&wtmp),
                &self.ids,
                &self.mask,
                self.seq,
                &self.labels,
                &mut state,
                self.base_packs.as_ref(),
            ),
        };
        self.wtmp = wtmp;
        self.state = state;
        Ok(v)
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(k);
        self.loss_k_impl(dirs, k, tau, &mut out)?;
        Ok(out)
    }

    fn loss_k_into(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        self.loss_k_impl(dirs, k, tau, out)
    }

    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let Some(dirs) = probes.dirs() {
            return self.loss_k_impl(dirs, k, tau, out);
        }
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.dim();
        assert_eq!(probes.dim(), d, "probe rows must be length d");
        self.ensure_batch()?;
        self.calls += k as u64;
        // per probe: materialize w = x + tau * v from the row's
        // regenerated column shards — the same fused perturb kernel the
        // slice path applies, so the forward sees identical floats and
        // the losses are bitwise equal.  Cursor, w and the activation
        // scratch are per worker, reused across that worker's probes.
        let spec = &self.spec;
        let store = &self.store;
        let frozen_base = &self.frozen_base;
        let base_packs = self.base_packs.as_ref();
        let lora_mode = self.mode == TrainMode::Lora;
        let ids = &self.ids;
        let mask = &self.mask;
        let labels = &self.labels;
        let seq = self.seq;
        let per_item_work = spec.forward_work(seq).saturating_mul(labels.len().max(1));
        let vals = self.exec.map_items_sized_scratch(
            k,
            per_item_work,
            || (probes.cursor(), vec![0.0f32; d], TransformerState::new(spec)),
            |scratch, j| {
                let (cur, w, st) = scratch;
                cur.visit_row(j, &mut |c0, piece| {
                    store.perturb_range_into(c0, tau, piece, &mut w[c0..c0 + piece.len()]);
                });
                if lora_mode {
                    batch_loss_packed(
                        spec, frozen_base, Some(w), ids, mask, seq, labels, st, base_packs,
                    )
                } else {
                    batch_loss_packed(spec, w, None, ids, mask, seq, labels, st, None)
                }
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }

    fn supports_streamed_probes(&self) -> bool {
        true
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn params(&self) -> &[f32] {
        self.store.as_f32()
    }

    fn params_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.store.len(), 0.0);
        self.store.dequant_into(out);
    }

    fn set_param_store(&mut self, mode: ParamStoreMode) -> Result<()> {
        if mode != self.store.mode() {
            self.store = self.store.convert(mode);
        }
        Ok(())
    }

    fn supports_param_store(&self) -> bool {
        true
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        if self.store.mode() == ParamStoreMode::F32 {
            f(self.store.as_f32_mut());
            return Ok(());
        }
        // dequant -> mutate -> requant; exact round-trip when f is the
        // identity, so restores reproduce the store bit-for-bit
        let mut tmp = std::mem::take(&mut self.wtmp);
        self.store.dequant_into(&mut tmp);
        f(&mut tmp);
        self.store.store_from(&tmp);
        self.wtmp = tmp;
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusSpec};

    fn tiny_spec() -> TransformerSpec {
        TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, crate::model::Pool::Cls, 2).unwrap()
    }

    fn corpus_batch() -> Batch {
        // shrunk to the tiny spec's vocab/max_seq (validation: vocab must
        // exceed 2 + 2*lexicon, min_len < seq, n_signal <= min_len)
        let spec = CorpusSpec {
            vocab: 64,
            seq: 8,
            lexicon: 16,
            min_len: 4,
            signal_min: 1,
            signal_max: 3,
            ..CorpusSpec::default_mini()
        };
        Corpus::new(spec).unwrap().train_batch(0, 4)
    }

    #[test]
    fn rejects_mismatched_vectors() {
        let s = tiny_spec();
        assert!(TransformerOracle::new(s.clone(), TrainMode::Ft, vec![0.0; 3], Vec::new())
            .is_err());
        let base = s.init_base(1);
        assert!(
            TransformerOracle::new(s.clone(), TrainMode::Lora, base.clone(), vec![0.0; 3])
                .is_err()
        );
        assert!(TransformerOracle::new(s, TrainMode::Ft, base, vec![0.0; 3]).is_err());
    }

    #[test]
    fn evaluation_requires_a_batch() {
        let mut o = TransformerOracle::from_seed(tiny_spec(), TrainMode::Lora, 1);
        let zeros = vec![0.0f32; o.dim()];
        let err = o.loss_dir(&zeros, 0.0).unwrap_err();
        assert!(err.to_string().contains("set_batch"), "{err}");
        assert_eq!(o.oracle_calls(), 0, "a rejected call must not be charged");
    }

    #[test]
    fn lora_dim_is_the_adapter_count() {
        let s = tiny_spec();
        let ft = TransformerOracle::from_seed(s.clone(), TrainMode::Ft, 2);
        let lora = TransformerOracle::from_seed(s.clone(), TrainMode::Lora, 2);
        assert_eq!(ft.dim(), s.d_ft());
        assert_eq!(lora.dim(), s.d_lora());
        assert!(lora.dim() < ft.dim() / 10, "LoRA must shrink d by >10x here");
    }

    #[test]
    fn set_batch_validates_tokens_and_labels() {
        let mut o = TransformerOracle::from_seed(tiny_spec(), TrainMode::Lora, 3);
        let mut b = corpus_batch();
        o.set_batch(&b).unwrap();
        b.ids[0] = 64; // outside vocab
        assert!(o.set_batch(&b).is_err());
        b.ids[0] = 1;
        b.labels[0] = 5;
        assert!(o.set_batch(&b).is_err());
        // feature batches have no token sequence to attend over
        let fb = Batch::from_features(4, vec![0.0; 8], vec![0, 1]);
        assert!(o.set_batch(&fb).is_err());
    }

    #[test]
    fn loss_at_init_is_near_chance_level() {
        let mut o = TransformerOracle::from_seed(tiny_spec(), TrainMode::Lora, 4);
        o.set_batch(&corpus_batch()).unwrap();
        let zeros = vec![0.0f32; o.dim()];
        let loss = o.loss_dir(&zeros, 0.0).unwrap();
        assert!(
            (loss - std::f64::consts::LN_2).abs() < 0.5,
            "chance-level CE should be near ln 2, got {loss}"
        );
        assert_eq!(o.oracle_calls(), 1);
    }

    #[test]
    fn loss_k_matches_loss_dir_bitwise_in_both_modes() {
        for mode in [TrainMode::Ft, TrainMode::Lora] {
            let mut o = TransformerOracle::from_seed(tiny_spec(), mode, 5);
            o.set_batch(&corpus_batch()).unwrap();
            let d = o.dim();
            let k = 3;
            let mut rng = crate::rng::Rng::new(12);
            let mut dirs = vec![0.0f32; k * d];
            rng.fill_normal(&mut dirs);
            let batched = o.loss_k(&dirs, k, 1e-2).unwrap();
            for (i, b) in batched.iter().enumerate() {
                let l = o.loss_dir(&dirs[i * d..(i + 1) * d], 1e-2).unwrap();
                assert_eq!(b.to_bits(), l.to_bits(), "{mode:?} probe {i}: {b} vs {l}");
            }
            assert!(o.loss_k(&[], 0, 1e-3).is_err());
        }
    }

    #[test]
    fn quantized_store_matches_materialized_dequant_bitwise() {
        // the qstore contract at the oracle level, in both train modes:
        // evaluating through the fused on-the-fly dequant kernels equals
        // rebuilding an f32 oracle from the dequantized trainable vector
        // and evaluating that, bit for bit (the frozen base stays f32 in
        // LoRA mode, so it is shared verbatim)
        let spec = tiny_spec();
        let batch = corpus_batch();
        let k = 3;
        for tm in [TrainMode::Ft, TrainMode::Lora] {
            for qm in [ParamStoreMode::F16, ParamStoreMode::Int8] {
                let mut q = TransformerOracle::from_seed(spec.clone(), tm, 9);
                let base = match tm {
                    TrainMode::Ft => Vec::new(),
                    TrainMode::Lora => q.base().to_vec(),
                };
                q.set_param_store(qm).unwrap();
                let d = q.dim();
                let mut rng = crate::rng::Rng::new(21);
                let mut dirs = vec![0.0f32; k * d];
                rng.fill_normal(&mut dirs);
                let mut deq = Vec::new();
                q.params_into(&mut deq);
                let mut r = match tm {
                    TrainMode::Ft => {
                        TransformerOracle::new(spec.clone(), tm, deq, Vec::new()).unwrap()
                    }
                    TrainMode::Lora => TransformerOracle::new(spec.clone(), tm, base, deq).unwrap(),
                };
                q.set_batch(&batch).unwrap();
                r.set_batch(&batch).unwrap();
                let a = q.loss_k(&dirs, k, 1e-2).unwrap();
                let b = r.loss_k(&dirs, k, 1e-2).unwrap();
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tm:?} {qm:?}");
                }
                // identity update must leave the store bitwise intact
                q.update_params(&mut |_| {}).unwrap();
                let after = q.loss_k(&dirs, k, 1e-2).unwrap();
                for (x, y) in a.iter().zip(after.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tm:?} {qm:?} identity update");
                }
            }
        }
    }

    #[test]
    fn loss_k_parallel_bitwise_matches_serial() {
        let spec = tiny_spec();
        let batch = corpus_batch();
        let k = 5;
        let mut serial = TransformerOracle::from_seed(spec.clone(), TrainMode::Lora, 7);
        serial.set_batch(&batch).unwrap();
        let d = serial.dim();
        let mut rng = crate::rng::Rng::new(13);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);
        let mut par = TransformerOracle::from_seed(spec, TrainMode::Lora, 7);
        par.set_exec(ExecContext::new(8).with_shard_len(16));
        par.set_batch(&batch).unwrap();
        let a = serial.loss_k(&dirs, k, 1e-3).unwrap();
        let b = par.loss_k(&dirs, k, 1e-3).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }
}
