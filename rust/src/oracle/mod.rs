//! Loss oracles: the `f` in `min f(x)`.
//!
//! A ZO method sees the objective only through forward evaluations; this
//! trait is that boundary.  Implementations:
//! * [`PjrtOracle`] (in `pjrt.rs`) — the real thing: AOT-compiled
//!   transformer loss executed via PJRT (one `loss_dir` call = one forward
//!   pass of the model at `x + scale * dir`).
//! * [`MlpOracle`] (in `mlp.rs`) — the forward-only MLP classifier: a
//!   real network evaluated entirely on the host, where forward cost (not
//!   probe algebra) dominates the step — the first workload of that shape
//!   (DESIGN.md §12).  Implements the full batched surface including
//!   streamed `loss_probes`.
//! * [`TransformerOracle`] (in `transformer.rs`) — the paper's workload
//!   shape: a host-evaluated decoder-transformer classifier with an
//!   FT or LoRA-restricted trainable subspace (DESIGN.md §13).  Same
//!   full batched surface as the MLP.
//! * [`QuadraticOracle`], [`LinRegOracle`], [`LogRegOracle`] — closed-form
//!   substrates for tests, the Fig. 2 toy experiment, and fast ablations.
//!   Each overrides [`Oracle::loss_k`] with a vectorized batch evaluation
//!   so the batched estimation path is exercised (and benchmarkable) even
//!   without PJRT artifacts.
//!
//! Every call increments an oracle-call counter: the paper's §5.1
//! comparisons are at *fixed oracle budget*, so accounting lives at this
//! boundary and is exact by construction (DESIGN.md §5).

mod closed_form;
mod mlp;
mod pjrt;
mod transformer;

pub use closed_form::{LinRegOracle, LogRegOracle, QuadraticOracle};
pub use mlp::{hash_features, MlpOracle};
pub use pjrt::{read_f32_bin as read_params_bin, PjrtOracle};
pub use transformer::TransformerOracle;

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::exec::ExecContext;
use crate::probe::ProbeSource;

/// Forward-evaluation interface.  The oracle owns the current iterate `x`
/// (so PJRT implementations can keep it device-resident) and evaluates the
/// objective at rank-1 perturbations of it.
pub trait Oracle {
    /// Trainable dimensionality d.
    fn dim(&self) -> usize;

    /// Point the oracle at the minibatch used for subsequent evaluations.
    /// Builtin (full-batch) oracles ignore this.
    fn set_batch(&mut self, batch: &Batch) -> Result<()>;

    /// f(x + scale * dir).  `scale = 0` or an all-zero dir gives f(x).
    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64>;

    /// Losses at `x + tau * dirs[i]` for i in 0..k (dirs row-major K x d).
    ///
    /// This is the batched K-probe entry point the estimators' two-phase
    /// `propose`/`consume` flow dispatches through: one call evaluates the
    /// whole probe matrix.  The PJRT oracle overrides it with the fused
    /// `loss_k` artifact (one device dispatch for K probes); the
    /// closed-form oracles override it with vectorized host loops.  The
    /// default implementation loops [`Oracle::loss_dir`].
    ///
    /// `k == 0` is a caller bug (an empty probe matrix cannot produce an
    /// estimate) and returns an error rather than a silently empty vector.
    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.dim();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        (0..k).map(|i| self.loss_dir(&dirs[i * d..(i + 1) * d], tau)).collect()
    }

    /// [`Oracle::loss_k`] into a caller-provided buffer — the train-loop
    /// hot path reuses one `Vec<f64>` across steps instead of allocating
    /// per dispatch.  The default delegates to `loss_k`; the closed-form
    /// oracles override both through one shared implementation.
    fn loss_k_into(
        &mut self,
        dirs: &[f32],
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let losses = self.loss_k(dirs, k, tau)?;
        out.clear();
        out.extend_from_slice(&losses);
        Ok(())
    }

    /// Evaluate one step's probe batch through a [`ProbeSource`]: losses
    /// at `x + tau * row_i` for the source's `k` presented rows, into a
    /// caller-reused buffer.
    ///
    /// For a materialized source this is exactly [`Oracle::loss_k_into`]
    /// on the stored matrix.  Oracles that support streamed evaluation
    /// (the closed-form substrates) override it to fold each row's
    /// lazily-regenerated column shards through the same accumulation the
    /// slice path runs, so the two storage modes return bitwise-identical
    /// losses (DESIGN.md §10).  The default rejects streamed sources —
    /// see [`Oracle::supports_streamed_probes`].
    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        match probes.dirs() {
            Some(dirs) => self.loss_k_into(dirs, k, tau, out),
            None => bail!(
                "oracle '{}' cannot evaluate streamed probes (needs a materialized \
                 probe matrix; use --probe-storage materialized)",
                self.name()
            ),
        }
    }

    /// True if [`Oracle::loss_probes`] can evaluate a streamed (matrix-
    /// free) probe source.  The trainer uses this to auto-select probe
    /// storage; oracles that need a host-side matrix (e.g. the PJRT
    /// dispatch path) keep the default `false`.
    fn supports_streamed_probes(&self) -> bool {
        false
    }

    /// Install the shard-parallel execution context used by vectorized
    /// evaluation paths (`loss_k` row parallelism on the closed-form
    /// oracles).  Oracles that dispatch elsewhere (PJRT) ignore it.
    fn set_exec(&mut self, ctx: ExecContext) {
        let _ = ctx;
    }

    /// Read access to the current iterate.
    ///
    /// Oracles running a quantized parameter store
    /// ([`Oracle::set_param_store`]) keep no resident f32 image and panic
    /// here — callers that only need a copy should use
    /// [`Oracle::params_into`], which works in every storage mode.
    fn params(&self) -> &[f32];

    /// Copy the current iterate (dequantized if needed) into `out` —
    /// the storage-agnostic read path used by snapshots and eval.  The
    /// default clones [`Oracle::params`]; quantized-store oracles
    /// override it with an exact dequantization.
    fn params_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(self.params());
    }

    /// Switch the resident parameter representation
    /// ([`crate::tensor::ParamStoreMode`]).  Quantized modes are only
    /// meaningful for forward-only oracles that evaluate through fused
    /// dequant kernels; the default accepts `F32` (a no-op) and rejects
    /// the rest — see [`Oracle::supports_param_store`].
    fn set_param_store(&mut self, mode: crate::tensor::ParamStoreMode) -> Result<()> {
        if mode == crate::tensor::ParamStoreMode::F32 {
            Ok(())
        } else {
            bail!(
                "oracle '{}' does not support --param-store {} (f32 only)",
                self.name(),
                mode.label()
            )
        }
    }

    /// True if [`Oracle::set_param_store`] accepts quantized (f16/int8)
    /// modes.  The trainer uses this to fall back quietly when an env
    /// override requests quantization on an unsupporting oracle.
    fn supports_param_store(&self) -> bool {
        false
    }

    /// Mutate the iterate (optimizer step).  Implementations must
    /// invalidate any device-resident copy.  Quantized-store oracles
    /// dequantize into scratch, apply `f`, and requantize — so `f` always
    /// sees exact current values and the store round-trips bitwise when
    /// `f` is the identity.
    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()>;

    /// Total forward evaluations so far (budget accounting).
    fn oracle_calls(&self) -> u64;

    /// Short identifier used in labels and error messages.
    fn name(&self) -> &str;
}

/// Oracles that can also expose the true gradient (first-order substrates
/// used by the Fig. 2 toy experiment and by alignment diagnostics).
pub trait GradOracle: Oracle {
    /// out = grad f(x); returns f(x).
    fn grad(&mut self, out: &mut [f32]) -> Result<f64>;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal oracle that relies on the *default* `loss_k` (unlike the
    /// closed-form oracles, which override it).
    struct SumOracle {
        x: Vec<f32>,
        calls: u64,
    }

    impl Oracle for SumOracle {
        fn dim(&self) -> usize {
            self.x.len()
        }

        fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
            Ok(())
        }

        fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
            self.calls += 1;
            Ok(self
                .x
                .iter()
                .zip(dir.iter())
                .map(|(a, b)| (*a + scale * *b) as f64)
                .sum())
        }

        fn params(&self) -> &[f32] {
            &self.x
        }

        fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
            f(&mut self.x);
            Ok(())
        }

        fn oracle_calls(&self) -> u64 {
            self.calls
        }

        fn name(&self) -> &str {
            "sum"
        }
    }

    #[test]
    fn default_loss_k_rejects_k_zero() {
        let mut o = SumOracle { x: vec![1.0; 4], calls: 0 };
        let err = o.loss_k(&[], 0, 0.1).unwrap_err();
        assert!(err.to_string().contains("k must be >= 1"), "{err}");
        assert_eq!(o.oracle_calls(), 0, "a rejected call must not be charged");
    }

    #[test]
    fn default_loss_k_matches_loss_dir_loop() {
        let mut o = SumOracle { x: vec![1.0, 2.0], calls: 0 };
        let dirs = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let batched = o.loss_k(&dirs, 3, 0.5).unwrap();
        assert_eq!(o.oracle_calls(), 3);
        let looped: Vec<f64> = (0..3)
            .map(|i| o.loss_dir(&dirs[i * 2..(i + 1) * 2], 0.5).unwrap())
            .collect();
        assert_eq!(batched, looped);
    }
}
