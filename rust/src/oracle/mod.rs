//! Loss oracles: the `f` in `min f(x)`.
//!
//! A ZO method sees the objective only through forward evaluations; this
//! trait is that boundary.  Implementations:
//! * [`PjrtOracle`] (in `pjrt.rs`) — the real thing: AOT-compiled
//!   transformer loss executed via PJRT (one `loss_dir` call = one forward
//!   pass of the model at `x + scale * dir`).
//! * [`QuadraticOracle`], [`LinRegOracle`], [`LogRegOracle`] — closed-form
//!   substrates for tests, the Fig. 2 toy experiment, and fast ablations.
//!
//! Every call increments an oracle-call counter: the paper's §5.1
//! comparisons are at *fixed oracle budget*, so accounting lives at this
//! boundary and is exact by construction.

mod closed_form;
mod pjrt;

pub use closed_form::{LinRegOracle, LogRegOracle, QuadraticOracle};
pub use pjrt::{read_f32_bin as read_params_bin, PjrtOracle};

use anyhow::Result;

use crate::data::Batch;

/// Forward-evaluation interface.  The oracle owns the current iterate `x`
/// (so PJRT implementations can keep it device-resident) and evaluates the
/// objective at rank-1 perturbations of it.
pub trait Oracle {
    /// Trainable dimensionality d.
    fn dim(&self) -> usize;

    /// Point the oracle at the minibatch used for subsequent evaluations.
    /// Builtin (full-batch) oracles ignore this.
    fn set_batch(&mut self, batch: &Batch) -> Result<()>;

    /// f(x + scale * dir).  `scale = 0` or an all-zero dir gives f(x).
    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64>;

    /// Losses at `x + tau * dirs[i]` for i in 0..k (dirs row-major K x d).
    /// Default implementation loops `loss_dir`; the PJRT oracle overrides
    /// it with the fused `loss_k` artifact (one dispatch for K probes).
    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let d = self.dim();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        (0..k).map(|i| self.loss_dir(&dirs[i * d..(i + 1) * d], tau)).collect()
    }

    /// Read access to the current iterate.
    fn params(&self) -> &[f32];

    /// Mutate the iterate (optimizer step).  Implementations must
    /// invalidate any device-resident copy.
    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()>;

    /// Total forward evaluations so far (budget accounting).
    fn oracle_calls(&self) -> u64;

    fn name(&self) -> &str;
}

/// Oracles that can also expose the true gradient (first-order substrates
/// used by the Fig. 2 toy experiment and by alignment diagnostics).
pub trait GradOracle: Oracle {
    /// out = grad f(x); returns f(x).
    fn grad(&mut self, out: &mut [f32]) -> Result<f64>;
}
