//! Closed-form oracle substrates: quadratic, linear regression, logistic
//! regression.  Exact losses and gradients in pure rust — used by the toy
//! experiment (Fig. 2), unit/property tests, and fast ablations.
//!
//! Each oracle overrides [`Oracle::loss_k`] with a *vectorized* batch
//! evaluation of the whole K x d probe matrix: shared per-iterate work
//! (residuals, base margins) is computed once, then the K probe rows are
//! evaluated independently — serial on a one-thread [`ExecContext`],
//! row-parallel otherwise.  Each probe's accumulation runs in the same
//! fixed order either way, so results are bitwise identical for any
//! worker count.  This makes the batched estimation path measurably
//! faster than the per-probe loop even without PJRT artifacts
//! (`perf_hotpath` pins the ratio and the thread-scaling rows), and the
//! batched/looped results agree to float tolerance (pinned by
//! `loss_k_matches_loss_dir_*` below).

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::exec::ExecContext;
use crate::probe::{ProbeCursor, ProbeSource};
use crate::tensor::{axpy_into, dot, perturb_eval, Matrix};

use super::{GradOracle, Oracle};

/// Shard-resumed probe projections for the data-matrix oracles: fold
/// probe row `j`'s lazily-regenerated column shards into per-data-row
/// f64 accumulators `proj[r] = <X_r, v_j>`.  Terms accumulate in column
/// order across shard boundaries — the identical f64 sequence the slice
/// kernels' full-row [`dot`] runs — so the downstream losses stay
/// bitwise equal to the materialized path.  Shared by the linreg and
/// logreg streamed `loss_probes` cores.
fn stream_projections(cur: &mut ProbeCursor<'_>, x_data: &Matrix, j: usize, proj: &mut [f64]) {
    proj.iter_mut().for_each(|p| *p = 0.0);
    cur.visit_row(j, &mut |c0, piece| {
        for (r, p) in proj.iter_mut().enumerate() {
            let xrow = &x_data.row(r)[c0..c0 + piece.len()];
            let mut acc = *p;
            for (xi, vi) in xrow.iter().zip(piece.iter()) {
                acc += (*xi as f64) * (*vi as f64);
            }
            *p = acc;
        }
    });
}

/// f(x) = 0.5 (x - c)^T A (x - c) with diagonal A — conditioning is
/// controllable, optimum known, perfect for convergence tests.
pub struct QuadraticOracle {
    /// Diagonal of A (per-coordinate curvatures).
    pub diag: Vec<f32>,
    /// The optimum c.
    pub center: Vec<f32>,
    x: Vec<f32>,
    scratch: Vec<f32>,
    exec: ExecContext,
    calls: u64,
}

impl QuadraticOracle {
    /// Build from curvature diagonal, optimum and start point (all length d).
    pub fn new(diag: Vec<f32>, center: Vec<f32>, x0: Vec<f32>) -> Self {
        assert_eq!(diag.len(), center.len());
        assert_eq!(diag.len(), x0.len());
        let d = diag.len();
        Self {
            diag,
            center,
            x: x0,
            scratch: vec![0.0; d],
            exec: ExecContext::serial(),
            calls: 0,
        }
    }

    /// Isotropic instance: f(x) = 0.5 ||x||^2 from a given start.
    pub fn isotropic(x0: Vec<f32>) -> Self {
        let d = x0.len();
        Self::new(vec![1.0; d], vec![0.0; d], x0)
    }

    fn value_at(&self, z: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..z.len() {
            let r = (z[i] - self.center[i]) as f64;
            acc += 0.5 * self.diag[i] as f64 * r * r;
        }
        acc
    }

    /// Shared `loss_k`/`loss_k_into` core: hoist the iterate residual once,
    /// then evaluate the K probe rows independently (row-parallel on the
    /// installed context; each row's fused sum runs in index order, so the
    /// output is bitwise identical for any worker count).
    fn loss_k_impl(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.x.len();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        self.calls += k as u64;
        // hoist the iterate residual r = x - c out of the probe loop
        // (sharded elementwise pass)
        {
            let x = &self.x;
            let c = &self.center;
            self.exec.for_each_shard_mut(&mut self.scratch, |_, start, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = x[start + i] - c[start + i];
                }
            });
        }
        // each probe is a single fused pass 0.5 * sum_i a_i (r_i + tau v_i)^2
        let scratch = &self.scratch;
        let diag = &self.diag;
        let vals = self.exec.map_items_sized(k, d, |j| {
            let row = &dirs[j * d..(j + 1) * d];
            let mut acc = 0.0f64;
            for i in 0..d {
                // fused, matching the perturb_eval kernel the streamed
                // path runs (tensor::lanes contract)
                let z = tau.mul_add(row[i], scratch[i]) as f64;
                acc += 0.5 * diag[i] as f64 * z * z;
            }
            acc
        });
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }
}

impl Oracle for QuadraticOracle {
    fn dim(&self) -> usize {
        self.x.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        axpy_into(&mut self.scratch, &self.x, scale, dir);
        // borrow dance: value_at needs &self
        let z = std::mem::take(&mut self.scratch);
        let v = self.value_at(&z);
        self.scratch = z;
        Ok(v)
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(k);
        self.loss_k_impl(dirs, k, tau, &mut out)?;
        Ok(out)
    }

    fn loss_k_into(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        self.loss_k_impl(dirs, k, tau, out)
    }

    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let Some(dirs) = probes.dirs() {
            return self.loss_k_impl(dirs, k, tau, out);
        }
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.x.len();
        assert_eq!(probes.dim(), d, "probe rows must be length d");
        self.calls += k as u64;
        // hoist the iterate residual exactly like loss_k_impl
        {
            let x = &self.x;
            let c = &self.center;
            self.exec.for_each_shard_mut(&mut self.scratch, |_, start, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = x[start + i] - c[start + i];
                }
            });
        }
        // per probe: one worker folds the row's lazily-regenerated column
        // shards through a running f64 accumulator — the identical term
        // sequence the slice kernel produces, so losses are bitwise equal.
        // Cursors (and their shard scratch) are per worker, not per probe.
        let scratch = &self.scratch;
        let diag = &self.diag;
        let vals = self.exec.map_items_sized_scratch(
            k,
            d,
            || probes.cursor(),
            |cur, j| {
                let mut acc = 0.0f64;
                cur.visit_row(j, &mut |c0, piece| {
                    perturb_eval(&scratch[c0..c0 + piece.len()], tau, piece, |i, z| {
                        let zf = z as f64;
                        acc += 0.5 * diag[c0 + i] as f64 * zf * zf;
                    });
                });
                acc
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }

    fn supports_streamed_probes(&self) -> bool {
        true
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn params(&self) -> &[f32] {
        &self.x
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.x);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "quadratic"
    }
}

impl GradOracle for QuadraticOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        for i in 0..self.x.len() {
            out[i] = self.diag[i] * (self.x[i] - self.center[i]);
        }
        Ok(self.value_at(&self.x))
    }
}

/// f(w) = 0.5/N ||Xw - y||^2 — the paper's toy objective on a9a.
pub struct LinRegOracle {
    /// Design matrix X (N x d).
    pub x_data: Matrix,
    /// Targets y (length N).
    pub y: Vec<f32>,
    w: Vec<f32>,
    resid: Vec<f32>,
    wtmp: Vec<f32>,
    exec: ExecContext,
    calls: u64,
}

impl LinRegOracle {
    /// Build from data (N x d), targets (N) and start weights (d).
    pub fn new(x_data: Matrix, y: Vec<f32>, w0: Vec<f32>) -> Self {
        assert_eq!(x_data.rows, y.len());
        assert_eq!(x_data.cols, w0.len());
        let n = y.len();
        let d = w0.len();
        Self {
            x_data,
            y,
            w: w0,
            resid: vec![0.0; n],
            wtmp: vec![0.0; d],
            exec: ExecContext::serial(),
            calls: 0,
        }
    }

    fn loss_at(&mut self, w: &[f32]) -> f64 {
        let n = self.x_data.rows;
        self.x_data.matvec(w, &mut self.resid);
        let mut acc = 0.0f64;
        for i in 0..n {
            let r = (self.resid[i] - self.y[i]) as f64;
            acc += r * r;
        }
        0.5 * acc / n as f64
    }

    /// Shared `loss_k`/`loss_k_into` core: base margins Xw once, then the
    /// K probes evaluated independently (row-parallel on the installed
    /// context).  Per probe the data rows accumulate in index order — the
    /// same order as the serial kernel — so results are bitwise identical
    /// for any worker count.
    fn loss_k_impl(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.w.len();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        self.calls += k as u64;
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.resid);
        let x_data = &self.x_data;
        let resid = &self.resid;
        let y = &self.y;
        let vals = self.exec.map_items_sized(k, n.saturating_mul(d), |j| {
            let dj = &dirs[j * d..(j + 1) * d];
            let mut acc = 0.0f64;
            for r in 0..n {
                let pj = dot(x_data.row(r), dj);
                let e = (resid[r] + tau * pj - y[r]) as f64;
                acc += e * e;
            }
            0.5 * acc / n as f64
        });
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }
}

impl Oracle for LinRegOracle {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        axpy_into(&mut wtmp, &self.w, scale, dir);
        let v = self.loss_at(&wtmp);
        self.wtmp = wtmp;
        Ok(v)
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(k);
        self.loss_k_impl(dirs, k, tau, &mut out)?;
        Ok(out)
    }

    fn loss_k_into(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        self.loss_k_impl(dirs, k, tau, out)
    }

    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let Some(dirs) = probes.dirs() {
            return self.loss_k_impl(dirs, k, tau, out);
        }
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.w.len();
        assert_eq!(probes.dim(), d, "probe rows must be length d");
        self.calls += k as u64;
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.resid);
        // per probe: the data-row projections X v_j accumulate across the
        // row's column shards in column order — the same f64 term sequence
        // as the slice kernel's full-row `dot`, paused and resumed at
        // shard boundaries, so the losses are bitwise equal.  Cursor and
        // projection accumulators are per worker, reset per probe.
        let x_data = &self.x_data;
        let resid = &self.resid;
        let y = &self.y;
        let vals = self.exec.map_items_sized_scratch(
            k,
            n.saturating_mul(d),
            || (probes.cursor(), vec![0.0f64; n]),
            |scratch, j| {
                let (cur, proj) = scratch;
                stream_projections(cur, x_data, j, proj);
                let mut acc = 0.0f64;
                for r in 0..n {
                    let pj = proj[r] as f32;
                    let e = (resid[r] + tau * pj - y[r]) as f64;
                    acc += e * e;
                }
                0.5 * acc / n as f64
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }

    fn supports_streamed_probes(&self) -> bool {
        true
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.w);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "linreg"
    }
}

impl GradOracle for LinRegOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.resid);
        for i in 0..n {
            self.resid[i] -= self.y[i];
        }
        let mut acc = 0.0f64;
        for r in &self.resid {
            acc += (*r as f64) * (*r as f64);
        }
        self.x_data.matvec_t(&self.resid, out);
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        Ok(0.5 * acc / n as f64)
    }
}

/// Binary logistic regression with labels in {-1, +1}:
/// f(w) = 1/N sum log(1 + exp(-y_i x_i^T w)).
pub struct LogRegOracle {
    /// Design matrix X (N x d).
    pub x_data: Matrix,
    /// Labels in {-1, +1} (length N).
    pub y: Vec<f32>,
    w: Vec<f32>,
    margin: Vec<f32>,
    wtmp: Vec<f32>,
    exec: ExecContext,
    calls: u64,
}

/// log(1 + e^-m), numerically stable for both signs of m.
#[inline]
fn log1p_exp_neg(m: f64) -> f64 {
    if m > 0.0 {
        (-m).exp().ln_1p()
    } else {
        -m + m.exp().ln_1p()
    }
}

impl LogRegOracle {
    /// Build from data (N x d), +-1 labels (N) and start weights (d).
    pub fn new(x_data: Matrix, y: Vec<f32>, w0: Vec<f32>) -> Self {
        assert_eq!(x_data.rows, y.len());
        assert_eq!(x_data.cols, w0.len());
        for lab in &y {
            assert!(*lab == 1.0 || *lab == -1.0, "labels must be +-1");
        }
        let n = y.len();
        let d = w0.len();
        Self {
            x_data,
            y,
            w: w0,
            margin: vec![0.0; n],
            wtmp: vec![0.0; d],
            exec: ExecContext::serial(),
            calls: 0,
        }
    }

    fn loss_at(&mut self, w: &[f32]) -> f64 {
        let n = self.x_data.rows;
        self.x_data.matvec(w, &mut self.margin);
        let mut acc = 0.0f64;
        for i in 0..n {
            let m = (self.y[i] * self.margin[i]) as f64;
            acc += log1p_exp_neg(m);
        }
        acc / n as f64
    }

    /// Shared `loss_k`/`loss_k_into` core (see [`LinRegOracle`]: same
    /// structure, logistic link).
    fn loss_k_impl(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.w.len();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        self.calls += k as u64;
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.margin);
        let x_data = &self.x_data;
        let margin = &self.margin;
        let y = &self.y;
        let vals = self.exec.map_items_sized(k, n.saturating_mul(d), |j| {
            let dj = &dirs[j * d..(j + 1) * d];
            let mut acc = 0.0f64;
            for r in 0..n {
                let pj = dot(x_data.row(r), dj);
                let m = (y[r] * (margin[r] + tau * pj)) as f64;
                acc += log1p_exp_neg(m);
            }
            acc / n as f64
        });
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }
}

impl Oracle for LogRegOracle {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        axpy_into(&mut wtmp, &self.w, scale, dir);
        let v = self.loss_at(&wtmp);
        self.wtmp = wtmp;
        Ok(v)
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(k);
        self.loss_k_impl(dirs, k, tau, &mut out)?;
        Ok(out)
    }

    fn loss_k_into(&mut self, dirs: &[f32], k: usize, tau: f32, out: &mut Vec<f64>) -> Result<()> {
        self.loss_k_impl(dirs, k, tau, out)
    }

    fn loss_probes(
        &mut self,
        probes: &dyn ProbeSource,
        k: usize,
        tau: f32,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let Some(dirs) = probes.dirs() {
            return self.loss_k_impl(dirs, k, tau, out);
        }
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.w.len();
        assert_eq!(probes.dim(), d, "probe rows must be length d");
        self.calls += k as u64;
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.margin);
        // see LinRegOracle::loss_probes: shard-resumed projections (per-
        // worker cursor + accumulators), then the logistic link in
        // data-row order — bitwise equal to the slice kernel
        let x_data = &self.x_data;
        let margin = &self.margin;
        let y = &self.y;
        let vals = self.exec.map_items_sized_scratch(
            k,
            n.saturating_mul(d),
            || (probes.cursor(), vec![0.0f64; n]),
            |scratch, j| {
                let (cur, proj) = scratch;
                stream_projections(cur, x_data, j, proj);
                let mut acc = 0.0f64;
                for r in 0..n {
                    let pj = proj[r] as f32;
                    let m = (y[r] * (margin[r] + tau * pj)) as f64;
                    acc += log1p_exp_neg(m);
                }
                acc / n as f64
            },
        );
        out.clear();
        out.extend_from_slice(&vals);
        Ok(())
    }

    fn supports_streamed_probes(&self) -> bool {
        true
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.w);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "logreg"
    }
}

impl GradOracle for LogRegOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.margin);
        let mut acc = 0.0f64;
        for i in 0..n {
            let m = (self.y[i] * self.margin[i]) as f64;
            acc += log1p_exp_neg(m);
            // dl/dmargin_i = -y_i * sigmoid(-y_i m_i)
            let s = 1.0 / (1.0 + m.exp());
            self.margin[i] = -(self.y[i] as f64 * s) as f32;
        }
        self.x_data.matvec_t(&self.margin, out);
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        Ok(acc / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::nrm2;

    fn fd_grad_check<O: GradOracle>(oracle: &mut O, tol: f64) {
        let d = oracle.dim();
        let mut g = vec![0.0f32; d];
        oracle.grad(&mut g).unwrap();
        let h = 1e-3f32;
        for i in (0..d).step_by((d / 7).max(1)) {
            let mut e = vec![0.0f32; d];
            e[i] = 1.0;
            let fp = oracle.loss_dir(&e, h).unwrap();
            let fm = oracle.loss_dir(&e, -h).unwrap();
            let fd = (fp - fm) / (2.0 * h as f64);
            assert!(
                (fd - g[i] as f64).abs() < tol * (1.0 + g[i].abs() as f64),
                "coord {i}: fd {fd} vs grad {}",
                g[i]
            );
        }
    }

    /// The batched `loss_k` override must agree with the per-probe
    /// `loss_dir` loop to float tolerance (the paths differ only in f32
    /// summation order), and must charge the same number of oracle calls.
    fn loss_k_equivalence_check<O: Oracle>(oracle: &mut O, k: usize, tau: f32, seed: u64) {
        let d = oracle.dim();
        let mut rng = crate::rng::Rng::new(seed);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);
        let before = oracle.oracle_calls();
        let batched = oracle.loss_k(&dirs, k, tau).unwrap();
        assert_eq!(
            oracle.oracle_calls() - before,
            k as u64,
            "{}: loss_k must charge k calls",
            oracle.name()
        );
        let looped: Vec<f64> = (0..k)
            .map(|i| oracle.loss_dir(&dirs[i * d..(i + 1) * d], tau).unwrap())
            .collect();
        assert_eq!(batched.len(), k);
        for (i, (b, l)) in batched.iter().zip(looped.iter()).enumerate() {
            assert!(
                (b - l).abs() <= 1e-4 * (1.0 + l.abs()),
                "{} probe {i}: batched {b} vs looped {l}",
                oracle.name()
            );
        }
        // k = 0 is rejected, not silently empty
        assert!(oracle.loss_k(&[], 0, tau).is_err());
    }

    #[test]
    fn quadratic_grad_matches_fd() {
        let d = 29;
        let diag: Vec<f32> = (0..d).map(|i| 1.0 + i as f32 * 0.3).collect();
        let center: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let x0: Vec<f32> = (0..d).map(|i| (i as f32).cos()).collect();
        let mut o = QuadraticOracle::new(diag, center, x0);
        fd_grad_check(&mut o, 1e-3);
    }

    #[test]
    fn quadratic_minimum_is_center() {
        let mut o = QuadraticOracle::new(
            vec![2.0, 3.0],
            vec![1.0, -1.0],
            vec![1.0, -1.0],
        );
        let zero = vec![0.0f32; 2];
        assert!(o.loss_dir(&zero, 0.0).unwrap() < 1e-12);
        let mut g = vec![0.0f32; 2];
        o.grad(&mut g).unwrap();
        assert!(nrm2(&g) < 1e-6);
    }

    #[test]
    fn quadratic_loss_k_matches_loss_dir() {
        let d = 37;
        let diag: Vec<f32> = (0..d).map(|i| 1.0 + 0.1 * i as f32).collect();
        let center: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin()).collect();
        let x0: Vec<f32> = (0..d).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut o = QuadraticOracle::new(diag, center, x0);
        loss_k_equivalence_check(&mut o, 5, 1e-2, 1);
    }

    #[test]
    fn linreg_loss_k_matches_loss_dir() {
        let ds = crate::data::SyntheticRegression::a9a_like(96, 9);
        let w0: Vec<f32> = (0..123).map(|i| 0.01 * (i as f32).sin()).collect();
        let mut o = LinRegOracle::new(ds.x, ds.y, w0);
        loss_k_equivalence_check(&mut o, 6, 0.05, 2);
    }

    #[test]
    fn logreg_loss_k_matches_loss_dir() {
        let ds = crate::data::SyntheticRegression::a9a_like(96, 10);
        let y: Vec<f32> = ds.y.iter().map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
        let mut o = LogRegOracle::new(ds.x, y, vec![0.05f32; 123]);
        loss_k_equivalence_check(&mut o, 4, 0.1, 3);
    }

    #[test]
    fn linreg_grad_matches_fd() {
        let ds = crate::data::SyntheticRegression::a9a_like(64, 5);
        let w0 = vec![0.1f32; 123];
        let mut o = LinRegOracle::new(ds.x, ds.y, w0);
        fd_grad_check(&mut o, 1e-2);
    }

    #[test]
    fn linreg_loss_near_zero_at_truth_with_no_noise() {
        let ds = crate::data::SyntheticRegression::generate(64, 20, 5, 0.0, 3);
        let w = ds.w_true.clone();
        let mut o = LinRegOracle::new(ds.x, ds.y, vec![0.0; 20]);
        let mut dir = w;
        let l_at_truth = o.loss_dir(&mut dir, 1.0).unwrap();
        assert!(l_at_truth < 1e-9, "{l_at_truth}");
    }

    #[test]
    fn logreg_grad_matches_fd() {
        let ds = crate::data::SyntheticRegression::a9a_like(64, 11);
        let y: Vec<f32> = ds.y.iter().map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
        let mut o = LogRegOracle::new(ds.x, y, vec![0.05f32; 123]);
        fd_grad_check(&mut o, 1e-2);
    }

    #[test]
    fn loss_k_parallel_bitwise_matches_serial() {
        // same oracle, serial vs 8-thread context: the probe losses must
        // be bit-for-bit equal (per-probe accumulation order is fixed)
        let d = 512;
        let k = 5;
        let diag: Vec<f32> = (0..d).map(|i| 1.0 + 0.1 * (i % 5) as f32).collect();
        let center: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin()).collect();
        let x0: Vec<f32> = (0..d).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut rng = crate::rng::Rng::new(11);
        let mut dirs = vec![0.0f32; k * d];
        rng.fill_normal(&mut dirs);

        let mut serial = QuadraticOracle::new(diag.clone(), center.clone(), x0.clone());
        let mut par = QuadraticOracle::new(diag, center, x0);
        par.set_exec(crate::exec::ExecContext::new(8).with_shard_len(64));
        let a = serial.loss_k(&dirs, k, 1e-2).unwrap();
        let b = par.loss_k(&dirs, k, 1e-2).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }

        let ds = crate::data::SyntheticRegression::a9a_like(96, 9);
        let w0: Vec<f32> = (0..123).map(|i| 0.01 * (i as f32).sin()).collect();
        let mut lin_s = LinRegOracle::new(ds.x.clone(), ds.y.clone(), w0.clone());
        let mut lin_p = LinRegOracle::new(ds.x, ds.y, w0);
        lin_p.set_exec(crate::exec::ExecContext::new(4).with_shard_len(64));
        let mut dirs2 = vec![0.0f32; 4 * 123];
        rng.fill_normal(&mut dirs2);
        let a2 = lin_s.loss_k(&dirs2, 4, 0.05).unwrap();
        let b2 = lin_p.loss_k(&dirs2, 4, 0.05).unwrap();
        for (x, y) in a2.iter().zip(b2.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn loss_probes_streamed_bitwise_matches_materialized() {
        use crate::probe::{BoxedSampler, MaterializedProbes, ProbeLayout, ProbeSource, StreamedProbes};
        use crate::sampler::{LdsdConfig, LdsdSampler};

        let k = 4;
        let tau = 1e-2f32;
        let check = |mut mk_oracle: Box<dyn FnMut() -> Box<dyn Oracle>>, d: usize| {
            for threads in [1usize, 4] {
                let ctx = crate::exec::ExecContext::new(threads).with_shard_len(37);
                let sampler =
                    |seed| -> BoxedSampler { Box::new(LdsdSampler::new(d, seed, LdsdConfig::default())) };
                let mut mat = MaterializedProbes::new(sampler(9), ProbeLayout::Direct, k);
                mat.set_exec(ctx.clone());
                let mut st = StreamedProbes::new(sampler(9), ProbeLayout::Direct, k);
                st.set_exec(ctx.clone());
                mat.advance();
                st.advance();
                let mut o1 = mk_oracle();
                o1.set_exec(ctx.clone());
                let mut o2 = mk_oracle();
                o2.set_exec(ctx);
                let mut l1 = Vec::new();
                let mut l2 = Vec::new();
                o1.loss_probes(&mat, k, tau, &mut l1).unwrap();
                o2.loss_probes(&st, k, tau, &mut l2).unwrap();
                assert_eq!(o1.oracle_calls(), o2.oracle_calls());
                assert_eq!(l1.len(), k);
                for (a, b) in l1.iter().zip(l2.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}: {a} vs {b}", o1.name());
                }
            }
        };

        let dq = 333;
        check(
            Box::new(move || {
                let diag: Vec<f32> = (0..dq).map(|i| 1.0 + 0.1 * (i % 5) as f32).collect();
                let center: Vec<f32> = (0..dq).map(|i| (i as f32 * 0.3).sin()).collect();
                let x0: Vec<f32> = (0..dq).map(|i| (i as f32 * 0.7).cos()).collect();
                let b: Box<dyn Oracle> = Box::new(QuadraticOracle::new(diag, center, x0));
                b
            }),
            dq,
        );
        check(
            Box::new(|| {
                let ds = crate::data::SyntheticRegression::a9a_like(64, 9);
                let w0: Vec<f32> = (0..123).map(|i| 0.01 * (i as f32).sin()).collect();
                let b: Box<dyn Oracle> = Box::new(LinRegOracle::new(ds.x, ds.y, w0));
                b
            }),
            123,
        );
        check(
            Box::new(|| {
                let ds = crate::data::SyntheticRegression::a9a_like(64, 10);
                let y: Vec<f32> =
                    ds.y.iter().map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
                let b: Box<dyn Oracle> = Box::new(LogRegOracle::new(ds.x, y, vec![0.05f32; 123]));
                b
            }),
            123,
        );
    }

    #[test]
    fn oracle_calls_counted() {
        let mut o = QuadraticOracle::isotropic(vec![1.0; 4]);
        let dir = vec![1.0f32, 0.0, 0.0, 0.0];
        assert_eq!(o.oracle_calls(), 0);
        o.loss_dir(&dir, 0.1).unwrap();
        o.loss_dir(&dir, -0.1).unwrap();
        assert_eq!(o.oracle_calls(), 2);
        let dirs = vec![0.5f32; 8];
        o.loss_k(&dirs, 2, 0.1).unwrap();
        assert_eq!(o.oracle_calls(), 4);
    }

    #[test]
    fn update_params_moves_iterate() {
        let mut o = QuadraticOracle::isotropic(vec![1.0; 3]);
        o.update_params(&mut |x| x[0] = 5.0).unwrap();
        assert_eq!(o.params()[0], 5.0);
    }
}
