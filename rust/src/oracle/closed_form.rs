//! Closed-form oracle substrates: quadratic, linear regression, logistic
//! regression.  Exact losses and gradients in pure rust — used by the toy
//! experiment (Fig. 2), unit/property tests, and fast ablations.

use anyhow::Result;

use crate::data::Batch;
use crate::tensor::{axpy_into, Matrix};

use super::{GradOracle, Oracle};

/// f(x) = 0.5 (x - c)^T A (x - c) with diagonal A — conditioning is
/// controllable, optimum known, perfect for convergence tests.
pub struct QuadraticOracle {
    pub diag: Vec<f32>,
    pub center: Vec<f32>,
    x: Vec<f32>,
    scratch: Vec<f32>,
    calls: u64,
}

impl QuadraticOracle {
    pub fn new(diag: Vec<f32>, center: Vec<f32>, x0: Vec<f32>) -> Self {
        assert_eq!(diag.len(), center.len());
        assert_eq!(diag.len(), x0.len());
        let d = diag.len();
        Self { diag, center, x: x0, scratch: vec![0.0; d], calls: 0 }
    }

    /// Isotropic instance: f(x) = 0.5 ||x||^2 from a given start.
    pub fn isotropic(x0: Vec<f32>) -> Self {
        let d = x0.len();
        Self::new(vec![1.0; d], vec![0.0; d], x0)
    }

    fn value_at(&self, z: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..z.len() {
            let r = (z[i] - self.center[i]) as f64;
            acc += 0.5 * self.diag[i] as f64 * r * r;
        }
        acc
    }
}

impl Oracle for QuadraticOracle {
    fn dim(&self) -> usize {
        self.x.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        axpy_into(&mut self.scratch, &self.x, scale, dir);
        // borrow dance: value_at needs &self
        let z = std::mem::take(&mut self.scratch);
        let v = self.value_at(&z);
        self.scratch = z;
        Ok(v)
    }

    fn params(&self) -> &[f32] {
        &self.x
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.x);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "quadratic"
    }
}

impl GradOracle for QuadraticOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        for i in 0..self.x.len() {
            out[i] = self.diag[i] * (self.x[i] - self.center[i]);
        }
        Ok(self.value_at(&self.x))
    }
}

/// f(w) = 0.5/N ||Xw - y||^2 — the paper's toy objective on a9a.
pub struct LinRegOracle {
    pub x_data: Matrix,
    pub y: Vec<f32>,
    w: Vec<f32>,
    resid: Vec<f32>,
    wtmp: Vec<f32>,
    calls: u64,
}

impl LinRegOracle {
    pub fn new(x_data: Matrix, y: Vec<f32>, w0: Vec<f32>) -> Self {
        assert_eq!(x_data.rows, y.len());
        assert_eq!(x_data.cols, w0.len());
        let n = y.len();
        let d = w0.len();
        Self { x_data, y, w: w0, resid: vec![0.0; n], wtmp: vec![0.0; d], calls: 0 }
    }

    fn loss_at(&mut self, w: &[f32]) -> f64 {
        let n = self.x_data.rows;
        self.x_data.matvec(w, &mut self.resid);
        let mut acc = 0.0f64;
        for i in 0..n {
            let r = (self.resid[i] - self.y[i]) as f64;
            acc += r * r;
        }
        0.5 * acc / n as f64
    }
}

impl Oracle for LinRegOracle {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        axpy_into(&mut wtmp, &self.w, scale, dir);
        let v = self.loss_at(&wtmp);
        self.wtmp = wtmp;
        Ok(v)
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.w);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "linreg"
    }
}

impl GradOracle for LinRegOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.resid);
        for i in 0..n {
            self.resid[i] -= self.y[i];
        }
        let mut acc = 0.0f64;
        for r in &self.resid {
            acc += (*r as f64) * (*r as f64);
        }
        self.x_data.matvec_t(&self.resid, out);
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        Ok(0.5 * acc / n as f64)
    }
}

/// Binary logistic regression with labels in {-1, +1}:
/// f(w) = 1/N sum log(1 + exp(-y_i x_i^T w)).
pub struct LogRegOracle {
    pub x_data: Matrix,
    pub y: Vec<f32>,
    w: Vec<f32>,
    margin: Vec<f32>,
    wtmp: Vec<f32>,
    calls: u64,
}

impl LogRegOracle {
    pub fn new(x_data: Matrix, y: Vec<f32>, w0: Vec<f32>) -> Self {
        assert_eq!(x_data.rows, y.len());
        assert_eq!(x_data.cols, w0.len());
        for lab in &y {
            assert!(*lab == 1.0 || *lab == -1.0, "labels must be +-1");
        }
        let n = y.len();
        let d = w0.len();
        Self { x_data, y, w: w0, margin: vec![0.0; n], wtmp: vec![0.0; d], calls: 0 }
    }

    fn loss_at(&mut self, w: &[f32]) -> f64 {
        let n = self.x_data.rows;
        self.x_data.matvec(w, &mut self.margin);
        let mut acc = 0.0f64;
        for i in 0..n {
            let m = (self.y[i] * self.margin[i]) as f64;
            // log(1 + e^-m), stable
            acc += if m > 0.0 { (-m).exp().ln_1p() } else { -m + m.exp().ln_1p() };
        }
        acc / n as f64
    }
}

impl Oracle for LogRegOracle {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn set_batch(&mut self, _batch: &Batch) -> Result<()> {
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        let mut wtmp = std::mem::take(&mut self.wtmp);
        axpy_into(&mut wtmp, &self.w, scale, dir);
        let v = self.loss_at(&wtmp);
        self.wtmp = wtmp;
        Ok(v)
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.w);
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        "logreg"
    }
}

impl GradOracle for LogRegOracle {
    fn grad(&mut self, out: &mut [f32]) -> Result<f64> {
        let n = self.x_data.rows;
        self.x_data.matvec(&self.w, &mut self.margin);
        let mut acc = 0.0f64;
        for i in 0..n {
            let m = (self.y[i] * self.margin[i]) as f64;
            acc += if m > 0.0 { (-m).exp().ln_1p() } else { -m + m.exp().ln_1p() };
            // dl/dmargin_i = -y_i * sigmoid(-y_i m_i)
            let s = 1.0 / (1.0 + m.exp());
            self.margin[i] = -(self.y[i] as f64 * s) as f32;
        }
        self.x_data.matvec_t(&self.margin, out);
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        Ok(acc / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::nrm2;

    fn fd_grad_check<O: GradOracle>(oracle: &mut O, tol: f64) {
        let d = oracle.dim();
        let mut g = vec![0.0f32; d];
        oracle.grad(&mut g).unwrap();
        let h = 1e-3f32;
        for i in (0..d).step_by((d / 7).max(1)) {
            let mut e = vec![0.0f32; d];
            e[i] = 1.0;
            let fp = oracle.loss_dir(&e, h).unwrap();
            let fm = oracle.loss_dir(&e, -h).unwrap();
            let fd = (fp - fm) / (2.0 * h as f64);
            assert!(
                (fd - g[i] as f64).abs() < tol * (1.0 + g[i].abs() as f64),
                "coord {i}: fd {fd} vs grad {}",
                g[i]
            );
        }
    }

    #[test]
    fn quadratic_grad_matches_fd() {
        let d = 29;
        let diag: Vec<f32> = (0..d).map(|i| 1.0 + i as f32 * 0.3).collect();
        let center: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let x0: Vec<f32> = (0..d).map(|i| (i as f32).cos()).collect();
        let mut o = QuadraticOracle::new(diag, center, x0);
        fd_grad_check(&mut o, 1e-3);
    }

    #[test]
    fn quadratic_minimum_is_center() {
        let mut o = QuadraticOracle::new(
            vec![2.0, 3.0],
            vec![1.0, -1.0],
            vec![1.0, -1.0],
        );
        let zero = vec![0.0f32; 2];
        assert!(o.loss_dir(&zero, 0.0).unwrap() < 1e-12);
        let mut g = vec![0.0f32; 2];
        o.grad(&mut g).unwrap();
        assert!(nrm2(&g) < 1e-6);
    }

    #[test]
    fn linreg_grad_matches_fd() {
        let ds = crate::data::SyntheticRegression::a9a_like(64, 5);
        let w0 = vec![0.1f32; 123];
        let mut o = LinRegOracle::new(ds.x, ds.y, w0);
        fd_grad_check(&mut o, 1e-2);
    }

    #[test]
    fn linreg_loss_near_zero_at_truth_with_no_noise() {
        let ds = crate::data::SyntheticRegression::generate(64, 20, 5, 0.0, 3);
        let w = ds.w_true.clone();
        let mut o = LinRegOracle::new(ds.x, ds.y, vec![0.0; 20]);
        let mut dir = w;
        let l_at_truth = o.loss_dir(&mut dir, 1.0).unwrap();
        assert!(l_at_truth < 1e-9, "{l_at_truth}");
    }

    #[test]
    fn logreg_grad_matches_fd() {
        let ds = crate::data::SyntheticRegression::a9a_like(64, 11);
        let y: Vec<f32> = ds.y.iter().map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
        let mut o = LogRegOracle::new(ds.x, y, vec![0.05f32; 123]);
        fd_grad_check(&mut o, 1e-2);
    }

    #[test]
    fn oracle_calls_counted() {
        let mut o = QuadraticOracle::isotropic(vec![1.0; 4]);
        let dir = vec![1.0f32, 0.0, 0.0, 0.0];
        assert_eq!(o.oracle_calls(), 0);
        o.loss_dir(&dir, 0.1).unwrap();
        o.loss_dir(&dir, -0.1).unwrap();
        assert_eq!(o.oracle_calls(), 2);
        let dirs = vec![0.5f32; 8];
        o.loss_k(&dirs, 2, 0.1).unwrap();
        assert_eq!(o.oracle_calls(), 4);
    }

    #[test]
    fn update_params_moves_iterate() {
        let mut o = QuadraticOracle::isotropic(vec![1.0; 3]);
        o.update_params(&mut |x| x[0] = 5.0).unwrap();
        assert_eq!(o.params()[0], 5.0);
    }
}
