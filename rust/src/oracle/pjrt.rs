//! PJRT-backed loss oracle: forward passes of the AOT-compiled transformer.
//!
//! Perf-relevant structure (EXPERIMENTS.md §Perf):
//! * trainable params are uploaded to the device once per optimizer update
//!   (dirty-flag), not once per probe — K+1 probes reuse the buffer;
//! * in LoRA mode the frozen base (d_ft floats) is uploaded exactly once
//!   for the lifetime of the oracle;
//! * the minibatch tensors are uploaded once per `set_batch`;
//! * `loss_k` uses the fused K-probe artifact: one PJRT dispatch evaluates
//!   all K candidate directions (Algorithm 2 line 4).

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelEntry, TrainMode};
use crate::data::Batch;
use crate::runtime::{Arg, DeviceBuffer, Executable, Runtime};

use super::Oracle;

/// Loss oracle backed by AOT-compiled transformer graphs executed via
/// PJRT (requires the `pjrt` feature and built artifacts at runtime).
pub struct PjrtOracle {
    rt: Runtime,
    entry: ModelEntry,
    mode: TrainMode,
    loss_dir_exe: Arc<Executable>,
    loss_k_exe: Option<Arc<Executable>>,
    /// current iterate (FT: full params; LoRA: adapter vector)
    trainable: Vec<f32>,
    trainable_dev: Option<DeviceBuffer>,
    /// LoRA mode only: frozen base params, device-resident
    base_dev: Option<DeviceBuffer>,
    batch_dev: Option<(DeviceBuffer, DeviceBuffer, DeviceBuffer)>,
    zero_dir: Vec<f32>,
    calls: u64,
    name: String,
}

impl PjrtOracle {
    /// Build from the manifest entry.  Loads params/lora .bin files from the
    /// runtime's artifact dir and compiles the loss artifacts.
    pub fn new(rt: &Runtime, entry: &ModelEntry, mode: TrainMode) -> Result<Self> {
        let dir = rt.artifact_dir().to_path_buf();
        let base = read_f32_bin(&dir.join(&entry.params_file), entry.d_ft)?;
        let (trainable, base_dev) = match mode {
            TrainMode::Ft => (base, None),
            TrainMode::Lora => {
                let lora = read_f32_bin(
                    &dir.join(&entry.lora_init_file),
                    entry.d_lora,
                )?;
                let dev = rt
                    .upload_f32(&base, &[entry.d_ft])
                    .context("uploading frozen LoRA base")?;
                (lora, Some(dev))
            }
        };
        let loss_dir_exe = rt.load(&entry.artifact(mode, "loss_dir"))?;
        // loss_k is an optimization; tolerate its absence (older manifests)
        let loss_k_exe = rt.load(&entry.artifact(mode, "loss_k")).ok();
        let d = trainable.len();
        Ok(Self {
            rt: rt.clone(),
            entry: entry.clone(),
            mode,
            loss_dir_exe,
            loss_k_exe,
            trainable,
            trainable_dev: None,
            base_dev,
            batch_dev: None,
            zero_dir: vec![0.0; d],
            calls: 0,
            name: format!("pjrt:{}:{}", entry.name, mode.as_str()),
        })
    }

    /// The train mode this oracle perturbs (ft or lora).
    pub fn mode(&self) -> TrainMode {
        self.mode
    }

    /// The manifest entry this oracle was built from.
    pub fn model(&self) -> &ModelEntry {
        &self.entry
    }

    /// Replace the trainable vector wholesale (checkpoint restore).
    pub fn load_trainable(&mut self, v: &[f32]) -> Result<()> {
        if v.len() != self.trainable.len() {
            bail!(
                "trainable size mismatch: got {}, want {}",
                v.len(),
                self.trainable.len()
            );
        }
        self.trainable.copy_from_slice(v);
        self.trainable_dev = None;
        Ok(())
    }

    fn ensure_trainable_dev(&mut self) -> Result<()> {
        if self.trainable_dev.is_none() {
            self.trainable_dev = Some(
                self.rt
                    .upload_f32(&self.trainable, &[self.trainable.len()])
                    .context("uploading trainable params")?,
            );
        }
        Ok(())
    }

    fn run_loss(
        &mut self,
        exe: Arc<Executable>,
        dir: &[f32],
        dir_dims: &[usize],
        tau: f32,
        n_out: usize,
    ) -> Result<Vec<f64>> {
        self.ensure_trainable_dev()?;
        let (ids_dev, mask_dev, lab_dev) = self
            .batch_dev
            .as_ref()
            .ok_or_else(|| anyhow!("{}: set_batch not called", self.name))?;
        let t_dev = self.trainable_dev.as_ref().unwrap();
        let dir_dev = self.rt.upload_f32(dir, dir_dims)?;
        let tau_dev = self.rt.upload_f32(&[tau], &[])?;
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(7);
        if let Some(bd) = &self.base_dev {
            args.push(Arg::Device(bd));
        }
        args.push(Arg::Device(t_dev));
        args.push(Arg::Device(&dir_dev));
        args.push(Arg::Device(&tau_dev));
        args.push(Arg::Device(ids_dev));
        args.push(Arg::Device(mask_dev));
        args.push(Arg::Device(lab_dev));
        let out = exe.run_with_device(&args)?;
        let losses = out
            .first()
            .ok_or_else(|| anyhow!("{}: empty output", exe.name))?;
        if losses.len() != n_out {
            bail!("{}: expected {n_out} losses, got {}", exe.name, losses.len());
        }
        Ok(losses.iter().map(|&x| x as f64).collect())
    }
}

impl Oracle for PjrtOracle {
    fn dim(&self) -> usize {
        self.trainable.len()
    }

    fn set_batch(&mut self, batch: &Batch) -> Result<()> {
        let s = self.entry.shapes;
        if batch.batch != s.batch || batch.seq != s.seq {
            bail!(
                "batch shape [{}, {}] does not match artifact [{}, {}]",
                batch.batch, batch.seq, s.batch, s.seq
            );
        }
        let ids = self.rt.upload_i32(&batch.ids, &[batch.batch, batch.seq])?;
        let mask = self.rt.upload_f32(&batch.mask, &[batch.batch, batch.seq])?;
        let lab = self.rt.upload_i32(&batch.labels, &[batch.batch])?;
        self.batch_dev = Some((ids, mask, lab));
        Ok(())
    }

    fn loss_dir(&mut self, dir: &[f32], scale: f32) -> Result<f64> {
        self.calls += 1;
        let d = self.dim();
        assert_eq!(dir.len(), d);
        let exe = self.loss_dir_exe.clone();
        Ok(self.run_loss(exe, dir, &[d], scale, 1)?[0])
    }

    fn loss_k(&mut self, dirs: &[f32], k: usize, tau: f32) -> Result<Vec<f64>> {
        if k == 0 {
            bail!("loss_k: k must be >= 1 (empty probe matrix)");
        }
        let d = self.dim();
        assert_eq!(dirs.len(), k * d, "dirs must be K x d");
        // the fused artifact is compiled for a fixed K
        if k == self.entry.shapes.k {
            if let Some(exe) = self.loss_k_exe.clone() {
                self.calls += k as u64;
                return self.run_loss(exe, dirs, &[k, d], tau, k);
            }
        }
        // fall back to K separate dispatches
        (0..k).map(|i| self.loss_dir(&dirs[i * d..(i + 1) * d], tau)).collect()
    }

    fn params(&self) -> &[f32] {
        &self.trainable
    }

    fn update_params(&mut self, f: &mut dyn FnMut(&mut [f32])) -> Result<()> {
        f(&mut self.trainable);
        self.trainable_dev = None; // device copy is stale now
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.calls
    }

    fn name(&self) -> &str {
        &self.name
    }
}

impl PjrtOracle {
    /// f(x) without perturbation (costs one oracle call).
    pub fn loss_base(&mut self) -> Result<f64> {
        let zeros = std::mem::take(&mut self.zero_dir);
        let r = self.loss_dir(&zeros, 0.0);
        self.zero_dir = zeros;
        r
    }
}

/// Read a little-endian f32 blob of exactly `expect` elements.
pub fn read_f32_bin(path: &std::path::Path, expect: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect * 4 {
        bail!(
            "{}: expected {} f32 ({} bytes), file has {} bytes",
            path.display(), expect, expect * 4, bytes.len()
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
