//! Crash-safe training snapshots (DESIGN.md §11).
//!
//! A full training snapshot of a ZO run is tiny — that is the paper's own
//! memory argument turned into an elasticity feature.  Because probe
//! directions are pure functions of their per-(seed, step, shard) RNG
//! cells (DESIGN.md §9/§10), nothing about the probe stream needs saving:
//! a snapshot is just the iterate, the O(d) optimizer moments, the LDSD
//! policy mean, and a handful of cursors (step, oracle calls, eval
//! threshold, sampler step label).  Restoring one and continuing produces
//! a **bitwise-identical** trajectory to the uninterrupted run, at any
//! thread count and under both probe-storage modes — the property
//! `tests/checkpoint_resume.rs` pins.
//!
//! # On-disk format (versioned)
//!
//! One snapshot is a directory `step-<NNNNNNNNNN>/` containing
//! `manifest.json` (written last — a crash mid-write leaves no manifest,
//! so the directory is simply invalid) plus raw little-endian blobs:
//!
//! * `params.bin` — the trainable vector (f32 LE);
//! * `opt-<i>.bin` — the optimizer's persistent moment buffers (f32 LE);
//! * `policy_mean.bin` — the LDSD policy mean, when the sampler has one;
//! * `loss_curve.bin` / `acc_curve.bin` — (u64 calls, f64 loss-bits)
//!   pairs, 16 bytes per entry.
//!
//! All floating-point state lives in blobs, never in JSON — JSON numbers
//! round-trip through decimal and cannot carry NaN/Inf, and bit-exactness
//! is the whole point.  The manifest stores u64 fields as fixed-width hex
//! strings (seeds use the full 64-bit range, above JSON's 2^53 integer
//! ceiling) and an FNV-1a checksum per blob, so corruption is detected at
//! load and [`load_latest`] falls back to the previous snapshot.
//!
//! Writes are atomic: blobs + manifest land in a `.tmp-*` sibling that is
//! `rename`d into place, and [`write_snapshot`] prunes all but the newest
//! two snapshots (the fallback depth).
//!
//! Completed trials additionally persist their final [`TrainOutcome`] as a
//! `completed/` record in the same container format, which lets
//! [`crate::coordinator::run_grid`] skip finished trials on a resumed grid
//! without re-running them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{parse, to_string_pretty, Json};
use crate::optim::OptimizerState;
use crate::train::TrainOutcome;

/// Current snapshot container version.  Version 2 added the
/// `data_cursor` field (the minibatch stream's batch cursor; DESIGN.md
/// §12) — version-1 snapshots predate the epoch-shuffled stream and are
/// refused rather than silently resumed with a rewound data pipeline.
pub const SNAPSHOT_VERSION: u64 = 2;

const SNAPSHOT_MAGIC: &str = "zosnap1";
const OUTCOME_MAGIC: &str = "zodone1";

/// Crash-safe checkpoint/resume policy for one training run.
///
/// Rides in [`crate::train::TrainConfig`] and threads from the CLI
/// (`--checkpoint-dir`, `--checkpoint-every`, `--resume`,
/// `--max-run-steps`) through `TrialSpec` to the trainer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CheckpointConfig {
    /// Snapshot directory for this run (None disables checkpointing).
    /// The coordinator rewrites a grid-level base directory to a per-trial
    /// subdirectory (`<base>/<sanitized trial id>`) before training.
    pub dir: Option<String>,
    /// Optimizer steps between snapshots (0 with a directory set: only
    /// the halt-time snapshot is written).
    pub every: u64,
    /// Resume from the newest valid snapshot in `dir` before training
    /// (no-op when none exists).
    pub resume: bool,
    /// Stop the session after this many optimizer steps (0 = run to
    /// budget).  Cooperative preemption for elastic workers and crash
    /// injection for the resume tests; a halted session writes a final
    /// snapshot so no step is lost.
    pub max_run_steps: u64,
}

/// Run-configuration identity a snapshot is only valid for.  Restoring
/// under a different estimator/optimizer/seed/budget would silently walk a
/// different trajectory, so mismatches are hard errors.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotFingerprint {
    /// Method label (`estimator.label() + "+" + optimizer`).
    pub label: String,
    /// Sampler/estimator seed.
    pub seed: u64,
    /// Total oracle budget of the run.
    pub budget: u64,
    /// Trainable dimensionality.
    pub dim: usize,
}

/// Everything needed to continue a training run bit-exactly: parameters,
/// optimizer moments, the sampler's RNG step label + learned policy mean,
/// and the run cursors (see the module docs for what deliberately does
/// *not* need saving).
#[derive(Clone, Debug)]
pub struct TrainerSnapshot {
    /// Container version ([`SNAPSHOT_VERSION`]).
    pub version: u64,
    /// The run configuration this snapshot belongs to.
    pub fingerprint: SnapshotFingerprint,
    /// Optimizer steps taken when the snapshot was captured.
    pub step: u64,
    /// Oracle calls consumed when the snapshot was captured.
    pub oracle_calls_used: u64,
    /// Next evaluation threshold (in oracle calls).
    pub next_eval: u64,
    /// Training examples consumed when the snapshot was captured — the
    /// minibatch stream's batch cursor (DESIGN.md §12).
    pub data_cursor: u64,
    /// The sampler's per-step RNG label (steps sampled so far).
    pub sampler_step: u64,
    /// Best test accuracy seen at any eval point so far.
    pub best_accuracy: f64,
    /// The trainable vector.
    pub params: Vec<f32>,
    /// The base optimizer's persistent state.
    pub optimizer: OptimizerState,
    /// The LDSD policy mean, when the sampler learns one.
    pub policy_mean: Option<Vec<f32>>,
    /// (oracle calls, training-loss proxy) per step so far.
    pub loss_curve: Vec<(u64, f64)>,
    /// (oracle calls, test accuracy) per eval point so far.
    pub acc_curve: Vec<(u64, f64)>,
}

// --- low-level encoding helpers -------------------------------------------

/// FNV-1a over a byte slice — the per-blob corruption check.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 '{s}'"))
}

fn jhex(x: u64) -> Json {
    Json::Str(hex64(x))
}

fn get_hex(manifest: &Json, key: &str) -> Result<u64> {
    let s = manifest
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing hex field '{key}'"))?;
    parse_hex64(s)
}

fn get_str<'a>(manifest: &'a Json, key: &str) -> Result<&'a str> {
    manifest
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing string field '{key}'"))
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("f32 blob length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn curve_to_bytes(curve: &[(u64, f64)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(curve.len() * 16);
    for (calls, loss) in curve {
        out.extend_from_slice(&calls.to_le_bytes());
        out.extend_from_slice(&loss.to_bits().to_le_bytes());
    }
    out
}

fn bytes_to_curve(bytes: &[u8]) -> Result<Vec<(u64, f64)>> {
    if bytes.len() % 16 != 0 {
        bail!("curve blob length {} not a multiple of 16", bytes.len());
    }
    Ok(bytes
        .chunks_exact(16)
        .map(|c| {
            let mut a = [0u8; 8];
            let mut b = [0u8; 8];
            a.copy_from_slice(&c[..8]);
            b.copy_from_slice(&c[8..]);
            (u64::from_le_bytes(a), f64::from_bits(u64::from_le_bytes(b)))
        })
        .collect())
}

// --- blob container -------------------------------------------------------

fn write_blob(
    dir: &Path,
    name: &str,
    bytes: &[u8],
    inventory: &mut BTreeMap<String, Json>,
) -> Result<()> {
    std::fs::write(dir.join(name), bytes)
        .with_context(|| format!("writing blob {}", dir.join(name).display()))?;
    let mut entry = BTreeMap::new();
    entry.insert("bytes".to_string(), Json::Num(bytes.len() as f64));
    entry.insert("fnv".to_string(), jhex(fnv64(bytes)));
    inventory.insert(name.to_string(), Json::Obj(entry));
    Ok(())
}

fn read_blob(dir: &Path, name: &str, inventory: &Json) -> Result<Vec<u8>> {
    let entry = inventory
        .get(name)
        .ok_or_else(|| anyhow!("manifest: blob '{name}' not in inventory"))?;
    let want_len = entry
        .get("bytes")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: blob '{name}' has no byte count"))?;
    let want_fnv = parse_hex64(
        entry
            .get("fnv")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest: blob '{name}' has no checksum"))?,
    )?;
    let path = dir.join(name);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading blob {}", path.display()))?;
    if bytes.len() != want_len {
        bail!("blob {}: {} bytes, manifest says {want_len}", path.display(), bytes.len());
    }
    let got = fnv64(&bytes);
    if got != want_fnv {
        bail!(
            "blob {}: checksum {} != manifest {} (corrupt snapshot)",
            path.display(),
            hex64(got),
            hex64(want_fnv)
        );
    }
    Ok(bytes)
}

fn read_manifest(dir: &Path, magic: &str) -> Result<Json> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let manifest = parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    if manifest.get("magic").and_then(Json::as_str) != Some(magic) {
        bail!("{}: bad magic (want {magic})", path.display());
    }
    Ok(manifest)
}

/// Write `manifest` + pre-staged blob dir atomically into `dir/name`:
/// everything is staged under a `.tmp-*` sibling by the caller, the
/// manifest goes in last, and the staged directory is renamed over the
/// target (removing a stale same-name directory first).
fn commit_dir(tmp: &Path, final_dir: &Path, manifest: Json) -> Result<()> {
    std::fs::write(tmp.join("manifest.json"), to_string_pretty(&manifest))
        .with_context(|| format!("writing {}", tmp.join("manifest.json").display()))?;
    if final_dir.exists() {
        std::fs::remove_dir_all(final_dir)
            .with_context(|| format!("replacing {}", final_dir.display()))?;
    }
    std::fs::rename(tmp, final_dir).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), final_dir.display())
    })?;
    Ok(())
}

fn stage_dir(base: &Path, name: &str) -> Result<PathBuf> {
    let tmp = base.join(format!(".tmp-{name}-{}", std::process::id()));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp).ok();
    }
    std::fs::create_dir_all(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    Ok(tmp)
}

// --- snapshot write / load ------------------------------------------------

/// Snapshots retained per run directory (the corrupt-snapshot fallback
/// depth: the newest plus one predecessor).
pub const SNAPSHOTS_KEPT: usize = 2;

fn step_dir_name(step: u64) -> String {
    format!("step-{step:010}")
}

/// Atomically write one snapshot under `dir` (created if missing) and
/// prune all but the newest [`SNAPSHOTS_KEPT`].  Returns the committed
/// snapshot directory.
pub fn write_snapshot(dir: &Path, snap: &TrainerSnapshot) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let name = step_dir_name(snap.step);
    let tmp = stage_dir(dir, &name)?;

    let mut blobs = BTreeMap::new();
    write_blob(&tmp, "params.bin", &f32s_to_bytes(&snap.params), &mut blobs)?;
    for (i, buf) in snap.optimizer.buffers.iter().enumerate() {
        write_blob(&tmp, &format!("opt-{i}.bin"), &f32s_to_bytes(buf), &mut blobs)?;
    }
    if let Some(mu) = &snap.policy_mean {
        write_blob(&tmp, "policy_mean.bin", &f32s_to_bytes(mu), &mut blobs)?;
    }
    write_blob(&tmp, "loss_curve.bin", &curve_to_bytes(&snap.loss_curve), &mut blobs)?;
    write_blob(&tmp, "acc_curve.bin", &curve_to_bytes(&snap.acc_curve), &mut blobs)?;

    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(SNAPSHOT_MAGIC.into()));
    m.insert("version".to_string(), jhex(snap.version));
    m.insert("label".to_string(), Json::Str(snap.fingerprint.label.clone()));
    m.insert("seed".to_string(), jhex(snap.fingerprint.seed));
    m.insert("budget".to_string(), jhex(snap.fingerprint.budget));
    m.insert("dim".to_string(), jhex(snap.fingerprint.dim as u64));
    m.insert("step".to_string(), jhex(snap.step));
    m.insert("oracle_calls_used".to_string(), jhex(snap.oracle_calls_used));
    m.insert("next_eval".to_string(), jhex(snap.next_eval));
    m.insert("data_cursor".to_string(), jhex(snap.data_cursor));
    m.insert("sampler_step".to_string(), jhex(snap.sampler_step));
    m.insert(
        "best_accuracy_bits".to_string(),
        jhex(snap.best_accuracy.to_bits()),
    );
    m.insert(
        "opt_scalars".to_string(),
        Json::Arr(snap.optimizer.scalars.iter().map(|s| jhex(*s)).collect()),
    );
    m.insert(
        "opt_buffers".to_string(),
        Json::Num(snap.optimizer.buffers.len() as f64),
    );
    m.insert(
        "has_policy_mean".to_string(),
        Json::Bool(snap.policy_mean.is_some()),
    );
    m.insert("blobs".to_string(), Json::Obj(blobs));

    let final_dir = dir.join(&name);
    commit_dir(&tmp, &final_dir, Json::Obj(m))?;
    prune(dir, SNAPSHOTS_KEPT);
    sweep_stale_staging(dir);
    Ok(final_dir)
}

/// Load and fully validate the snapshot stored in `snap_dir` (manifest
/// magic/version, blob lengths, checksums).
pub fn load_snapshot(snap_dir: &Path) -> Result<TrainerSnapshot> {
    let m = read_manifest(snap_dir, SNAPSHOT_MAGIC)?;
    let version = get_hex(&m, "version")?;
    if version != SNAPSHOT_VERSION {
        bail!("snapshot version {version} (this build reads {SNAPSHOT_VERSION})");
    }
    let blobs = m
        .get("blobs")
        .ok_or_else(|| anyhow!("manifest: missing blob inventory"))?
        .clone();
    let dim = get_hex(&m, "dim")? as usize;
    let params = bytes_to_f32s(&read_blob(snap_dir, "params.bin", &blobs)?)?;
    if params.len() != dim {
        bail!("params.bin holds {} f32, manifest says {dim}", params.len());
    }
    let n_buffers = m
        .get("opt_buffers")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: missing opt_buffers"))?;
    let mut buffers = Vec::with_capacity(n_buffers);
    for i in 0..n_buffers {
        buffers.push(bytes_to_f32s(&read_blob(
            snap_dir,
            &format!("opt-{i}.bin"),
            &blobs,
        )?)?);
    }
    let scalars = m
        .get("opt_scalars")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing opt_scalars"))?
        .iter()
        .map(|s| {
            s.as_str()
                .ok_or_else(|| anyhow!("opt_scalars: non-string entry"))
                .and_then(parse_hex64)
        })
        .collect::<Result<Vec<u64>>>()?;
    let policy_mean = if m.get("has_policy_mean").and_then(Json::as_bool) == Some(true) {
        Some(bytes_to_f32s(&read_blob(snap_dir, "policy_mean.bin", &blobs)?)?)
    } else {
        None
    };
    Ok(TrainerSnapshot {
        version,
        fingerprint: SnapshotFingerprint {
            label: get_str(&m, "label")?.to_string(),
            seed: get_hex(&m, "seed")?,
            budget: get_hex(&m, "budget")?,
            dim,
        },
        step: get_hex(&m, "step")?,
        oracle_calls_used: get_hex(&m, "oracle_calls_used")?,
        next_eval: get_hex(&m, "next_eval")?,
        data_cursor: get_hex(&m, "data_cursor")?,
        sampler_step: get_hex(&m, "sampler_step")?,
        best_accuracy: f64::from_bits(get_hex(&m, "best_accuracy_bits")?),
        params,
        optimizer: OptimizerState { scalars, buffers },
        policy_mean,
        loss_curve: bytes_to_curve(&read_blob(snap_dir, "loss_curve.bin", &blobs)?)?,
        acc_curve: bytes_to_curve(&read_blob(snap_dir, "acc_curve.bin", &blobs)?)?,
    })
}

/// The `(step, path)` of every snapshot directory under `dir`, ascending
/// by step.  Unreadable directories and staging leftovers are ignored.
pub fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return out,
    };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(num) = name.strip_prefix("step-") {
            if let Ok(step) = num.parse::<u64>() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort_by_key(|(step, _)| *step);
    out
}

/// Load the newest *valid* snapshot under `dir`: corrupt or half-written
/// snapshots are skipped (with a note on stderr) and the previous one is
/// tried — the crash-safety contract with [`write_snapshot`]'s atomic
/// rename and retention of [`SNAPSHOTS_KEPT`] generations.
pub fn load_latest(dir: &Path) -> Option<TrainerSnapshot> {
    for (_, path) in list_snapshots(dir).iter().rev() {
        match load_snapshot(path) {
            Ok(snap) => return Some(snap),
            Err(e) => {
                eprintln!("snapshot: skipping {} ({e:#})", path.display());
            }
        }
    }
    None
}

fn prune(dir: &Path, keep: usize) {
    let snaps = list_snapshots(dir);
    if snaps.len() > keep {
        for (_, path) in &snaps[..snaps.len() - keep] {
            std::fs::remove_dir_all(path).ok();
        }
    }
}

/// Remove `.tmp-*` staging leftovers under `dir` — the garbage a process
/// killed mid-write leaves behind (invalid by construction: their
/// manifest, written last, never landed).  Called after every successful
/// commit so preempt/resume cycles cannot accumulate checkpoint-sized
/// debris.
fn sweep_stale_staging(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            if entry.file_name().to_string_lossy().starts_with(".tmp-") {
                std::fs::remove_dir_all(entry.path()).ok();
            }
        }
    }
}

// --- completed-trial outcome records --------------------------------------

/// A completed trial's persisted [`TrainOutcome`] plus the identity it
/// was produced under — enough for a resumed grid to refuse a record
/// whose configuration no longer matches (seed/budget edits between grid
/// runs must re-run the trial, not silently reuse stale numbers).
#[derive(Clone, Debug)]
pub struct OutcomeRecord {
    /// The finished trial's outcome (always `completed`).
    pub outcome: TrainOutcome,
    /// The probe storage the run resolved to ("materialized"|"streamed").
    pub probe_storage: String,
    /// The run's sampler/estimator seed.
    pub seed: u64,
    /// The run's total oracle budget.
    pub budget: u64,
}

/// Atomically persist a finished trial's [`TrainOutcome`] (plus the probe
/// storage it resolved to and the run's seed/budget identity) as
/// `dir/completed/`, in the same blob container format as snapshots.  A
/// resumed grid returns this record instead of re-running the trial.
pub fn write_outcome(
    dir: &Path,
    outcome: &TrainOutcome,
    probe_storage: &str,
    seed: u64,
    budget: u64,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let tmp = stage_dir(dir, "completed")?;
    let mut blobs = BTreeMap::new();
    write_blob(&tmp, "loss_curve.bin", &curve_to_bytes(&outcome.loss_curve), &mut blobs)?;
    write_blob(&tmp, "acc_curve.bin", &curve_to_bytes(&outcome.acc_curve), &mut blobs)?;
    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(OUTCOME_MAGIC.into()));
    m.insert("version".to_string(), jhex(SNAPSHOT_VERSION));
    m.insert("label".to_string(), Json::Str(outcome.label.clone()));
    m.insert("seed".to_string(), jhex(seed));
    m.insert("budget".to_string(), jhex(budget));
    m.insert("steps".to_string(), jhex(outcome.steps));
    m.insert("oracle_calls".to_string(), jhex(outcome.oracle_calls));
    m.insert(
        "final_accuracy_bits".to_string(),
        jhex(outcome.final_accuracy.to_bits()),
    );
    m.insert(
        "best_accuracy_bits".to_string(),
        jhex(outcome.best_accuracy.to_bits()),
    );
    m.insert(
        "wall_seconds_bits".to_string(),
        jhex(outcome.wall_seconds.to_bits()),
    );
    m.insert("probe_storage".to_string(), Json::Str(probe_storage.to_string()));
    m.insert("blobs".to_string(), Json::Obj(blobs));
    commit_dir(&tmp, &dir.join("completed"), Json::Obj(m))?;
    sweep_stale_staging(dir);
    Ok(())
}

/// Load a completed-trial record written by [`write_outcome`], if one
/// exists and validates.  A corrupt record is reported and treated as
/// absent (the trial just re-runs).
pub fn load_outcome(dir: &Path) -> Option<OutcomeRecord> {
    let cdir = dir.join("completed");
    if !cdir.join("manifest.json").exists() {
        return None;
    }
    match try_load_outcome(&cdir) {
        Ok(v) => Some(v),
        Err(e) => {
            eprintln!("snapshot: ignoring {} ({e:#})", cdir.display());
            None
        }
    }
}

fn try_load_outcome(cdir: &Path) -> Result<OutcomeRecord> {
    let m = read_manifest(cdir, OUTCOME_MAGIC)?;
    let version = get_hex(&m, "version")?;
    if version != SNAPSHOT_VERSION {
        bail!("outcome version {version} (this build reads {SNAPSHOT_VERSION})");
    }
    let blobs = m
        .get("blobs")
        .ok_or_else(|| anyhow!("manifest: missing blob inventory"))?
        .clone();
    let outcome = TrainOutcome {
        loss_curve: bytes_to_curve(&read_blob(cdir, "loss_curve.bin", &blobs)?)?,
        acc_curve: bytes_to_curve(&read_blob(cdir, "acc_curve.bin", &blobs)?)?,
        final_accuracy: f64::from_bits(get_hex(&m, "final_accuracy_bits")?),
        best_accuracy: f64::from_bits(get_hex(&m, "best_accuracy_bits")?),
        steps: get_hex(&m, "steps")?,
        oracle_calls: get_hex(&m, "oracle_calls")?,
        wall_seconds: f64::from_bits(get_hex(&m, "wall_seconds_bits")?),
        label: get_str(&m, "label")?.to_string(),
        completed: true,
    };
    Ok(OutcomeRecord {
        outcome,
        probe_storage: get_str(&m, "probe_storage")?.to_string(),
        seed: get_hex(&m, "seed")?,
        budget: get_hex(&m, "budget")?,
    })
}

/// Filesystem-safe, *injective* directory name for a trial id: the
/// readable sanitized form plus a short FNV hash of the raw id, so two
/// ids that sanitize to the same characters (`"a/b"` vs `"a_b"`) can
/// never share a checkpoint directory.
pub fn sanitize_id(id: &str) -> String {
    let safe: String = id
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '.' | '_') {
                c
            } else {
                '_'
            }
        })
        .collect();
    format!("{safe}-{:08x}", fnv64(id.as_bytes()) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("zo_snap_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_snapshot(step: u64) -> TrainerSnapshot {
        TrainerSnapshot {
            version: SNAPSHOT_VERSION,
            fingerprint: SnapshotFingerprint {
                label: "bestofk5/ldsd+zo_sgd".into(),
                seed: u64::MAX - 7, // above 2^53: must survive JSON
                budget: 6000,
                dim: 5,
            },
            step,
            oracle_calls_used: step * 6,
            next_eval: 1200,
            data_cursor: step * 8,
            sampler_step: step,
            best_accuracy: 0.1 + step as f64,
            params: vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.0e-38],
            optimizer: OptimizerState {
                scalars: vec![step],
                buffers: vec![vec![0.5; 5], vec![-0.25; 5]],
            },
            policy_mean: Some(vec![0.125; 5]),
            loss_curve: vec![(6, 0.75), (12, f64::from_bits(0x3FF123456789ABCD))],
            acc_curve: vec![(12, 0.5)],
        }
    }

    fn assert_snapshots_equal(a: &TrainerSnapshot, b: &TrainerSnapshot) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.step, b.step);
        assert_eq!(a.oracle_calls_used, b.oracle_calls_used);
        assert_eq!(a.next_eval, b.next_eval);
        assert_eq!(a.data_cursor, b.data_cursor);
        assert_eq!(a.sampler_step, b.sampler_step);
        assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
        assert_eq!(a.params.len(), b.params.len());
        for (x, y) in a.params.iter().zip(b.params.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.optimizer.scalars, b.optimizer.scalars);
        assert_eq!(a.optimizer.buffers.len(), b.optimizer.buffers.len());
        for (ba, bb) in a.optimizer.buffers.iter().zip(b.optimizer.buffers.iter()) {
            for (x, y) in ba.iter().zip(bb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.policy_mean.is_some(), b.policy_mean.is_some());
        assert_eq!(a.loss_curve.len(), b.loss_curve.len());
        for ((ca, la), (cb, lb)) in a.loss_curve.iter().zip(b.loss_curve.iter()) {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        assert_eq!(a.acc_curve.len(), b.acc_curve.len());
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact() {
        let dir = tmpdir("roundtrip");
        let snap = sample_snapshot(42);
        let path = write_snapshot(&dir, &snap).unwrap();
        let back = load_snapshot(&path).unwrap();
        assert_snapshots_equal(&snap, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_wins_and_retention_prunes() {
        let dir = tmpdir("retention");
        for step in [10u64, 20, 30] {
            write_snapshot(&dir, &sample_snapshot(step)).unwrap();
        }
        let snaps = list_snapshots(&dir);
        assert_eq!(
            snaps.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![20, 30],
            "only the newest {SNAPSHOTS_KEPT} are retained"
        );
        assert_eq!(load_latest(&dir).unwrap().step, 30);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        write_snapshot(&dir, &sample_snapshot(10)).unwrap();
        let newest = write_snapshot(&dir, &sample_snapshot(20)).unwrap();
        // flip a byte in the newest params blob: checksum must catch it
        let pb = newest.join("params.bin");
        let mut bytes = std::fs::read(&pb).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&pb, &bytes).unwrap();
        let loaded = load_latest(&dir).unwrap();
        assert_eq!(loaded.step, 10, "corrupt newest must fall back");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn half_written_snapshot_is_invisible() {
        let dir = tmpdir("halfwrite");
        write_snapshot(&dir, &sample_snapshot(5)).unwrap();
        // a crash mid-write leaves a .tmp-* staging dir with no manifest
        let staged = dir.join(".tmp-step-0000000009-dead");
        std::fs::create_dir_all(&staged).unwrap();
        std::fs::write(staged.join("params.bin"), [0u8; 8]).unwrap();
        // and possibly a committed dir missing its manifest
        let bare = dir.join("step-0000000099");
        std::fs::create_dir_all(&bare).unwrap();
        let loaded = load_latest(&dir).unwrap();
        assert_eq!(loaded.step, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_record_roundtrip() {
        let dir = tmpdir("outcome");
        let out = TrainOutcome {
            loss_curve: vec![(6, 1.5), (12, 0.25)],
            acc_curve: vec![(12, 0.625)],
            final_accuracy: 0.625,
            best_accuracy: 0.75,
            steps: 2,
            oracle_calls: 12,
            wall_seconds: 0.125,
            label: "bestofk5/ldsd+zo_sgd".into(),
            completed: true,
        };
        assert!(load_outcome(&dir).is_none());
        write_outcome(&dir, &out, "streamed", 41, 12).unwrap();
        let rec = load_outcome(&dir).unwrap();
        let back = &rec.outcome;
        assert_eq!(rec.probe_storage, "streamed");
        assert_eq!(rec.seed, 41);
        assert_eq!(rec.budget, 12);
        assert!(back.completed);
        assert_eq!(back.steps, 2);
        assert_eq!(back.final_accuracy.to_bits(), out.final_accuracy.to_bits());
        assert_eq!(back.loss_curve.len(), 2);
        for ((ca, la), (cb, lb)) in out.loss_curve.iter().zip(back.loss_curve.iter()) {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sanitize_makes_ids_path_safe_and_injective() {
        let s = sanitize_id("roberta_mini/lora/alg2+zo_sgd");
        assert!(s.starts_with("roberta_mini_lora_alg2_zo_sgd-"), "{s}");
        assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || "-._".contains(c)));
        // ids that sanitize to identical characters must not collide
        assert_ne!(sanitize_id("a/b"), sanitize_id("a_b"));
        assert_ne!(sanitize_id("a b"), sanitize_id("a+b"));
        // and the mapping is deterministic
        assert_eq!(sanitize_id("a/b"), sanitize_id("a/b"));
    }

    #[test]
    fn commits_sweep_stale_staging_leftovers() {
        let dir = tmpdir("sweep");
        // a previous process died mid-write, leaving manifest-less staging
        let stale = dir.join(".tmp-step-0000000003-12345");
        std::fs::create_dir_all(&stale).unwrap();
        std::fs::write(stale.join("params.bin"), [0u8; 16]).unwrap();
        write_snapshot(&dir, &sample_snapshot(7)).unwrap();
        assert!(!stale.exists(), "stale staging must be swept on commit");
        assert_eq!(load_latest(&dir).unwrap().step, 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fnv_is_stable() {
        // golden values pin the on-disk checksum algorithm across builds
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
    }
}
