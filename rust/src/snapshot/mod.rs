//! Crash-safe training snapshots (DESIGN.md §11) on the content-addressed
//! store (DESIGN.md §16).
//!
//! A full training snapshot of a ZO run is tiny — that is the paper's own
//! memory argument turned into an elasticity feature.  Because probe
//! directions are pure functions of their per-(seed, step, shard) RNG
//! cells (DESIGN.md §9/§10), nothing about the probe stream needs saving:
//! a snapshot is just the iterate, the O(d) optimizer moments, the LDSD
//! policy mean, and a handful of cursors (step, oracle calls, eval
//! threshold, sampler step label).  Restoring one and continuing produces
//! a **bitwise-identical** trajectory to the uninterrupted run, at any
//! thread count and under both probe-storage modes — the property
//! `tests/checkpoint_resume.rs` pins.
//!
//! # On-disk format (versioned)
//!
//! One snapshot is a directory `step-<NNNNNNNNNN>/` containing only
//! `manifest.json` (written last into a `.tmp-*` staging sibling and
//! `rename`d — a crash mid-write leaves no manifest, so the directory is
//! simply invalid).  The blobs themselves live in the content-addressed
//! [`crate::store::Store`], referenced from the manifest's inventory by
//! SHA-256 hash:
//!
//! * `params.bin` — the trainable vector (f32 LE);
//! * `opt-<i>.bin` — the optimizer's persistent moment buffers (f32 LE);
//! * `policy_mean.bin` — the LDSD policy mean, when the sampler has one;
//! * `loss_curve.bin` / `acc_curve.bin` — (u64 calls, f64 loss-bits)
//!   pairs, 16 bytes per entry.
//!
//! Content addressing dedups for free: blobs unchanged between retained
//! generations (optimizer buffers early in training, the policy mean, a
//! frozen LoRA base, curve prefixes) are stored exactly once.  All
//! floating-point state lives in blobs, never in JSON — JSON numbers
//! round-trip through decimal and cannot carry NaN/Inf, and bit-exactness
//! is the whole point.  The manifest stores u64 fields as fixed-width hex
//! strings (seeds use the full 64-bit range, above JSON's 2^53 integer
//! ceiling) and, per blob, byte length + FNV-1a checksum + object hash —
//! corruption is detected at load (store reads also re-hash) and
//! [`load_latest`] falls back to the previous snapshot.
//!
//! **Version 2** snapshots (pre-store: blobs as raw sibling files inside
//! the `step-<N>/` directory) remain fully readable — [`load_snapshot`]
//! dispatches on the manifest version, so a checkpoint tree written by an
//! older build resumes bit-for-bit.  [`write_snapshot`] always writes
//! version [`SNAPSHOT_VERSION`]; [`write_snapshot_legacy`] keeps the v2
//! writer alive for the migration tests.
//!
//! [`write_snapshot`] prunes all but the newest two snapshot *manifests*
//! (the fallback depth); unrooted store objects are reclaimed by
//! [`crate::store::Store::gc`], not by pruning.
//!
//! Completed trials persist their final [`TrainOutcome`] twice over the
//! same bytes: a canonical-JSON outcome record *object* in the store
//! (whose hash `grid.lock.json` pins for the coordinator's warm-start
//! short-circuit) and a human-readable `completed/manifest.json` mirror
//! in the trial directory.  The record carries the trial's canonical
//! spec hash, so a resumed grid validates identity by hash — exact stale
//! detection — rather than by comparing a few hand-picked fields.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{parse, to_string_canonical, to_string_pretty, Json};
use crate::optim::OptimizerState;
use crate::store::Store;
use crate::train::TrainOutcome;

/// Current snapshot container version.  Version 3 moved blobs into the
/// content-addressed store (manifests reference them by SHA-256 hash);
/// version 2 (raw sibling blobs) is still read for migration.  Version-1
/// snapshots predate the epoch-shuffled stream's `data_cursor` and are
/// refused rather than silently resumed with a rewound data pipeline.
pub const SNAPSHOT_VERSION: u64 = 3;

/// The pre-store container version (blobs as sibling files) — still
/// readable, written only by the `*_legacy` helpers.
pub const LEGACY_SNAPSHOT_VERSION: u64 = 2;

const SNAPSHOT_MAGIC: &str = "zosnap1";
const OUTCOME_MAGIC: &str = "zodone1";

/// Crash-safe checkpoint/resume policy for one training run.
///
/// Rides in [`crate::train::TrainConfig`] and threads from the CLI
/// (`--checkpoint-dir`, `--checkpoint-every`, `--resume`, `--store-dir`,
/// `--max-run-steps`) through `TrialSpec` to the trainer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CheckpointConfig {
    /// Snapshot directory for this run (None disables checkpointing).
    /// The coordinator rewrites a grid-level base directory to a per-trial
    /// subdirectory (`<base>/<sanitized trial id>`) before training.
    pub dir: Option<String>,
    /// Optimizer steps between snapshots (0 with a directory set: only
    /// the halt-time snapshot is written).
    pub every: u64,
    /// Resume from the newest valid snapshot in `dir` before training
    /// (no-op when none exists).
    pub resume: bool,
    /// Stop the session after this many optimizer steps (0 = run to
    /// budget).  Cooperative preemption for elastic workers and crash
    /// injection for the resume tests; a halted session writes a final
    /// snapshot so no step is lost.
    pub max_run_steps: u64,
    /// Root of the content-addressed blob store (None: `ZO_STORE_DIR`
    /// when set, else `<dir>/store`; an explicit config beats the env —
    /// the uniform CONFIGURED > ENV precedence contract, DESIGN.md §17).
    /// The coordinator points every trial of a grid at one shared store
    /// under the grid base so blobs dedup across trials.
    pub store_dir: Option<String>,
}

/// Resolve the store root for a checkpoint config under the uniform
/// CONFIGURED > ENV precedence contract (DESIGN.md §17):
/// [`CheckpointConfig::store_dir`] (explicit config, wins) →
/// `ZO_STORE_DIR` (environment, nonempty) → `<checkpoint-dir>/store`.
/// None when checkpointing is disabled.
pub fn resolve_store_dir(ck: &CheckpointConfig) -> Option<PathBuf> {
    let dir = ck.dir.as_ref()?;
    if let Some(sd) = &ck.store_dir {
        return Some(PathBuf::from(sd));
    }
    if let Ok(env) = std::env::var("ZO_STORE_DIR") {
        if !env.trim().is_empty() {
            return Some(PathBuf::from(env));
        }
    }
    Some(Path::new(dir).join("store"))
}

/// Open the resolved store for a checkpoint config (None when
/// checkpointing is disabled; opening is lazy, so this costs nothing for
/// read-only paths against a legacy tree).
pub fn open_store(ck: &CheckpointConfig) -> Option<Store> {
    resolve_store_dir(ck).map(Store::open)
}

/// Run-configuration identity a snapshot is only valid for.  Restoring
/// under a different estimator/optimizer/seed/budget would silently walk a
/// different trajectory, so mismatches are hard errors.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotFingerprint {
    /// Method label (`estimator.label() + "+" + optimizer`).
    pub label: String,
    /// Sampler/estimator seed.
    pub seed: u64,
    /// Total oracle budget of the run.
    pub budget: u64,
    /// Trainable dimensionality.
    pub dim: usize,
}

/// Everything needed to continue a training run bit-exactly: parameters,
/// optimizer moments, the sampler's RNG step label + learned policy mean,
/// and the run cursors (see the module docs for what deliberately does
/// *not* need saving).
#[derive(Clone, Debug)]
pub struct TrainerSnapshot {
    /// Container version ([`SNAPSHOT_VERSION`]; loaders normalize legacy
    /// versions to the current one after a successful read).
    pub version: u64,
    /// The run configuration this snapshot belongs to.
    pub fingerprint: SnapshotFingerprint,
    /// Optimizer steps taken when the snapshot was captured.
    pub step: u64,
    /// Oracle calls consumed when the snapshot was captured.
    pub oracle_calls_used: u64,
    /// Next evaluation threshold (in oracle calls).
    pub next_eval: u64,
    /// Training examples consumed when the snapshot was captured — the
    /// minibatch stream's batch cursor (DESIGN.md §12).
    pub data_cursor: u64,
    /// The sampler's per-step RNG label (steps sampled so far).
    pub sampler_step: u64,
    /// Best test accuracy seen at any eval point so far.
    pub best_accuracy: f64,
    /// The trainable vector.
    pub params: Vec<f32>,
    /// The base optimizer's persistent state.
    pub optimizer: OptimizerState,
    /// The LDSD policy mean, when the sampler learns one.
    pub policy_mean: Option<Vec<f32>>,
    /// (oracle calls, training-loss proxy) per step so far.
    pub loss_curve: Vec<(u64, f64)>,
    /// (oracle calls, test accuracy) per eval point so far.
    pub acc_curve: Vec<(u64, f64)>,
}

// --- low-level encoding helpers -------------------------------------------

/// FNV-1a over a byte slice — the per-blob corruption check.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 '{s}'"))
}

fn jhex(x: u64) -> Json {
    Json::Str(hex64(x))
}

fn get_hex(manifest: &Json, key: &str) -> Result<u64> {
    let s = manifest
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing hex field '{key}'"))?;
    parse_hex64(s)
}

fn get_str<'a>(manifest: &'a Json, key: &str) -> Result<&'a str> {
    manifest
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing string field '{key}'"))
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("f32 blob length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn curve_to_bytes(curve: &[(u64, f64)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(curve.len() * 16);
    for (calls, loss) in curve {
        out.extend_from_slice(&calls.to_le_bytes());
        out.extend_from_slice(&loss.to_bits().to_le_bytes());
    }
    out
}

fn bytes_to_curve(bytes: &[u8]) -> Result<Vec<(u64, f64)>> {
    if bytes.len() % 16 != 0 {
        bail!("curve blob length {} not a multiple of 16", bytes.len());
    }
    Ok(bytes
        .chunks_exact(16)
        .map(|c| {
            let mut a = [0u8; 8];
            let mut b = [0u8; 8];
            a.copy_from_slice(&c[..8]);
            b.copy_from_slice(&c[8..]);
            (u64::from_le_bytes(a), f64::from_bits(u64::from_le_bytes(b)))
        })
        .collect())
}

// --- blob container -------------------------------------------------------

/// Legacy (v2) blob write: raw sibling file + {bytes, fnv} inventory
/// entry.
fn write_blob(
    dir: &Path,
    name: &str,
    bytes: &[u8],
    inventory: &mut BTreeMap<String, Json>,
) -> Result<()> {
    std::fs::write(dir.join(name), bytes)
        .with_context(|| format!("writing blob {}", dir.join(name).display()))?;
    let mut entry = BTreeMap::new();
    entry.insert("bytes".to_string(), Json::Num(bytes.len() as f64));
    entry.insert("fnv".to_string(), jhex(fnv64(bytes)));
    inventory.insert(name.to_string(), Json::Obj(entry));
    Ok(())
}

/// Store-backed (v3) blob write: put into the store (idempotent — an
/// unchanged blob dedups against every prior generation) and record
/// {bytes, fnv, hash} in the inventory.
fn put_blob(
    store: &Store,
    name: &str,
    bytes: &[u8],
    inventory: &mut BTreeMap<String, Json>,
) -> Result<()> {
    let hash = store.put(bytes)?;
    let mut entry = BTreeMap::new();
    entry.insert("bytes".to_string(), Json::Num(bytes.len() as f64));
    entry.insert("fnv".to_string(), jhex(fnv64(bytes)));
    entry.insert("hash".to_string(), Json::Str(hash));
    inventory.insert(name.to_string(), Json::Obj(entry));
    Ok(())
}

fn inventory_entry<'a>(inventory: &'a Json, name: &str) -> Result<(&'a Json, usize, u64)> {
    let entry = inventory
        .get(name)
        .ok_or_else(|| anyhow!("manifest: blob '{name}' not in inventory"))?;
    let want_len = entry
        .get("bytes")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: blob '{name}' has no byte count"))?;
    let want_fnv = parse_hex64(
        entry
            .get("fnv")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest: blob '{name}' has no checksum"))?,
    )?;
    Ok((entry, want_len, want_fnv))
}

fn check_blob(bytes: &[u8], what: &str, want_len: usize, want_fnv: u64) -> Result<()> {
    if bytes.len() != want_len {
        bail!("blob {what}: {} bytes, manifest says {want_len}", bytes.len());
    }
    let got = fnv64(bytes);
    if got != want_fnv {
        bail!(
            "blob {what}: checksum {} != manifest {} (corrupt snapshot)",
            hex64(got),
            hex64(want_fnv)
        );
    }
    Ok(())
}

/// Legacy (v2) blob read: sibling file, validated against the manifest's
/// byte length + FNV checksum.
fn read_blob(dir: &Path, name: &str, inventory: &Json) -> Result<Vec<u8>> {
    let (_, want_len, want_fnv) = inventory_entry(inventory, name)?;
    let path = dir.join(name);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading blob {}", path.display()))?;
    check_blob(&bytes, &path.display().to_string(), want_len, want_fnv)?;
    Ok(bytes)
}

/// Store-backed (v3) blob read: fetch by object hash (the store re-hashes
/// on read), then cross-check the manifest's byte length + FNV checksum —
/// the FNV machinery doubles as a guard against a manifest pointing at
/// the wrong (but intact) object.
fn read_blob_store(store: &Store, name: &str, inventory: &Json) -> Result<Vec<u8>> {
    let (entry, want_len, want_fnv) = inventory_entry(inventory, name)?;
    let hash = entry
        .get("hash")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: blob '{name}' has no object hash"))?;
    let bytes = store
        .get(hash)
        .with_context(|| format!("blob '{name}'"))?;
    check_blob(&bytes, name, want_len, want_fnv)?;
    Ok(bytes)
}

/// Version-dispatching blob read for snapshot manifests.
fn fetch_blob(
    dir: &Path,
    store: Option<&Store>,
    version: u64,
    name: &str,
    inventory: &Json,
) -> Result<Vec<u8>> {
    if version >= SNAPSHOT_VERSION {
        let store = store.ok_or_else(|| {
            anyhow!(
                "{}: store-backed snapshot (v{version}) but no store available \
                 (set --store-dir / ZO_STORE_DIR)",
                dir.display()
            )
        })?;
        read_blob_store(store, name, inventory)
    } else {
        read_blob(dir, name, inventory)
    }
}

fn read_manifest(dir: &Path, magic: &str) -> Result<Json> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let manifest = parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    if manifest.get("magic").and_then(Json::as_str) != Some(magic) {
        bail!("{}: bad magic (want {magic})", path.display());
    }
    Ok(manifest)
}

/// Write `manifest` + pre-staged blob dir atomically into `dir/name`:
/// everything is staged under a `.tmp-*` sibling by the caller, the
/// manifest goes in last, and the staged directory is renamed over the
/// target (removing a stale same-name directory first).
fn commit_dir(tmp: &Path, final_dir: &Path, manifest: Json) -> Result<()> {
    std::fs::write(tmp.join("manifest.json"), to_string_pretty(&manifest))
        .with_context(|| format!("writing {}", tmp.join("manifest.json").display()))?;
    if final_dir.exists() {
        std::fs::remove_dir_all(final_dir)
            .with_context(|| format!("replacing {}", final_dir.display()))?;
    }
    std::fs::rename(tmp, final_dir).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), final_dir.display())
    })?;
    Ok(())
}

fn stage_dir(base: &Path, name: &str) -> Result<PathBuf> {
    let tmp = base.join(format!(".tmp-{name}-{}", std::process::id()));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp).ok();
    }
    std::fs::create_dir_all(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    Ok(tmp)
}

// --- snapshot write / load ------------------------------------------------

/// Snapshots retained per run directory (the corrupt-snapshot fallback
/// depth: the newest plus one predecessor).
pub const SNAPSHOTS_KEPT: usize = 2;

fn step_dir_name(step: u64) -> String {
    format!("step-{step:010}")
}

fn snapshot_manifest_fields(snap: &TrainerSnapshot, version: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(SNAPSHOT_MAGIC.into()));
    m.insert("version".to_string(), jhex(version));
    m.insert("label".to_string(), Json::Str(snap.fingerprint.label.clone()));
    m.insert("seed".to_string(), jhex(snap.fingerprint.seed));
    m.insert("budget".to_string(), jhex(snap.fingerprint.budget));
    m.insert("dim".to_string(), jhex(snap.fingerprint.dim as u64));
    m.insert("step".to_string(), jhex(snap.step));
    m.insert("oracle_calls_used".to_string(), jhex(snap.oracle_calls_used));
    m.insert("next_eval".to_string(), jhex(snap.next_eval));
    m.insert("data_cursor".to_string(), jhex(snap.data_cursor));
    m.insert("sampler_step".to_string(), jhex(snap.sampler_step));
    m.insert(
        "best_accuracy_bits".to_string(),
        jhex(snap.best_accuracy.to_bits()),
    );
    m.insert(
        "opt_scalars".to_string(),
        Json::Arr(snap.optimizer.scalars.iter().map(|s| jhex(*s)).collect()),
    );
    m.insert(
        "opt_buffers".to_string(),
        Json::Num(snap.optimizer.buffers.len() as f64),
    );
    m.insert(
        "has_policy_mean".to_string(),
        Json::Bool(snap.policy_mean.is_some()),
    );
    m
}

/// Atomically write one snapshot under `dir` (created if missing): blobs
/// go into `store` (content-addressed, deduped against every prior
/// generation), the `step-<N>/` directory holds only the manifest.  All
/// but the newest [`SNAPSHOTS_KEPT`] manifests are pruned (their objects
/// become unreachable and are reclaimed by the next GC).  Returns the
/// committed snapshot directory.
pub fn write_snapshot(dir: &Path, store: &Store, snap: &TrainerSnapshot) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let name = step_dir_name(snap.step);
    let tmp = stage_dir(dir, &name)?;

    let mut blobs = BTreeMap::new();
    put_blob(store, "params.bin", &f32s_to_bytes(&snap.params), &mut blobs)?;
    for (i, buf) in snap.optimizer.buffers.iter().enumerate() {
        put_blob(store, &format!("opt-{i}.bin"), &f32s_to_bytes(buf), &mut blobs)?;
    }
    if let Some(mu) = &snap.policy_mean {
        put_blob(store, "policy_mean.bin", &f32s_to_bytes(mu), &mut blobs)?;
    }
    put_blob(store, "loss_curve.bin", &curve_to_bytes(&snap.loss_curve), &mut blobs)?;
    put_blob(store, "acc_curve.bin", &curve_to_bytes(&snap.acc_curve), &mut blobs)?;

    let mut m = snapshot_manifest_fields(snap, SNAPSHOT_VERSION);
    m.insert("blobs".to_string(), Json::Obj(blobs));

    let final_dir = dir.join(&name);
    commit_dir(&tmp, &final_dir, Json::Obj(m))?;
    prune(dir, SNAPSHOTS_KEPT);
    sweep_stale_staging(dir);
    Ok(final_dir)
}

/// The pre-store (v2) snapshot writer: blobs as raw sibling files inside
/// the step directory.  Kept so the migration tests can fabricate
/// checkpoints exactly as an older build would have written them; new
/// code writes through [`write_snapshot`].
pub fn write_snapshot_legacy(dir: &Path, snap: &TrainerSnapshot) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let name = step_dir_name(snap.step);
    let tmp = stage_dir(dir, &name)?;

    let mut blobs = BTreeMap::new();
    write_blob(&tmp, "params.bin", &f32s_to_bytes(&snap.params), &mut blobs)?;
    for (i, buf) in snap.optimizer.buffers.iter().enumerate() {
        write_blob(&tmp, &format!("opt-{i}.bin"), &f32s_to_bytes(buf), &mut blobs)?;
    }
    if let Some(mu) = &snap.policy_mean {
        write_blob(&tmp, "policy_mean.bin", &f32s_to_bytes(mu), &mut blobs)?;
    }
    write_blob(&tmp, "loss_curve.bin", &curve_to_bytes(&snap.loss_curve), &mut blobs)?;
    write_blob(&tmp, "acc_curve.bin", &curve_to_bytes(&snap.acc_curve), &mut blobs)?;

    let mut m = snapshot_manifest_fields(snap, LEGACY_SNAPSHOT_VERSION);
    m.insert("blobs".to_string(), Json::Obj(blobs));

    let final_dir = dir.join(&name);
    commit_dir(&tmp, &final_dir, Json::Obj(m))?;
    prune(dir, SNAPSHOTS_KEPT);
    sweep_stale_staging(dir);
    Ok(final_dir)
}

/// Load and fully validate the snapshot stored in `snap_dir` (manifest
/// magic/version, blob lengths, checksums).  Dispatches on the manifest
/// version: v3 manifests resolve blobs through `store`, legacy v2
/// manifests read sibling blob files (no store needed).  The returned
/// snapshot's `version` is normalized to [`SNAPSHOT_VERSION`].
pub fn load_snapshot(snap_dir: &Path, store: Option<&Store>) -> Result<TrainerSnapshot> {
    let m = read_manifest(snap_dir, SNAPSHOT_MAGIC)?;
    let version = get_hex(&m, "version")?;
    if version != SNAPSHOT_VERSION && version != LEGACY_SNAPSHOT_VERSION {
        bail!(
            "snapshot version {version} (this build reads \
             {LEGACY_SNAPSHOT_VERSION} and {SNAPSHOT_VERSION})"
        );
    }
    let blobs = m
        .get("blobs")
        .ok_or_else(|| anyhow!("manifest: missing blob inventory"))?
        .clone();
    let dim = get_hex(&m, "dim")? as usize;
    let params = bytes_to_f32s(&fetch_blob(snap_dir, store, version, "params.bin", &blobs)?)?;
    if params.len() != dim {
        bail!("params.bin holds {} f32, manifest says {dim}", params.len());
    }
    let n_buffers = m
        .get("opt_buffers")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: missing opt_buffers"))?;
    let mut buffers = Vec::with_capacity(n_buffers);
    for i in 0..n_buffers {
        buffers.push(bytes_to_f32s(&fetch_blob(
            snap_dir,
            store,
            version,
            &format!("opt-{i}.bin"),
            &blobs,
        )?)?);
    }
    let scalars = m
        .get("opt_scalars")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing opt_scalars"))?
        .iter()
        .map(|s| {
            s.as_str()
                .ok_or_else(|| anyhow!("opt_scalars: non-string entry"))
                .and_then(parse_hex64)
        })
        .collect::<Result<Vec<u64>>>()?;
    let policy_mean = if m.get("has_policy_mean").and_then(Json::as_bool) == Some(true) {
        Some(bytes_to_f32s(&fetch_blob(
            snap_dir,
            store,
            version,
            "policy_mean.bin",
            &blobs,
        )?)?)
    } else {
        None
    };
    Ok(TrainerSnapshot {
        version: SNAPSHOT_VERSION,
        fingerprint: SnapshotFingerprint {
            label: get_str(&m, "label")?.to_string(),
            seed: get_hex(&m, "seed")?,
            budget: get_hex(&m, "budget")?,
            dim,
        },
        step: get_hex(&m, "step")?,
        oracle_calls_used: get_hex(&m, "oracle_calls_used")?,
        next_eval: get_hex(&m, "next_eval")?,
        data_cursor: get_hex(&m, "data_cursor")?,
        sampler_step: get_hex(&m, "sampler_step")?,
        best_accuracy: f64::from_bits(get_hex(&m, "best_accuracy_bits")?),
        params,
        optimizer: OptimizerState { scalars, buffers },
        policy_mean,
        loss_curve: bytes_to_curve(&fetch_blob(snap_dir, store, version, "loss_curve.bin", &blobs)?)?,
        acc_curve: bytes_to_curve(&fetch_blob(snap_dir, store, version, "acc_curve.bin", &blobs)?)?,
    })
}

/// The `(step, path)` of every snapshot directory under `dir`, ascending
/// by step.  Unreadable directories and staging leftovers are ignored.
pub fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return out,
    };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(num) = name.strip_prefix("step-") {
            if let Ok(step) = num.parse::<u64>() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort_by_key(|(step, _)| *step);
    out
}

/// Load the newest *valid* snapshot under `dir`: corrupt or half-written
/// snapshots are skipped (with a note on stderr) and the previous one is
/// tried — the crash-safety contract with [`write_snapshot`]'s atomic
/// rename and retention of [`SNAPSHOTS_KEPT`] generations.  Legacy v2
/// snapshot directories load without a store, so a pre-store checkpoint
/// tree resumes unchanged.
pub fn load_latest(dir: &Path, store: Option<&Store>) -> Option<TrainerSnapshot> {
    for (_, path) in list_snapshots(dir).iter().rev() {
        match load_snapshot(path, store) {
            Ok(snap) => return Some(snap),
            Err(e) => {
                eprintln!("snapshot: skipping {} ({e:#})", path.display());
            }
        }
    }
    None
}

fn prune(dir: &Path, keep: usize) {
    let snaps = list_snapshots(dir);
    if snaps.len() > keep {
        for (_, path) in &snaps[..snaps.len() - keep] {
            std::fs::remove_dir_all(path).ok();
        }
    }
}

/// Remove `.tmp-*` staging leftovers under `dir` — the garbage a process
/// killed mid-write leaves behind (invalid by construction: their
/// manifest, written last, never landed).  Called after every successful
/// commit so preempt/resume cycles cannot accumulate checkpoint-sized
/// debris.
fn sweep_stale_staging(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            if entry.file_name().to_string_lossy().starts_with(".tmp-") {
                std::fs::remove_dir_all(entry.path()).ok();
            }
        }
    }
}

// --- completed-trial outcome records --------------------------------------

/// A completed trial's persisted [`TrainOutcome`] plus the identity it
/// was produced under.  The canonical spec hash is the exact identity a
/// resumed grid validates against (any change to a hashed field changes
/// the hash, so staleness detection cannot miss); legacy v2 records
/// predate spec hashing and carry `None`, falling back to the old
/// label/seed/budget comparison.
#[derive(Clone, Debug)]
pub struct OutcomeRecord {
    /// The finished trial's outcome (always `completed`).
    pub outcome: TrainOutcome,
    /// The probe storage the run resolved to ("materialized"|"streamed").
    pub probe_storage: String,
    /// The run's sampler/estimator seed.
    pub seed: u64,
    /// The run's total oracle budget.
    pub budget: u64,
    /// Canonical spec hash of the trial that produced this record
    /// (None on legacy records).
    pub spec_hash: Option<String>,
}

/// Build the outcome-record manifest (shared by the store object and the
/// `completed/` mirror): curve blobs are put into `store` first so the
/// inventory can reference them by hash.
fn outcome_manifest(store: &Store, rec: &OutcomeRecord) -> Result<Json> {
    let mut blobs = BTreeMap::new();
    put_blob(store, "loss_curve.bin", &curve_to_bytes(&rec.outcome.loss_curve), &mut blobs)?;
    put_blob(store, "acc_curve.bin", &curve_to_bytes(&rec.outcome.acc_curve), &mut blobs)?;
    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(OUTCOME_MAGIC.into()));
    m.insert("version".to_string(), jhex(SNAPSHOT_VERSION));
    m.insert("label".to_string(), Json::Str(rec.outcome.label.clone()));
    m.insert("seed".to_string(), jhex(rec.seed));
    m.insert("budget".to_string(), jhex(rec.budget));
    m.insert("steps".to_string(), jhex(rec.outcome.steps));
    m.insert("oracle_calls".to_string(), jhex(rec.outcome.oracle_calls));
    m.insert(
        "final_accuracy_bits".to_string(),
        jhex(rec.outcome.final_accuracy.to_bits()),
    );
    m.insert(
        "best_accuracy_bits".to_string(),
        jhex(rec.outcome.best_accuracy.to_bits()),
    );
    m.insert(
        "wall_seconds_bits".to_string(),
        jhex(rec.outcome.wall_seconds.to_bits()),
    );
    m.insert(
        "probe_storage".to_string(),
        Json::Str(rec.probe_storage.clone()),
    );
    if let Some(h) = &rec.spec_hash {
        m.insert("spec_hash".to_string(), Json::Str(h.clone()));
    }
    m.insert("blobs".to_string(), Json::Obj(blobs));
    Ok(Json::Obj(m))
}

/// Persist an outcome record as a canonical-JSON store object and return
/// its hash — the value `grid.lock.json` pins.  Idempotent: the same
/// record always hashes to the same object, so re-recording a cached
/// trial (lock backfill) costs nothing.
pub fn outcome_to_store(store: &Store, rec: &OutcomeRecord) -> Result<String> {
    let m = outcome_manifest(store, rec)?;
    store.put(to_string_canonical(&m).as_bytes())
}

/// Load an outcome record from its store object (as pinned by
/// `grid.lock.json`).  The store read re-hashes the manifest object and
/// every curve blob, so a corrupt entry fails here and the trial re-runs.
pub fn outcome_from_store(store: &Store, hash: &str) -> Result<OutcomeRecord> {
    let bytes = store.get(hash)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| anyhow!("outcome object {hash}: not UTF-8"))?;
    let m = parse(text).map_err(|e| anyhow!("outcome object {hash}: {e}"))?;
    if m.get("magic").and_then(Json::as_str) != Some(OUTCOME_MAGIC) {
        bail!("outcome object {hash}: bad magic");
    }
    outcome_from_manifest(&m, Path::new(""), Some(store))
}

/// Atomically persist a finished trial's outcome record: the canonical
/// object goes into `store` (returning its hash for the grid lock) and a
/// human-readable mirror of the same manifest is committed as
/// `dir/completed/` — the per-trial record a resumed grid can still find
/// without the lockfile.
pub fn write_outcome(dir: &Path, store: &Store, rec: &OutcomeRecord) -> Result<String> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let m = outcome_manifest(store, rec)?;
    let hash = store.put(to_string_canonical(&m).as_bytes())?;
    let tmp = stage_dir(dir, "completed")?;
    commit_dir(&tmp, &dir.join("completed"), m)?;
    sweep_stale_staging(dir);
    Ok(hash)
}

/// The pre-store (v2) outcome writer: curve blobs as sibling files under
/// `dir/completed/`, no spec hash.  Kept so the migration tests can
/// fabricate records exactly as an older build would have written them.
pub fn write_outcome_legacy(
    dir: &Path,
    outcome: &TrainOutcome,
    probe_storage: &str,
    seed: u64,
    budget: u64,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let tmp = stage_dir(dir, "completed")?;
    let mut blobs = BTreeMap::new();
    write_blob(&tmp, "loss_curve.bin", &curve_to_bytes(&outcome.loss_curve), &mut blobs)?;
    write_blob(&tmp, "acc_curve.bin", &curve_to_bytes(&outcome.acc_curve), &mut blobs)?;
    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(OUTCOME_MAGIC.into()));
    m.insert("version".to_string(), jhex(LEGACY_SNAPSHOT_VERSION));
    m.insert("label".to_string(), Json::Str(outcome.label.clone()));
    m.insert("seed".to_string(), jhex(seed));
    m.insert("budget".to_string(), jhex(budget));
    m.insert("steps".to_string(), jhex(outcome.steps));
    m.insert("oracle_calls".to_string(), jhex(outcome.oracle_calls));
    m.insert(
        "final_accuracy_bits".to_string(),
        jhex(outcome.final_accuracy.to_bits()),
    );
    m.insert(
        "best_accuracy_bits".to_string(),
        jhex(outcome.best_accuracy.to_bits()),
    );
    m.insert(
        "wall_seconds_bits".to_string(),
        jhex(outcome.wall_seconds.to_bits()),
    );
    m.insert("probe_storage".to_string(), Json::Str(probe_storage.to_string()));
    m.insert("blobs".to_string(), Json::Obj(blobs));
    commit_dir(&tmp, &dir.join("completed"), Json::Obj(m))?;
    sweep_stale_staging(dir);
    Ok(())
}

/// Load a completed-trial record written by [`write_outcome`] (or a
/// legacy v2 record), if one exists and validates.  A corrupt record is
/// reported and treated as absent (the trial just re-runs).
pub fn load_outcome(dir: &Path, store: Option<&Store>) -> Option<OutcomeRecord> {
    let cdir = dir.join("completed");
    if !cdir.join("manifest.json").exists() {
        return None;
    }
    match try_load_outcome(&cdir, store) {
        Ok(v) => Some(v),
        Err(e) => {
            eprintln!("snapshot: ignoring {} ({e:#})", cdir.display());
            None
        }
    }
}

fn try_load_outcome(cdir: &Path, store: Option<&Store>) -> Result<OutcomeRecord> {
    let m = read_manifest(cdir, OUTCOME_MAGIC)?;
    outcome_from_manifest(&m, cdir, store)
}

fn outcome_from_manifest(m: &Json, cdir: &Path, store: Option<&Store>) -> Result<OutcomeRecord> {
    let version = get_hex(m, "version")?;
    if version != SNAPSHOT_VERSION && version != LEGACY_SNAPSHOT_VERSION {
        bail!(
            "outcome version {version} (this build reads \
             {LEGACY_SNAPSHOT_VERSION} and {SNAPSHOT_VERSION})"
        );
    }
    let blobs = m
        .get("blobs")
        .ok_or_else(|| anyhow!("manifest: missing blob inventory"))?
        .clone();
    let outcome = TrainOutcome {
        loss_curve: bytes_to_curve(&fetch_blob(cdir, store, version, "loss_curve.bin", &blobs)?)?,
        acc_curve: bytes_to_curve(&fetch_blob(cdir, store, version, "acc_curve.bin", &blobs)?)?,
        final_accuracy: f64::from_bits(get_hex(m, "final_accuracy_bits")?),
        best_accuracy: f64::from_bits(get_hex(m, "best_accuracy_bits")?),
        steps: get_hex(m, "steps")?,
        oracle_calls: get_hex(m, "oracle_calls")?,
        wall_seconds: f64::from_bits(get_hex(m, "wall_seconds_bits")?),
        label: get_str(m, "label")?.to_string(),
        completed: true,
    };
    Ok(OutcomeRecord {
        outcome,
        probe_storage: get_str(m, "probe_storage")?.to_string(),
        seed: get_hex(m, "seed")?,
        budget: get_hex(m, "budget")?,
        spec_hash: m
            .get("spec_hash")
            .and_then(Json::as_str)
            .map(str::to_string),
    })
}

/// Filesystem-safe, *injective* directory name for a trial id: the
/// readable sanitized form plus a short FNV hash of the raw id, so two
/// ids that sanitize to the same characters (`"a/b"` vs `"a_b"`) can
/// never share a checkpoint directory.
pub fn sanitize_id(id: &str) -> String {
    let safe: String = id
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '.' | '_') {
                c
            } else {
                '_'
            }
        })
        .collect();
    format!("{safe}-{:08x}", fnv64(id.as_bytes()) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("zo_snap_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn store_for(dir: &Path) -> Store {
        Store::open(dir.join("store"))
    }

    fn sample_snapshot(step: u64) -> TrainerSnapshot {
        TrainerSnapshot {
            version: SNAPSHOT_VERSION,
            fingerprint: SnapshotFingerprint {
                label: "bestofk5/ldsd+zo_sgd".into(),
                seed: u64::MAX - 7, // above 2^53: must survive JSON
                budget: 6000,
                dim: 5,
            },
            step,
            oracle_calls_used: step * 6,
            next_eval: 1200,
            data_cursor: step * 8,
            sampler_step: step,
            best_accuracy: 0.1 + step as f64,
            // step-dependent params (the iterate moves every step), while
            // the optimizer buffers and policy mean below stay constant —
            // the realistic dedup shape across retained generations
            params: vec![1.5 + step as f32, -2.25, f32::MIN_POSITIVE, 0.0, 3.0e-38],
            optimizer: OptimizerState {
                scalars: vec![step],
                buffers: vec![vec![0.5; 5], vec![-0.25; 5]],
            },
            policy_mean: Some(vec![0.125; 5]),
            loss_curve: vec![(6, 0.75), (12, f64::from_bits(0x3FF123456789ABCD))],
            acc_curve: vec![(12, 0.5)],
        }
    }

    fn assert_snapshots_equal(a: &TrainerSnapshot, b: &TrainerSnapshot) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.step, b.step);
        assert_eq!(a.oracle_calls_used, b.oracle_calls_used);
        assert_eq!(a.next_eval, b.next_eval);
        assert_eq!(a.data_cursor, b.data_cursor);
        assert_eq!(a.sampler_step, b.sampler_step);
        assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
        assert_eq!(a.params.len(), b.params.len());
        for (x, y) in a.params.iter().zip(b.params.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.optimizer.scalars, b.optimizer.scalars);
        assert_eq!(a.optimizer.buffers.len(), b.optimizer.buffers.len());
        for (ba, bb) in a.optimizer.buffers.iter().zip(b.optimizer.buffers.iter()) {
            for (x, y) in ba.iter().zip(bb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.policy_mean.is_some(), b.policy_mean.is_some());
        assert_eq!(a.loss_curve.len(), b.loss_curve.len());
        for ((ca, la), (cb, lb)) in a.loss_curve.iter().zip(b.loss_curve.iter()) {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        assert_eq!(a.acc_curve.len(), b.acc_curve.len());
    }

    #[test]
    fn snapshot_roundtrip_is_bit_exact() {
        let dir = tmpdir("roundtrip");
        let store = store_for(&dir);
        let snap = sample_snapshot(42);
        let path = write_snapshot(&dir, &store, &snap).unwrap();
        let back = load_snapshot(&path, Some(&store)).unwrap();
        assert_snapshots_equal(&snap, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_v2_snapshot_still_loads_without_store() {
        let dir = tmpdir("legacy");
        let snap = sample_snapshot(42);
        let path = write_snapshot_legacy(&dir, &snap).unwrap();
        // sibling blobs on disk, readable with no store at all
        assert!(path.join("params.bin").exists());
        let back = load_snapshot(&path, None).unwrap();
        assert_snapshots_equal(&snap, &back);
        assert_eq!(back.version, SNAPSHOT_VERSION, "version normalized on load");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_backed_snapshot_requires_store() {
        let dir = tmpdir("needstore");
        let store = store_for(&dir);
        let path = write_snapshot(&dir, &store, &sample_snapshot(3)).unwrap();
        let err = load_snapshot(&path, None).unwrap_err();
        assert!(err.to_string().contains("store"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retained_generations_dedup_shared_blobs() {
        let dir = tmpdir("dedup");
        let store = store_for(&dir);
        write_snapshot(&dir, &store, &sample_snapshot(10)).unwrap();
        write_snapshot(&dir, &store, &sample_snapshot(20)).unwrap();
        // 6 blobs per snapshot, but opt-0, opt-1, policy_mean and both
        // curves are identical across the two generations: 2 params +
        // 2 opt + 1 policy + 2 curves = 7 objects, not 12
        assert_eq!(store.object_count(), 7, "shared blobs must be stored once");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_wins_and_retention_prunes() {
        let dir = tmpdir("retention");
        let store = store_for(&dir);
        for step in [10u64, 20, 30] {
            write_snapshot(&dir, &store, &sample_snapshot(step)).unwrap();
        }
        let snaps = list_snapshots(&dir);
        assert_eq!(
            snaps.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![20, 30],
            "only the newest {SNAPSHOTS_KEPT} are retained"
        );
        assert_eq!(load_latest(&dir, Some(&store)).unwrap().step, 30);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        let store = store_for(&dir);
        write_snapshot(&dir, &store, &sample_snapshot(10)).unwrap();
        let newest = write_snapshot(&dir, &store, &sample_snapshot(20)).unwrap();
        // flip a byte in the newest params *object*: the store's
        // re-hash-on-read must catch it (params are step-dependent, so
        // step 10's object is untouched)
        let m = read_manifest(&newest, SNAPSHOT_MAGIC).unwrap();
        let hash = m
            .get("blobs")
            .and_then(|b| b.get("params.bin"))
            .and_then(|e| e.get("hash"))
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        let pb = store.object_path(&hash);
        let mut bytes = std::fs::read(&pb).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&pb, &bytes).unwrap();
        let loaded = load_latest(&dir, Some(&store)).unwrap();
        assert_eq!(loaded.step, 10, "corrupt newest must fall back");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn half_written_snapshot_is_invisible() {
        let dir = tmpdir("halfwrite");
        let store = store_for(&dir);
        write_snapshot(&dir, &store, &sample_snapshot(5)).unwrap();
        // a crash mid-write leaves a .tmp-* staging dir with no manifest
        let staged = dir.join(".tmp-step-0000000009-dead");
        std::fs::create_dir_all(&staged).unwrap();
        std::fs::write(staged.join("params.bin"), [0u8; 8]).unwrap();
        // and possibly a committed dir missing its manifest
        let bare = dir.join("step-0000000099");
        std::fs::create_dir_all(&bare).unwrap();
        let loaded = load_latest(&dir, Some(&store)).unwrap();
        assert_eq!(loaded.step, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_outcome() -> TrainOutcome {
        TrainOutcome {
            loss_curve: vec![(6, 1.5), (12, 0.25)],
            acc_curve: vec![(12, 0.625)],
            final_accuracy: 0.625,
            best_accuracy: 0.75,
            steps: 2,
            oracle_calls: 12,
            wall_seconds: 0.125,
            label: "bestofk5/ldsd+zo_sgd".into(),
            completed: true,
        }
    }

    #[test]
    fn outcome_record_roundtrip() {
        let dir = tmpdir("outcome");
        let store = store_for(&dir);
        let rec = OutcomeRecord {
            outcome: sample_outcome(),
            probe_storage: "streamed".into(),
            seed: 41,
            budget: 12,
            spec_hash: Some("ab".repeat(32)),
        };
        assert!(load_outcome(&dir, Some(&store)).is_none());
        let hash = write_outcome(&dir, &store, &rec).unwrap();
        // via the completed/ mirror
        let back = load_outcome(&dir, Some(&store)).unwrap();
        assert_eq!(back.probe_storage, "streamed");
        assert_eq!(back.seed, 41);
        assert_eq!(back.budget, 12);
        assert_eq!(back.spec_hash.as_deref(), Some("ab".repeat(32).as_str()));
        assert!(back.outcome.completed);
        assert_eq!(back.outcome.steps, 2);
        assert_eq!(
            back.outcome.final_accuracy.to_bits(),
            rec.outcome.final_accuracy.to_bits()
        );
        for ((ca, la), (cb, lb)) in rec.outcome.loss_curve.iter().zip(back.outcome.loss_curve.iter())
        {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        // via the store object pinned by the grid lock
        let from_store = outcome_from_store(&store, &hash).unwrap();
        assert_eq!(from_store.seed, 41);
        assert_eq!(
            from_store.outcome.final_accuracy.to_bits(),
            rec.outcome.final_accuracy.to_bits()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_to_store_is_idempotent() {
        let dir = tmpdir("outcome_idem");
        let store = store_for(&dir);
        let rec = OutcomeRecord {
            outcome: sample_outcome(),
            probe_storage: "materialized".into(),
            seed: 7,
            budget: 12,
            spec_hash: Some("cd".repeat(32)),
        };
        let h1 = outcome_to_store(&store, &rec).unwrap();
        let n = store.object_count();
        let h2 = outcome_to_store(&store, &rec).unwrap();
        assert_eq!(h1, h2, "same record must hash to the same object");
        assert_eq!(store.object_count(), n, "re-recording adds no objects");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_v2_outcome_still_loads() {
        let dir = tmpdir("outcome_legacy");
        let out = sample_outcome();
        write_outcome_legacy(&dir, &out, "streamed", 41, 12).unwrap();
        let rec = load_outcome(&dir, None).unwrap();
        assert_eq!(rec.probe_storage, "streamed");
        assert_eq!(rec.seed, 41);
        assert_eq!(rec.budget, 12);
        assert_eq!(rec.spec_hash, None, "legacy records carry no spec hash");
        assert_eq!(
            rec.outcome.final_accuracy.to_bits(),
            out.final_accuracy.to_bits()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sanitize_makes_ids_path_safe_and_injective() {
        let s = sanitize_id("roberta_mini/lora/alg2+zo_sgd");
        assert!(s.starts_with("roberta_mini_lora_alg2_zo_sgd-"), "{s}");
        assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || "-._".contains(c)));
        // ids that sanitize to identical characters must not collide
        assert_ne!(sanitize_id("a/b"), sanitize_id("a_b"));
        assert_ne!(sanitize_id("a b"), sanitize_id("a+b"));
        // and the mapping is deterministic
        assert_eq!(sanitize_id("a/b"), sanitize_id("a/b"));
    }

    #[test]
    fn commits_sweep_stale_staging_leftovers() {
        let dir = tmpdir("sweep");
        let store = store_for(&dir);
        // a previous process died mid-write, leaving manifest-less staging
        let stale = dir.join(".tmp-step-0000000003-12345");
        std::fs::create_dir_all(&stale).unwrap();
        std::fs::write(stale.join("params.bin"), [0u8; 16]).unwrap();
        write_snapshot(&dir, &store, &sample_snapshot(7)).unwrap();
        assert!(!stale.exists(), "stale staging must be swept on commit");
        assert_eq!(load_latest(&dir, Some(&store)).unwrap().step, 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_store_dir_defaults_and_overrides() {
        // no checkpoint dir → no store
        assert_eq!(resolve_store_dir(&CheckpointConfig::default()), None);
        // default: <dir>/store
        let ck = CheckpointConfig {
            dir: Some("/tmp/ck".into()),
            ..Default::default()
        };
        assert_eq!(resolve_store_dir(&ck), Some(PathBuf::from("/tmp/ck/store")));
        // explicit store_dir wins over the default
        let ck2 = CheckpointConfig {
            dir: Some("/tmp/ck".into()),
            store_dir: Some("/tmp/shared-store".into()),
            ..Default::default()
        };
        assert_eq!(
            resolve_store_dir(&ck2),
            Some(PathBuf::from("/tmp/shared-store"))
        );
        // (the CONFIGURED > ENV ordering against ZO_STORE_DIR is covered
        // in tests/store_env.rs and tests/precedence.rs to keep env
        // mutation out of the parallel unit-test process)
    }

    #[test]
    fn fnv_is_stable() {
        // golden values pin the on-disk checksum algorithm across builds
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
    }
}
