//! Property-testing mini-framework (replaces the proptest crate).
//!
//! Seeded generators + a `check` driver that reports the failing case and
//! the seed to reproduce it.  Shrinking is deliberately simple: on failure
//! we retry with halved numeric magnitudes / shorter vectors a few times
//! and report the smallest still-failing case.

use crate::rng::Rng;

/// A generator of random test inputs.
pub trait Gen<T> {
    /// Produce one random value.
    fn generate(&self, rng: &mut Rng) -> T;
    /// Propose smaller variants of a failing value (best-effort shrink).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs; panics with the seed and the
/// (possibly shrunk) counterexample on failure.
pub fn check<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> bool>(
    name: &str,
    gen: &G,
    cases: usize,
    prop: P,
) {
    let base_seed = 0x5EED_CAFE ^ fxhash(name);
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(case as u64));
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            // try to shrink
            let mut smallest = value;
            'outer: for _ in 0..8 {
                for cand in gen.shrink(&smallest) {
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed at case {case} (seed {base_seed:#x}):\n{smallest:#?}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// f32 vectors with entries in `[-scale, scale]`.
pub struct VecF32 {
    /// Shortest vector to generate.
    pub min_len: usize,
    /// Longest vector to generate.
    pub max_len: usize,
    /// Entry magnitude bound.
    pub scale: f32,
}

impl Gen<Vec<f32>> for VecF32 {
    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let span = (self.max_len - self.min_len + 1) as u64;
        let len = self.min_len + rng.below(span) as usize;
        (0..len)
            .map(|_| ((rng.next_f64() as f32) * 2.0 - 1.0) * self.scale)
            .collect()
    }

    fn shrink(&self, value: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if value.len() > self.min_len {
            out.push(value[..value.len() / 2.max(self.min_len)].to_vec());
        }
        out.push(value.iter().map(|x| x / 2.0).collect());
        out.retain(|v: &Vec<f32>| v.len() >= self.min_len);
        out
    }
}

/// Pairs of equal-length vectors.
pub struct VecPairF32(
    /// Generator for each component.
    pub VecF32,
);

impl Gen<(Vec<f32>, Vec<f32>)> for VecPairF32 {
    fn generate(&self, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let a = self.0.generate(rng);
        let b: Vec<f32> = (0..a.len())
            .map(|_| ((rng.next_f64() as f32) * 2.0 - 1.0) * self.0.scale)
            .collect();
        (a, b)
    }

    fn shrink(&self, value: &(Vec<f32>, Vec<f32>)) -> Vec<(Vec<f32>, Vec<f32>)> {
        let (a, b) = value;
        if a.len() > self.0.min_len {
            let h = (a.len() / 2).max(self.0.min_len);
            vec![(a[..h].to_vec(), b[..h].to_vec())]
        } else {
            Vec::new()
        }
    }
}

/// Uniform u64 ranges (for seeds / indices).
pub struct U64Range(
    /// Inclusive lower bound.
    pub u64,
    /// Inclusive upper bound.
    pub u64,
);

impl Gen<u64> for U64Range {
    fn generate(&self, rng: &mut Rng) -> u64 {
        self.0 + rng.below(self.1 - self.0 + 1)
    }

    fn shrink(&self, value: &u64) -> Vec<u64> {
        if *value > self.0 {
            vec![self.0 + (value - self.0) / 2]
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("len_bounded", &VecF32 { min_len: 1, max_len: 16, scale: 1.0 }, 200, |v| {
            v.len() >= 1 && v.len() <= 16
        });
    }

    #[test]
    #[should_panic(expected = "always_false")]
    fn failing_property_panics_with_name() {
        check("always_false", &U64Range(0, 10), 10, |_| false);
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let gen = VecF32 { min_len: 2, max_len: 8, scale: 2.0 };
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(gen.generate(&mut r1), gen.generate(&mut r2));
    }

    #[test]
    fn pair_lengths_match() {
        check(
            "pair_lens",
            &VecPairF32(VecF32 { min_len: 1, max_len: 32, scale: 1.0 }),
            100,
            |(a, b)| a.len() == b.len(),
        );
    }
}
