//! LIBSVM-format parsing + a9a-like synthetic regression data (Fig. 2 toy).
//!
//! The paper's toy experiment trains linear regression on a9a (d=123).  We
//! ship (a) a real LIBSVM text parser so the actual a9a file drops in when
//! available, and (b) a synthetic generator matching a9a's dimensionality
//! and sparse binary feature structure (DESIGN.md §5).

use crate::rng::SplitMix64;
use crate::tensor::Matrix;

/// Parsed LIBSVM dataset: dense row-major features + labels.
#[derive(Clone, Debug)]
pub struct LibsvmDataset {
    /// Dense features (N x d).
    pub x: Matrix,
    /// Labels (length N).
    pub y: Vec<f32>,
}

/// Parse LIBSVM text (`label idx:val idx:val ...`, 1-based indices).
pub fn parse_libsvm(text: &str, dims: usize) -> Result<LibsvmDataset, String> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut y = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f32 = parts
            .next()
            .ok_or_else(|| format!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| format!("line {}: bad label: {e}", lineno + 1))?;
        let mut row = vec![0.0f32; dims];
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad feature '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .map_err(|e| format!("line {}: bad index: {e}", lineno + 1))?;
            let val: f32 = val
                .parse()
                .map_err(|e| format!("line {}: bad value: {e}", lineno + 1))?;
            if idx == 0 || idx > dims {
                return Err(format!(
                    "line {}: index {idx} out of range 1..={dims}",
                    lineno + 1
                ));
            }
            row[idx - 1] = val;
        }
        rows.push(row);
        y.push(label);
    }
    let n = rows.len();
    let data: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(LibsvmDataset { x: Matrix::from_vec(n, dims, data), y })
}

/// a9a-like synthetic regression task: sparse binary features (14 active of
/// 123, like a9a's one-hot blocks), linear ground truth + noise.
#[derive(Clone, Debug)]
pub struct SyntheticRegression {
    /// Sparse binary features (N x d).
    pub x: Matrix,
    /// Noisy linear targets (length N).
    pub y: Vec<f32>,
    /// The ground-truth weight vector.
    pub w_true: Vec<f32>,
}

impl SyntheticRegression {
    /// a9a-shaped instance: d=123, 14 active features, noise 0.1.
    pub fn a9a_like(n: usize, seed: u64) -> Self {
        Self::generate(n, 123, 14, 0.1, seed)
    }

    /// Generate `n` rows with `active` of `d` features set, targets
    /// `<x, w_true> + noise * N(0,1)`.
    pub fn generate(
        n: usize, d: usize, active: usize, noise: f32, seed: u64,
    ) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut w_true = vec![0.0f32; d];
        for w in w_true.iter_mut() {
            *w = (rng.next_f64() as f32 - 0.5) * 2.0;
        }
        let mut x = Matrix::zeros(n, d);
        let mut y = vec![0.0f32; n];
        for r in 0..n {
            let row = &mut x.data[r * d..(r + 1) * d];
            // `active` distinct features per row via partial Fisher-Yates
            let mut chosen = vec![false; d];
            let mut placed = 0;
            while placed < active.min(d) {
                let j = (rng.next_u64() % d as u64) as usize;
                if !chosen[j] {
                    chosen[j] = true;
                    row[j] = 1.0;
                    placed += 1;
                }
            }
            let mut dotp = 0.0f32;
            for j in 0..d {
                dotp += row[j] * w_true[j];
            }
            let eps = {
                // Box–Muller from two uniforms
                let u1 = (rng.next_f64().max(1e-12)) as f32;
                let u2 = rng.next_f64() as f32;
                (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos()
            };
            y[r] = dotp + noise * eps;
        }
        Self { x, y, w_true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let text = "+1 1:1 3:0.5\n-1 2:1\n";
        let ds = parse_libsvm(text, 3).unwrap();
        assert_eq!(ds.x.rows, 2);
        assert_eq!(ds.x.row(0), &[1.0, 0.0, 0.5]);
        assert_eq!(ds.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn parse_rejects_out_of_range() {
        assert!(parse_libsvm("+1 0:1\n", 3).is_err());
        assert!(parse_libsvm("+1 4:1\n", 3).is_err());
        assert!(parse_libsvm("+1 a:1\n", 3).is_err());
    }

    #[test]
    fn parse_skips_blank_and_comments() {
        let ds = parse_libsvm("\n# c\n+1 1:2\n", 2).unwrap();
        assert_eq!(ds.x.rows, 1);
    }

    #[test]
    fn synthetic_shape_and_sparsity() {
        let ds = SyntheticRegression::a9a_like(100, 1);
        assert_eq!(ds.x.rows, 100);
        assert_eq!(ds.x.cols, 123);
        for r in 0..100 {
            let nnz = ds.x.row(r).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 14);
        }
    }

    #[test]
    fn synthetic_is_learnable() {
        // residual at w_true should be far below residual at 0
        let ds = SyntheticRegression::a9a_like(200, 7);
        let mut pred = vec![0.0f32; 200];
        ds.x.matvec(&ds.w_true, &mut pred);
        let sse: f32 = pred
            .iter()
            .zip(ds.y.iter())
            .map(|(p, y)| (p - y) * (p - y))
            .sum();
        let sse0: f32 = ds.y.iter().map(|y| y * y).sum();
        assert!(sse < 0.2 * sse0, "sse {sse} vs sse0 {sse0}");
    }
}
