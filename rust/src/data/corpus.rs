//! Synthetic SST-2-like corpus — rust half of the dual implementation.
//!
//! Draw order per example is an ABI shared with
//! `python/compile/corpus.py::generate_example`; see the doc comment there.
//! `artifacts/golden.json` carries python-generated batches that the
//! integration tests compare against byte-for-byte.

use anyhow::{bail, Result};

use crate::rng::{SplitMix64, GOLDEN_GAMMA};

use super::Batch;

/// Padding token id.
pub const PAD: i32 = 0;
/// Leading classifier token id.
pub const CLS: i32 = 1;
/// Test examples live at indices >= this; train examples at `[0, 2^20)`.
pub const TEST_INDEX_BASE: u64 = 1 << 20;

/// Generation parameters of the synthetic corpus (ABI with python).
#[derive(Clone, Debug, PartialEq)]
pub struct CorpusSpec {
    /// Vocabulary size (ids 0/1 are PAD/CLS).
    pub vocab: u64,
    /// Sequence length.
    pub seq: usize,
    /// Number of classes (2: binary sentiment).
    pub n_classes: u64,
    /// Tokens per class lexicon.
    pub lexicon: u64,
    /// Minimum valid-token length per example.
    pub min_len: u64,
    /// Minimum signal tokens per example.
    pub signal_min: u64,
    /// Maximum signal tokens per example.
    pub signal_max: u64,
    /// Probability a signal token comes from the wrong class lexicon.
    pub contra: f64,
    /// Label-flip probability.
    pub noise: f64,
    /// Base seed mixed with the example index.
    pub seed: u64,
}

impl CorpusSpec {
    /// Matches python `configs.DEFAULT_CORPUS`.
    pub fn default_mini() -> Self {
        Self {
            vocab: 4096,
            seq: 32,
            n_classes: 2,
            lexicon: 64,
            min_len: 16,
            signal_min: 2,
            signal_max: 6,
            contra: 0.08,
            noise: 0.04,
            seed: 0x5EED,
        }
    }

    fn n_neutral(&self) -> u64 {
        self.vocab - 2 - 2 * self.lexicon
    }
}

/// One generated example.
#[derive(Clone, Debug)]
pub struct Example {
    /// Token ids (seq, PAD-padded).
    pub ids: Vec<i32>,
    /// Validity mask (1.0 valid / 0.0 pad), a prefix.
    pub mask: Vec<f32>,
    /// label after noise (what training sees)
    pub label: i32,
    /// label before noise (for diagnostics)
    pub clean_label: i32,
}

/// Stateless corpus view: any example index is generated on demand.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// The generation parameters.
    pub spec: CorpusSpec,
}

impl Corpus {
    /// Validate the spec and build the (stateless) corpus view.  Invalid
    /// specs (a bad CLI config, a hand-edited manifest) fail with a
    /// contextual error instead of a panic.
    pub fn new(spec: CorpusSpec) -> Result<Self> {
        if spec.seq == 0 {
            bail!("corpus spec: seq must be positive");
        }
        if spec.vocab <= 2 + 2 * spec.lexicon {
            bail!(
                "corpus spec: vocab {} too small for 2 lexicons of {} tokens \
                 (+ PAD/CLS); need at least {}",
                spec.vocab,
                spec.lexicon,
                2 + 2 * spec.lexicon + 1
            );
        }
        if spec.min_len < 2 || spec.min_len as usize >= spec.seq {
            bail!(
                "corpus spec: min_len {} must be in [2, seq = {})",
                spec.min_len,
                spec.seq
            );
        }
        if spec.signal_min > spec.signal_max {
            bail!(
                "corpus spec: signal_min {} > signal_max {}",
                spec.signal_min,
                spec.signal_max
            );
        }
        for (name, p) in [("contra", spec.contra), ("noise", spec.noise)] {
            if !(0.0..=1.0).contains(&p) {
                bail!("corpus spec: {name} = {p} is not a probability");
            }
        }
        Ok(Self { spec })
    }

    fn example_seed(&self, index: u64) -> u64 {
        self.spec.seed ^ (index.wrapping_add(1)).wrapping_mul(GOLDEN_GAMMA)
    }

    /// Generate the example at `index` (deterministic; ABI with python).
    pub fn example(&self, index: u64) -> Example {
        let s = &self.spec;
        let mut rng = SplitMix64::new(self.example_seed(index));
        let lex = s.lexicon;

        let label = (rng.next_u64() & 1) as i32;
        let length = s.min_len + rng.next_u64() % (s.seq as u64 - s.min_len);
        let mut n_signal =
            s.signal_min + rng.next_u64() % (s.signal_max - s.signal_min + 1);
        let content = length - 1;
        n_signal = n_signal.min(content);

        let mut ids = vec![PAD; s.seq];
        let mut mask = vec![0.0f32; s.seq];
        ids[0] = CLS;
        for m in mask.iter_mut().take(length as usize) {
            *m = 1.0;
        }

        let mut remaining_signal = n_signal;
        for j in 1..length {
            let remaining_positions = length - j;
            let is_signal = rng.next_u64() % remaining_positions < remaining_signal;
            let tok = if is_signal {
                remaining_signal -= 1;
                let contra = rng.next_f64() < s.contra;
                let cls_id = if contra { 1 - label } else { label } as u64;
                2 + lex * cls_id + rng.next_u64() % lex
            } else {
                2 + 2 * lex + rng.next_u64() % s.n_neutral()
            };
            ids[j as usize] = tok as i32;
        }
        let flip = rng.next_f64() < s.noise;
        let emitted = if flip { 1 - label } else { label };
        Example { ids, mask, label: emitted, clean_label: label }
    }

    /// Contiguous batch starting at `start_index`.
    pub fn batch(&self, start_index: u64, batch: usize) -> Batch {
        let mut out = Batch::zeros(batch, self.spec.seq);
        for b in 0..batch {
            let ex = self.example(start_index + b as u64);
            out.ids[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.ids);
            out.mask[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.mask);
            out.labels[b] = ex.label;
        }
        out
    }

    /// Batch of arbitrary example indices (the epoch-shuffled stream's
    /// entry point; see [`crate::data::TrainStream`]).
    pub fn batch_at_indices(&self, indices: &[u64]) -> Batch {
        let mut out = Batch::zeros(indices.len(), self.spec.seq);
        for (b, &idx) in indices.iter().enumerate() {
            let ex = self.example(idx);
            out.ids[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.ids);
            out.mask[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.mask);
            out.labels[b] = ex.label;
        }
        out
    }

    /// Training batch for a step (stream of disjoint index windows).
    pub fn train_batch(&self, step: u64, batch: usize) -> Batch {
        self.batch(step * batch as u64, batch)
    }

    /// Held-out batch (indices offset by [`TEST_INDEX_BASE`]).
    pub fn test_batch(&self, step: u64, batch: usize) -> Batch {
        self.batch(TEST_INDEX_BASE + step * batch as u64, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusSpec::default_mini()).unwrap()
    }

    #[test]
    fn invalid_specs_error_with_context_instead_of_panicking() {
        let bad_vocab = CorpusSpec { vocab: 100, lexicon: 64, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_vocab).unwrap_err();
        assert!(err.to_string().contains("vocab"), "{err}");

        let bad_len = CorpusSpec { min_len: 40, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_len).unwrap_err();
        assert!(err.to_string().contains("min_len"), "{err}");

        let bad_signal =
            CorpusSpec { signal_min: 7, signal_max: 2, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_signal).unwrap_err();
        assert!(err.to_string().contains("signal"), "{err}");

        let bad_noise = CorpusSpec { noise: 1.5, ..CorpusSpec::default_mini() };
        assert!(Corpus::new(bad_noise).is_err());

        assert!(Corpus::new(CorpusSpec::default_mini()).is_ok());
    }

    #[test]
    fn deterministic_per_index() {
        let c = corpus();
        let a = c.example(42);
        let b = c.example(42);
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn structure_invariants() {
        let c = corpus();
        for i in 0..200 {
            let ex = c.example(i);
            assert_eq!(ex.ids.len(), 32);
            assert_eq!(ex.ids[0], CLS);
            let valid = ex.mask.iter().filter(|&&m| m == 1.0).count() as u64;
            assert!(valid >= c.spec.min_len && valid < c.spec.seq as u64);
            // mask is a prefix
            for j in 1..ex.mask.len() {
                assert!(ex.mask[j] <= ex.mask[j - 1]);
            }
            // padded region is PAD tokens
            for j in 0..ex.ids.len() {
                if ex.mask[j] == 0.0 {
                    assert_eq!(ex.ids[j], PAD);
                } else {
                    assert!(ex.ids[j] >= 1 && (ex.ids[j] as u64) < c.spec.vocab);
                }
            }
            assert!(ex.label == 0 || ex.label == 1);
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let c = corpus();
        let n = 2000;
        let ones: i32 = (0..n).map(|i| c.example(i).label).sum();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "label balance {frac}");
    }

    #[test]
    fn signal_tokens_correlate_with_clean_label() {
        let c = corpus();
        let lex = c.spec.lexicon as i32;
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..500 {
            let ex = c.example(i);
            let pos = ex
                .ids
                .iter()
                .filter(|&&t| t >= 2 && t < 2 + lex)
                .count() as i32;
            let neg = ex
                .ids
                .iter()
                .filter(|&&t| t >= 2 + lex && t < 2 + 2 * lex)
                .count() as i32;
            if pos != neg {
                total += 1;
                let majority = if pos > neg { 0 } else { 1 };
                if majority == ex.clean_label {
                    agree += 1;
                }
            }
        }
        // the contra rate is 8%, so the majority signal should almost always
        // match the clean label
        assert!(agree as f64 / total as f64 > 0.9);
    }

    #[test]
    fn train_and_test_streams_disjoint() {
        let c = corpus();
        let tr = c.train_batch(0, 4);
        let te = c.test_batch(0, 4);
        assert_ne!(tr.ids, te.ids);
    }
}
