//! Synthetic SST-2-like corpus — rust half of the dual implementation.
//!
//! Draw order per example is an ABI shared with
//! `python/compile/corpus.py::generate_example`; see the doc comment there.
//! `artifacts/golden.json` carries python-generated batches that the
//! integration tests compare against byte-for-byte.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{parse, to_string_canonical, to_string_pretty, Json};
use crate::rng::{SplitMix64, GOLDEN_GAMMA};
use crate::store::Store;

use super::Batch;

/// Padding token id.
pub const PAD: i32 = 0;
/// Leading classifier token id.
pub const CLS: i32 = 1;
/// Test examples live at indices >= this; train examples at `[0, 2^20)`.
pub const TEST_INDEX_BASE: u64 = 1 << 20;

/// Generation parameters of the synthetic corpus (ABI with python).
#[derive(Clone, Debug, PartialEq)]
pub struct CorpusSpec {
    /// Vocabulary size (ids 0/1 are PAD/CLS).
    pub vocab: u64,
    /// Sequence length.
    pub seq: usize,
    /// Number of classes (2: binary sentiment).
    pub n_classes: u64,
    /// Tokens per class lexicon.
    pub lexicon: u64,
    /// Minimum valid-token length per example.
    pub min_len: u64,
    /// Minimum signal tokens per example.
    pub signal_min: u64,
    /// Maximum signal tokens per example.
    pub signal_max: u64,
    /// Probability a signal token comes from the wrong class lexicon.
    pub contra: f64,
    /// Label-flip probability.
    pub noise: f64,
    /// Base seed mixed with the example index.
    pub seed: u64,
}

impl CorpusSpec {
    /// Matches python `configs.DEFAULT_CORPUS`.
    pub fn default_mini() -> Self {
        Self {
            vocab: 4096,
            seq: 32,
            n_classes: 2,
            lexicon: 64,
            min_len: 16,
            signal_min: 2,
            signal_max: 6,
            contra: 0.08,
            noise: 0.04,
            seed: 0x5EED,
        }
    }

    fn n_neutral(&self) -> u64 {
        self.vocab - 2 - 2 * self.lexicon
    }
}

/// One generated example.
#[derive(Clone, Debug)]
pub struct Example {
    /// Token ids (seq, PAD-padded).
    pub ids: Vec<i32>,
    /// Validity mask (1.0 valid / 0.0 pad), a prefix.
    pub mask: Vec<f32>,
    /// label after noise (what training sees)
    pub label: i32,
    /// label before noise (for diagnostics)
    pub clean_label: i32,
}

/// Stateless corpus view: any example index is generated on demand.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// The generation parameters.
    pub spec: CorpusSpec,
}

impl Corpus {
    /// Validate the spec and build the (stateless) corpus view.  Invalid
    /// specs (a bad CLI config, a hand-edited manifest) fail with a
    /// contextual error instead of a panic.
    pub fn new(spec: CorpusSpec) -> Result<Self> {
        if spec.seq == 0 {
            bail!("corpus spec: seq must be positive");
        }
        if spec.vocab <= 2 + 2 * spec.lexicon {
            bail!(
                "corpus spec: vocab {} too small for 2 lexicons of {} tokens \
                 (+ PAD/CLS); need at least {}",
                spec.vocab,
                spec.lexicon,
                2 + 2 * spec.lexicon + 1
            );
        }
        if spec.min_len < 2 || spec.min_len as usize >= spec.seq {
            bail!(
                "corpus spec: min_len {} must be in [2, seq = {})",
                spec.min_len,
                spec.seq
            );
        }
        if spec.signal_min > spec.signal_max {
            bail!(
                "corpus spec: signal_min {} > signal_max {}",
                spec.signal_min,
                spec.signal_max
            );
        }
        for (name, p) in [("contra", spec.contra), ("noise", spec.noise)] {
            if !(0.0..=1.0).contains(&p) {
                bail!("corpus spec: {name} = {p} is not a probability");
            }
        }
        Ok(Self { spec })
    }

    fn example_seed(&self, index: u64) -> u64 {
        self.spec.seed ^ (index.wrapping_add(1)).wrapping_mul(GOLDEN_GAMMA)
    }

    /// Generate the example at `index` (deterministic; ABI with python).
    pub fn example(&self, index: u64) -> Example {
        let s = &self.spec;
        let mut rng = SplitMix64::new(self.example_seed(index));
        let lex = s.lexicon;

        let label = (rng.next_u64() & 1) as i32;
        let length = s.min_len + rng.next_u64() % (s.seq as u64 - s.min_len);
        let mut n_signal =
            s.signal_min + rng.next_u64() % (s.signal_max - s.signal_min + 1);
        let content = length - 1;
        n_signal = n_signal.min(content);

        let mut ids = vec![PAD; s.seq];
        let mut mask = vec![0.0f32; s.seq];
        ids[0] = CLS;
        for m in mask.iter_mut().take(length as usize) {
            *m = 1.0;
        }

        let mut remaining_signal = n_signal;
        for j in 1..length {
            let remaining_positions = length - j;
            let is_signal = rng.next_u64() % remaining_positions < remaining_signal;
            let tok = if is_signal {
                remaining_signal -= 1;
                let contra = rng.next_f64() < s.contra;
                let cls_id = if contra { 1 - label } else { label } as u64;
                2 + lex * cls_id + rng.next_u64() % lex
            } else {
                2 + 2 * lex + rng.next_u64() % s.n_neutral()
            };
            ids[j as usize] = tok as i32;
        }
        let flip = rng.next_f64() < s.noise;
        let emitted = if flip { 1 - label } else { label };
        Example { ids, mask, label: emitted, clean_label: label }
    }

    /// Contiguous batch starting at `start_index`.
    pub fn batch(&self, start_index: u64, batch: usize) -> Batch {
        let mut out = Batch::zeros(batch, self.spec.seq);
        for b in 0..batch {
            let ex = self.example(start_index + b as u64);
            out.ids[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.ids);
            out.mask[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.mask);
            out.labels[b] = ex.label;
        }
        out
    }

    /// Batch of arbitrary example indices (the epoch-shuffled stream's
    /// entry point; see [`crate::data::TrainStream`]).
    pub fn batch_at_indices(&self, indices: &[u64]) -> Batch {
        let mut out = Batch::zeros(indices.len(), self.spec.seq);
        for (b, &idx) in indices.iter().enumerate() {
            let ex = self.example(idx);
            out.ids[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.ids);
            out.mask[b * self.spec.seq..(b + 1) * self.spec.seq]
                .copy_from_slice(&ex.mask);
            out.labels[b] = ex.label;
        }
        out
    }

    /// Training batch for a step (stream of disjoint index windows).
    pub fn train_batch(&self, step: u64, batch: usize) -> Batch {
        self.batch(step * batch as u64, batch)
    }

    /// Held-out batch (indices offset by [`TEST_INDEX_BASE`]).
    pub fn test_batch(&self, step: u64, batch: usize) -> Batch {
        self.batch(TEST_INDEX_BASE + step * batch as u64, batch)
    }
}

// --- content-addressed corpus archive (DESIGN.md §16) ----------------------

/// Magic tag of an archived-corpus manifest object.
const CORPUS_MAGIC: &str = "zocorp1";

/// Registry file at the store root mapping archive names to manifest
/// hashes.  Living under the store root makes it a GC root automatically:
/// `Store::gc` scans `*.json` files there, so registered corpora are
/// never swept.
pub const CORPORA_FILE: &str = "corpora.json";

fn i32s_to_bytes(xs: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_i32s(bytes: &[u8]) -> Result<Vec<i32>> {
    if bytes.len() % 4 != 0 {
        bail!("i32 blob length {} is not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("f32 blob length {} is not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn chex(x: u64) -> Json {
    Json::Str(format!("{x:016x}"))
}

fn chex_get(obj: &Json, key: &str) -> Result<u64> {
    let s = obj
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("corpus manifest: missing hex field '{key}'"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 '{s}' for '{key}'"))
}

fn cf64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn cf64_get(obj: &Json, key: &str) -> Result<f64> {
    Ok(f64::from_bits(chex_get(obj, key)?))
}

fn cnum_get(obj: &Json, key: &str) -> Result<usize> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("corpus manifest: missing numeric field '{key}'"))
}

/// Archive the first `n` train examples of `spec` into the
/// content-addressed store: token ids, masks and labels as little-endian
/// blobs plus a canonical manifest object, registered under `name` in the
/// store-root [`CORPORA_FILE`] (which pins it against GC).  Returns the
/// manifest hash.  Re-archiving identical content is a no-op: every blob
/// dedups to the same objects.
pub fn corpus_to_store(store: &Store, name: &str, spec: &CorpusSpec, n: usize) -> Result<String> {
    if n == 0 {
        bail!("corpus archive: n must be positive");
    }
    let batch = Corpus::new(spec.clone())?.batch(0, n);
    let mut blobs = BTreeMap::new();
    blobs.insert("ids".to_string(), Json::Str(store.put(&i32s_to_bytes(&batch.ids))?));
    blobs.insert("mask".to_string(), Json::Str(store.put(&f32s_to_bytes(&batch.mask))?));
    blobs
        .insert("labels".to_string(), Json::Str(store.put(&i32s_to_bytes(&batch.labels))?));
    let mut sp = BTreeMap::new();
    sp.insert("vocab".to_string(), chex(spec.vocab));
    sp.insert("seq".to_string(), Json::Num(spec.seq as f64));
    sp.insert("n_classes".to_string(), chex(spec.n_classes));
    sp.insert("lexicon".to_string(), chex(spec.lexicon));
    sp.insert("min_len".to_string(), chex(spec.min_len));
    sp.insert("signal_min".to_string(), chex(spec.signal_min));
    sp.insert("signal_max".to_string(), chex(spec.signal_max));
    sp.insert("contra".to_string(), cf64(spec.contra));
    sp.insert("noise".to_string(), cf64(spec.noise));
    sp.insert("seed".to_string(), chex(spec.seed));
    let mut m = BTreeMap::new();
    m.insert("magic".to_string(), Json::Str(CORPUS_MAGIC.to_string()));
    m.insert("version".to_string(), Json::Num(1.0));
    m.insert("n".to_string(), Json::Num(n as f64));
    m.insert("spec".to_string(), Json::Obj(sp));
    m.insert("blobs".to_string(), Json::Obj(blobs));
    let hash = store.put(to_string_canonical(&Json::Obj(m)).as_bytes())?;
    register_corpus(store, name, &hash)?;
    Ok(hash)
}

/// Update the store-root corpora registry (`name → manifest hash`),
/// preserving other entries and committing with tmp+rename.
fn register_corpus(store: &Store, name: &str, hash: &str) -> Result<()> {
    let path = store.root().join(CORPORA_FILE);
    let mut entries: BTreeMap<String, Json> = match std::fs::read_to_string(&path) {
        Ok(text) => parse(&text)
            .ok()
            .and_then(|j| j.get("entries").and_then(Json::as_obj).cloned())
            .unwrap_or_default(),
        Err(_) => BTreeMap::new(),
    };
    entries.insert(name.to_string(), Json::Str(hash.to_string()));
    let mut root = BTreeMap::new();
    root.insert("magic".to_string(), Json::Str(CORPUS_MAGIC.to_string()));
    root.insert("version".to_string(), Json::Num(1.0));
    root.insert("entries".to_string(), Json::Obj(entries));
    std::fs::create_dir_all(store.root())
        .with_context(|| format!("creating {}", store.root().display()))?;
    let tmp = store
        .root()
        .join(format!(".tmp-{CORPORA_FILE}-{}", std::process::id()));
    std::fs::write(&tmp, to_string_pretty(&Json::Obj(root)))
        .with_context(|| format!("staging {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("committing {}", path.display()))?;
    Ok(())
}

/// Load an archived corpus back from its manifest hash: the generation
/// spec plus the materialized batch, bit-for-bit as archived.  Every read
/// goes through [`Store::get`], so corrupt blobs fail loudly instead of
/// returning wrong examples.
pub fn corpus_from_store(store: &Store, hash: &str) -> Result<(CorpusSpec, Batch)> {
    let bytes = store.get(hash)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| anyhow!("corpus object {hash}: not UTF-8"))?;
    let m = parse(text).map_err(|e| anyhow!("corpus object {hash}: {e}"))?;
    if m.get("magic").and_then(Json::as_str) != Some(CORPUS_MAGIC) {
        bail!("corpus object {hash}: bad magic");
    }
    let sp = m
        .get("spec")
        .ok_or_else(|| anyhow!("corpus object {hash}: missing spec"))?;
    let spec = CorpusSpec {
        vocab: chex_get(sp, "vocab")?,
        seq: cnum_get(sp, "seq")?,
        n_classes: chex_get(sp, "n_classes")?,
        lexicon: chex_get(sp, "lexicon")?,
        min_len: chex_get(sp, "min_len")?,
        signal_min: chex_get(sp, "signal_min")?,
        signal_max: chex_get(sp, "signal_max")?,
        contra: cf64_get(sp, "contra")?,
        noise: cf64_get(sp, "noise")?,
        seed: chex_get(sp, "seed")?,
    };
    let n = cnum_get(&m, "n")?;
    let blobs = m
        .get("blobs")
        .ok_or_else(|| anyhow!("corpus object {hash}: missing blobs"))?;
    let blob_hash = |key: &str| -> Result<&str> {
        blobs
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("corpus object {hash}: missing blob '{key}'"))
    };
    let ids = bytes_to_i32s(&store.get(blob_hash("ids")?)?)?;
    let mask = bytes_to_f32s(&store.get(blob_hash("mask")?)?)?;
    let labels = bytes_to_i32s(&store.get(blob_hash("labels")?)?)?;
    if ids.len() != n * spec.seq || mask.len() != n * spec.seq || labels.len() != n {
        bail!(
            "corpus object {hash}: blob shapes ({}, {}, {}) do not match n = {n}, seq = {}",
            ids.len(),
            mask.len(),
            labels.len(),
            spec.seq,
        );
    }
    let batch = Batch { batch: n, seq: spec.seq, ids, mask, labels, features: None };
    Ok((spec, batch))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusSpec::default_mini()).unwrap()
    }

    #[test]
    fn invalid_specs_error_with_context_instead_of_panicking() {
        let bad_vocab = CorpusSpec { vocab: 100, lexicon: 64, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_vocab).unwrap_err();
        assert!(err.to_string().contains("vocab"), "{err}");

        let bad_len = CorpusSpec { min_len: 40, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_len).unwrap_err();
        assert!(err.to_string().contains("min_len"), "{err}");

        let bad_signal =
            CorpusSpec { signal_min: 7, signal_max: 2, ..CorpusSpec::default_mini() };
        let err = Corpus::new(bad_signal).unwrap_err();
        assert!(err.to_string().contains("signal"), "{err}");

        let bad_noise = CorpusSpec { noise: 1.5, ..CorpusSpec::default_mini() };
        assert!(Corpus::new(bad_noise).is_err());

        assert!(Corpus::new(CorpusSpec::default_mini()).is_ok());
    }

    #[test]
    fn deterministic_per_index() {
        let c = corpus();
        let a = c.example(42);
        let b = c.example(42);
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn structure_invariants() {
        let c = corpus();
        for i in 0..200 {
            let ex = c.example(i);
            assert_eq!(ex.ids.len(), 32);
            assert_eq!(ex.ids[0], CLS);
            let valid = ex.mask.iter().filter(|&&m| m == 1.0).count() as u64;
            assert!(valid >= c.spec.min_len && valid < c.spec.seq as u64);
            // mask is a prefix
            for j in 1..ex.mask.len() {
                assert!(ex.mask[j] <= ex.mask[j - 1]);
            }
            // padded region is PAD tokens
            for j in 0..ex.ids.len() {
                if ex.mask[j] == 0.0 {
                    assert_eq!(ex.ids[j], PAD);
                } else {
                    assert!(ex.ids[j] >= 1 && (ex.ids[j] as u64) < c.spec.vocab);
                }
            }
            assert!(ex.label == 0 || ex.label == 1);
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let c = corpus();
        let n = 2000;
        let ones: i32 = (0..n).map(|i| c.example(i).label).sum();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "label balance {frac}");
    }

    #[test]
    fn signal_tokens_correlate_with_clean_label() {
        let c = corpus();
        let lex = c.spec.lexicon as i32;
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..500 {
            let ex = c.example(i);
            let pos = ex
                .ids
                .iter()
                .filter(|&&t| t >= 2 && t < 2 + lex)
                .count() as i32;
            let neg = ex
                .ids
                .iter()
                .filter(|&&t| t >= 2 + lex && t < 2 + 2 * lex)
                .count() as i32;
            if pos != neg {
                total += 1;
                let majority = if pos > neg { 0 } else { 1 };
                if majority == ex.clean_label {
                    agree += 1;
                }
            }
        }
        // the contra rate is 8%, so the majority signal should almost always
        // match the clean label
        assert!(agree as f64 / total as f64 > 0.9);
    }

    #[test]
    fn train_and_test_streams_disjoint() {
        let c = corpus();
        let tr = c.train_batch(0, 4);
        let te = c.test_batch(0, 4);
        assert_ne!(tr.ids, te.ids);
    }

    #[test]
    fn corpus_archive_roundtrip_bitwise_dedup_and_gc_rooted() {
        let dir = std::env::temp_dir()
            .join(format!("zo_corpus_store_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = Store::open(dir.join("store"));
        let spec = CorpusSpec::default_mini();

        let h1 = corpus_to_store(&store, "mini", &spec, 16).unwrap();
        let count = store.object_count();
        assert_eq!(count, 4, "ids + mask + labels + manifest");

        // bit-for-bit round trip against a freshly generated batch
        let (spec2, batch) = corpus_from_store(&store, &h1).unwrap();
        assert_eq!(spec2, spec);
        let fresh = corpus().batch(0, 16);
        assert_eq!(batch.ids, fresh.ids);
        assert_eq!(batch.labels, fresh.labels);
        let bits: Vec<u32> = batch.mask.iter().map(|x| x.to_bits()).collect();
        let fresh_bits: Vec<u32> = fresh.mask.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, fresh_bits);

        // re-archiving identical content dedups to the same objects
        let h2 = corpus_to_store(&store, "mini", &spec, 16).unwrap();
        assert_eq!(h1, h2);
        assert_eq!(store.object_count(), count);

        // the store-root registry pins the archive, so GC keeps all of it
        let registry =
            std::fs::read_to_string(store.root().join(CORPORA_FILE)).unwrap();
        assert!(registry.contains(&h1));
        let report = store.gc(&[]).unwrap();
        assert_eq!(report.swept, 0);
        assert_eq!(report.live, count);

        std::fs::remove_dir_all(&dir).ok();
    }
}
