//! Deterministic minibatch streams over the corpus (DESIGN.md §12).
//!
//! The trainer consumes examples through a [`TrainStream`] addressed by a
//! single **batch cursor** — the count of training examples consumed so
//! far.  Two orderings exist:
//!
//! * **sequential** — example index = cursor (the original disjoint-window
//!   stream; the PJRT workloads keep using this);
//! * **epoch-shuffled** — a finite prefix of `n_train` corpus examples is
//!   visited once per epoch in a per-epoch pseudorandom order.
//!
//! The shuffled order is a *pure function* of (seed, epoch, slot): a
//! 4-round Feistel network over the smallest even-bit power-of-two domain
//! covering `n_train`, cycle-walked back into `[0, n_train)`.  No
//! permutation array is ever materialized — O(1) state, any position is
//! addressable directly — which is what makes the stream trivially
//! snapshot/resumable: the batch cursor in
//! [`crate::train::RunProgress`] is the *only* data-pipeline state a
//! checkpoint needs (DESIGN.md §12).

use anyhow::{bail, Result};

use crate::rng::GOLDEN_GAMMA;

use super::corpus::TEST_INDEX_BASE;
use super::{Batch, Corpus};

/// SplitMix64 finalizer: a fixed 64-bit mixing permutation used as the
/// Feistel round function.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Stateless per-epoch permutation of `[0, n)`: position `pos` in the
/// global example stream maps to example `permute(pos / n, pos % n)`.
/// Every epoch visits each of the `n` examples exactly once, in an order
/// keyed by (seed, epoch).
#[derive(Clone, Debug)]
pub struct EpochShuffle {
    n: u64,
    seed: u64,
    half_bits: u32,
    half_mask: u64,
}

impl EpochShuffle {
    /// Permutation over `[0, n)` keyed by `seed` (`n >= 1`).
    pub fn new(n: u64, seed: u64) -> Result<Self> {
        if n == 0 {
            bail!("epoch shuffle: need at least one example");
        }
        // smallest even bit count whose power-of-two domain covers n
        let mut bits = 64 - (n - 1).max(1).leading_zeros();
        if bits < 2 {
            bits = 2;
        }
        if bits % 2 == 1 {
            bits += 1;
        }
        let half_bits = bits / 2;
        Ok(Self { n, seed, half_bits, half_mask: (1u64 << half_bits) - 1 })
    }

    /// Examples per epoch.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// The example index at global stream position `pos` (pure function).
    pub fn index_at(&self, pos: u64) -> u64 {
        self.permute(pos / self.n, pos % self.n)
    }

    #[inline]
    fn round_key(&self, epoch: u64, round: u64) -> u64 {
        mix64(
            self.seed
                ^ epoch.wrapping_mul(GOLDEN_GAMMA)
                ^ round.wrapping_mul(0xD1B5_4A32_D192_ED03),
        )
    }

    /// 4-round Feistel permutation of the 2^(2·half_bits) domain,
    /// cycle-walked until the image lands back inside `[0, n)`.  Walking
    /// terminates because a permutation's orbits are cycles and the start
    /// point is inside the target range.
    fn permute(&self, epoch: u64, slot: u64) -> u64 {
        debug_assert!(slot < self.n);
        let mut x = slot;
        loop {
            let mut l = x >> self.half_bits;
            let mut r = x & self.half_mask;
            for round in 0..4u64 {
                let f = mix64(r ^ self.round_key(epoch, round)) & self.half_mask;
                let next_r = l ^ f;
                l = r;
                r = next_r;
            }
            x = (l << self.half_bits) | r;
            if x < self.n {
                return x;
            }
        }
    }
}

/// The trainer's view of the training data: a corpus plus an ordering,
/// addressed by the run's batch cursor (examples consumed so far).
#[derive(Clone, Debug)]
pub struct TrainStream {
    corpus: Corpus,
    shuffle: Option<EpochShuffle>,
}

impl TrainStream {
    /// Sequential stream: example index = cursor (disjoint index windows,
    /// never repeating — the stateless synthetic corpus is effectively
    /// infinite).
    pub fn sequential(corpus: Corpus) -> Self {
        Self { corpus, shuffle: None }
    }

    /// Epoch-shuffled stream over the first `n_train` corpus examples.
    /// The prefix must stay below [`TEST_INDEX_BASE`] so training never
    /// touches held-out indices.
    pub fn shuffled(corpus: Corpus, n_train: u64, seed: u64) -> Result<Self> {
        if n_train > TEST_INDEX_BASE {
            bail!(
                "epoch shuffle: n_train {n_train} overlaps the held-out index \
                 range (must be <= {TEST_INDEX_BASE})"
            );
        }
        Ok(Self { corpus, shuffle: Some(EpochShuffle::new(n_train, seed)?) })
    }

    /// The underlying corpus (evaluation reads test batches from it).
    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// True when this stream epoch-shuffles a finite prefix.
    pub fn is_shuffled(&self) -> bool {
        self.shuffle.is_some()
    }

    /// The training batch at batch cursor `cursor` (examples consumed so
    /// far).  Pure function of (stream, cursor) — a resumed run that
    /// restores the cursor sees the identical batch sequence.
    pub fn train_batch(&self, cursor: u64, batch: usize) -> Batch {
        match &self.shuffle {
            None => self.corpus.batch(cursor, batch),
            Some(sh) => {
                let indices: Vec<u64> =
                    (0..batch as u64).map(|i| sh.index_at(cursor + i)).collect();
                self.corpus.batch_at_indices(&indices)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;

    #[test]
    fn every_epoch_is_a_permutation() {
        for n in [1u64, 2, 3, 7, 8, 33, 100] {
            let sh = EpochShuffle::new(n, 0xFEED).unwrap();
            for epoch in [0u64, 1, 5] {
                let mut seen = vec![false; n as usize];
                for slot in 0..n {
                    let idx = sh.index_at(epoch * n + slot);
                    assert!(idx < n, "n={n} epoch={epoch}: index {idx} out of range");
                    assert!(
                        !seen[idx as usize],
                        "n={n} epoch={epoch}: index {idx} repeated"
                    );
                    seen[idx as usize] = true;
                }
                assert!(seen.iter().all(|&s| s), "n={n} epoch={epoch}: not onto");
            }
        }
    }

    #[test]
    fn epochs_are_reordered_and_deterministic() {
        let n = 100u64;
        let sh = EpochShuffle::new(n, 7).unwrap();
        let e0: Vec<u64> = (0..n).map(|s| sh.index_at(s)).collect();
        let e1: Vec<u64> = (0..n).map(|s| sh.index_at(n + s)).collect();
        assert_ne!(e0, e1, "consecutive epochs must reshuffle");
        assert!(
            e0.iter().enumerate().any(|(s, &i)| i != s as u64),
            "epoch 0 must not be the identity"
        );
        let again = EpochShuffle::new(n, 7).unwrap();
        let e0b: Vec<u64> = (0..n).map(|s| again.index_at(s)).collect();
        assert_eq!(e0, e0b, "same seed must give the same order");
        let other = EpochShuffle::new(n, 8).unwrap();
        let e0c: Vec<u64> = (0..n).map(|s| other.index_at(s)).collect();
        assert_ne!(e0, e0c, "different seeds must give different orders");
    }

    #[test]
    fn zero_examples_rejected() {
        assert!(EpochShuffle::new(0, 1).is_err());
    }

    #[test]
    fn sequential_stream_matches_corpus_windows() {
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let stream = TrainStream::sequential(corpus.clone());
        assert!(!stream.is_shuffled());
        let a = stream.train_batch(16, 8);
        let b = corpus.batch(16, 8);
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn shuffled_stream_covers_the_prefix_each_epoch() {
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let n_train = 24u64;
        let stream = TrainStream::shuffled(corpus.clone(), n_train, 5).unwrap();
        assert!(stream.is_shuffled());
        // one epoch of batches re-labels exactly the first n_train examples
        let mut labels_stream = Vec::new();
        for step in 0..3u64 {
            let b = stream.train_batch(step * 8, 8);
            labels_stream.extend_from_slice(&b.labels);
        }
        let mut labels_seq: Vec<i32> =
            (0..n_train).map(|i| corpus.example(i).label).collect();
        labels_stream.sort_unstable();
        labels_seq.sort_unstable();
        assert_eq!(labels_stream, labels_seq);
        // and the stream is a pure function of the cursor
        let again = stream.train_batch(8, 8);
        let first = stream.train_batch(8, 8);
        assert_eq!(again.ids, first.ids);
    }

    #[test]
    fn shuffled_prefix_must_not_reach_test_indices() {
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        assert!(TrainStream::shuffled(corpus, TEST_INDEX_BASE + 1, 0).is_err());
    }
}
