//! Data substrate: synthetic corpora, LIBSVM parsing, batching, streams.
//!
//! * [`corpus`] — the synthetic SST-2-like sentiment stream, byte-identical
//!   to `python/compile/corpus.py` (golden-tested).
//! * [`libsvm`] — LIBSVM text format parser plus the a9a-like generator
//!   used by the Fig. 2 toy experiment.
//! * [`stream`] — deterministic minibatch streams: the sequential
//!   disjoint-window stream and the finite-epoch shuffled stream the MLP
//!   workload trains on (batch-cursor addressed, snapshot-resumable).
//! * [`Batch`] — the (ids, mask, labels) triple fed to the PJRT oracles,
//!   optionally carrying dense [`Features`] rows for feature-vector
//!   oracles (the MLP over LIBSVM-style inputs).

pub mod corpus;
pub mod libsvm;
pub mod stream;

pub use corpus::{Corpus, CorpusSpec, Example, TEST_INDEX_BASE};
pub use libsvm::{parse_libsvm, LibsvmDataset, SyntheticRegression};
pub use stream::{EpochShuffle, TrainStream};

/// Dense per-example feature rows riding along a [`Batch`]: row-major
/// `[batch, dim]`.  Token oracles ignore them; feature-vector oracles
/// (the MLP) consume them directly instead of featurizing the token ids
/// — the bridge that lets LIBSVM datasets flow through the same
/// `set_batch` interface (DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct Features {
    /// Feature dimensionality per example.
    pub dim: usize,
    /// Row-major `[batch, dim]` feature values.
    pub data: Vec<f32>,
}

/// One tokenized training/eval batch in the artifact ABI layout.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Examples per batch.
    pub batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// row-major `[batch, seq]` i32 token ids
    pub ids: Vec<i32>,
    /// row-major `[batch, seq]` f32 (1.0 valid / 0.0 pad)
    pub mask: Vec<f32>,
    /// `[batch]` i32 labels
    pub labels: Vec<i32>,
    /// Optional dense feature rows for feature-vector oracles (None for
    /// corpus token batches; the MLP featurizes the ids instead).
    pub features: Option<Features>,
}

impl Batch {
    /// All-zero batch of the given shape (filled by the corpus).
    pub fn zeros(batch: usize, seq: usize) -> Self {
        Self {
            batch,
            seq,
            ids: vec![0; batch * seq],
            mask: vec![0.0; batch * seq],
            labels: vec![0; batch],
            features: None,
        }
    }

    /// A feature-vector batch (LIBSVM-style input): dense rows + labels,
    /// with empty token/mask planes (`seq = 0`).
    pub fn from_features(dim: usize, data: Vec<f32>, labels: Vec<i32>) -> Self {
        assert_eq!(data.len(), labels.len() * dim, "features must be batch x dim");
        Self {
            batch: labels.len(),
            seq: 0,
            ids: Vec::new(),
            mask: Vec::new(),
            labels,
            features: Some(Features { dim, data }),
        }
    }
}
