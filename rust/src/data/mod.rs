//! Data substrate: synthetic corpora, LIBSVM parsing, batching.
//!
//! * [`corpus`] — the synthetic SST-2-like sentiment stream, byte-identical
//!   to `python/compile/corpus.py` (golden-tested).
//! * [`libsvm`] — LIBSVM text format parser plus the a9a-like generator
//!   used by the Fig. 2 toy experiment.
//! * [`Batch`] — the (ids, mask, labels) triple fed to the PJRT oracles.

pub mod corpus;
pub mod libsvm;

pub use corpus::{Corpus, CorpusSpec, Example, TEST_INDEX_BASE};
pub use libsvm::{parse_libsvm, LibsvmDataset, SyntheticRegression};

/// One tokenized training/eval batch in the artifact ABI layout.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Examples per batch.
    pub batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// row-major `[batch, seq]` i32 token ids
    pub ids: Vec<i32>,
    /// row-major `[batch, seq]` f32 (1.0 valid / 0.0 pad)
    pub mask: Vec<f32>,
    /// `[batch]` i32 labels
    pub labels: Vec<i32>,
}

impl Batch {
    /// All-zero batch of the given shape (filled by the corpus).
    pub fn zeros(batch: usize, seq: usize) -> Self {
        Self {
            batch,
            seq,
            ids: vec![0; batch * seq],
            mask: vec![0.0; batch * seq],
            labels: vec![0; batch],
        }
    }
}
