//! Report emitters: markdown tables, CSV series, JSON result files.
//!
//! Every bench/example writes its numbers through this module so
//! EXPERIMENTS.md entries and regenerated artifacts share one format.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::jsonio::{to_string_pretty, Json};

/// A rectangular markdown table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table heading (empty string suppresses it).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Cell rows (each the same arity as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (arity-checked).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a github-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print the markdown rendering to stdout.
    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

/// Write (x, series...) columns as CSV — the figure-regeneration format.
pub fn write_csv(
    path: &Path,
    headers: &[&str],
    columns: &[&[f64]],
) -> Result<()> {
    assert_eq!(headers.len(), columns.len(), "csv arity mismatch");
    let n = columns.first().map(|c| c.len()).unwrap_or(0);
    for c in columns {
        assert_eq!(c.len(), n, "csv column length mismatch");
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{}", headers.join(","))?;
    for i in 0..n {
        let row: Vec<String> = columns.iter().map(|c| format!("{}", c[i])).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Write a JSON value to a file (pretty).
pub fn write_json(path: &Path, value: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(path, to_string_pretty(value))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Terse Json number builder.
pub fn jnum(x: f64) -> Json {
    Json::Num(x)
}

/// Terse Json string builder.
pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

/// Terse Json object builder from (key, value) pairs.
pub fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Terse Json number-array builder.
pub fn jarr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_table_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a "));
        assert!(md.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("zo_ldsd_test_csv");
        let path = dir.join("x.csv");
        write_csv(&path, &["step", "loss"], &[&[1.0, 2.0], &[0.5, 0.25]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().next().unwrap(), "step,loss");
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_builders() {
        let v = jobj(vec![("a", jnum(1.0)), ("b", jarr_f64(&[1.0, 2.0]))]);
        let s = to_string_pretty(&v);
        assert!(s.contains("\"a\""));
    }
}
