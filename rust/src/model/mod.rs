//! Parameter store + checkpointing, plus the forward-only MLP model core.
//!
//! Checkpoint format (`.zock`): a small JSON header (magic, model, mode,
//! d, step, metadata) followed by the raw little-endian f32 payload.
//! Self-describing so restores validate against the manifest before
//! touching the oracle.
//!
//! [`mlp`] holds the MLP classifier's forward/backward core and
//! [`transformer`] the decoder-transformer + LoRA forward; both flat
//! parameter vectors use the same [`LayoutEntry`] layout scheme, so
//! [`views`] and `.zock` checkpoints apply to them unchanged (DESIGN.md
//! §12–§13).

pub mod mlp;
pub mod transformer;

pub use mlp::{Activation, MlpSpec, MlpState};
pub use transformer::{LoraTargets, Pool, TransformerSpec, TransformerState};

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::LayoutEntry;
use crate::jsonio::{parse, to_string_pretty, Json};

const MAGIC: &str = "zock1";

/// A named view into a flat parameter vector (from the manifest layout).
pub struct ParamView<'a> {
    /// Tensor name.
    pub name: &'a str,
    /// Tensor shape.
    pub shape: &'a [usize],
    /// The tensor's slice of the flat vector.
    pub data: &'a [f32],
}

/// Slice a flat vector by manifest layout entries.
pub fn views<'a>(flat: &'a [f32], layout: &'a [LayoutEntry]) -> Result<Vec<ParamView<'a>>> {
    let total: usize = layout.iter().map(|l| l.len).sum();
    if total != flat.len() {
        bail!("layout total {total} != flat len {}", flat.len());
    }
    Ok(layout
        .iter()
        .map(|l| ParamView {
            name: l.name.as_str(),
            shape: l.shape.as_slice(),
            data: &flat[l.offset..l.offset + l.len],
        })
        .collect())
}

/// A saved trainable vector plus enough metadata to validate a restore.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Model name the vector belongs to.
    pub model: String,
    /// Train mode ("ft" | "lora").
    pub mode: String,
    /// Optimizer step the snapshot was taken at.
    pub step: u64,
    /// Oracle calls consumed when the snapshot was taken.
    pub oracle_calls: u64,
    /// The trainable vector.
    pub data: Vec<f32>,
}

impl Checkpoint {
    /// Write header + payload to `path` (parents created).
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let header = Json::Obj(
            [
                ("magic".to_string(), Json::Str(MAGIC.into())),
                ("model".to_string(), Json::Str(self.model.clone())),
                ("mode".to_string(), Json::Str(self.mode.clone())),
                ("d".to_string(), Json::Num(self.data.len() as f64)),
                ("step".to_string(), Json::Num(self.step as f64)),
                (
                    "oracle_calls".to_string(),
                    Json::Num(self.oracle_calls as f64),
                ),
            ]
            .into_iter()
            .collect(),
        );
        let header_text = to_string_pretty(&header);
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(&(header_text.len() as u64).to_le_bytes())?;
        f.write_all(header_text.as_bytes())?;
        for v in &self.data {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read and validate a checkpoint from `path`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8).context("reading header length")?;
        let hlen = u64::from_le_bytes(len8) as usize;
        if hlen > 1 << 20 {
            bail!("implausible checkpoint header length {hlen}");
        }
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf).context("reading header")?;
        let header = parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        if header.get("magic").and_then(Json::as_str) != Some(MAGIC) {
            bail!("not a zo-ldsd checkpoint (bad magic)");
        }
        let d = header
            .field("d")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("bad d"))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if payload.len() != d * 4 {
            bail!("checkpoint payload {} bytes, want {}", payload.len(), d * 4);
        }
        let data = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self {
            model: header
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            mode: header
                .get("mode")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            step: header.get("step").and_then(Json::as_u64).unwrap_or(0),
            oracle_calls: header
                .get("oracle_calls")
                .and_then(Json::as_u64)
                .unwrap_or(0),
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let ck = Checkpoint {
            model: "roberta_mini".into(),
            mode: "lora".into(),
            step: 42,
            oracle_calls: 252,
            data: (0..100).map(|i| i as f32 * 0.5).collect(),
        };
        let dir = std::env::temp_dir().join("zo_ldsd_ck_test");
        let path = dir.join("t.zock");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("zo_ldsd_ck_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.zock");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn views_slice_by_layout() {
        let layout = vec![
            LayoutEntry { name: "a".into(), shape: vec![2, 2], offset: 0, len: 4 },
            LayoutEntry { name: "b".into(), shape: vec![3], offset: 4, len: 3 },
        ];
        let flat: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let v = views(&flat, &layout).unwrap();
        assert_eq!(v[0].data, &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(v[1].data, &[4.0, 5.0, 6.0]);
        assert!(views(&flat[..6], &layout).is_err());
    }
}
