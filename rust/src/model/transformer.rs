//! Pure-Rust decoder-transformer forward + LoRA subspace (DESIGN.md §13).
//!
//! The paper's actual workload shape: a pre-LN residual transformer
//! classifier (token+position embeddings, multi-head attention, GELU MLP
//! blocks, layernorm, linear head) evaluated *forward-only* on the host.
//! The flat parameter vector uses the same [`LayoutEntry`] manifest
//! scheme as the PJRT artifacts and the MLP — names, shapes and order
//! mirror `python/compile/params.py` exactly, so [`crate::model::views`],
//! `.zock` checkpoints and snapshots apply unchanged, and a flat vector
//! is interchangeable between the Rust and JAX forwards (the golden
//! parity test in `tests/transformer_golden.rs` pins this).
//!
//! LoRA mode restricts the trainable vector to rank-r adapter factors on
//! a configurable subset of the attention projections (default W_q/W_v,
//! the reference layout) plus the classifier head, so the probe dimension
//! `d` is the adapter count — the small-`d` regime where LDSD's learned
//! sampling and the streamed probe engine compound.
//!
//! Determinism contract (DESIGN.md §9): everything here is per-example
//! sequential fixed-order arithmetic — matmuls accumulate input-major in
//! ascending index order, layernorm statistics and softmax partition
//! functions fold through f64, batch losses fold in data-row order.  The
//! oracle parallelizes over *probes*, never inside one forward, so losses
//! are bitwise identical for any worker count.
//!
//! Numerics mirror `python/compile/model.py::forward_pure`: layernorm
//! eps 1e-5, additive -1e9 key-padding mask, where-style causal mask,
//! tanh-approximation GELU (`jax.nn.gelu`'s default), "cls" (position 0)
//! or "last" (final valid position) pooling.
//!
//! [`batch_dir_derivative`] is an analytic forward-mode (JVP) directional
//! derivative used by the fd-vs-analytic cross-checks in
//! `tests/transformer_train.rs`; the training path never calls it.

use anyhow::{bail, Result};

use crate::config::LayoutEntry;
use crate::model::mlp::cross_entropy;
use crate::tensor::gemm::{self, GemmMode, PackedB};
use crate::tensor::lanes::accum_row;

/// The additive key-padding mask value (mirrors `kernels/ref.py::NEG_INF`).
const NEG_INF: f32 = -1e9;

/// Classifier pooling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    /// Pool position 0 (RoBERTa-style CLS token).
    Cls,
    /// Pool the final valid position per example (OPT-style decoder).
    Last,
}

impl Pool {
    /// Parse from a CLI/config string ("cls" | "last").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "cls" => Ok(Pool::Cls),
            "last" => Ok(Pool::Last),
            other => bail!("unknown pool '{other}' (cls|last)"),
        }
    }

    /// Canonical lowercase name.
    pub fn label(&self) -> &'static str {
        match self {
            Pool::Cls => "cls",
            Pool::Last => "last",
        }
    }
}

/// Which attention projections carry LoRA adapters.  The reference layout
/// (`python/compile/params.py::lora_layout`) adapts W_q and W_v; the
/// other combinations generalize the same scheme (canonical layout order
/// is always q, k, v, o).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoraTargets {
    /// Adapt the query projection W_q.
    pub q: bool,
    /// Adapt the key projection W_k.
    pub k: bool,
    /// Adapt the value projection W_v.
    pub v: bool,
    /// Adapt the output projection W_o.
    pub o: bool,
}

impl LoraTargets {
    /// The reference target set: W_q + W_v (the python ABI layout).
    pub fn qv() -> Self {
        Self { q: true, k: false, v: true, o: false }
    }

    /// Parse from a CLI string: any subset of the letters q/k/v/o
    /// (commas optional), e.g. "qv", "q,v", "qkvo".
    pub fn parse(s: &str) -> Result<Self> {
        let mut t = Self { q: false, k: false, v: false, o: false };
        for c in s.chars() {
            match c {
                'q' => t.q = true,
                'k' => t.k = true,
                'v' => t.v = true,
                'o' => t.o = true,
                ',' | ' ' => {}
                other => bail!("unknown lora target '{other}' (subset of qkvo)"),
            }
        }
        if !(t.q || t.k || t.v || t.o) {
            bail!("lora targets '{s}': need at least one of q/k/v/o");
        }
        Ok(t)
    }

    /// Canonical label ("qv", "qkvo", ...), always in q,k,v,o order.
    pub fn label(&self) -> String {
        let mut out = String::new();
        for (on, c) in [(self.q, 'q'), (self.k, 'k'), (self.v, 'v'), (self.o, 'o')] {
            if on {
                out.push(c);
            }
        }
        out
    }

    /// Adapted projections per layer.
    fn count(&self) -> usize {
        [self.q, self.k, self.v, self.o].iter().filter(|&&b| b).count()
    }
}

/// Architecture of one transformer classifier plus its LoRA subspace
/// geometry.  Mirrors `python/compile/configs.py::ModelConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct TransformerSpec {
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer depth.
    pub n_layers: usize,
    /// Attention heads (must divide `d_model`).
    pub n_heads: usize,
    /// MLP-block hidden width.
    pub d_ff: usize,
    /// Maximum sequence length (position-embedding table size).
    pub max_seq: usize,
    /// Classifier output classes (>= 2).
    pub n_classes: usize,
    /// Causal (decoder) vs bidirectional attention.
    pub causal: bool,
    /// Classifier pooling strategy.
    pub pool: Pool,
    /// LoRA adapter rank r.
    pub lora_rank: usize,
    /// LoRA delta scale (alpha / r; 2.0 in the reference configs).
    pub lora_scale: f32,
    /// Which attention projections carry adapters.
    pub lora_targets: LoraTargets,
}

impl TransformerSpec {
    /// Validated constructor.
    pub fn new(
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
        max_seq: usize,
        n_classes: usize,
        causal: bool,
        pool: Pool,
        lora_rank: usize,
    ) -> Result<Self> {
        if vocab < 2 {
            bail!("transformer spec: vocab must be >= 2");
        }
        if d_model == 0 || n_heads == 0 || d_model % n_heads != 0 {
            bail!(
                "transformer spec: n_heads {n_heads} must divide d_model {d_model}"
            );
        }
        if n_layers == 0 || d_ff == 0 || max_seq == 0 {
            bail!("transformer spec: n_layers, d_ff and max_seq must be positive");
        }
        if n_classes < 2 {
            bail!("transformer spec: need at least 2 classes, got {n_classes}");
        }
        if lora_rank == 0 {
            bail!("transformer spec: lora_rank must be >= 1");
        }
        Ok(Self {
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            max_seq,
            n_classes,
            causal,
            pool,
            lora_rank,
            lora_scale: 2.0,
            lora_targets: LoraTargets::qv(),
        })
    }

    /// The `roberta_mini` reference config (configs.py).
    pub fn roberta_mini() -> Self {
        Self::new(4096, 128, 4, 4, 512, 32, 2, false, Pool::Cls, 8)
            .expect("reference config is valid")
    }

    /// The `opt_mini` reference config (configs.py).
    pub fn opt_mini() -> Self {
        Self::new(4096, 160, 4, 4, 640, 32, 2, true, Pool::Last, 8)
            .expect("reference config is valid")
    }

    /// Per-head width.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Full fine-tuning dimensionality d_ft.
    pub fn d_ft(&self) -> usize {
        let d = self.d_model;
        let per_layer = 2 * d          // ln1
            + 4 * (d * d + d)          // wq/bq wk/bk wv/bv wo/bo
            + 2 * d                    // ln2
            + d * self.d_ff + self.d_ff // wf1/bf1
            + self.d_ff * d + d; // wf2/bf2
        (self.vocab + self.max_seq) * d
            + self.n_layers * per_layer
            + 2 * d
            + d * self.n_classes
            + self.n_classes
    }

    /// LoRA trainable dimensionality d_lora (adapters + head).
    pub fn d_lora(&self) -> usize {
        let d = self.d_model;
        self.n_layers * self.lora_targets.count() * 2 * d * self.lora_rank
            + d * self.n_classes
            + self.n_classes
    }

    /// Full fine-tuning flat-vector layout — names, shapes and order
    /// mirror `python/compile/params.py::ft_layout` (weights are stored
    /// input-major `[d_in, d_out]`, y = x W).
    pub fn ft_layout(&self) -> Vec<LayoutEntry> {
        let d = self.d_model;
        let f = self.d_ff;
        let mut out = Vec::new();
        let mut off = 0usize;
        let mut push = |name: String, shape: Vec<usize>| {
            let len: usize = shape.iter().product();
            out.push(LayoutEntry { name, shape, offset: off, len });
            off += len;
        };
        push("tok_emb".into(), vec![self.vocab, d]);
        push("pos_emb".into(), vec![self.max_seq, d]);
        for i in 0..self.n_layers {
            let p = format!("layer{i}.");
            push(format!("{p}ln1.g"), vec![d]);
            push(format!("{p}ln1.b"), vec![d]);
            push(format!("{p}wq"), vec![d, d]);
            push(format!("{p}bq"), vec![d]);
            push(format!("{p}wk"), vec![d, d]);
            push(format!("{p}bk"), vec![d]);
            push(format!("{p}wv"), vec![d, d]);
            push(format!("{p}bv"), vec![d]);
            push(format!("{p}wo"), vec![d, d]);
            push(format!("{p}bo"), vec![d]);
            push(format!("{p}ln2.g"), vec![d]);
            push(format!("{p}ln2.b"), vec![d]);
            push(format!("{p}wf1"), vec![d, f]);
            push(format!("{p}bf1"), vec![f]);
            push(format!("{p}wf2"), vec![f, d]);
            push(format!("{p}bf2"), vec![d]);
        }
        push("final_ln.g".into(), vec![d]);
        push("final_ln.b".into(), vec![d]);
        push("head.w".into(), vec![d, self.n_classes]);
        push("head.b".into(), vec![self.n_classes]);
        out
    }

    /// LoRA flat-vector layout: per layer, rank-r A/B factors for each
    /// adapted projection (canonical q,k,v,o order), then the classifier
    /// head.  With the default q+v targets this equals
    /// `python/compile/params.py::lora_layout` name for name.
    pub fn lora_layout(&self) -> Vec<LayoutEntry> {
        let d = self.d_model;
        let r = self.lora_rank;
        let mut out = Vec::new();
        let mut off = 0usize;
        let mut push = |name: String, shape: Vec<usize>| {
            let len: usize = shape.iter().product();
            out.push(LayoutEntry { name, shape, offset: off, len });
            off += len;
        };
        let t = self.lora_targets;
        for i in 0..self.n_layers {
            let p = format!("layer{i}.");
            for (on, tag) in [(t.q, "q"), (t.k, "k"), (t.v, "v"), (t.o, "o")] {
                if on {
                    push(format!("{p}lora_{tag}.a"), vec![d, r]);
                    push(format!("{p}lora_{tag}.b"), vec![r, d]);
                }
            }
        }
        push("head.w".into(), vec![d, self.n_classes]);
        push("head.b".into(), vec![self.n_classes]);
        out
    }

    /// Deterministic base-model init: layernorm gains 1, biases 0, all
    /// other weights ~ N(0, 0.02) — the `params.py::init_ft` recipe,
    /// drawn from this crate's own RNG.  A pure function of (spec, seed).
    pub fn init_base(&self, seed: u64) -> Vec<f32> {
        // fixed tag so the init stream never aliases the samplers' streams
        let mut rng = crate::rng::Rng::new(seed ^ 0x5452_464D);
        let mut p = vec![0.0f32; self.d_ft()];
        for e in self.ft_layout() {
            let block = &mut p[e.offset..e.offset + e.len];
            if e.name.ends_with(".g") {
                block.iter_mut().for_each(|v| *v = 1.0);
            } else if is_ft_bias(&e.name) {
                // already zero
            } else {
                rng.fill_normal(block);
                block.iter_mut().for_each(|v| *v *= 0.02);
            }
        }
        p
    }

    /// Deterministic LoRA init: A ~ N(0, 0.01), B = 0 (the delta starts
    /// at zero), head copied from `base` when given (the fine-tuning
    /// practice `params.py::init_lora` mirrors) else ~ N(0, 0.02).
    pub fn init_lora(&self, seed: u64, base: Option<&[f32]>) -> Vec<f32> {
        let mut rng = crate::rng::Rng::new(seed ^ 0x4C4F_5241);
        let mut p = vec![0.0f32; self.d_lora()];
        for e in self.lora_layout() {
            let block = &mut p[e.offset..e.offset + e.len];
            if e.name.ends_with(".a") {
                rng.fill_normal(block);
                block.iter_mut().for_each(|v| *v *= 0.01);
            } else if e.name == "head.w" {
                match base {
                    Some(b) => {
                        let fo = FtOffsets::new(self);
                        block.copy_from_slice(&b[fo.head_w..fo.head_w + e.len]);
                    }
                    None => {
                        rng.fill_normal(block);
                        block.iter_mut().for_each(|v| *v *= 0.02);
                    }
                }
            }
            // lora .b factors and head.b stay zero
        }
        p
    }

    /// Rough forward cost (MACs) of one example at sequence length `seq`
    /// — the work estimate the execution engine sizes dispatches by.
    pub fn forward_work(&self, seq: usize) -> usize {
        let d = self.d_model;
        let per_pos = 4 * d * d + 2 * d * self.d_ff + 2 * seq * d;
        self.n_layers * per_pos * seq + d * self.n_classes
    }

    /// Short identifier for labels ("tfm2x2d32").
    pub fn label(&self) -> String {
        format!("tfm{}x{}d{}", self.n_layers, self.n_heads, self.d_model)
    }
}

/// True for the base-layout bias blocks (zero-initialized).
fn is_ft_bias(name: &str) -> bool {
    name.ends_with(".b")
        || name.ends_with("bq")
        || name.ends_with("bk")
        || name.ends_with("bv")
        || name.ends_with("bo")
        || name.ends_with("bf1")
        || name.ends_with("bf2")
}

/// Numeric offsets of one layer's blocks in the base flat vector.
#[derive(Clone, Copy, Debug)]
struct FtLayer {
    ln1_g: usize,
    ln1_b: usize,
    wq: usize,
    bq: usize,
    wk: usize,
    bk: usize,
    wv: usize,
    bv: usize,
    wo: usize,
    bo: usize,
    ln2_g: usize,
    ln2_b: usize,
    wf1: usize,
    bf1: usize,
    wf2: usize,
    bf2: usize,
}

/// Numeric offsets of the full base layout (derived from
/// [`TransformerSpec::ft_layout`], held by the per-worker state so the
/// forward never does name lookups).
#[derive(Clone, Debug)]
struct FtOffsets {
    tok_emb: usize,
    pos_emb: usize,
    layers: Vec<FtLayer>,
    final_ln_g: usize,
    final_ln_b: usize,
    head_w: usize,
    head_b: usize,
    total: usize,
}

impl FtOffsets {
    fn new(spec: &TransformerSpec) -> Self {
        let d = spec.d_model;
        let f = spec.d_ff;
        let mut off = 0usize;
        let mut take = |len: usize| {
            let at = off;
            off += len;
            at
        };
        let tok_emb = take(spec.vocab * d);
        let pos_emb = take(spec.max_seq * d);
        let layers = (0..spec.n_layers)
            .map(|_| FtLayer {
                ln1_g: take(d),
                ln1_b: take(d),
                wq: take(d * d),
                bq: take(d),
                wk: take(d * d),
                bk: take(d),
                wv: take(d * d),
                bv: take(d),
                wo: take(d * d),
                bo: take(d),
                ln2_g: take(d),
                ln2_b: take(d),
                wf1: take(d * f),
                bf1: take(f),
                wf2: take(f * d),
                bf2: take(d),
            })
            .collect();
        let final_ln_g = take(d);
        let final_ln_b = take(d);
        let head_w = take(d * spec.n_classes);
        let head_b = take(spec.n_classes);
        Self {
            tok_emb,
            pos_emb,
            layers,
            final_ln_g,
            final_ln_b,
            head_w,
            head_b,
            total: off,
        }
    }
}

/// (A offset, B offset) of one adapted projection, None if unadapted.
type LoraPair = Option<(usize, usize)>;

/// Per-layer adapter offsets in the LoRA flat vector.
#[derive(Clone, Copy, Debug)]
struct LoraLayer {
    q: LoraPair,
    k: LoraPair,
    v: LoraPair,
    o: LoraPair,
}

/// Numeric offsets of the LoRA layout.
#[derive(Clone, Debug)]
struct LoraOffsets {
    layers: Vec<LoraLayer>,
    head_w: usize,
    head_b: usize,
    total: usize,
}

impl LoraOffsets {
    fn new(spec: &TransformerSpec) -> Self {
        let d = spec.d_model;
        let r = spec.lora_rank;
        let t = spec.lora_targets;
        let mut off = 0usize;
        let mut pair = |on: bool| -> LoraPair {
            if on {
                let a = off;
                off += d * r;
                let b = off;
                off += r * d;
                Some((a, b))
            } else {
                None
            }
        };
        let layers = (0..spec.n_layers)
            .map(|_| LoraLayer {
                q: pair(t.q),
                k: pair(t.k),
                v: pair(t.v),
                o: pair(t.o),
            })
            .collect();
        let head_w = off;
        off += d * spec.n_classes;
        let head_b = off;
        off += spec.n_classes;
        Self { layers, head_w, head_b, total: off }
    }
}

/// One layer's weight matrices packed for the blocked GEMM engine
/// (panel-major [`PackedB`] images of the six `[d_in, d_out]` mats the
/// batched forward multiplies by).  Biases, layernorm params and
/// embeddings are read in place — only B-operands of GEMMs pack.
struct LayerPacks {
    wq: PackedB,
    wk: PackedB,
    wv: PackedB,
    wo: PackedB,
    wf1: PackedB,
    wf2: PackedB,
}

impl LayerPacks {
    fn empty() -> Self {
        Self {
            wq: PackedB::empty(),
            wk: PackedB::empty(),
            wv: PackedB::empty(),
            wo: PackedB::empty(),
            wf1: PackedB::empty(),
            wf2: PackedB::empty(),
        }
    }
}

/// The weight-pack cache: every base weight matrix the batched forward
/// feeds to the blocked engine, packed tile-major once and reused across
/// all rows of the batch and all probes that share the base vector.
/// Packing is a bit-free copy, so a pack of vector `w` and `w` itself
/// produce identical forwards — the cache is a pure speed artifact.
///
/// Invalidation rules (DESIGN.md §15): in **LoRA mode** the base is
/// frozen for the whole run, so the oracle packs once at construction
/// and every probe of every step reuses it — packing amortizes to zero.
/// In **FT mode** the trainable vector *is* the base, so the per-worker
/// state repacks from the perturbed vector on each batch evaluation
/// (reusing its allocations); the pack cost is one extra read of the
/// weights, which the m = batch·seq GEMM rows amortize.  The classifier
/// head and LoRA adapter A-factors are narrow (`n <= NR`) and run
/// unpacked; adapter B-factors are per-probe trainables packed into
/// worker scratch.
pub struct BasePacks {
    layers: Vec<LayerPacks>,
}

impl BasePacks {
    /// An empty cache that [`BasePacks::repack`] fills (worker scratch).
    pub fn empty() -> Self {
        Self { layers: Vec::new() }
    }

    /// Pack every GEMM weight of `base` (a full `ft_layout` vector) —
    /// the LoRA-mode once-per-run entry.
    pub fn pack(spec: &TransformerSpec, base: &[f32]) -> Self {
        let mut p = Self::empty();
        p.repack_with(spec, &FtOffsets::new(spec), base);
        p
    }

    /// Re-pack in place from a (possibly perturbed) base vector, reusing
    /// allocations — the FT-mode per-evaluation entry.
    pub fn repack(&mut self, spec: &TransformerSpec, base: &[f32]) {
        self.repack_with(spec, &FtOffsets::new(spec), base);
    }

    fn repack_with(&mut self, spec: &TransformerSpec, ft: &FtOffsets, base: &[f32]) {
        let d = spec.d_model;
        let f = spec.d_ff;
        if self.layers.len() != spec.n_layers {
            self.layers = (0..spec.n_layers).map(|_| LayerPacks::empty()).collect();
        }
        for (lp, lo) in self.layers.iter_mut().zip(ft.layers.iter()) {
            lp.wq.repack(&base[lo.wq..][..d * d], d, d);
            lp.wk.repack(&base[lo.wk..][..d * d], d, d);
            lp.wv.repack(&base[lo.wv..][..d * d], d, d);
            lp.wo.repack(&base[lo.wo..][..d * d], d, d);
            lp.wf1.repack(&base[lo.wf1..][..d * f], d, f);
            lp.wf2.repack(&base[lo.wf2..][..f * d], f, d);
        }
    }
}

/// Per-worker forward scratch: layout offsets + activation buffers sized
/// for `max_seq`.  Workers of a parallel K-probe evaluation each own one
/// (allocated once per dispatch, reused across that worker's probes).
pub struct TransformerState {
    ft: FtOffsets,
    lora: LoraOffsets,
    /// residual stream [seq, d]
    x: Vec<f32>,
    /// layernormed stream [seq, d]
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// merged attention output [seq, d]
    attn: Vec<f32>,
    /// per-query attention scores/probs [seq]
    probs: Vec<f32>,
    /// d-wide matmul staging
    tmp_d: Vec<f32>,
    /// second d-wide staging (LoRA delta on the output projection)
    tmp_d2: Vec<f32>,
    /// rank-r LoRA staging
    tmp_r: Vec<f32>,
    /// d_ff-wide MLP hidden staging
    hid: Vec<f32>,
    logits: Vec<f32>,
    /// batched-forward arena (`[batch*seq, _]` activations for the
    /// blocked engine): lazily grown to the largest batch this worker
    /// has seen, then reused with zero heap traffic across probes
    bx: Vec<f32>,
    bxn: Vec<f32>,
    bq: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    battn: Vec<f32>,
    bproj: Vec<f32>,
    bdelta: Vec<f32>,
    bhid: Vec<f32>,
    bt: Vec<f32>,
    pooled: Vec<f32>,
    blogits: Vec<f32>,
    /// FT-mode pack cache, repacked from the perturbed vector per
    /// evaluation (LoRA-mode callers pass a run-lifetime cache instead)
    own_packs: BasePacks,
    /// per-probe pack scratch for trainable LoRA adapter B-factors
    lora_pack: PackedB,
}

impl TransformerState {
    /// Scratch sized for `spec`.
    pub fn new(spec: &TransformerSpec) -> Self {
        let sd = spec.max_seq * spec.d_model;
        Self {
            ft: FtOffsets::new(spec),
            lora: LoraOffsets::new(spec),
            x: vec![0.0; sd],
            xn: vec![0.0; sd],
            q: vec![0.0; sd],
            k: vec![0.0; sd],
            v: vec![0.0; sd],
            attn: vec![0.0; sd],
            probs: vec![0.0; spec.max_seq],
            tmp_d: vec![0.0; spec.d_model],
            tmp_d2: vec![0.0; spec.d_model],
            tmp_r: vec![0.0; spec.lora_rank],
            hid: vec![0.0; spec.d_ff],
            logits: vec![0.0; spec.n_classes],
            bx: Vec::new(),
            bxn: Vec::new(),
            bq: Vec::new(),
            bk: Vec::new(),
            bv: Vec::new(),
            battn: Vec::new(),
            bproj: Vec::new(),
            bdelta: Vec::new(),
            bhid: Vec::new(),
            bt: Vec::new(),
            pooled: Vec::new(),
            blogits: Vec::new(),
            own_packs: BasePacks::empty(),
            lora_pack: PackedB::empty(),
        }
    }

    /// The logits of the last forward pass.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Grow the batched arena to `bsz` examples of `seq` tokens (never
    /// shrinks, so steady-state probe evaluations allocate nothing).
    fn ensure_batch(&mut self, spec: &TransformerSpec, bsz: usize, seq: usize) {
        let m = bsz * seq;
        let d = spec.d_model;
        let md = m * d;
        if self.bx.len() < md {
            self.bx.resize(md, 0.0);
            self.bxn.resize(md, 0.0);
            self.bq.resize(md, 0.0);
            self.bk.resize(md, 0.0);
            self.bv.resize(md, 0.0);
            self.battn.resize(md, 0.0);
            self.bproj.resize(md, 0.0);
            self.bdelta.resize(md, 0.0);
        }
        if self.bhid.len() < m * spec.d_ff {
            self.bhid.resize(m * spec.d_ff, 0.0);
        }
        if self.bt.len() < m * spec.lora_rank {
            self.bt.resize(m * spec.lora_rank, 0.0);
        }
        if self.pooled.len() < bsz * d {
            self.pooled.resize(bsz * d, 0.0);
        }
        if self.blogits.len() < bsz * spec.n_classes {
            self.blogits.resize(bsz * spec.n_classes, 0.0);
        }
    }
}

/// `out = x W (+ b)` with W stored input-major `[d_in, d_out]` — the
/// python `x @ W` convention.  Accumulates over inputs in ascending index
/// order (per output element the identical f32 addition sequence as a
/// per-output dot), so results are a pure function of the operands.  The
/// inner row update runs through [`crate::tensor::lanes::accum_row`],
/// whose unfused mul-then-add arithmetic is exactly this loop's — the
/// committed f32 forward golden stays valid in both lane modes.
fn matmul(x: &[f32], w: &[f32], b: Option<&[f32]>, out: &mut [f32]) {
    let d_out = out.len();
    debug_assert_eq!(w.len(), x.len() * d_out);
    match b {
        Some(b) => out.copy_from_slice(b),
        None => out.iter_mut().for_each(|v| *v = 0.0),
    }
    for (i, &xi) in x.iter().enumerate() {
        let wr = &w[i * d_out..(i + 1) * d_out];
        accum_row(xi, wr, out);
    }
}

/// `out = scale * ((x A) B)` — the additive LoRA delta, A `[d_in, r]`,
/// B `[r, d_out]` (mirrors `forward_pure`'s `scale * ((xn @ A) @ B)`).
fn lora_delta(
    x: &[f32],
    a: &[f32],
    bmat: &[f32],
    r: usize,
    scale: f32,
    tmp_r: &mut [f32],
    out: &mut [f32],
) {
    let tr = &mut tmp_r[..r];
    tr.iter_mut().for_each(|v| *v = 0.0);
    for (i, &xi) in x.iter().enumerate() {
        let ar = &a[i * r..(i + 1) * r];
        accum_row(xi, ar, tr);
    }
    let d_out = out.len();
    out.iter_mut().for_each(|v| *v = 0.0);
    for c in 0..r {
        let br = &bmat[c * d_out..(c + 1) * d_out];
        accum_row(tr[c], br, out);
    }
    for j in 0..d_out {
        out[j] *= scale;
    }
}

/// Row layernorm, eps 1e-5: statistics fold through f64 (fixed order),
/// then `out = (x - mean) * rsqrt(var + eps) * g + b` in f32.
fn layernorm_row(xr: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = xr.len() as f64;
    let mut mean = 0.0f64;
    for &v in xr {
        mean += v as f64;
    }
    mean /= n;
    let mut var = 0.0f64;
    for &v in xr {
        let c = v as f64 - mean;
        var += c * c;
    }
    var /= n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for j in 0..xr.len() {
        out[j] = (((xr[j] as f64 - mean) * inv) as f32) * g[j] + b[j];
    }
}

/// tanh-approximation GELU (`jax.nn.gelu`'s default `approximate=True`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f64 as f32; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// The pooled position: 0 for CLS, `max(sum(mask) - 1, 0)` for last.
fn pooled_position(pool: Pool, mask: &[f32]) -> usize {
    match pool {
        Pool::Cls => 0,
        Pool::Last => {
            let sum: f32 = mask.iter().sum();
            (sum as i64 - 1).max(0) as usize
        }
    }
}

/// One forward pass of a single example: fills `state` and returns the
/// logits.  `lora = None` runs the base model (FT mode); `Some` applies
/// the rank-r deltas to the adapted projections and takes the classifier
/// head from the LoRA vector (the base head is ignored), exactly like
/// the reference `forward_pure`.
pub fn forward_example<'a>(
    spec: &TransformerSpec,
    base: &[f32],
    lora: Option<&[f32]>,
    ids: &[i32],
    mask: &[f32],
    state: &'a mut TransformerState,
) -> &'a [f32] {
    let s = ids.len();
    let d = spec.d_model;
    let dh = spec.head_dim();
    let r = spec.lora_rank;
    assert!(
        (1..=spec.max_seq).contains(&s),
        "seq {s} outside 1..={}",
        spec.max_seq
    );
    assert_eq!(mask.len(), s, "one mask value per token");
    debug_assert_eq!(base.len(), state.ft.total, "base must match spec layout");
    if let Some(lv) = lora {
        debug_assert_eq!(lv.len(), state.lora.total, "lora must match spec layout");
    }

    // token + position embeddings
    for t in 0..s {
        let id = ids[t];
        assert!(
            id >= 0 && (id as usize) < spec.vocab,
            "token id {id} outside vocab {}",
            spec.vocab
        );
        let tok = &base[state.ft.tok_emb + id as usize * d..][..d];
        let pos = &base[state.ft.pos_emb + t * d..][..d];
        let xr = &mut state.x[t * d..(t + 1) * d];
        for j in 0..d {
            xr[j] = tok[j] + pos[j];
        }
    }

    let denom = (dh as f32).sqrt();
    for li in 0..spec.n_layers {
        let lo = state.ft.layers[li];
        let ll = state.lora.layers.get(li).copied();

        // pre-LN + q/k/v projections (LoRA deltas on the adapted ones)
        for t in 0..s {
            layernorm_row(
                &state.x[t * d..(t + 1) * d],
                &base[lo.ln1_g..][..d],
                &base[lo.ln1_b..][..d],
                &mut state.xn[t * d..(t + 1) * d],
            );
        }
        for t in 0..s {
            let xr = &state.xn[t * d..(t + 1) * d];
            matmul(xr, &base[lo.wq..][..d * d], Some(&base[lo.bq..][..d]), &mut state.q[t * d..(t + 1) * d]);
            matmul(xr, &base[lo.wk..][..d * d], Some(&base[lo.bk..][..d]), &mut state.k[t * d..(t + 1) * d]);
            matmul(xr, &base[lo.wv..][..d * d], Some(&base[lo.bv..][..d]), &mut state.v[t * d..(t + 1) * d]);
        }
        if let (Some(lv), Some(ll)) = (lora, ll) {
            for t in 0..s {
                for (pair, buf) in [
                    (ll.q, &mut state.q),
                    (ll.k, &mut state.k),
                    (ll.v, &mut state.v),
                ] {
                    if let Some((ao, bo)) = pair {
                        lora_delta(
                            &state.xn[t * d..(t + 1) * d],
                            &lv[ao..][..d * r],
                            &lv[bo..][..r * d],
                            r,
                            spec.lora_scale,
                            &mut state.tmp_r,
                            &mut state.tmp_d,
                        );
                        let row = &mut buf[t * d..(t + 1) * d];
                        for j in 0..d {
                            row[j] += state.tmp_d[j];
                        }
                    }
                }
            }
        }

        // multi-head attention: additive -1e9 padding mask, where-style
        // causal mask, max-shifted softmax with an f64 partition function
        for hh in 0..spec.n_heads {
            let hd0 = hh * dh;
            for t in 0..s {
                for j in 0..s {
                    let qrow = &state.q[t * d + hd0..t * d + hd0 + dh];
                    let krow = &state.k[j * d + hd0..j * d + hd0 + dh];
                    let mut sc = crate::tensor::dot(qrow, krow) / denom;
                    sc += (1.0 - mask[j]) * NEG_INF;
                    if spec.causal && j > t {
                        sc = NEG_INF;
                    }
                    state.probs[j] = sc;
                }
                let mut m = f32::NEG_INFINITY;
                for j in 0..s {
                    m = m.max(state.probs[j]);
                }
                let mut z = 0.0f64;
                for j in 0..s {
                    z += ((state.probs[j] - m) as f64).exp();
                }
                for j in 0..s {
                    state.probs[j] = (((state.probs[j] - m) as f64).exp() / z) as f32;
                }
                let ar = &mut state.attn[t * d + hd0..t * d + hd0 + dh];
                ar.iter_mut().for_each(|v| *v = 0.0);
                for j in 0..s {
                    let p = state.probs[j];
                    let vr = &state.v[j * d + hd0..j * d + hd0 + dh];
                    for c in 0..dh {
                        ar[c] += p * vr[c];
                    }
                }
            }
        }

        // output projection (+ optional LoRA delta) + residual
        for t in 0..s {
            let arow = &state.attn[t * d..(t + 1) * d];
            matmul(arow, &base[lo.wo..][..d * d], Some(&base[lo.bo..][..d]), &mut state.tmp_d);
            if let (Some(lv), Some(ll)) = (lora, ll) {
                if let Some((ao, bo)) = ll.o {
                    lora_delta(
                        arow,
                        &lv[ao..][..d * r],
                        &lv[bo..][..r * d],
                        r,
                        spec.lora_scale,
                        &mut state.tmp_r,
                        &mut state.tmp_d2,
                    );
                    for j in 0..d {
                        state.tmp_d[j] += state.tmp_d2[j];
                    }
                }
            }
            let xr = &mut state.x[t * d..(t + 1) * d];
            for j in 0..d {
                xr[j] += state.tmp_d[j];
            }
        }

        // pre-LN MLP block with tanh-GELU + residual
        for t in 0..s {
            layernorm_row(
                &state.x[t * d..(t + 1) * d],
                &base[lo.ln2_g..][..d],
                &base[lo.ln2_b..][..d],
                &mut state.xn[t * d..(t + 1) * d],
            );
        }
        for t in 0..s {
            matmul(
                &state.xn[t * d..(t + 1) * d],
                &base[lo.wf1..][..d * spec.d_ff],
                Some(&base[lo.bf1..][..spec.d_ff]),
                &mut state.hid,
            );
            state.hid.iter_mut().for_each(|v| *v = gelu(*v));
            matmul(
                &state.hid,
                &base[lo.wf2..][..spec.d_ff * d],
                Some(&base[lo.bf2..][..d]),
                &mut state.tmp_d,
            );
            let xr = &mut state.x[t * d..(t + 1) * d];
            for j in 0..d {
                xr[j] += state.tmp_d[j];
            }
        }
    }

    // final LN, pooling, classifier head (LoRA head in LoRA mode)
    for t in 0..s {
        layernorm_row(
            &state.x[t * d..(t + 1) * d],
            &base[state.ft.final_ln_g..][..d],
            &base[state.ft.final_ln_b..][..d],
            &mut state.xn[t * d..(t + 1) * d],
        );
    }
    let pt = pooled_position(spec.pool, mask).min(s - 1);
    let c = spec.n_classes;
    let (hw, hb): (&[f32], &[f32]) = match lora {
        Some(lv) => (
            &lv[state.lora.head_w..][..d * c],
            &lv[state.lora.head_b..][..c],
        ),
        None => (
            &base[state.ft.head_w..][..d * c],
            &base[state.ft.head_b..][..c],
        ),
    };
    matmul(&state.xn[pt * d..(pt + 1) * d], hw, Some(hb), &mut state.logits);
    &state.logits
}

/// Mean softmax cross-entropy of a token minibatch: examples evaluated in
/// data-row order, losses folded through one f64 accumulator — the fixed
/// term sequence that keeps every evaluation path (loss_dir, vectorized
/// loss_k, streamed loss_probes) bitwise identical.  Dispatches between
/// the per-example reference forward and the batched blocked GEMM engine
/// on [`gemm::effective_gemm_mode`]; the two are bit-identical by the
/// §15 tiling contract, so the mode only changes speed.
pub fn batch_loss(
    spec: &TransformerSpec,
    base: &[f32],
    lora: Option<&[f32]>,
    ids: &[i32],
    mask: &[f32],
    seq: usize,
    labels: &[i32],
    state: &mut TransformerState,
) -> f64 {
    batch_loss_packed(spec, base, lora, ids, mask, seq, labels, state, None)
}

/// [`batch_loss`] with an optional weight-pack cache.  `packs` supplies a
/// pre-packed image of `base` for the blocked engine (the LoRA oracle's
/// run-lifetime cache — the base is frozen, so it packs once); `None`
/// makes the blocked path repack from `base` into worker scratch (the FT
/// rule: the trainable vector *is* the base, so every perturbed
/// evaluation repacks).  Packing is a bit-free copy, so both choices —
/// and both engines — return identical bits.
#[allow(clippy::too_many_arguments)]
pub fn batch_loss_packed(
    spec: &TransformerSpec,
    base: &[f32],
    lora: Option<&[f32]>,
    ids: &[i32],
    mask: &[f32],
    seq: usize,
    labels: &[i32],
    state: &mut TransformerState,
    packs: Option<&BasePacks>,
) -> f64 {
    let b = labels.len();
    debug_assert_eq!(ids.len(), b * seq, "one id row per label");
    debug_assert_eq!(mask.len(), b * seq, "one mask row per label");
    match gemm::effective_gemm_mode() {
        GemmMode::Reference => {
            let mut acc = 0.0f64;
            for row in 0..b {
                let logits = forward_example(
                    spec,
                    base,
                    lora,
                    &ids[row * seq..(row + 1) * seq],
                    &mask[row * seq..(row + 1) * seq],
                    state,
                );
                acc += cross_entropy(logits, labels[row]);
            }
            acc / b.max(1) as f64
        }
        GemmMode::Blocked => {
            batch_loss_blocked(spec, base, lora, ids, mask, seq, labels, state, packs)
        }
    }
}

/// Dispatch a narrow-B product: single-panel blocked when `n` fits one
/// packed panel (LoRA A-factors, classifier heads — raw row-major B *is*
/// the packed layout there), else the reference row loop.  Bit-identical
/// either way, so this is purely a speed choice.
fn gemm_narrow_auto(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    if n <= gemm::NR {
        gemm::gemm_blocked_narrow(a, m, k, b, n, bias, out);
    } else {
        gemm::gemm_reference(a, m, k, b, n, bias, out);
    }
}

/// Batched LoRA delta: `target += scale * ((Xn · A) · B)` over all m
/// rows — the GEMM form of [`lora_delta`].  Element for element the same
/// arithmetic: T and the delta accumulate ascending-k from zero, the
/// scale multiplies the finished delta once, and the scaled value adds
/// into the target.  B is a per-probe trainable, packed into worker
/// scratch on each call (cost O(r·d), amortized by the m GEMM rows).
#[allow(clippy::too_many_arguments)]
fn lora_delta_batch(
    lv: &[f32],
    ao: usize,
    bo: usize,
    d: usize,
    r: usize,
    scale: f32,
    xn: &[f32],
    m: usize,
    t: &mut [f32],
    pack: &mut PackedB,
    delta: &mut [f32],
    target: &mut [f32],
) {
    let a = &lv[ao..][..d * r];
    let bmat = &lv[bo..][..r * d];
    let t = &mut t[..m * r];
    gemm_narrow_auto(xn, m, d, a, r, None, t);
    pack.repack(bmat, r, d);
    let delta = &mut delta[..m * d];
    gemm::gemm_blocked(t, m, r, pack, None, delta);
    for (tv, dv) in target.iter_mut().zip(delta.iter()) {
        *tv += *dv * scale;
    }
}

/// The batched blocked-engine evaluation of [`batch_loss`]: every
/// projection of every layer is one `[batch·seq, d_in] × [d_in, d_out]`
/// blocked GEMM over the whole minibatch instead of batch·seq separate
/// row×matrix loops.  Bit-for-bit identical to the reference path: each
/// activation element's f32 operation sequence is unchanged (the tiling
/// contract covers the GEMMs; embeddings, layernorm, attention, GELU,
/// residual adds and the CE fold run the reference expressions in
/// reference order per element), only the iteration over independent
/// elements is rearranged.
#[allow(clippy::too_many_arguments)]
fn batch_loss_blocked(
    spec: &TransformerSpec,
    base: &[f32],
    lora: Option<&[f32]>,
    ids: &[i32],
    mask: &[f32],
    seq: usize,
    labels: &[i32],
    state: &mut TransformerState,
    packs: Option<&BasePacks>,
) -> f64 {
    let bsz = labels.len();
    if bsz == 0 {
        return 0.0;
    }
    let d = spec.d_model;
    let f = spec.d_ff;
    let dh = spec.head_dim();
    let r = spec.lora_rank;
    let c = spec.n_classes;
    let m = bsz * seq;
    assert!(
        (1..=spec.max_seq).contains(&seq),
        "seq {seq} outside 1..={}",
        spec.max_seq
    );
    debug_assert_eq!(base.len(), state.ft.total, "base must match spec layout");
    if let Some(lv) = lora {
        debug_assert_eq!(lv.len(), state.lora.total, "lora must match spec layout");
    }
    state.ensure_batch(spec, bsz, seq);
    if packs.is_none() {
        // FT rule: the trainable vector is the base — repack it for this
        // evaluation (worker scratch, allocation-free at steady state)
        let st = &mut *state;
        st.own_packs.repack_with(spec, &st.ft, base);
    }
    let TransformerState {
        ft,
        lora: lora_off,
        probs,
        bx,
        bxn,
        bq,
        bk,
        bv,
        battn,
        bproj,
        bdelta,
        bhid,
        bt,
        pooled,
        blogits,
        own_packs,
        lora_pack,
        ..
    } = state;
    let packs: &BasePacks = packs.unwrap_or(&*own_packs);
    let bx = &mut bx[..m * d];
    let bxn = &mut bxn[..m * d];
    let bq = &mut bq[..m * d];
    let bk = &mut bk[..m * d];
    let bv = &mut bv[..m * d];
    let battn = &mut battn[..m * d];
    let bproj = &mut bproj[..m * d];
    let bhid = &mut bhid[..m * f];
    let pooled = &mut pooled[..bsz * d];
    let blogits = &mut blogits[..bsz * c];

    // token + position embeddings, example-major rows
    for row in 0..bsz {
        for t in 0..seq {
            let id = ids[row * seq + t];
            assert!(
                id >= 0 && (id as usize) < spec.vocab,
                "token id {id} outside vocab {}",
                spec.vocab
            );
            let tok = &base[ft.tok_emb + id as usize * d..][..d];
            let pos = &base[ft.pos_emb + t * d..][..d];
            let xr = &mut bx[(row * seq + t) * d..][..d];
            for j in 0..d {
                xr[j] = tok[j] + pos[j];
            }
        }
    }

    let denom = (dh as f32).sqrt();
    let scale = spec.lora_scale;
    for li in 0..spec.n_layers {
        let lo = ft.layers[li];
        let ll = lora_off.layers.get(li).copied();
        let lp = &packs.layers[li];

        // pre-LN + q/k/v projections as whole-batch GEMMs
        for i in 0..m {
            layernorm_row(
                &bx[i * d..(i + 1) * d],
                &base[lo.ln1_g..][..d],
                &base[lo.ln1_b..][..d],
                &mut bxn[i * d..(i + 1) * d],
            );
        }
        gemm::gemm_blocked(bxn, m, d, &lp.wq, Some(&base[lo.bq..][..d]), bq);
        gemm::gemm_blocked(bxn, m, d, &lp.wk, Some(&base[lo.bk..][..d]), bk);
        gemm::gemm_blocked(bxn, m, d, &lp.wv, Some(&base[lo.bv..][..d]), bv);
        if let (Some(lv), Some(ll)) = (lora, ll) {
            for (pair, buf) in [(ll.q, &mut *bq), (ll.k, &mut *bk), (ll.v, &mut *bv)] {
                if let Some((ao, bo)) = pair {
                    lora_delta_batch(lv, ao, bo, d, r, scale, bxn, m, bt, lora_pack, bdelta, buf);
                }
            }
        }

        // multi-head attention, per example — reference arithmetic on the
        // batched q/k/v rows (sequential dot, f64 partition function)
        for ex in 0..bsz {
            let mrow = &mask[ex * seq..(ex + 1) * seq];
            let r0 = ex * seq;
            for hh in 0..spec.n_heads {
                let hd0 = hh * dh;
                for t in 0..seq {
                    for j in 0..seq {
                        let qrow = &bq[(r0 + t) * d + hd0..(r0 + t) * d + hd0 + dh];
                        let krow = &bk[(r0 + j) * d + hd0..(r0 + j) * d + hd0 + dh];
                        let mut sc = crate::tensor::dot(qrow, krow) / denom;
                        sc += (1.0 - mrow[j]) * NEG_INF;
                        if spec.causal && j > t {
                            sc = NEG_INF;
                        }
                        probs[j] = sc;
                    }
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..seq {
                        mx = mx.max(probs[j]);
                    }
                    let mut z = 0.0f64;
                    for j in 0..seq {
                        z += ((probs[j] - mx) as f64).exp();
                    }
                    for j in 0..seq {
                        probs[j] = (((probs[j] - mx) as f64).exp() / z) as f32;
                    }
                    let ar = &mut battn[(r0 + t) * d + hd0..(r0 + t) * d + hd0 + dh];
                    ar.iter_mut().for_each(|v| *v = 0.0);
                    for j in 0..seq {
                        let p = probs[j];
                        let vr = &bv[(r0 + j) * d + hd0..(r0 + j) * d + hd0 + dh];
                        for cc in 0..dh {
                            ar[cc] += p * vr[cc];
                        }
                    }
                }
            }
        }

        // output projection (+ optional LoRA delta) + residual
        gemm::gemm_blocked(battn, m, d, &lp.wo, Some(&base[lo.bo..][..d]), bproj);
        if let (Some(lv), Some(ll)) = (lora, ll) {
            if let Some((ao, bo)) = ll.o {
                lora_delta_batch(lv, ao, bo, d, r, scale, battn, m, bt, lora_pack, bdelta, bproj);
            }
        }
        for (xv, pv) in bx.iter_mut().zip(bproj.iter()) {
            *xv += *pv;
        }

        // pre-LN MLP block with tanh-GELU + residual
        for i in 0..m {
            layernorm_row(
                &bx[i * d..(i + 1) * d],
                &base[lo.ln2_g..][..d],
                &base[lo.ln2_b..][..d],
                &mut bxn[i * d..(i + 1) * d],
            );
        }
        gemm::gemm_blocked(bxn, m, d, &lp.wf1, Some(&base[lo.bf1..][..f]), bhid);
        bhid.iter_mut().for_each(|v| *v = gelu(*v));
        gemm::gemm_blocked(bhid, m, f, &lp.wf2, Some(&base[lo.bf2..][..d]), bproj);
        for (xv, pv) in bx.iter_mut().zip(bproj.iter()) {
            *xv += *pv;
        }
    }

    // final LN on the pooled rows only (rows are independent, and the
    // reference path discards every non-pooled row), then the head as
    // one narrow GEMM over the gathered [bsz, d] pool
    for ex in 0..bsz {
        let mrow = &mask[ex * seq..(ex + 1) * seq];
        let pt = pooled_position(spec.pool, mrow).min(seq - 1);
        layernorm_row(
            &bx[(ex * seq + pt) * d..(ex * seq + pt + 1) * d],
            &base[ft.final_ln_g..][..d],
            &base[ft.final_ln_b..][..d],
            &mut pooled[ex * d..(ex + 1) * d],
        );
    }
    let (hw, hb): (&[f32], &[f32]) = match lora {
        Some(lv) => (
            &lv[lora_off.head_w..][..d * c],
            &lv[lora_off.head_b..][..c],
        ),
        None => (
            &base[ft.head_w..][..d * c],
            &base[ft.head_b..][..c],
        ),
    };
    gemm_narrow_auto(pooled, bsz, d, hw, c, Some(hb), blogits);
    let mut acc = 0.0f64;
    for (row, &label) in labels.iter().enumerate() {
        acc += cross_entropy(&blogits[row * c..(row + 1) * c], label);
    }
    acc / bsz as f64
}

// ---------------------------------------------------------------------------
// Analytic directional derivative (forward-mode JVP), diagnostics only
// ---------------------------------------------------------------------------

/// f64 dual buffers for one JVP forward (values + tangents side by side).
struct Dual {
    x: Vec<f64>,
    dx: Vec<f64>,
}

impl Dual {
    fn new(n: usize) -> Self {
        Self { x: vec![0.0; n], dx: vec![0.0; n] }
    }
}

/// `out = x W + b`, `dout = dx W + x dW + db` (f64, input-major W).
fn mm_dual(
    x: &[f64],
    dx: &[f64],
    w: &[f64],
    dw: Option<&[f64]>,
    b: Option<(&[f64], Option<&[f64]>)>,
    out: &mut [f64],
    dout: &mut [f64],
) {
    let d_out = out.len();
    match b {
        Some((bv, dbv)) => {
            out.copy_from_slice(bv);
            match dbv {
                Some(dbv) => dout.copy_from_slice(dbv),
                None => dout.iter_mut().for_each(|v| *v = 0.0),
            }
        }
        None => {
            out.iter_mut().for_each(|v| *v = 0.0);
            dout.iter_mut().for_each(|v| *v = 0.0);
        }
    }
    for i in 0..x.len() {
        let wr = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            out[j] += x[i] * wr[j];
            dout[j] += dx[i] * wr[j];
        }
        if let Some(dw) = dw {
            let dwr = &dw[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                dout[j] += x[i] * dwr[j];
            }
        }
    }
}

/// Layernorm JVP (gain/bias are constants here: the base model is either
/// the trainable vector itself — handled by passing `dg`/`db` — or
/// frozen).
fn ln_dual(
    x: &[f64],
    dx: &[f64],
    g: &[f64],
    dg: Option<&[f64]>,
    b: &[f64],
    db: Option<&[f64]>,
    out: &mut [f64],
    dout: &mut [f64],
) {
    let n = x.len() as f64;
    let mut mu = 0.0;
    let mut dmu = 0.0;
    for i in 0..x.len() {
        mu += x[i];
        dmu += dx[i];
    }
    mu /= n;
    dmu /= n;
    let mut var = 0.0;
    let mut dvar = 0.0;
    for i in 0..x.len() {
        let c = x[i] - mu;
        var += c * c;
        dvar += 2.0 * c * (dx[i] - dmu);
    }
    var /= n;
    dvar /= n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    let dinv = -0.5 * inv * inv * inv * dvar;
    for i in 0..x.len() {
        let xh = (x[i] - mu) * inv;
        let dxh = (dx[i] - dmu) * inv + (x[i] - mu) * dinv;
        out[i] = xh * g[i] + b[i];
        dout[i] = dxh * g[i];
        if let Some(dg) = dg {
            dout[i] += xh * dg[i];
        }
        if let Some(db) = db {
            dout[i] += db[i];
        }
    }
}

/// LoRA delta JVP: `out = s * ((x A) B)`; `dout` carries all three
/// product-rule terms (the A/B tangents come from the trainable LoRA
/// vector at offsets `ao`/`bo` in `l64`/`dl64`).
fn lora_dual(
    xr: &[f64],
    dxr: &[f64],
    ao: usize,
    bo: usize,
    r: usize,
    scale: f64,
    l64: &[f64],
    dl64: &[f64],
    tr: &mut Dual,
    out: &mut Dual,
) {
    let a = &l64[ao..ao + xr.len() * r];
    let da = &dl64[ao..ao + xr.len() * r];
    let d_out = out.x.len();
    let bm = &l64[bo..bo + r * d_out];
    let dbm = &dl64[bo..bo + r * d_out];
    for cc in 0..r {
        tr.x[cc] = 0.0;
        tr.dx[cc] = 0.0;
    }
    for i in 0..xr.len() {
        for cc in 0..r {
            tr.x[cc] += xr[i] * a[i * r + cc];
            tr.dx[cc] += dxr[i] * a[i * r + cc] + xr[i] * da[i * r + cc];
        }
    }
    for j in 0..d_out {
        out.x[j] = 0.0;
        out.dx[j] = 0.0;
    }
    for cc in 0..r {
        for j in 0..d_out {
            out.x[j] += tr.x[cc] * bm[cc * d_out + j];
            out.dx[j] += tr.dx[cc] * bm[cc * d_out + j] + tr.x[cc] * dbm[cc * d_out + j];
        }
    }
    for j in 0..d_out {
        out.x[j] *= scale;
        out.dx[j] *= scale;
    }
}

/// GELU (tanh approximation) value + derivative at `x`.
fn gelu_dual(x: f64, dx: f64) -> (f64, f64) {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let y = 0.5 * x * (1.0 + t);
    let du = c * (1.0 + 3.0 * 0.044715 * x * x) * dx;
    let dy = 0.5 * (1.0 + t) * dx + 0.5 * x * (1.0 - t * t) * du;
    (y, dy)
}

/// Analytic directional derivative of the batch loss along `dir`, via a
/// full forward-mode (JVP) pass in f64.  In LoRA mode (`lora = Some`)
/// the tangent rides the LoRA vector; in FT mode it rides the base.
/// Returns `(loss, d loss / d tau at tau = 0)` — the reference the
/// finite-difference cross-checks in `tests/transformer_train.rs`
/// compare `loss_dir` against.  Diagnostics only: f64 throughout, no
/// claim of bitwise agreement with the f32 training forward.
pub fn batch_dir_derivative(
    spec: &TransformerSpec,
    base: &[f32],
    lora: Option<&[f32]>,
    dir: &[f32],
    ids: &[i32],
    mask: &[f32],
    seq: usize,
    labels: &[i32],
) -> (f64, f64) {
    let d = spec.d_model;
    let dh = spec.head_dim();
    let r = spec.lora_rank;
    let c = spec.n_classes;
    let nb = labels.len();
    let fo = FtOffsets::new(spec);
    let lo_all = LoraOffsets::new(spec);
    assert_eq!(base.len(), fo.total, "base must match spec layout");

    let b64: Vec<f64> = base.iter().map(|&v| v as f64).collect();
    // the tangent lives on whichever vector is trainable
    let (l64, db64, dl64): (Vec<f64>, Vec<f64>, Vec<f64>) = match lora {
        Some(lv) => {
            assert_eq!(lv.len(), lo_all.total, "lora must match spec layout");
            assert_eq!(dir.len(), lo_all.total, "dir must match d_lora");
            (
                lv.iter().map(|&v| v as f64).collect(),
                vec![0.0; fo.total],
                dir.iter().map(|&v| v as f64).collect(),
            )
        }
        None => {
            assert_eq!(dir.len(), fo.total, "dir must match d_ft");
            (
                Vec::new(),
                dir.iter().map(|&v| v as f64).collect(),
                Vec::new(),
            )
        }
    };
    let lora_mode = lora.is_some();

    let sd = seq * d;
    let mut x = Dual::new(sd);
    let mut xn = Dual::new(sd);
    let mut q = Dual::new(sd);
    let mut k = Dual::new(sd);
    let mut v = Dual::new(sd);
    let mut attn = Dual::new(sd);
    let mut scores = Dual::new(seq);
    let mut tmp = Dual::new(d);
    let mut tmp2 = Dual::new(d);
    let mut tr = Dual::new(r);
    let mut hid = Dual::new(spec.d_ff);
    let mut logits = Dual::new(c);

    let scale64 = spec.lora_scale as f64;

    let mut loss = 0.0f64;
    let mut dloss = 0.0f64;
    for row in 0..nb {
        let rids = &ids[row * seq..(row + 1) * seq];
        let rmask = &mask[row * seq..(row + 1) * seq];
        // embeddings
        for t in 0..seq {
            let id = rids[t] as usize;
            for j in 0..d {
                x.x[t * d + j] = b64[fo.tok_emb + id * d + j] + b64[fo.pos_emb + t * d + j];
                x.dx[t * d + j] =
                    db64[fo.tok_emb + id * d + j] + db64[fo.pos_emb + t * d + j];
            }
        }
        for li in 0..spec.n_layers {
            let lo = fo.layers[li];
            let ll = lo_all.layers.get(li).copied();
            for t in 0..seq {
                ln_dual(
                    &x.x[t * d..(t + 1) * d],
                    &x.dx[t * d..(t + 1) * d],
                    &b64[lo.ln1_g..lo.ln1_g + d],
                    Some(&db64[lo.ln1_g..lo.ln1_g + d]),
                    &b64[lo.ln1_b..lo.ln1_b + d],
                    Some(&db64[lo.ln1_b..lo.ln1_b + d]),
                    &mut xn.x[t * d..(t + 1) * d],
                    &mut xn.dx[t * d..(t + 1) * d],
                );
            }
            for t in 0..seq {
                let xr = &xn.x[t * d..(t + 1) * d];
                let dxr = &xn.dx[t * d..(t + 1) * d];
                for (w0, b0, buf) in [
                    (lo.wq, lo.bq, &mut q),
                    (lo.wk, lo.bk, &mut k),
                    (lo.wv, lo.bv, &mut v),
                ] {
                    mm_dual(
                        xr,
                        dxr,
                        &b64[w0..w0 + d * d],
                        Some(&db64[w0..w0 + d * d]),
                        Some((&b64[b0..b0 + d], Some(&db64[b0..b0 + d]))),
                        &mut buf.x[t * d..(t + 1) * d],
                        &mut buf.dx[t * d..(t + 1) * d],
                    );
                }
                if lora_mode {
                    if let Some(ll) = ll {
                        for (pair, buf) in
                            [(ll.q, &mut q), (ll.k, &mut k), (ll.v, &mut v)]
                        {
                            if let Some((ao, bo)) = pair {
                                lora_dual(
                                    xr, dxr, ao, bo, r, scale64, &l64, &dl64, &mut tr, &mut tmp,
                                );
                                for j in 0..d {
                                    buf.x[t * d + j] += tmp.x[j];
                                    buf.dx[t * d + j] += tmp.dx[j];
                                }
                            }
                        }
                    }
                }
            }
            // attention JVP
            let denom = (dh as f64).sqrt();
            for hh in 0..spec.n_heads {
                let hd0 = hh * dh;
                for t in 0..seq {
                    for j in 0..seq {
                        let mut sc = 0.0;
                        let mut dsc = 0.0;
                        for cc in 0..dh {
                            let qq = q.x[t * d + hd0 + cc];
                            let kk = k.x[j * d + hd0 + cc];
                            sc += qq * kk;
                            dsc +=
                                q.dx[t * d + hd0 + cc] * kk + qq * k.dx[j * d + hd0 + cc];
                        }
                        sc /= denom;
                        dsc /= denom;
                        sc += (1.0 - rmask[j] as f64) * NEG_INF as f64;
                        if spec.causal && j > t {
                            sc = NEG_INF as f64;
                            dsc = 0.0;
                        }
                        scores.x[j] = sc;
                        scores.dx[j] = dsc;
                    }
                    let mut m = f64::NEG_INFINITY;
                    for j in 0..seq {
                        m = m.max(scores.x[j]);
                    }
                    let mut z = 0.0;
                    for j in 0..seq {
                        z += (scores.x[j] - m).exp();
                    }
                    let mut sdot = 0.0;
                    for j in 0..seq {
                        scores.x[j] = (scores.x[j] - m).exp() / z; // now probs
                        sdot += scores.x[j] * scores.dx[j];
                    }
                    for cc in 0..dh {
                        let mut o = 0.0;
                        let mut doo = 0.0;
                        for j in 0..seq {
                            let p = scores.x[j];
                            let dp = p * (scores.dx[j] - sdot);
                            o += p * v.x[j * d + hd0 + cc];
                            doo += dp * v.x[j * d + hd0 + cc] + p * v.dx[j * d + hd0 + cc];
                        }
                        attn.x[t * d + hd0 + cc] = o;
                        attn.dx[t * d + hd0 + cc] = doo;
                    }
                }
            }
            for t in 0..seq {
                mm_dual(
                    &attn.x[t * d..(t + 1) * d],
                    &attn.dx[t * d..(t + 1) * d],
                    &b64[lo.wo..lo.wo + d * d],
                    Some(&db64[lo.wo..lo.wo + d * d]),
                    Some((&b64[lo.bo..lo.bo + d], Some(&db64[lo.bo..lo.bo + d]))),
                    &mut tmp.x,
                    &mut tmp.dx,
                );
                if lora_mode {
                    if let Some(Some((ao, bo))) = ll.map(|l| l.o) {
                        lora_dual(
                            &attn.x[t * d..(t + 1) * d],
                            &attn.dx[t * d..(t + 1) * d],
                            ao,
                            bo,
                            r,
                            scale64,
                            &l64,
                            &dl64,
                            &mut tr,
                            &mut tmp2,
                        );
                        for j in 0..d {
                            tmp.x[j] += tmp2.x[j];
                            tmp.dx[j] += tmp2.dx[j];
                        }
                    }
                }
                for j in 0..d {
                    x.x[t * d + j] += tmp.x[j];
                    x.dx[t * d + j] += tmp.dx[j];
                }
            }
            for t in 0..seq {
                ln_dual(
                    &x.x[t * d..(t + 1) * d],
                    &x.dx[t * d..(t + 1) * d],
                    &b64[lo.ln2_g..lo.ln2_g + d],
                    Some(&db64[lo.ln2_g..lo.ln2_g + d]),
                    &b64[lo.ln2_b..lo.ln2_b + d],
                    Some(&db64[lo.ln2_b..lo.ln2_b + d]),
                    &mut xn.x[t * d..(t + 1) * d],
                    &mut xn.dx[t * d..(t + 1) * d],
                );
                mm_dual(
                    &xn.x[t * d..(t + 1) * d],
                    &xn.dx[t * d..(t + 1) * d],
                    &b64[lo.wf1..lo.wf1 + d * spec.d_ff],
                    Some(&db64[lo.wf1..lo.wf1 + d * spec.d_ff]),
                    Some((
                        &b64[lo.bf1..lo.bf1 + spec.d_ff],
                        Some(&db64[lo.bf1..lo.bf1 + spec.d_ff]),
                    )),
                    &mut hid.x,
                    &mut hid.dx,
                );
                for e in 0..spec.d_ff {
                    let (y, dy) = gelu_dual(hid.x[e], hid.dx[e]);
                    hid.x[e] = y;
                    hid.dx[e] = dy;
                }
                mm_dual(
                    &hid.x,
                    &hid.dx,
                    &b64[lo.wf2..lo.wf2 + spec.d_ff * d],
                    Some(&db64[lo.wf2..lo.wf2 + spec.d_ff * d]),
                    Some((&b64[lo.bf2..lo.bf2 + d], Some(&db64[lo.bf2..lo.bf2 + d]))),
                    &mut tmp.x,
                    &mut tmp.dx,
                );
                for j in 0..d {
                    x.x[t * d + j] += tmp.x[j];
                    x.dx[t * d + j] += tmp.dx[j];
                }
            }
        }
        for t in 0..seq {
            ln_dual(
                &x.x[t * d..(t + 1) * d],
                &x.dx[t * d..(t + 1) * d],
                &b64[fo.final_ln_g..fo.final_ln_g + d],
                Some(&db64[fo.final_ln_g..fo.final_ln_g + d]),
                &b64[fo.final_ln_b..fo.final_ln_b + d],
                Some(&db64[fo.final_ln_b..fo.final_ln_b + d]),
                &mut xn.x[t * d..(t + 1) * d],
                &mut xn.dx[t * d..(t + 1) * d],
            );
        }
        let pt = pooled_position(spec.pool, rmask).min(seq - 1);
        if lora_mode {
            mm_dual(
                &xn.x[pt * d..(pt + 1) * d],
                &xn.dx[pt * d..(pt + 1) * d],
                &l64[lo_all.head_w..lo_all.head_w + d * c],
                Some(&dl64[lo_all.head_w..lo_all.head_w + d * c]),
                Some((
                    &l64[lo_all.head_b..lo_all.head_b + c],
                    Some(&dl64[lo_all.head_b..lo_all.head_b + c]),
                )),
                &mut logits.x,
                &mut logits.dx,
            );
        } else {
            mm_dual(
                &xn.x[pt * d..(pt + 1) * d],
                &xn.dx[pt * d..(pt + 1) * d],
                &b64[fo.head_w..fo.head_w + d * c],
                Some(&db64[fo.head_w..fo.head_w + d * c]),
                Some((
                    &b64[fo.head_b..fo.head_b + c],
                    Some(&db64[fo.head_b..fo.head_b + c]),
                )),
                &mut logits.x,
                &mut logits.dx,
            );
        }
        // cross-entropy JVP: dL = sum_j (softmax_j - onehot_j) dz_j
        let lab = labels[row] as usize;
        let mut m = f64::NEG_INFINITY;
        for j in 0..c {
            m = m.max(logits.x[j]);
        }
        let mut z = 0.0;
        for j in 0..c {
            z += (logits.x[j] - m).exp();
        }
        loss += m + z.ln() - logits.x[lab];
        for j in 0..c {
            let p = (logits.x[j] - m).exp() / z;
            let ind = if j == lab { 1.0 } else { 0.0 };
            dloss += (p - ind) * logits.dx[j];
        }
    }
    (loss / nb.max(1) as f64, dloss / nb.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::views;

    fn tiny() -> TransformerSpec {
        TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, Pool::Cls, 2).unwrap()
    }

    #[test]
    fn layouts_match_python_names_and_sizes() {
        let s = tiny();
        let ft = s.ft_layout();
        assert_eq!(ft[0].name, "tok_emb");
        assert_eq!(ft[0].shape, vec![64, 16]);
        assert_eq!(ft[1].name, "pos_emb");
        assert_eq!(ft[2].name, "layer0.ln1.g");
        assert_eq!(ft.last().unwrap().name, "head.b");
        let total: usize = ft.iter().map(|l| l.len).sum();
        assert_eq!(total, s.d_ft());

        let lora = s.lora_layout();
        // reference q+v targets: per layer a/b for q then v
        assert_eq!(lora[0].name, "layer0.lora_q.a");
        assert_eq!(lora[0].shape, vec![16, 2]);
        assert_eq!(lora[1].name, "layer0.lora_q.b");
        assert_eq!(lora[1].shape, vec![2, 16]);
        assert_eq!(lora[2].name, "layer0.lora_v.a");
        assert_eq!(lora[3].name, "layer0.lora_v.b");
        assert_eq!(lora[lora.len() - 2].name, "head.w");
        assert_eq!(lora.last().unwrap().name, "head.b");
        let total: usize = lora.iter().map(|l| l.len).sum();
        assert_eq!(total, s.d_lora());
        // model::views slices both flat vectors by these layouts unchanged
        let base = s.init_base(1);
        assert!(views(&base, &ft).is_ok());
        let lv = s.init_lora(1, Some(&base));
        assert!(views(&lv, &lora).is_ok());
    }

    #[test]
    fn init_is_deterministic_with_reference_structure() {
        let s = tiny();
        let a = s.init_base(7);
        assert_eq!(a, s.init_base(7));
        assert_ne!(a, s.init_base(8));
        let fo = FtOffsets::new(&s);
        // layernorm gains 1, biases 0
        assert!(a[fo.layers[0].ln1_g..fo.layers[0].ln1_g + 16].iter().all(|&v| v == 1.0));
        assert!(a[fo.layers[0].bq..fo.layers[0].bq + 16].iter().all(|&v| v == 0.0));
        assert!(a[fo.head_b..fo.head_b + 2].iter().all(|&v| v == 0.0));
        // weights are small but nonzero
        assert!(a[fo.layers[0].wq..fo.layers[0].wq + 256].iter().any(|&v| v != 0.0));

        let l = s.init_lora(7, Some(&a));
        assert_eq!(l, s.init_lora(7, Some(&a)));
        let lo = LoraOffsets::new(&s);
        // B factors zero (the delta starts at 0), head copied from base
        let (_, qb) = lo.layers[0].q.unwrap();
        assert!(l[qb..qb + 32].iter().all(|&v| v == 0.0));
        assert_eq!(&l[lo.head_w..lo.head_w + 32], &a[fo.head_w..fo.head_w + 32]);
    }

    #[test]
    fn lora_targets_parse_and_layout_order() {
        assert_eq!(LoraTargets::parse("qv").unwrap(), LoraTargets::qv());
        assert_eq!(LoraTargets::parse("v,q").unwrap(), LoraTargets::qv());
        let all = LoraTargets::parse("qkvo").unwrap();
        assert_eq!(all.label(), "qkvo");
        assert!(LoraTargets::parse("").is_err());
        assert!(LoraTargets::parse("x").is_err());
        let mut s = tiny();
        s.lora_targets = all;
        let lora = s.lora_layout();
        assert_eq!(lora[0].name, "layer0.lora_q.a");
        assert_eq!(lora[2].name, "layer0.lora_k.a");
        assert_eq!(lora[4].name, "layer0.lora_v.a");
        assert_eq!(lora[6].name, "layer0.lora_o.a");
        assert_eq!(s.d_lora(), 2 * 4 * 2 * 16 * 2 + 16 * 2 + 2);
    }

    #[test]
    fn forward_is_deterministic_and_zero_lora_delta_changes_only_head() {
        let s = tiny();
        let base = s.init_base(3);
        let ids = [1i32, 5, 9, 2];
        let mask = [1.0f32, 1.0, 1.0, 1.0];
        let mut st1 = TransformerState::new(&s);
        let mut st2 = TransformerState::new(&s);
        let a = forward_example(&s, &base, None, &ids, &mask, &mut st1).to_vec();
        let b = forward_example(&s, &base, None, &ids, &mask, &mut st2).to_vec();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // B = 0 => adapter delta is exactly 0; with the head copied from
        // the base, LoRA-mode logits equal FT-mode logits bit for bit
        let lv = s.init_lora(3, Some(&base));
        let c = forward_example(&s, &base, Some(&lv), &ids, &mask, &mut st1).to_vec();
        for (x, y) in a.iter().zip(c.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn padded_positions_do_not_affect_cls_logits() {
        let s = tiny();
        let base = s.init_base(11);
        let mut st = TransformerState::new(&s);
        let ids_short = [1i32, 7, 3];
        let mask_short = [1.0f32, 1.0, 1.0];
        let a = forward_example(&s, &base, None, &ids_short, &mask_short, &mut st).to_vec();
        // same example padded out with ids that must not leak through
        let ids_pad = [1i32, 7, 3, 63, 62];
        let mask_pad = [1.0f32, 1.0, 1.0, 0.0, 0.0];
        let b = forward_example(&s, &base, None, &ids_pad, &mask_pad, &mut st).to_vec();
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn causal_masking_blocks_future_tokens() {
        let mut s = tiny();
        s.causal = true;
        s.pool = Pool::Last;
        let base = s.init_base(5);
        let mut st = TransformerState::new(&s);
        // pooled position is 2 (3 valid tokens); the masked-off position 3
        // carries different ids in the two calls and must not leak
        let a = forward_example(&s, &base, None, &[1, 4, 9, 13], &[1.0, 1.0, 1.0, 0.0], &mut st)
            .to_vec();
        let b = forward_example(&s, &base, None, &[1, 4, 9, 44], &[1.0, 1.0, 1.0, 0.0], &mut st)
            .to_vec();
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn jvp_matches_finite_difference_on_lora_subspace() {
        let s = tiny();
        let base = s.init_base(17);
        let mut lv = s.init_lora(17, Some(&base));
        // move off the B = 0 init so the adapters actually contribute
        let mut rng = crate::rng::Rng::new(23);
        for vv in lv.iter_mut() {
            *vv += 0.05 * rng.normal() as f32;
        }
        let mut dir = vec![0.0f32; s.d_lora()];
        rng.fill_normal(&mut dir);
        let ids = [1i32, 3, 8, 21];
        let mask = [1.0f32, 1.0, 1.0, 1.0];
        let labels = [0i32, 1];
        let all_ids = [ids, [1, 9, 2, 4]].concat();
        let all_mask = [mask, mask].concat();
        let (loss, dd) = batch_dir_derivative(
            &s, &base, Some(&lv), &dir, &all_ids, &all_mask, 4, &labels,
        );
        assert!(loss.is_finite());
        // central finite difference of the f64 JVP loss itself
        let eps = 1e-3f32;
        let perturb = |scale: f32| {
            let lp: Vec<f32> =
                lv.iter().zip(dir.iter()).map(|(a, b)| a + scale * b).collect();
            let zero = vec![0.0f32; s.d_lora()];
            batch_dir_derivative(&s, &base, Some(&lp), &zero, &all_ids, &all_mask, 4, &labels).0
        };
        let fd = (perturb(eps) - perturb(-eps)) / (2.0 * eps as f64);
        let denom = dd.abs().max(1e-8);
        assert!(
            (fd - dd).abs() / denom < 2e-2,
            "analytic {dd} vs fd {fd}"
        );
    }
}
