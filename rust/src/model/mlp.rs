//! Forward-only MLP classifier core (DESIGN.md §12).
//!
//! The first *network* workload of the crate: a configurable multi-layer
//! perceptron with tanh/relu hidden activations and a softmax
//! cross-entropy head.  The trainable vector is flat f32, laid out via
//! the same [`LayoutEntry`] manifest scheme the transformer models use —
//! so [`crate::model::views`] and `.zock` checkpoints apply unchanged.
//!
//! Everything here is *per-example sequential, fixed-order* arithmetic:
//! one forward (or backward) pass touches one example at a time and
//! accumulates the batch loss in data-row order through an f64
//! accumulator.  The MLP oracle parallelizes over *probes*, never inside
//! one forward, so losses are bitwise identical for any worker count —
//! the same determinism contract the closed-form oracles carry
//! (DESIGN.md §9).
//!
//! The analytic [`batch_grad`] backprop exists for diagnostics and the
//! finite-difference cross-checks in `tests/mlp_train.rs`; the training
//! path itself is forward-only.

use anyhow::{anyhow, bail, Result};

use crate::config::LayoutEntry;
use crate::tensor::gemm::{self, GemmMode};
use crate::tensor::{dot_lanes, Matrix};

/// Hidden-layer nonlinearity of the MLP.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// tanh (smooth; the finite-difference reference activation).
    Tanh,
    /// rectified linear unit.
    Relu,
}

impl Activation {
    /// Parse from a CLI/config string ("tanh" | "relu").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "tanh" => Ok(Activation::Tanh),
            "relu" => Ok(Activation::Relu),
            other => bail!("unknown activation '{other}' (tanh|relu)"),
        }
    }

    /// Canonical lowercase name.
    pub fn label(&self) -> &'static str {
        match self {
            Activation::Tanh => "tanh",
            Activation::Relu => "relu",
        }
    }

    /// The nonlinearity itself.
    #[inline]
    pub fn apply(&self, z: f32) -> f32 {
        match self {
            Activation::Tanh => z.tanh(),
            Activation::Relu => {
                if z > 0.0 {
                    z
                } else {
                    0.0
                }
            }
        }
    }

    /// Derivative expressed through the *post-activation* value `a`
    /// (tanh': 1 - a², relu': 1 for a > 0) — so backprop needs no stored
    /// pre-activations.
    #[inline]
    pub fn deriv(&self, a: f32) -> f32 {
        match self {
            Activation::Tanh => 1.0 - a * a,
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Architecture of one MLP classifier: input width, hidden widths, class
/// count and hidden activation.  The flat parameter vector concatenates
/// per layer a `[out, in]` row-major weight matrix and an `[out]` bias.
#[derive(Clone, Debug, PartialEq)]
pub struct MlpSpec {
    /// Input feature dimensionality.
    pub in_dim: usize,
    /// Hidden-layer widths, input to output order (may be empty: a
    /// softmax-regression head).
    pub hidden: Vec<usize>,
    /// Output classes (>= 2).
    pub n_classes: usize,
    /// Hidden-layer nonlinearity.
    pub activation: Activation,
}

impl MlpSpec {
    /// Validated constructor.
    pub fn new(
        in_dim: usize,
        hidden: Vec<usize>,
        n_classes: usize,
        activation: Activation,
    ) -> Result<Self> {
        if in_dim == 0 {
            bail!("mlp spec: in_dim must be positive");
        }
        if n_classes < 2 {
            bail!("mlp spec: need at least 2 classes, got {n_classes}");
        }
        if let Some(h) = hidden.iter().find(|&&h| h == 0) {
            bail!("mlp spec: hidden width must be positive, got {h}");
        }
        Ok(Self { in_dim, hidden, n_classes, activation })
    }

    /// Parse a `--hidden` CLI value ("64,64") into hidden widths.
    pub fn parse_hidden(s: &str) -> Result<Vec<usize>> {
        let mut out = Vec::new();
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let h: usize = tok
                .parse()
                .map_err(|e| anyhow!("--hidden '{tok}': {e}"))?;
            if h == 0 {
                bail!("--hidden: layer width must be positive");
            }
            out.push(h);
        }
        if out.is_empty() {
            bail!("--hidden '{s}': expected comma-separated layer widths (e.g. 64,64)");
        }
        Ok(out)
    }

    /// (fan_in, fan_out) of every layer, input to output.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::with_capacity(self.hidden.len() + 1);
        let mut prev = self.in_dim;
        for &h in &self.hidden {
            dims.push((prev, h));
            prev = h;
        }
        dims.push((prev, self.n_classes));
        dims
    }

    /// Flat-vector offset of every layer's parameter block.
    pub fn layer_offsets(&self) -> Vec<usize> {
        let mut out = Vec::new();
        let mut offset = 0usize;
        for (fan_in, fan_out) in self.layer_dims() {
            out.push(offset);
            offset += (fan_in + 1) * fan_out;
        }
        out
    }

    /// Total trainable dimensionality d.
    pub fn dim(&self) -> usize {
        self.layer_dims()
            .iter()
            .map(|(fan_in, fan_out)| (fan_in + 1) * fan_out)
            .sum()
    }

    /// The flat parameter vector's manifest layout — the same
    /// [`LayoutEntry`] scheme the transformer manifests use, so
    /// [`crate::model::views`] and `.zock` checkpoints apply to MLP
    /// parameters unchanged.
    pub fn layout(&self) -> Vec<LayoutEntry> {
        let mut out = Vec::new();
        let mut offset = 0usize;
        for (l, (fan_in, fan_out)) in self.layer_dims().into_iter().enumerate() {
            let wlen = fan_in * fan_out;
            out.push(LayoutEntry {
                name: format!("layer{l}.w"),
                shape: vec![fan_out, fan_in],
                offset,
                len: wlen,
            });
            offset += wlen;
            out.push(LayoutEntry {
                name: format!("layer{l}.b"),
                shape: vec![fan_out],
                offset,
                len: fan_out,
            });
            offset += fan_out;
        }
        out
    }

    /// Deterministic initialization: weights ~ N(0, 1/fan_in), biases
    /// zero.  A pure function of (spec, seed).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        // mix a fixed tag so the init stream never aliases the direction
        // samplers' streams at the same run seed
        let mut rng = crate::rng::Rng::new(seed ^ 0x4D4C_5001);
        let mut p = vec![0.0f32; self.dim()];
        let offsets = self.layer_offsets();
        for (l, (fan_in, fan_out)) in self.layer_dims().into_iter().enumerate() {
            let woff = offsets[l];
            let wlen = fan_in * fan_out;
            let scale = 1.0 / (fan_in as f32).sqrt();
            rng.fill_normal(&mut p[woff..woff + wlen]);
            for v in &mut p[woff..woff + wlen] {
                *v *= scale;
            }
        }
        p
    }

    /// Short identifier for labels ("mlp64x64/tanh").
    pub fn label(&self) -> String {
        let widths: Vec<String> = self.hidden.iter().map(|h| h.to_string()).collect();
        format!("mlp{}/{}", widths.join("x"), self.activation.label())
    }
}

/// Per-worker forward/backward scratch: one post-activation buffer and one
/// delta buffer per layer.  Workers of a parallel K-probe evaluation each
/// own one (allocated once per dispatch, reused across that worker's
/// probes).
pub struct MlpState {
    /// Post-activation values per layer (the last entry holds the logits).
    acts: Vec<Vec<f32>>,
    /// Backprop deltas per layer (same shapes as `acts`).
    deltas: Vec<Vec<f32>>,
    /// Whole-minibatch activations per layer (`[rows, fan_out]`) for the
    /// blocked batched forward — lazily grown to the largest batch this
    /// worker has seen, then reused allocation-free across probes.
    batch_acts: Vec<Vec<f32>>,
}

impl MlpState {
    /// Scratch sized for `spec`.
    pub fn new(spec: &MlpSpec) -> Self {
        let acts: Vec<Vec<f32>> = spec
            .layer_dims()
            .iter()
            .map(|(_, fan_out)| vec![0.0f32; *fan_out])
            .collect();
        let deltas = acts.clone();
        let batch_acts = acts.iter().map(|_| Vec::new()).collect();
        Self { acts, deltas, batch_acts }
    }

    /// The logits of the last forward pass.
    pub fn logits(&self) -> &[f32] {
        self.acts.last().expect("spec has at least one layer")
    }

    /// Grow the batched arena to `rows` examples (never shrinks).
    fn ensure_batch(&mut self, rows: usize, dims: &[(usize, usize)]) {
        if self.batch_acts.len() != dims.len() {
            self.batch_acts = dims.iter().map(|_| Vec::new()).collect();
        }
        for (buf, (_, fan_out)) in self.batch_acts.iter_mut().zip(dims.iter()) {
            if buf.len() < rows * fan_out {
                buf.resize(rows * fan_out, 0.0);
            }
        }
    }
}

/// One forward pass of a single example: fills `state`'s activations and
/// returns the logits.  Fixed evaluation order — per output unit one
/// [`dot_lanes`] reduction over the input (lane partials in the pinned
/// element-to-lane assignment, so scalar and wide modes agree bitwise) —
/// so results are a pure function of (spec, params, x).
pub fn forward_example<'a>(
    spec: &MlpSpec,
    params: &[f32],
    x: &[f32],
    state: &'a mut MlpState,
) -> &'a [f32] {
    debug_assert_eq!(params.len(), spec.dim(), "params must match spec.dim()");
    assert_eq!(x.len(), spec.in_dim, "feature row must be in_dim wide");
    let dims = spec.layer_dims();
    let n_layers = dims.len();
    let mut off = 0usize;
    for (l, (fan_in, fan_out)) in dims.into_iter().enumerate() {
        let w = &params[off..off + fan_in * fan_out];
        let b = &params[off + fan_in * fan_out..off + (fan_in + 1) * fan_out];
        off += (fan_in + 1) * fan_out;
        let (done, todo) = state.acts.split_at_mut(l);
        let input: &[f32] = if l == 0 { x } else { &done[l - 1] };
        let out = &mut todo[0];
        let last = l + 1 == n_layers;
        for j in 0..fan_out {
            let z = b[j] + dot_lanes(&w[j * fan_in..(j + 1) * fan_in], input) as f32;
            out[j] = if last { z } else { spec.activation.apply(z) };
        }
    }
    state.logits()
}

/// Softmax cross-entropy of one example from raw logits, computed in f64
/// via a max-shifted log-sum-exp (stable for both logit signs).
pub fn cross_entropy(logits: &[f32], label: i32) -> f64 {
    let lab = label as usize;
    debug_assert!(lab < logits.len(), "label must be a class index");
    let mut m = f64::NEG_INFINITY;
    for v in logits {
        m = m.max(*v as f64);
    }
    let mut sum = 0.0f64;
    for v in logits {
        sum += ((*v as f64) - m).exp();
    }
    m + sum.ln() - logits[lab] as f64
}

/// Mean softmax cross-entropy of a feature minibatch: examples evaluated
/// in data-row order, losses folded through one f64 accumulator — the
/// fixed term sequence that keeps every evaluation path (loss_dir,
/// vectorized loss_k, streamed loss_probes) bitwise identical.
pub fn batch_loss(
    spec: &MlpSpec,
    params: &[f32],
    feats: &Matrix,
    labels: &[i32],
    state: &mut MlpState,
) -> f64 {
    debug_assert_eq!(feats.rows, labels.len(), "one label per feature row");
    match gemm::effective_gemm_mode() {
        GemmMode::Reference => {
            let mut acc = 0.0f64;
            for r in 0..feats.rows {
                let logits = forward_example(spec, params, feats.row(r), state);
                acc += cross_entropy(logits, labels[r]);
            }
            acc / feats.rows.max(1) as f64
        }
        GemmMode::Blocked => batch_loss_blocked(spec, params, feats, labels, state),
    }
}

/// The batched blocked-engine evaluation of [`batch_loss`]: each layer
/// runs one [`gemm::gemm_rowmajor_lanes`] product over the whole
/// minibatch instead of per-example unit loops.  Bit-identical to the
/// reference path — every activation element is the same closed-form
/// `bias + dot_lanes(w_row, x_row)` expression (then the same
/// activation), only evaluated in a weight-row-reusing order; the CE
/// fold stays in data-row order.
fn batch_loss_blocked(
    spec: &MlpSpec,
    params: &[f32],
    feats: &Matrix,
    labels: &[i32],
    state: &mut MlpState,
) -> f64 {
    let m = feats.rows;
    if m == 0 {
        return 0.0;
    }
    debug_assert_eq!(params.len(), spec.dim(), "params must match spec.dim()");
    assert_eq!(feats.cols, spec.in_dim, "feature rows must be in_dim wide");
    let dims = spec.layer_dims();
    let n_layers = dims.len();
    state.ensure_batch(m, &dims);
    let mut off = 0usize;
    for (l, &(fan_in, fan_out)) in dims.iter().enumerate() {
        let w = &params[off..off + fan_in * fan_out];
        let b = &params[off + fan_in * fan_out..off + (fan_in + 1) * fan_out];
        off += (fan_in + 1) * fan_out;
        let (done, todo) = state.batch_acts.split_at_mut(l);
        let input: &[f32] = if l == 0 { &feats.data } else { &done[l - 1][..m * fan_in] };
        let out = &mut todo[0][..m * fan_out];
        gemm::gemm_rowmajor_lanes(input, m, fan_in, w, b, fan_out, out);
        if l + 1 != n_layers {
            out.iter_mut().for_each(|v| *v = spec.activation.apply(*v));
        }
    }
    let c = dims.last().expect("spec has at least one layer").1;
    let logits_all = state.batch_acts.last().expect("spec has at least one layer");
    let mut acc = 0.0f64;
    for (r, &label) in labels.iter().enumerate() {
        acc += cross_entropy(&logits_all[r * c..(r + 1) * c], label);
    }
    acc / m as f64
}

/// Analytic mean-loss gradient over a feature minibatch (standard
/// backprop; `grad` is overwritten, length [`MlpSpec::dim`]).  Returns the
/// batch loss.  Diagnostics only — the training path never calls this.
pub fn batch_grad(
    spec: &MlpSpec,
    params: &[f32],
    feats: &Matrix,
    labels: &[i32],
    grad: &mut [f32],
    state: &mut MlpState,
) -> f64 {
    assert_eq!(grad.len(), spec.dim(), "grad must be d long");
    debug_assert_eq!(feats.rows, labels.len(), "one label per feature row");
    grad.iter_mut().for_each(|g| *g = 0.0);
    let dims = spec.layer_dims();
    let offsets = spec.layer_offsets();
    let n_layers = dims.len();
    let inv_n = 1.0 / feats.rows.max(1) as f32;
    let mut acc = 0.0f64;
    for r in 0..feats.rows {
        forward_example(spec, params, feats.row(r), state);
        let label = labels[r] as usize;
        // head delta = softmax(logits) - onehot(label)
        {
            let logits = &state.acts[n_layers - 1];
            acc += cross_entropy(logits, labels[r]);
            let mut m = f64::NEG_INFINITY;
            for v in logits.iter() {
                m = m.max(*v as f64);
            }
            let mut sum = 0.0f64;
            for v in logits.iter() {
                sum += ((*v as f64) - m).exp();
            }
            let delta = &mut state.deltas[n_layers - 1];
            for (j, v) in logits.iter().enumerate() {
                let p = ((((*v as f64) - m).exp()) / sum) as f32;
                delta[j] = if j == label { p - 1.0 } else { p };
            }
        }
        // walk the layers backwards: accumulate this example's weight and
        // bias gradients, then push the delta one layer down
        for l in (0..n_layers).rev() {
            let (fan_in, fan_out) = dims[l];
            let woff = offsets[l];
            let boff = woff + fan_in * fan_out;
            {
                let input: &[f32] =
                    if l == 0 { feats.row(r) } else { &state.acts[l - 1] };
                let delta = &state.deltas[l];
                for j in 0..fan_out {
                    let dj = delta[j] * inv_n;
                    let grow = &mut grad[woff + j * fan_in..woff + (j + 1) * fan_in];
                    for i in 0..fan_in {
                        grow[i] += dj * input[i];
                    }
                    grad[boff + j] += dj;
                }
            }
            if l > 0 {
                let w = &params[woff..boff];
                let (below, from) = state.deltas.split_at_mut(l);
                let dprev = &mut below[l - 1];
                let delta = &from[0];
                let a_prev = &state.acts[l - 1];
                for i in 0..fan_in {
                    let mut s = 0.0f32;
                    for j in 0..fan_out {
                        s += delta[j] * w[j * fan_in + i];
                    }
                    dprev[i] = s * spec.activation.deriv(a_prev[i]);
                }
            }
        }
    }
    acc / feats.rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::views;

    fn spec() -> MlpSpec {
        MlpSpec::new(5, vec![4, 3], 2, Activation::Tanh).unwrap()
    }

    #[test]
    fn dims_layout_and_offsets_agree() {
        let s = spec();
        // (5+1)*4 + (4+1)*3 + (3+1)*2 = 24 + 15 + 8 = 47
        assert_eq!(s.dim(), 47);
        assert_eq!(s.layer_dims(), vec![(5, 4), (4, 3), (3, 2)]);
        assert_eq!(s.layer_offsets(), vec![0, 24, 39]);
        let layout = s.layout();
        assert_eq!(layout.len(), 6);
        assert_eq!(layout[0].name, "layer0.w");
        assert_eq!(layout[0].shape, vec![4, 5]);
        assert_eq!(layout[5].name, "layer2.b");
        let total: usize = layout.iter().map(|l| l.len).sum();
        assert_eq!(total, s.dim());
        // model::views slices the flat vector by this layout unchanged
        let p = s.init_params(3);
        let v = views(&p, &layout).unwrap();
        assert_eq!(v.len(), 6);
        assert_eq!(v[1].data.len(), 4);
    }

    #[test]
    fn init_is_deterministic_and_biases_zero() {
        let s = spec();
        let a = s.init_params(9);
        let b = s.init_params(9);
        assert_eq!(a, b);
        assert_ne!(a, s.init_params(10));
        // layer0 bias block is zero
        assert!(a[20..24].iter().all(|&v| v == 0.0));
        // weights are not all zero
        assert!(a[..20].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn parse_hidden_roundtrip() {
        assert_eq!(MlpSpec::parse_hidden("64,64").unwrap(), vec![64, 64]);
        assert_eq!(MlpSpec::parse_hidden(" 8 , 4 ").unwrap(), vec![8, 4]);
        assert_eq!(MlpSpec::parse_hidden("16").unwrap(), vec![16]);
        assert!(MlpSpec::parse_hidden("").is_err());
        assert!(MlpSpec::parse_hidden("8,0").is_err());
        assert!(MlpSpec::parse_hidden("8,x").is_err());
    }

    #[test]
    fn activation_parse_and_shapes() {
        assert_eq!(Activation::parse("tanh").unwrap(), Activation::Tanh);
        assert_eq!(Activation::parse("relu").unwrap(), Activation::Relu);
        assert!(Activation::parse("gelu").is_err());
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert!((Activation::Tanh.deriv(0.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn cross_entropy_matches_closed_form() {
        // two logits (0, 0): loss = ln 2 for either label
        assert!((cross_entropy(&[0.0, 0.0], 0) - std::f64::consts::LN_2).abs() < 1e-12);
        // a confidently correct prediction has near-zero loss
        assert!(cross_entropy(&[20.0, -20.0], 0) < 1e-8);
        // shift invariance of the stable log-sum-exp
        let a = cross_entropy(&[1.0, -2.0, 0.5], 2);
        let b = cross_entropy(&[101.0, 98.0, 100.5], 2);
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }

    #[test]
    fn forward_is_deterministic_across_states() {
        let s = spec();
        let p = s.init_params(4);
        let x = [0.1f32, -0.2, 0.3, 0.0, 0.7];
        let mut st1 = MlpState::new(&s);
        let mut st2 = MlpState::new(&s);
        let l1 = forward_example(&s, &p, &x, &mut st1).to_vec();
        let l2 = forward_example(&s, &p, &x, &mut st2).to_vec();
        assert_eq!(l1.len(), 2);
        for (a, b) in l1.iter().zip(l2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_grad_returns_batch_loss() {
        let s = spec();
        let p = s.init_params(4);
        let feats = Matrix::from_vec(
            2,
            5,
            vec![0.1, -0.2, 0.3, 0.0, 0.7, -0.5, 0.2, 0.1, 0.9, -0.3],
        );
        let labels = [0, 1];
        let mut st = MlpState::new(&s);
        let loss = batch_loss(&s, &p, &feats, &labels, &mut st);
        let mut g = vec![0.0f32; s.dim()];
        let loss2 = batch_grad(&s, &p, &feats, &labels, &mut g, &mut st);
        assert_eq!(loss.to_bits(), loss2.to_bits());
        assert!(g.iter().any(|&v| v != 0.0));
    }
}
