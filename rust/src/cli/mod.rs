//! CLI argument parsing substrate (replaces clap).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and a generated usage
//! string.  [`CommandSpec`] declares one subcommand's surface — its
//! usage text plus the exact option/flag sets it accepts — giving every
//! subcommand its own `--help` and strict unknown-flag rejection.  Used
//! by `rust/src/main.rs` and the examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One subcommand's declared surface: summary + usage text and the
/// option/flag sets it accepts.  Shared global options (`--threads`,
/// `--store-dir`) are just listed in each accepting command's `opts`.
#[derive(Clone, Copy, Debug)]
pub struct CommandSpec {
    /// Subcommand token (`train`, `serve`, ...).
    pub name: &'static str,
    /// One-line description for the global usage listing.
    pub summary: &'static str,
    /// Multi-line usage text printed by `<command> --help`.
    pub usage: &'static str,
    /// Value-taking options this command accepts (without `--`).
    pub opts: &'static [&'static str],
    /// Boolean flags this command accepts (without `--`).
    pub flags: &'static [&'static str],
}

impl CommandSpec {
    /// The `--help` text for this subcommand.
    pub fn help(&self) -> String {
        format!("{} — {}\n\nusage:\n{}", self.name, self.summary, self.usage)
    }

    /// Strict validation against this command's declared surface:
    /// unknown options or flags are errors (`--help` is always known).
    pub fn validate(&self, args: &Args) -> Result<()> {
        let mut opts: Vec<&str> = self.opts.to_vec();
        opts.push("help");
        let mut flags: Vec<&str> = self.flags.to_vec();
        flags.push("help");
        args.reject_unknown(&opts, &flags).map_err(|e| {
            anyhow!("{}: {e} (see `{} --help`)", self.name, self.name)
        })
    }
}

/// Parsed command line: subcommand, options, flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The recognized first token, if any.
    pub subcommand: Option<String>,
    /// Non-option tokens in order.
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]).  `subcommands` lists the recognized
    /// first tokens; pass `&[]` for a flat CLI.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        subcommands: &[&str],
    ) -> Result<Self> {
        Self::parse_with_flags(argv, subcommands, &[])
    }

    /// Like [`Args::parse`] but with declared boolean flags: a token in
    /// `flags` never consumes the following argument as its value (so
    /// `--verbose positional` parses as flag + positional).
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        argv: I,
        subcommands: &[&str],
        declared_flags: &[&str],
    ) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if subcommands.contains(&first.as_str()) {
                out.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // "--": everything after is positional
                    out.positional.extend(iter.by_ref());
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if declared_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    // value-taking if next token isn't another option
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options
                                .entry(stripped.to_string())
                                .or_default()
                                .push(v);
                        }
                        _ => out.flags.push(stripped.to_string()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process argv (excluding argv\[0\]).
    pub fn from_env(subcommands: &[&str]) -> Result<Self> {
        Self::parse(std::env::args().skip(1), subcommands)
    }

    /// [`Args::from_env`] with declared boolean flags (see
    /// [`Args::parse_with_flags`]): a declared flag never swallows the
    /// following token as its value.
    pub fn from_env_with_flags(subcommands: &[&str], flags: &[&str]) -> Result<Self> {
        Self::parse_with_flags(std::env::args().skip(1), subcommands, flags)
    }

    /// True if the boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last value of `--name` (last occurrence wins).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// All values for a repeatable option (e.g. `--set a=1 --set b=2`).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// Like [`Args::get`] with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Error if the option is absent.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Typed accessor: f64 with default.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("--{name} '{s}': {e}")),
        }
    }

    /// Typed accessor: usize with default.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("--{name} '{s}': {e}")),
        }
    }

    /// Typed accessor: u64 with default.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("--{name} '{s}': {e}")),
        }
    }

    /// Error on unknown options (call after reading everything you accept).
    pub fn reject_unknown(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) && !known_opts.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_with_flags(
            s.split_whitespace().map(String::from),
            &["train", "toy"],
            &["verbose"],
        )
        .unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model roberta_mini --lr=1e-6 --verbose pos1");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("roberta_mini"));
        assert_eq!(a.get("lr"), Some("1e-6"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = parse("train --set a=1 --set b=2");
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.get("set"), Some("b=2"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("toy --steps 50 --gamma 2.5");
        assert_eq!(a.get_usize("steps", 1).unwrap(), 50);
        assert_eq!(a.get_f64("gamma", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("absent", 9.0).unwrap(), 9.0);
        assert!(a.get_f64("steps", 0.0).is_ok());
        let bad = parse("toy --steps abc");
        assert!(bad.get_usize("steps", 1).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("train -- --not-an-option");
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let a = parse("train --modle x");
        assert!(a.reject_unknown(&["model"], &[]).is_err());
    }
}
