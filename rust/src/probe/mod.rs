//! Probe representation: how one step's K x d direction matrix is stored,
//! evaluated and combined (DESIGN.md §10).
//!
//! Estimators no longer own a probe buffer; they own a [`ProbeSource`]
//! with two implementations:
//!
//! * [`MaterializedProbes`] — the reference path: the K x d matrix lives
//!   in one tracked buffer, filled by the sampler each step.  O(K d)
//!   probe state.
//! * [`StreamedProbes`] — MeZO-style seed replay generalized to the
//!   batched K-probe pipeline: no matrix is ever held.  Every consumer
//!   regenerates the probe values it needs, one column shard at a time,
//!   straight from the sampler's per-(seed, step, shard) RNG cells
//!   ([`DirectionSampler::fill_row_range`]).  Probe state is
//!   O(K · shard_len) *per worker* — one shard block — which is what
//!   unlocks d >= 2^24 runs the materialized path cannot reach.
//!
//! The contract between the two is **bitwise identity**: the streamed
//! path replays the exact RNG cells the materialized fill would have
//! written, and every consumer (fused `loss_k`-style evaluation, the
//! combine kernels, the LDSD policy update) applies per-element arithmetic
//! in the same order.  A probe is regenerated once for the forward
//! evaluations and once for the update passes (the "replay twice" cost:
//! ~2x sampling compute traded for the O(K d) buffer).
//!
//! Probe-state buffers allocate through [`crate::metrics::TrackedBuf`], so
//! the global [`crate::metrics::probe_tracker`] measures real per-trial
//! peaks — the acceptance test pins that streaming never allocates a
//! K x d buffer.

use anyhow::{bail, Result};

use crate::exec::ExecContext;
use crate::metrics::TrackedBuf;
use crate::sampler::DirectionSampler;
use crate::tensor::{axpy_k_ctx, probe_combine_ctx, replay_axpy};

/// A boxed direction sampler as owned by a probe source (`Sync` because
/// streamed consumers replay rows from worker threads).
pub type BoxedSampler = Box<dyn DirectionSampler + Send + Sync>;

/// How one step's probe matrix is stored.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProbeStorage {
    /// Decide by memory budget: streamed when the K x d matrix would
    /// exceed the budget (256 MiB, `ZO_PROBE_BUDGET_MB` overrides) and the
    /// sampler supports seed replay; materialized otherwise.
    #[default]
    Auto,
    /// Hold the full K x d matrix (the reference path).
    Materialized,
    /// Regenerate probe shards on demand from RNG cells (seed replay).
    Streamed,
}

impl ProbeStorage {
    /// Parse from a CLI string ("auto" | "materialized" | "streamed").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(ProbeStorage::Auto),
            "materialized" => Ok(ProbeStorage::Materialized),
            "streamed" => Ok(ProbeStorage::Streamed),
            other => bail!("unknown probe storage '{other}' (auto|materialized|streamed)"),
        }
    }

    /// Label fragment for tables and bench rows.
    pub fn label(&self) -> &'static str {
        match self {
            ProbeStorage::Auto => "auto",
            ProbeStorage::Materialized => "materialized",
            ProbeStorage::Streamed => "streamed",
        }
    }

    /// The `ZO_PROBE_STORAGE` environment override, if set.  CI forces
    /// `streamed` through this to run the whole suite on the replay path,
    /// so an *invalid* value panics rather than silently un-forcing the
    /// suite (a typo must fail loudly, not pass greenly on the default
    /// path).
    pub fn from_env() -> Option<Self> {
        std::env::var("ZO_PROBE_STORAGE").ok().map(|v| {
            Self::parse(&v).unwrap_or_else(|e| panic!("ZO_PROBE_STORAGE: {e}"))
        })
    }

    /// Resolve `Auto` against the memory budget and the sampler's replay
    /// capability.  Explicit choices pass through unchanged (an explicit
    /// `Streamed` over a non-replayable sampler is rejected later, in
    /// [`build_source`]).
    pub fn resolve(self, d: usize, k: usize, replay_ok: bool) -> ProbeStorage {
        match self {
            ProbeStorage::Auto => {
                let matrix_bytes = k.saturating_mul(d).saturating_mul(4);
                if replay_ok && matrix_bytes > auto_budget_bytes() {
                    ProbeStorage::Streamed
                } else {
                    ProbeStorage::Materialized
                }
            }
            other => other,
        }
    }
}

/// Probe-matrix budget for [`ProbeStorage::Auto`]: 256 MiB unless
/// `ZO_PROBE_BUDGET_MB` overrides it.  An unparseable override panics —
/// a silently-ignored budget would flip Auto runs onto the wrong storage
/// without a trace.
pub fn auto_budget_bytes() -> usize {
    match std::env::var("ZO_PROBE_BUDGET_MB") {
        Ok(v) => {
            let mb: usize = v
                .parse()
                .unwrap_or_else(|e| panic!("ZO_PROBE_BUDGET_MB '{v}': {e}"));
            mb.saturating_mul(1 << 20)
        }
        Err(_) => 256 << 20,
    }
}

/// How presented probe rows map onto sampler rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeLayout {
    /// Row i is sampler row i (K sampler rows).
    Direct,
    /// Two presented rows `[v; -v]` derived from one sampler row — the
    /// central-difference pair.
    CentralPair,
}

/// One step's K x d probe matrix, abstracted over storage.
///
/// `advance` resamples (no oracle calls); consumers then read the rows
/// through [`ProbeSource::dirs`] (materialized fast path), a streaming
/// [`ProbeSource::cursor`], or the fused combine entry points.  All paths
/// are bitwise identical across storage modes and worker counts.
pub trait ProbeSource: Send + Sync {
    /// Presented probe rows K.
    fn k(&self) -> usize;

    /// Row length d.
    fn dim(&self) -> usize;

    /// Sample the next step's probes (no oracle calls).
    fn advance(&mut self);

    /// The materialized row-major K x d matrix, if this source holds one.
    fn dirs(&self) -> Option<&[f32]>;

    /// A per-worker cursor over this step's rows (column order).
    fn cursor(&self) -> ProbeCursor<'_>;

    /// `g = sum_i w[i] * row_i` (g is overwritten).
    fn combine(&self, w: &[f32], g: &mut [f32]);

    /// `y += sum_i w[i] * row_i`.
    fn axpy_rows(&self, w: &[f32], y: &mut [f32]);

    /// `out = c * row_i`.
    fn scaled_row(&self, i: usize, c: f32, out: &mut [f32]);

    /// Feed the step's probe losses back to the sampler's policy
    /// (Algorithm 2 lines 6/8); no-op for policy-free samplers and
    /// derived layouts.
    fn observe(&mut self, losses: &[f64]);

    /// Probe-representation bytes held across steps: the K x d matrix for
    /// materialized, zero for streamed (its transient per-worker scratch
    /// is bounded by (K + 1) * min(shard_len, d) floats per worker and
    /// measured by [`crate::metrics::probe_tracker`]).
    fn probe_state_bytes(&self) -> usize;

    /// The underlying direction sampler (diagnostics).
    fn sampler(&self) -> &dyn DirectionSampler;

    /// Mutable access to the underlying sampler (snapshot restore: the
    /// trainer reinstates the RNG step label and policy mean through it).
    fn sampler_mut(&mut self) -> &mut dyn DirectionSampler;

    /// Install the execution context (cascades to the sampler).
    fn set_exec(&mut self, ctx: ExecContext);

    /// Storage label ("materialized" | "streamed").
    fn label(&self) -> &'static str;
}

/// Per-worker streaming access to one step's probe rows.
///
/// Obtained from [`ProbeSource::cursor`]; each worker of a parallel
/// evaluation holds its own cursor (the replayed variant owns the shard
/// scratch regeneration writes into).
pub enum ProbeCursor<'a> {
    /// Rows borrowed from a materialized K x d matrix: `visit_row` yields
    /// the whole row as one piece, no copies.
    Borrowed {
        /// The row-major K x d matrix.
        dirs: &'a [f32],
        /// Row length d.
        d: usize,
    },
    /// Rows replayed shard-by-shard from the sampler's RNG cells.
    Replayed {
        /// The streamed source rows are replayed from.
        src: &'a StreamedProbes,
        /// Piece buffer handed to the visitor (one column shard).
        piece: TrackedBuf,
        /// Substream regeneration staging (one RNG cell).
        stage: TrackedBuf,
    },
}

impl ProbeCursor<'_> {
    /// Visit the pieces of probe row `i` in column order:
    /// `f(col0, values)`.  Running accumulations over the pieces are
    /// bitwise independent of piece boundaries, so borrowed (one piece)
    /// and replayed (one piece per column shard) cursors produce identical
    /// results.
    pub fn visit_row(&mut self, i: usize, f: &mut dyn FnMut(usize, &[f32])) {
        match self {
            ProbeCursor::Borrowed { dirs, d } => f(0, &dirs[i * *d..(i + 1) * *d]),
            ProbeCursor::Replayed { src, piece, stage } => {
                let d = src.d;
                let sl = src.exec.shard_len();
                let mut c0 = 0usize;
                while c0 < d {
                    let len = sl.min(d - c0);
                    src.fill_piece(i, c0, &mut piece[..len], stage);
                    f(c0, &piece[..len]);
                    c0 += len;
                }
            }
        }
    }
}

/// The reference probe representation: the K x d matrix is held in one
/// tracked buffer and refilled by the sampler each step.
pub struct MaterializedProbes {
    sampler: BoxedSampler,
    dirs: TrackedBuf,
    k: usize,
    d: usize,
    layout: ProbeLayout,
    exec: ExecContext,
}

impl MaterializedProbes {
    /// Build for `k` presented rows over `sampler`.  For
    /// [`ProbeLayout::CentralPair`], `k` must be 2.
    pub fn new(sampler: BoxedSampler, layout: ProbeLayout, k: usize) -> Self {
        assert!(k >= 1);
        if layout == ProbeLayout::CentralPair {
            assert_eq!(k, 2, "central layout presents exactly [v; -v]");
        }
        let d = sampler.dim();
        Self {
            sampler,
            dirs: TrackedBuf::zeroed(k * d),
            k,
            d,
            layout,
            exec: ExecContext::serial(),
        }
    }
}

impl ProbeSource for MaterializedProbes {
    fn k(&self) -> usize {
        self.k
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn advance(&mut self) {
        match self.layout {
            ProbeLayout::Direct => self.sampler.sample(&mut self.dirs, self.k),
            ProbeLayout::CentralPair => {
                let d = self.d;
                let (v, neg) = self.dirs.split_at_mut(d);
                self.sampler.sample(v, 1);
                let v_ro: &[f32] = v;
                self.exec.for_each_shard_mut(neg, |_, start, chunk| {
                    for (i, n) in chunk.iter_mut().enumerate() {
                        *n = -v_ro[start + i];
                    }
                });
            }
        }
    }

    fn dirs(&self) -> Option<&[f32]> {
        Some(&self.dirs[..])
    }

    fn cursor(&self) -> ProbeCursor<'_> {
        ProbeCursor::Borrowed { dirs: &self.dirs[..], d: self.d }
    }

    fn combine(&self, w: &[f32], g: &mut [f32]) {
        assert_eq!(w.len(), self.k);
        probe_combine_ctx(&self.exec, &self.dirs, self.d, w, g);
    }

    fn axpy_rows(&self, w: &[f32], y: &mut [f32]) {
        assert_eq!(w.len(), self.k);
        axpy_k_ctx(&self.exec, w, &self.dirs, y);
    }

    fn scaled_row(&self, i: usize, c: f32, out: &mut [f32]) {
        assert!(i < self.k);
        assert_eq!(out.len(), self.d);
        let row = &self.dirs[i * self.d..(i + 1) * self.d];
        self.exec.for_each_shard_mut(out, |_, start, gb| {
            for (j, gi) in gb.iter_mut().enumerate() {
                *gi = c * row[start + j];
            }
        });
    }

    fn observe(&mut self, losses: &[f64]) {
        if self.layout == ProbeLayout::Direct {
            self.sampler.observe(&self.dirs, losses, self.k);
        }
    }

    fn probe_state_bytes(&self) -> usize {
        self.dirs.len() * 4
    }

    fn sampler(&self) -> &dyn DirectionSampler {
        &*self.sampler
    }

    fn sampler_mut(&mut self) -> &mut dyn DirectionSampler {
        &mut *self.sampler
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.sampler.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn label(&self) -> &'static str {
        "materialized"
    }
}

/// Seed-replay probe representation: no matrix is held; every consumer
/// regenerates the shards it needs from the sampler's RNG cells, at most
/// one (K + 1)-shard block per worker at a time.
pub struct StreamedProbes {
    sampler: BoxedSampler,
    k: usize,
    d: usize,
    layout: ProbeLayout,
    exec: ExecContext,
}

impl StreamedProbes {
    /// Build for `k` presented rows over a seed-replay sampler
    /// ([`DirectionSampler::supports_replay`] must hold).  For
    /// [`ProbeLayout::CentralPair`], `k` must be 2.
    pub fn new(sampler: BoxedSampler, layout: ProbeLayout, k: usize) -> Self {
        assert!(k >= 1);
        assert!(
            sampler.supports_replay(),
            "streamed probes need a seed-replay sampler ({} cannot replay)",
            sampler.name()
        );
        if layout == ProbeLayout::CentralPair {
            assert_eq!(k, 2, "central layout presents exactly [v; -v]");
        }
        let d = sampler.dim();
        Self { sampler, k, d, layout, exec: ExecContext::serial() }
    }

    /// Rows the sampler itself draws (the central pair derives both its
    /// rows from one sampler row).
    fn sampler_k(&self) -> usize {
        match self.layout {
            ProbeLayout::Direct => self.k,
            ProbeLayout::CentralPair => 1,
        }
    }

    /// Map a presented row to (sampler row, negate).
    fn map_row(&self, i: usize) -> (usize, bool) {
        match self.layout {
            ProbeLayout::Direct => (i, false),
            ProbeLayout::CentralPair => (0, i == 1),
        }
    }

    /// Per-worker row-piece scratch: one column shard, clamped to d so a
    /// small trainable subspace (LoRA: d well under `shard_len`) never
    /// over-allocates.
    fn piece_len(&self) -> usize {
        self.exec.shard_len().min(self.d.max(1))
    }

    /// Per-worker substream staging: [`DirectionSampler::fill_row_range`]
    /// needs `shard_len.min(k * d)` elements (the sampler's flat-buffer
    /// RNG cells cover `k * d` values total, so the final cell — and with
    /// `k * d < shard_len` the *only* cell — is that short).
    fn stage_len(&self) -> usize {
        self.exec.shard_len().min((self.sampler_k() * self.d).max(1))
    }

    /// Regenerate presented row `i`, columns `[col0, col0 + out.len())`.
    fn fill_piece(&self, i: usize, col0: usize, out: &mut [f32], stage: &mut [f32]) {
        let (srow, neg) = self.map_row(i);
        self.sampler.fill_row_range(self.sampler_k(), srow, col0, out, stage);
        if neg {
            for v in out.iter_mut() {
                *v = -*v;
            }
        }
    }
}

impl ProbeSource for StreamedProbes {
    fn k(&self) -> usize {
        self.k
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn advance(&mut self) {
        self.sampler.advance_step();
    }

    fn dirs(&self) -> Option<&[f32]> {
        None
    }

    fn cursor(&self) -> ProbeCursor<'_> {
        ProbeCursor::Replayed {
            src: self,
            piece: TrackedBuf::zeroed(self.piece_len()),
            stage: TrackedBuf::zeroed(self.stage_len()),
        }
    }

    fn combine(&self, w: &[f32], g: &mut [f32]) {
        assert_eq!(w.len(), self.k);
        assert_eq!(g.len(), self.d);
        let (pl, stl) = (self.piece_len(), self.stage_len());
        self.exec.for_each_shard_mut_scratch(
            g,
            || (TrackedBuf::zeroed(pl), TrackedBuf::zeroed(stl)),
            |scratch, _, start, gb| {
                let (row_buf, stage) = scratch;
                gb.iter_mut().for_each(|v| *v = 0.0);
                replay_axpy(w, row_buf, gb, |i, out| self.fill_piece(i, start, out, stage));
            },
        );
    }

    fn axpy_rows(&self, w: &[f32], y: &mut [f32]) {
        assert_eq!(w.len(), self.k);
        assert_eq!(y.len(), self.d);
        let (pl, stl) = (self.piece_len(), self.stage_len());
        self.exec.for_each_shard_mut_scratch(
            y,
            || (TrackedBuf::zeroed(pl), TrackedBuf::zeroed(stl)),
            |scratch, _, start, yb| {
                let (row_buf, stage) = scratch;
                replay_axpy(w, row_buf, yb, |i, out| self.fill_piece(i, start, out, stage));
            },
        );
    }

    fn scaled_row(&self, i: usize, c: f32, out: &mut [f32]) {
        assert!(i < self.k);
        assert_eq!(out.len(), self.d);
        let stl = self.stage_len();
        self.exec.for_each_shard_mut_scratch(
            out,
            || TrackedBuf::zeroed(stl),
            |stage, _, start, gb| {
                self.fill_piece(i, start, gb, stage);
                for v in gb.iter_mut() {
                    *v *= c;
                }
            },
        );
    }

    fn observe(&mut self, losses: &[f64]) {
        if self.layout == ProbeLayout::Direct {
            self.sampler.observe_replay(losses, self.k);
        }
    }

    fn probe_state_bytes(&self) -> usize {
        0
    }

    fn sampler(&self) -> &dyn DirectionSampler {
        &*self.sampler
    }

    fn sampler_mut(&mut self) -> &mut dyn DirectionSampler {
        &mut *self.sampler
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.sampler.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn label(&self) -> &'static str {
        "streamed"
    }
}

/// Build the probe source for `k` presented rows over `sampler`, resolving
/// [`ProbeStorage::Auto`] by the memory budget.  Errors when `Streamed` is
/// explicitly requested for a sampler that cannot seed-replay.
pub fn build_source(
    storage: ProbeStorage,
    sampler: BoxedSampler,
    layout: ProbeLayout,
    k: usize,
) -> Result<Box<dyn ProbeSource>> {
    let resolved = storage.resolve(sampler.dim(), k, sampler.supports_replay());
    match resolved {
        ProbeStorage::Streamed => {
            if !sampler.supports_replay() {
                bail!(
                    "probe storage 'streamed' needs a seed-replay sampler, but '{}' \
                     cannot replay (use --probe-storage materialized)",
                    sampler.name()
                );
            }
            Ok(Box::new(StreamedProbes::new(sampler, layout, k)))
        }
        _ => Ok(Box::new(MaterializedProbes::new(sampler, layout, k))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{GaussianSampler, LdsdConfig, LdsdSampler, SphereSampler};

    fn pair(
        d: usize,
        k: usize,
        layout: ProbeLayout,
        threads: usize,
        shard_len: usize,
    ) -> (MaterializedProbes, StreamedProbes) {
        let ctx = ExecContext::new(threads).with_shard_len(shard_len);
        let mk = |seed| -> BoxedSampler { Box::new(LdsdSampler::new(d, seed, LdsdConfig::default())) };
        let mut mat = MaterializedProbes::new(mk(33), layout, k);
        mat.set_exec(ctx.clone());
        let mut st = StreamedProbes::new(mk(33), layout, k);
        st.set_exec(ctx);
        (mat, st)
    }

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} diverged at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn streamed_consumers_bitwise_match_materialized() {
        for (layout, k) in [(ProbeLayout::Direct, 5), (ProbeLayout::CentralPair, 2)] {
            for threads in [1usize, 4] {
                let d = 777; // misaligned with the shard length on purpose
                let (mut mat, mut st) = pair(d, k, layout, threads, 128);
                for _ in 0..3 {
                    mat.advance();
                    st.advance();
                    let w: Vec<f32> = (0..k).map(|i| 0.3 * i as f32 - 0.4).collect();
                    let mut g1 = vec![0.0f32; d];
                    let mut g2 = vec![0.0f32; d];
                    mat.combine(&w, &mut g1);
                    st.combine(&w, &mut g2);
                    assert_bits(&g1, &g2, "combine");
                    let mut y1 = vec![0.5f32; d];
                    let mut y2 = vec![0.5f32; d];
                    mat.axpy_rows(&w, &mut y1);
                    st.axpy_rows(&w, &mut y2);
                    assert_bits(&y1, &y2, "axpy_rows");
                    mat.scaled_row(k - 1, -1.25, &mut g1);
                    st.scaled_row(k - 1, -1.25, &mut g2);
                    assert_bits(&g1, &g2, "scaled_row");
                }
            }
        }
    }

    #[test]
    fn cursors_visit_identical_values() {
        let d = 300;
        let k = 3;
        let (mut mat, mut st) = pair(d, k, ProbeLayout::Direct, 1, 64);
        mat.advance();
        st.advance();
        for row in 0..k {
            let mut from_mat = vec![0.0f32; d];
            let mut from_st = vec![0.0f32; d];
            mat.cursor().visit_row(row, &mut |c0, piece| {
                from_mat[c0..c0 + piece.len()].copy_from_slice(piece);
            });
            st.cursor().visit_row(row, &mut |c0, piece| {
                from_st[c0..c0 + piece.len()].copy_from_slice(piece);
            });
            assert_bits(&from_mat, &from_st, "cursor row");
        }
    }

    #[test]
    fn small_unaligned_d_bitwise_matches_materialized() {
        // regression: d far below shard_len and not dividing it (the LoRA
        // subspace shape) — every streamed consumer must still replay the
        // exact materialized values, and the clamped scratch must cover
        // the single short RNG cell
        let d = 37;
        let k = 5;
        for threads in [1usize, 4] {
            let (mut mat, mut st) = pair(d, k, ProbeLayout::Direct, threads, 64);
            for step in 0..3 {
                mat.advance();
                st.advance();
                let w: Vec<f32> = (0..k).map(|i| 0.7 - 0.2 * i as f32).collect();
                let mut g1 = vec![0.0f32; d];
                let mut g2 = vec![0.0f32; d];
                mat.combine(&w, &mut g1);
                st.combine(&w, &mut g2);
                assert_bits(&g1, &g2, "combine (small d)");
                let mut y1 = vec![-0.25f32; d];
                let mut y2 = vec![-0.25f32; d];
                mat.axpy_rows(&w, &mut y1);
                st.axpy_rows(&w, &mut y2);
                assert_bits(&y1, &y2, "axpy_rows (small d)");
                mat.scaled_row(0, 2.0, &mut g1);
                st.scaled_row(0, 2.0, &mut g2);
                assert_bits(&g1, &g2, "scaled_row (small d)");
                for row in 0..k {
                    let mut a = vec![0.0f32; d];
                    let mut b = vec![0.0f32; d];
                    mat.cursor().visit_row(row, &mut |c0, piece| {
                        a[c0..c0 + piece.len()].copy_from_slice(piece);
                    });
                    st.cursor().visit_row(row, &mut |c0, piece| {
                        b[c0..c0 + piece.len()].copy_from_slice(piece);
                    });
                    assert_bits(&a, &b, "cursor row (small d)");
                }
                let losses: Vec<f64> =
                    (0..k).map(|i| 0.25 * ((i + step) % 4) as f64).collect();
                mat.observe(&losses);
                st.observe(&losses);
                assert_bits(
                    mat.sampler().policy_mean().unwrap(),
                    st.sampler().policy_mean().unwrap(),
                    "policy mean (small d)",
                );
            }
        }
    }

    #[test]
    fn central_pair_presents_v_and_negated_v() {
        let d = 90;
        let (mut mat, mut st) = pair(d, 2, ProbeLayout::CentralPair, 1, 32);
        mat.advance();
        st.advance();
        let dirs = mat.dirs().unwrap().to_vec();
        for j in 0..d {
            assert_eq!(dirs[d + j].to_bits(), (-dirs[j]).to_bits());
        }
        let mut row1 = vec![0.0f32; d];
        st.scaled_row(1, 1.0, &mut row1);
        assert_bits(&row1, &dirs[d..], "streamed negated row");
    }

    #[test]
    fn observe_keeps_policies_in_lockstep() {
        let d = 400;
        let k = 4;
        let (mut mat, mut st) = pair(d, k, ProbeLayout::Direct, 2, 96);
        for step in 0..4 {
            mat.advance();
            st.advance();
            let losses: Vec<f64> = (0..k).map(|i| ((i + step) % 3) as f64 * 0.5).collect();
            mat.observe(&losses);
            st.observe(&losses);
            let a = mat.sampler().policy_mean().unwrap();
            let b = st.sampler().policy_mean().unwrap();
            assert_bits(a, b, "policy mean");
        }
    }

    #[test]
    fn auto_resolution_uses_budget_and_capability() {
        // tiny matrix: stays materialized
        assert_eq!(
            ProbeStorage::Auto.resolve(1024, 5, true),
            ProbeStorage::Materialized
        );
        // over-budget and replayable: streams
        let huge = (auto_budget_bytes() / 4) + 1;
        assert_eq!(ProbeStorage::Auto.resolve(huge, 1, true), ProbeStorage::Streamed);
        // over-budget but not replayable: falls back to materialized
        assert_eq!(
            ProbeStorage::Auto.resolve(huge, 1, false),
            ProbeStorage::Materialized
        );
        // explicit choices pass through
        assert_eq!(
            ProbeStorage::Streamed.resolve(4, 1, true),
            ProbeStorage::Streamed
        );
    }

    #[test]
    fn explicit_streamed_rejects_non_replay_sampler() {
        let sphere: BoxedSampler = Box::new(SphereSampler::new(16, 1));
        let err = build_source(ProbeStorage::Streamed, sphere, ProbeLayout::Direct, 3)
            .err()
            .expect("sphere cannot stream");
        assert!(err.to_string().contains("seed-replay"), "{err}");
        // auto quietly falls back instead
        let sphere2: BoxedSampler = Box::new(SphereSampler::new(16, 1));
        let src = build_source(ProbeStorage::Auto, sphere2, ProbeLayout::Direct, 3).unwrap();
        assert_eq!(src.label(), "materialized");
    }

    #[test]
    fn storage_parse_roundtrip() {
        assert_eq!(ProbeStorage::parse("auto").unwrap(), ProbeStorage::Auto);
        assert_eq!(
            ProbeStorage::parse("materialized").unwrap(),
            ProbeStorage::Materialized
        );
        assert_eq!(ProbeStorage::parse("streamed").unwrap(), ProbeStorage::Streamed);
        assert!(ProbeStorage::parse("warp").is_err());
        assert_eq!(ProbeStorage::default(), ProbeStorage::Auto);
    }

    #[test]
    fn streamed_holds_no_kd_state() {
        let d = 1 << 16;
        let k = 6;
        let gauss = |seed| -> BoxedSampler { Box::new(GaussianSampler::new(d, seed)) };
        let mat = MaterializedProbes::new(gauss(1), ProbeLayout::Direct, k);
        assert_eq!(mat.probe_state_bytes(), k * d * 4);
        let st = StreamedProbes::new(gauss(1), ProbeLayout::Direct, k);
        assert_eq!(st.probe_state_bytes(), 0);
    }
}
