//! Policy-free baseline samplers.

use crate::rng::Rng;
use crate::tensor::normalize;

use super::DirectionSampler;

/// v ~ N(0, I): the classical ZO direction distribution
/// (Nesterov–Spokoiny / Ghadimi–Lan / MeZO).
pub struct GaussianSampler {
    rng: Rng,
    d: usize,
}

impl GaussianSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { rng: Rng::new(seed), d }
    }
}

impl DirectionSampler for GaussianSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        self.rng.fill_normal(dirs);
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0 // no per-parameter state
    }

    fn name(&self) -> &str {
        "gaussian"
    }
}

/// v uniform on the unit sphere RS(1): normalized Gaussian draws.
pub struct SphereSampler {
    rng: Rng,
    d: usize,
}

impl SphereSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { rng: Rng::new(seed), d }
    }
}

impl DirectionSampler for SphereSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        for i in 0..k {
            let row = &mut dirs[i * self.d..(i + 1) * self.d];
            loop {
                self.rng.fill_normal(row);
                if normalize(row) > 0.0 {
                    break;
                }
            }
        }
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "sphere"
    }
}

/// v = sqrt(d) * e_j with j uniform — the coordinate/one-hot distribution
/// (Duchi et al.).  Scaled by sqrt(d) so E[v v^T] = I like the Gaussian.
pub struct CoordinateSampler {
    rng: Rng,
    d: usize,
    scale: f32,
}

impl CoordinateSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { rng: Rng::new(seed), d, scale: (d as f32).sqrt() }
    }
}

impl DirectionSampler for CoordinateSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        dirs.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..k {
            let j = self.rng.below(self.d as u64) as usize;
            dirs[i * self.d + j] = self.scale;
        }
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "coordinate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, nrm2};

    #[test]
    fn gaussian_rows_roughly_unit_scale() {
        let d = 4096;
        let mut s = GaussianSampler::new(d, 1);
        let mut dirs = vec![0.0f32; 3 * d];
        s.sample(&mut dirs, 3);
        for i in 0..3 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            // ||N(0, I_d)|| concentrates around sqrt(d)
            assert!((n - (d as f32).sqrt()).abs() < 0.1 * (d as f32).sqrt());
        }
    }

    #[test]
    fn gaussian_rows_nearly_orthogonal() {
        let d = 8192;
        let mut s = GaussianSampler::new(d, 2);
        let mut dirs = vec![0.0f32; 2 * d];
        s.sample(&mut dirs, 2);
        let (a, b) = dirs.split_at(d);
        let cos = dot(a, b) / (nrm2(a) * nrm2(b));
        assert!(cos.abs() < 0.05, "cos {cos}");
    }

    #[test]
    fn sphere_rows_unit_norm() {
        let d = 100;
        let mut s = SphereSampler::new(d, 3);
        let mut dirs = vec![0.0f32; 5 * d];
        s.sample(&mut dirs, 5);
        for i in 0..5 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn coordinate_rows_one_hot() {
        let d = 64;
        let mut s = CoordinateSampler::new(d, 4);
        let mut dirs = vec![0.0f32; 10 * d];
        s.sample(&mut dirs, 10);
        for i in 0..10 {
            let row = &dirs[i * d..(i + 1) * d];
            let nnz = row.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 1);
            assert!((nrm2(row) - (d as f32).sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn samplers_deterministic_by_seed() {
        let d = 32;
        let mut a = GaussianSampler::new(d, 9);
        let mut b = GaussianSampler::new(d, 9);
        let mut da = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        a.sample(&mut da, 1);
        b.sample(&mut db, 1);
        assert_eq!(da, db);
    }
}
