//! Policy-free baseline samplers.
//!
//! All fills go through `fill_normal_sharded`: each (step, shard) cell
//! of the flat K x d buffer draws from its own SplitMix64-derived
//! substream, so the probe matrix is a pure function of (seed, step,
//! shard geometry) — shard-parallel on the installed [`ExecContext`] and
//! bitwise identical for any worker count.

use crate::exec::ExecContext;
use crate::rng::substream;
use crate::tensor::normalize;

use super::DirectionSampler;

/// Substream tag space reserved for non-fill draws (row refills, index
/// draws): keeps them disjoint from the shard tags `0..shard_count` used
/// by the main fill.
const AUX_TAG: u64 = 1 << 63;

/// Shard-parallel iid N(0, 1) fill: shard `s` of the flat buffer draws
/// from the substream keyed by `(seed, step, s)`.  Boundaries come from
/// `exec.shard_len()`, never from worker count, so the output is
/// deterministic under any schedule.
pub(super) fn fill_normal_sharded(exec: &ExecContext, seed: u64, step: u64, out: &mut [f32]) {
    exec.for_each_shard_mut(out, |shard, _, chunk| {
        let mut rng = substream(seed, step, shard as u64);
        rng.fill_normal(chunk);
    });
}

/// Seed replay of [`fill_normal_sharded`]: regenerate the flat-buffer
/// range `[lo, lo + out.len())` of a `total`-element fill with shard
/// length `shard_len`, bitwise identical to what the materialized fill
/// wrote there.  Each overlapping RNG cell is regenerated at its full
/// shard length (`fill_normal`'s pairwise stream is positional within the
/// cell, so a cell must be replayed whole); `scratch` (>= `shard_len`
/// elements) stages cells the range only partially covers.
pub(super) fn fill_replay_range(
    shard_len: usize,
    seed: u64,
    step: u64,
    total: usize,
    lo: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    let hi = lo + out.len();
    debug_assert!(hi <= total, "replay range {lo}..{hi} out of {total}");
    debug_assert!(scratch.len() >= shard_len.min(total));
    let mut filled = 0usize;
    let mut shard = lo / shard_len;
    while filled < out.len() {
        let s_start = shard * shard_len;
        let s_len = shard_len.min(total - s_start);
        let a = lo.max(s_start);
        let b = hi.min(s_start + s_len);
        let mut rng = substream(seed, step, shard as u64);
        if a == s_start && b == s_start + s_len {
            // range covers the whole cell: regenerate in place
            rng.fill_normal(&mut out[filled..filled + s_len]);
        } else {
            let cell = &mut scratch[..s_len];
            rng.fill_normal(cell);
            out[filled..filled + (b - a)].copy_from_slice(&cell[a - s_start..b - s_start]);
        }
        filled += b - a;
        shard += 1;
    }
}

/// v ~ N(0, I): the classical ZO direction distribution
/// (Nesterov–Spokoiny / Ghadimi–Lan / MeZO).
pub struct GaussianSampler {
    d: usize,
    seed: u64,
    step: u64,
    exec: ExecContext,
}

impl GaussianSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, exec: ExecContext::serial() }
    }
}

impl DirectionSampler for GaussianSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn supports_replay(&self) -> bool {
        true
    }

    fn advance_step(&mut self) {
        self.step += 1;
    }

    fn fill_row_range(
        &self,
        k: usize,
        row: usize,
        col0: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        debug_assert!(self.step > 0, "fill_row_range before any sample/advance");
        fill_replay_range(
            self.exec.shard_len(),
            self.seed,
            self.step - 1,
            k * self.d,
            row * self.d + col0,
            out,
            scratch,
        );
    }

    fn step_label(&self) -> u64 {
        self.step
    }

    fn restore_state(
        &mut self,
        step: u64,
        _policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        self.step = step;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0 // no per-parameter state
    }

    fn name(&self) -> &str {
        "gaussian"
    }
}

/// v uniform on the unit sphere RS(1): normalized Gaussian draws.
pub struct SphereSampler {
    d: usize,
    seed: u64,
    step: u64,
    exec: ExecContext,
}

impl SphereSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, exec: ExecContext::serial() }
    }
}

impl DirectionSampler for SphereSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        let (seed, step, d) = (self.seed, self.step, self.d);
        self.exec.for_each_row_mut(dirs, d, |row, chunk| {
            // astronomically rare: a zero-norm row redraws from a
            // row-tagged substream until it normalizes
            let mut attempt = 0u64;
            while normalize(chunk) == 0.0 {
                attempt += 1;
                let tag = AUX_TAG | ((row as u64) << 16) | attempt;
                let mut rng = substream(seed, step, tag);
                rng.fill_normal(chunk);
            }
        });
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn step_label(&self) -> u64 {
        self.step
    }

    fn restore_state(
        &mut self,
        step: u64,
        _policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        // no seed *replay* (rows normalize whole-row), but the per-step
        // substream label still fully determines future draws
        self.step = step;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "sphere"
    }
}

/// v = sqrt(d) * e_j with j uniform — the coordinate/one-hot distribution
/// (Duchi et al.).  Scaled by sqrt(d) so E[v v^T] = I like the Gaussian.
pub struct CoordinateSampler {
    d: usize,
    seed: u64,
    step: u64,
    scale: f32,
    exec: ExecContext,
}

impl CoordinateSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, scale: (d as f32).sqrt(), exec: ExecContext::serial() }
    }
}

impl DirectionSampler for CoordinateSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        // zero shard-parallel; the K index draws are O(K) and serial
        self.exec.for_each_shard_mut(dirs, |_, _, chunk| {
            chunk.iter_mut().for_each(|v| *v = 0.0);
        });
        let mut rng = substream(self.seed, self.step, AUX_TAG);
        for i in 0..k {
            let j = rng.below(self.d as u64) as usize;
            dirs[i * self.d + j] = self.scale;
        }
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn supports_replay(&self) -> bool {
        true
    }

    fn advance_step(&mut self) {
        self.step += 1;
    }

    fn fill_row_range(
        &self,
        _k: usize,
        row: usize,
        col0: usize,
        out: &mut [f32],
        _scratch: &mut [f32],
    ) {
        debug_assert!(self.step > 0, "fill_row_range before any sample/advance");
        // replay the O(K) index draws of the last step's AUX substream;
        // the row's single non-zero lands in the window iff j is in range
        let mut rng = substream(self.seed, self.step - 1, AUX_TAG);
        let mut j = 0usize;
        for _ in 0..=row {
            j = rng.below(self.d as u64) as usize;
        }
        out.iter_mut().for_each(|v| *v = 0.0);
        if j >= col0 && j < col0 + out.len() {
            out[j - col0] = self.scale;
        }
    }

    fn step_label(&self) -> u64 {
        self.step
    }

    fn restore_state(
        &mut self,
        step: u64,
        _policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        self.step = step;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "coordinate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, nrm2};

    #[test]
    fn gaussian_rows_roughly_unit_scale() {
        let d = 4096;
        let mut s = GaussianSampler::new(d, 1);
        let mut dirs = vec![0.0f32; 3 * d];
        s.sample(&mut dirs, 3);
        for i in 0..3 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            // ||N(0, I_d)|| concentrates around sqrt(d)
            assert!((n - (d as f32).sqrt()).abs() < 0.1 * (d as f32).sqrt());
        }
    }

    #[test]
    fn gaussian_rows_nearly_orthogonal() {
        let d = 8192;
        let mut s = GaussianSampler::new(d, 2);
        let mut dirs = vec![0.0f32; 2 * d];
        s.sample(&mut dirs, 2);
        let (a, b) = dirs.split_at(d);
        let cos = dot(a, b) / (nrm2(a) * nrm2(b));
        assert!(cos.abs() < 0.05, "cos {cos}");
    }

    #[test]
    fn gaussian_steps_produce_fresh_draws() {
        let d = 64;
        let mut s = GaussianSampler::new(d, 3);
        let mut first = vec![0.0f32; d];
        let mut second = vec![0.0f32; d];
        s.sample(&mut first, 1);
        s.sample(&mut second, 1);
        assert_ne!(first, second, "per-step substreams must differ");
    }

    #[test]
    fn sphere_rows_unit_norm() {
        let d = 100;
        let mut s = SphereSampler::new(d, 3);
        let mut dirs = vec![0.0f32; 5 * d];
        s.sample(&mut dirs, 5);
        for i in 0..5 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn coordinate_rows_one_hot() {
        let d = 64;
        let mut s = CoordinateSampler::new(d, 4);
        let mut dirs = vec![0.0f32; 10 * d];
        s.sample(&mut dirs, 10);
        for i in 0..10 {
            let row = &dirs[i * d..(i + 1) * d];
            let nnz = row.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 1);
            assert!((nrm2(row) - (d as f32).sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn gaussian_replay_bitwise_matches_sample() {
        // materialize a K x d matrix, then replay arbitrary (row, column)
        // windows on a twin sampler that only advanced its step counter:
        // every piece must be bit-identical, including windows that cross
        // shard-cell boundaries (d chosen to misalign with shard_len)
        let d = 301;
        let k = 3;
        let ctx = crate::exec::ExecContext::new(1).with_shard_len(64);
        let mut mat = GaussianSampler::new(d, 17);
        mat.set_exec(ctx.clone());
        let mut dirs = vec![0.0f32; k * d];
        mat.sample(&mut dirs, k);
        mat.sample(&mut dirs, k); // second step: replay must track steps

        let mut rep = GaussianSampler::new(d, 17);
        rep.set_exec(ctx);
        rep.advance_step();
        rep.advance_step();
        let mut scratch = vec![0.0f32; 64];
        for (row, col0, len) in [(0usize, 0usize, d), (1, 37, 101), (2, 290, 11), (1, 63, 2)] {
            let mut piece = vec![0.0f32; len];
            rep.fill_row_range(k, row, col0, &mut piece, &mut scratch);
            for (i, v) in piece.iter().enumerate() {
                let want = dirs[row * d + col0 + i];
                assert_eq!(
                    v.to_bits(),
                    want.to_bits(),
                    "row {row} col {} diverged: {v} vs {want}",
                    col0 + i
                );
            }
        }
    }

    #[test]
    fn coordinate_replay_bitwise_matches_sample() {
        let d = 50;
        let k = 6;
        let mut mat = CoordinateSampler::new(d, 5);
        let mut dirs = vec![0.0f32; k * d];
        mat.sample(&mut dirs, k);
        let mut rep = CoordinateSampler::new(d, 5);
        rep.advance_step();
        let mut scratch = vec![0.0f32; 8];
        for row in 0..k {
            for (col0, len) in [(0usize, d), (13, 20)] {
                let mut piece = vec![9.0f32; len];
                rep.fill_row_range(k, row, col0, &mut piece, &mut scratch);
                assert_eq!(&piece[..], &dirs[row * d + col0..row * d + col0 + len]);
            }
        }
    }

    #[test]
    fn sphere_does_not_claim_replay() {
        // normalization needs the full row before any element is final
        let s = SphereSampler::new(16, 1);
        assert!(!s.supports_replay());
        assert!(GaussianSampler::new(16, 1).supports_replay());
        assert!(CoordinateSampler::new(16, 1).supports_replay());
    }

    #[test]
    fn samplers_deterministic_by_seed() {
        let d = 32;
        let mut a = GaussianSampler::new(d, 9);
        let mut b = GaussianSampler::new(d, 9);
        let mut da = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        a.sample(&mut da, 1);
        b.sample(&mut db, 1);
        assert_eq!(da, db);
    }
}
