//! Policy-free baseline samplers.
//!
//! All fills go through `fill_normal_sharded`: each (step, shard) cell
//! of the flat K x d buffer draws from its own SplitMix64-derived
//! substream, so the probe matrix is a pure function of (seed, step,
//! shard geometry) — shard-parallel on the installed [`ExecContext`] and
//! bitwise identical for any worker count.

use crate::exec::ExecContext;
use crate::rng::substream;
use crate::tensor::normalize;

use super::DirectionSampler;

/// Substream tag space reserved for non-fill draws (row refills, index
/// draws): keeps them disjoint from the shard tags `0..shard_count` used
/// by the main fill.
const AUX_TAG: u64 = 1 << 63;

/// Shard-parallel iid N(0, 1) fill: shard `s` of the flat buffer draws
/// from the substream keyed by `(seed, step, s)`.  Boundaries come from
/// `exec.shard_len()`, never from worker count, so the output is
/// deterministic under any schedule.
pub(super) fn fill_normal_sharded(exec: &ExecContext, seed: u64, step: u64, out: &mut [f32]) {
    exec.for_each_shard_mut(out, |shard, _, chunk| {
        let mut rng = substream(seed, step, shard as u64);
        rng.fill_normal(chunk);
    });
}

/// v ~ N(0, I): the classical ZO direction distribution
/// (Nesterov–Spokoiny / Ghadimi–Lan / MeZO).
pub struct GaussianSampler {
    d: usize,
    seed: u64,
    step: u64,
    exec: ExecContext,
}

impl GaussianSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, exec: ExecContext::serial() }
    }
}

impl DirectionSampler for GaussianSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0 // no per-parameter state
    }

    fn name(&self) -> &str {
        "gaussian"
    }
}

/// v uniform on the unit sphere RS(1): normalized Gaussian draws.
pub struct SphereSampler {
    d: usize,
    seed: u64,
    step: u64,
    exec: ExecContext,
}

impl SphereSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, exec: ExecContext::serial() }
    }
}

impl DirectionSampler for SphereSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        let (seed, step, d) = (self.seed, self.step, self.d);
        self.exec.for_each_row_mut(dirs, d, |row, chunk| {
            // astronomically rare: a zero-norm row redraws from a
            // row-tagged substream until it normalizes
            let mut attempt = 0u64;
            while normalize(chunk) == 0.0 {
                attempt += 1;
                let tag = AUX_TAG | ((row as u64) << 16) | attempt;
                let mut rng = substream(seed, step, tag);
                rng.fill_normal(chunk);
            }
        });
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "sphere"
    }
}

/// v = sqrt(d) * e_j with j uniform — the coordinate/one-hot distribution
/// (Duchi et al.).  Scaled by sqrt(d) so E[v v^T] = I like the Gaussian.
pub struct CoordinateSampler {
    d: usize,
    seed: u64,
    step: u64,
    scale: f32,
    exec: ExecContext,
}

impl CoordinateSampler {
    /// Build for dimensionality `d` with a seeded stream.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { d, seed, step: 0, scale: (d as f32).sqrt(), exec: ExecContext::serial() }
    }
}

impl DirectionSampler for CoordinateSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        assert_eq!(dirs.len(), k * self.d);
        // zero shard-parallel; the K index draws are O(K) and serial
        self.exec.for_each_shard_mut(dirs, |_, _, chunk| {
            chunk.iter_mut().for_each(|v| *v = 0.0);
        });
        let mut rng = substream(self.seed, self.step, AUX_TAG);
        for i in 0..k {
            let j = rng.below(self.d as u64) as usize;
            dirs[i * self.d + j] = self.scale;
        }
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, _dirs: &[f32], _losses: &[f64], _k: usize) {}

    fn dim(&self) -> usize {
        self.d
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "coordinate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, nrm2};

    #[test]
    fn gaussian_rows_roughly_unit_scale() {
        let d = 4096;
        let mut s = GaussianSampler::new(d, 1);
        let mut dirs = vec![0.0f32; 3 * d];
        s.sample(&mut dirs, 3);
        for i in 0..3 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            // ||N(0, I_d)|| concentrates around sqrt(d)
            assert!((n - (d as f32).sqrt()).abs() < 0.1 * (d as f32).sqrt());
        }
    }

    #[test]
    fn gaussian_rows_nearly_orthogonal() {
        let d = 8192;
        let mut s = GaussianSampler::new(d, 2);
        let mut dirs = vec![0.0f32; 2 * d];
        s.sample(&mut dirs, 2);
        let (a, b) = dirs.split_at(d);
        let cos = dot(a, b) / (nrm2(a) * nrm2(b));
        assert!(cos.abs() < 0.05, "cos {cos}");
    }

    #[test]
    fn gaussian_steps_produce_fresh_draws() {
        let d = 64;
        let mut s = GaussianSampler::new(d, 3);
        let mut first = vec![0.0f32; d];
        let mut second = vec![0.0f32; d];
        s.sample(&mut first, 1);
        s.sample(&mut second, 1);
        assert_ne!(first, second, "per-step substreams must differ");
    }

    #[test]
    fn sphere_rows_unit_norm() {
        let d = 100;
        let mut s = SphereSampler::new(d, 3);
        let mut dirs = vec![0.0f32; 5 * d];
        s.sample(&mut dirs, 5);
        for i in 0..5 {
            let n = nrm2(&dirs[i * d..(i + 1) * d]);
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn coordinate_rows_one_hot() {
        let d = 64;
        let mut s = CoordinateSampler::new(d, 4);
        let mut dirs = vec![0.0f32; 10 * d];
        s.sample(&mut dirs, 10);
        for i in 0..10 {
            let row = &dirs[i * d..(i + 1) * d];
            let nnz = row.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 1);
            assert!((nrm2(row) - (d as f32).sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn samplers_deterministic_by_seed() {
        let d = 32;
        let mut a = GaussianSampler::new(d, 9);
        let mut b = GaussianSampler::new(d, 9);
        let mut da = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        a.sample(&mut da, 1);
        b.sample(&mut db, 1);
        assert_eq!(da, db);
    }
}
