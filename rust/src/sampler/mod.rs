//! Direction sampling — the paper's contribution.
//!
//! A [`DirectionSampler`] produces the K candidate perturbation directions
//! of Algorithm 2 line 3 and (optionally) learns from the observed probe
//! losses (lines 6/8).  Implementations:
//!
//! * [`GaussianSampler`] — classical ZO: v ~ N(0, I) (MeZO / ZO-SGD
//!   baseline; equivalently the paper's mu ≡ 0 case).
//! * [`SphereSampler`] — uniform on the unit sphere (normalized Gaussian).
//! * [`CoordinateSampler`] — uniform one-hot basis vectors (Duchi et al.).
//! * [`LdsdSampler`] — the paper: v ~ N(mu, eps^2 I) with mu updated by a
//!   REINFORCE / leave-one-out estimator from the probe losses.
//!
//! The sampler is deliberately decoupled from the base optimizer: the
//! paper's §4 "plug-and-play" claim is this trait boundary.
//!
//! Samplers whose fills are pure functions of their (seed, step, shard)
//! RNG cells also support *seed replay*
//! ([`DirectionSampler::fill_row_range`]): any piece of the probe matrix
//! can be regenerated on demand without a backing buffer, which is what
//! the streamed probe engine ([`crate::probe`]) builds on (DESIGN.md §10).

mod alignment;
mod gaussian;
mod ldsd;

pub use alignment::{expected_alignment_mc, AlignmentTracker};
pub use gaussian::{CoordinateSampler, GaussianSampler, SphereSampler};
pub use ldsd::{LdsdConfig, LdsdSampler};

/// Produces candidate directions and learns from probe feedback.
pub trait DirectionSampler {
    /// Fill `dirs` (row-major K x d) with K sampled directions.
    ///
    /// Fills are shard-parallel and deterministic: each (step, shard) cell
    /// of the flat buffer draws from its own [`crate::rng::substream`],
    /// with shard boundaries fixed by the installed context's `shard_len`
    /// — the same directions come out for any worker count.
    fn sample(&mut self, dirs: &mut [f32], k: usize);

    /// Install the shard-parallel execution context used by `sample` (and
    /// by learnable policies' `observe` updates).  Samplers default to the
    /// serial context.
    fn set_exec(&mut self, ctx: crate::exec::ExecContext) {
        let _ = ctx;
    }

    /// Observe the probe losses `f(x + tau * dirs[i])` for the directions
    /// produced by the last `sample` call.  Policy-free samplers ignore it.
    fn observe(&mut self, dirs: &[f32], losses: &[f64], k: usize);

    /// True if this sampler can regenerate any piece of its probe matrix
    /// on demand from its RNG cells ([`DirectionSampler::fill_row_range`])
    /// — the property the streamed probe engine relies on.  Samplers whose
    /// rows need a full-row pass before any element is final (e.g. the
    /// normalized sphere) return `false` and stay on the materialized
    /// path.
    fn supports_replay(&self) -> bool {
        false
    }

    /// Advance the per-step substream counter without materializing a
    /// probe matrix — the streamed engine's replacement for `sample`.
    /// After this call, [`DirectionSampler::fill_row_range`] replays the
    /// step a `sample` call here would have produced.
    fn advance_step(&mut self) {
        panic!("{}: seed replay not supported (supports_replay is false)", self.name());
    }

    /// Seed replay: write row `row`, columns `[col0, col0 + out.len())` of
    /// the most recently sampled/advanced step's K x d probe matrix into
    /// `out`, exactly as `sample` would have produced it.  `k` is the row
    /// count of that matrix (part of the flat-buffer RNG geometry);
    /// `scratch` must hold at least `shard_len.min(k * d)` elements, where
    /// `shard_len` is the installed context's shard length (substream
    /// staging: RNG cells tile the `k * d` flat buffer, so no cell — and
    /// hence no staged regeneration — ever exceeds that bound; `d` need
    /// not be shard-aligned).  Pure in the sampler state: any number of
    /// calls return the same values.
    fn fill_row_range(
        &self,
        k: usize,
        row: usize,
        col0: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        let _ = (k, row, col0, out, scratch);
        panic!("{}: seed replay not supported (supports_replay is false)", self.name());
    }

    /// Policy update where the step's directions are replayed on demand
    /// instead of passed as a slice — the streamed equivalent of
    /// [`DirectionSampler::observe`], bitwise identical to it.
    /// Policy-free samplers ignore it.
    fn observe_replay(&mut self, losses: &[f64], k: usize) {
        let _ = (losses, k);
    }

    /// The per-step RNG label: how many steps this sampler has drawn
    /// (each `sample`/`advance_step` advances it by one).  Probe fills are
    /// pure functions of (seed, step label, shard geometry), so together
    /// with [`DirectionSampler::policy_mean`] this is the sampler's entire
    /// snapshot state (crash-safe checkpointing, DESIGN.md §11).
    fn step_label(&self) -> u64 {
        0
    }

    /// Restore the per-step RNG label (and the learned policy state, for
    /// samplers that have one) captured by a snapshot.  The restored
    /// sampler draws the exact directions the snapshotted one would have
    /// drawn next.  Samplers without replayable per-step state reject the
    /// call.
    fn restore_state(
        &mut self,
        step: u64,
        policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        let _ = (step, policy_mean);
        anyhow::bail!("{}: snapshot restore not supported", self.name())
    }

    /// Trainable dimensionality this sampler emits.
    fn dim(&self) -> usize;

    /// Bytes of persistent sampler state (memory-table accounting).
    fn state_bytes(&self) -> usize;

    /// Short identifier used in labels.
    fn name(&self) -> &str;

    /// The learned policy mean, if any (diagnostics; LDSD only).
    fn policy_mean(&self) -> Option<&[f32]> {
        None
    }
}
