//! Direction sampling — the paper's contribution.
//!
//! A [`DirectionSampler`] produces the K candidate perturbation directions
//! of Algorithm 2 line 3 and (optionally) learns from the observed probe
//! losses (lines 6/8).  Implementations:
//!
//! * [`GaussianSampler`] — classical ZO: v ~ N(0, I) (MeZO / ZO-SGD
//!   baseline; equivalently the paper's mu ≡ 0 case).
//! * [`SphereSampler`] — uniform on the unit sphere (normalized Gaussian).
//! * [`CoordinateSampler`] — uniform one-hot basis vectors (Duchi et al.).
//! * [`LdsdSampler`] — the paper: v ~ N(mu, eps^2 I) with mu updated by a
//!   REINFORCE / leave-one-out estimator from the probe losses.
//!
//! The sampler is deliberately decoupled from the base optimizer: the
//! paper's §4 "plug-and-play" claim is this trait boundary.

mod alignment;
mod gaussian;
mod ldsd;

pub use alignment::{expected_alignment_mc, AlignmentTracker};
pub use gaussian::{CoordinateSampler, GaussianSampler, SphereSampler};
pub use ldsd::{LdsdConfig, LdsdSampler};

/// Produces candidate directions and learns from probe feedback.
pub trait DirectionSampler {
    /// Fill `dirs` (row-major K x d) with K sampled directions.
    ///
    /// Fills are shard-parallel and deterministic: each (step, shard) cell
    /// of the flat buffer draws from its own [`crate::rng::substream`],
    /// with shard boundaries fixed by the installed context's `shard_len`
    /// — the same directions come out for any worker count.
    fn sample(&mut self, dirs: &mut [f32], k: usize);

    /// Install the shard-parallel execution context used by `sample` (and
    /// by learnable policies' `observe` updates).  Samplers default to the
    /// serial context.
    fn set_exec(&mut self, ctx: crate::exec::ExecContext) {
        let _ = ctx;
    }

    /// Observe the probe losses `f(x + tau * dirs[i])` for the directions
    /// produced by the last `sample` call.  Policy-free samplers ignore it.
    fn observe(&mut self, dirs: &[f32], losses: &[f64], k: usize);

    /// Trainable dimensionality this sampler emits.
    fn dim(&self) -> usize;

    /// Bytes of persistent sampler state (memory-table accounting).
    fn state_bytes(&self) -> usize;

    /// Short identifier used in labels.
    fn name(&self) -> &str;

    /// The learned policy mean, if any (diagnostics; LDSD only).
    fn policy_mean(&self) -> Option<&[f32]> {
        None
    }
}
