//! LDSD: the learnable direction-sampling policy (Algorithms 1-2).
//!
//! Directions are drawn from N(mu, eps^2 I); after the K probe losses
//! `f(x + tau v_i)` are observed, the policy mean is updated with the
//! REINFORCE / leave-one-out estimator of Algorithm 2 line 6:
//!
//! ```text
//! g_mu = (1/K) sum_i  w_i * (v_i - mu) / eps^2,
//! w_i  = (K f_i - sum_j f_j) / (K - 1)        (leave-one-out advantage)
//! mu  <- mu + gamma_mu * sign * g_mu
//! ```
//!
//! **Sign note** (DESIGN.md §5): as printed, line 8 (`mu += gamma_mu g_mu`
//! with w_i the loss-advantage) *ascends* E[f(x + tau v)], i.e. steers the
//! policy toward high-loss directions — the opposite of the stated goal of
//! concentrating mass on "empirically useful directions" and of the
//! first-order Algorithm 1, which ascends the alignment reward.  We treat
//! the printed sign as a typo: the default `reward_sign = -1.0` descends
//! the loss (reward = -f).  Set `reward_sign = 1.0` to reproduce the
//! literal paper update; the `fig3` ablation bench sweeps both.

use crate::exec::ExecContext;
use crate::rng::Rng;
use crate::tensor::{axpy_k_ctx, nrm2, scal};

use super::gaussian::fill_normal_sharded;
use super::DirectionSampler;

/// Hyperparameters of the LDSD policy (Algorithm 2 defaults in §A.2).
#[derive(Clone, Debug)]
pub struct LdsdConfig {
    /// Std-dev of the sampling distribution (paper's epsilon; §A.2 uses 1).
    pub eps: f32,
    /// Policy learning rate (paper's gamma_mu; §A.2 uses 1e-3).
    pub gamma_mu: f32,
    /// -1.0 (default): reward = -loss (descend f).  +1.0: literal paper.
    pub reward_sign: f32,
    /// Initial ||mu||; mu0 is random isotropic at this norm.  Theorem 1
    /// excludes mu0 = 0 (saddle of the alignment landscape), so this must
    /// be positive.
    pub init_norm: f32,
    /// Optionally renormalize mu to `init_norm` after each update — the
    /// paper's §3.5 closing remark suggests ||mu|| = 1 as a natural design
    /// choice; we keep it optional and ablate it.
    pub renormalize: bool,
    /// Use the leave-one-out baseline (Algorithm 2).  `false` uses the
    /// plain mean baseline of §3.6.
    pub leave_one_out: bool,
}

impl Default for LdsdConfig {
    fn default() -> Self {
        Self {
            eps: 1.0,
            gamma_mu: 1e-3,
            reward_sign: -1.0,
            init_norm: 1.0,
            renormalize: false,
            leave_one_out: true,
        }
    }
}

/// The learnable direction policy: v ~ N(mu, eps^2 I) with REINFORCE
/// updates of mu from observed probe losses.
pub struct LdsdSampler {
    cfg: LdsdConfig,
    mu: Vec<f32>,
    seed: u64,
    step: u64,
    exec: ExecContext,
    /// scratch for the weighted reduce (kept across steps: zero-alloc loop)
    weights: Vec<f32>,
}

impl LdsdSampler {
    /// Build for dimensionality `d`; mu0 is random isotropic at
    /// `cfg.init_norm` (which must be positive — Theorem 1).
    pub fn new(d: usize, seed: u64, cfg: LdsdConfig) -> Self {
        assert!(cfg.eps > 0.0, "eps must be positive");
        assert!(cfg.init_norm > 0.0, "mu0 = 0 is a saddle (Theorem 1)");
        let mut rng = Rng::new(seed);
        let mut mu = vec![0.0f32; d];
        rng.fill_normal(&mut mu);
        let n = nrm2(&mu);
        if n > 0.0 {
            scal(cfg.init_norm / n, &mut mu);
        }
        Self { cfg, mu, seed, step: 0, exec: ExecContext::serial(), weights: Vec::new() }
    }

    /// Warm-start the policy mean along a known direction (Lemma 3's
    /// `mu^0 || grad f(x^0)` initialization).
    pub fn set_mean(&mut self, mean: &[f32]) {
        assert_eq!(mean.len(), self.mu.len());
        self.mu.copy_from_slice(mean);
    }

    /// The policy configuration.
    pub fn config(&self) -> &LdsdConfig {
        &self.cfg
    }

    /// Current ||mu||.
    pub fn mu_norm(&self) -> f32 {
        nrm2(&self.mu)
    }
}

impl DirectionSampler for LdsdSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        let d = self.mu.len();
        assert_eq!(dirs.len(), k * d);
        // shard-parallel z ~ N(0, I) fill, then the affine v = mu + eps z
        // row-parallel — both deterministic for any worker count
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        let eps = self.cfg.eps;
        let mu = &self.mu;
        self.exec.for_each_row_mut(dirs, d, |_, row| {
            for (r, m) in row.iter_mut().zip(mu.iter()) {
                *r = m + eps * *r;
            }
        });
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, dirs: &[f32], losses: &[f64], k: usize) {
        let d = self.mu.len();
        assert_eq!(dirs.len(), k * d);
        assert_eq!(losses.len(), k);
        if k < 2 {
            // no baseline is possible; skip the policy update
            return;
        }
        let sum: f64 = losses.iter().sum();
        self.weights.clear();
        for i in 0..k {
            let adv = if self.cfg.leave_one_out {
                (k as f64 * losses[i] - sum) / (k as f64 - 1.0)
            } else {
                losses[i] - sum / k as f64
            };
            self.weights.push(adv as f32);
        }
        // mu += gamma_mu * sign * (1/K) sum_i w_i (v_i - mu) / eps^2
        let coef = self.cfg.gamma_mu * self.cfg.reward_sign
            / (k as f32 * self.cfg.eps * self.cfg.eps);
        // (v_i - mu) = dirs_i - mu:
        //   mu_new = (1 - coef * wsum) * mu + coef * sum_i w_i dirs_i.
        // Both baselines make the advantages sum to zero analytically
        // (wsum ~ 0), but we keep the exact form: scale mu first, then
        // accumulate the direction contributions — reusing the estimator's
        // probe matrix in one fused blocked pass (`axpy_k_ctx`, shard-
        // parallel on the installed context) instead of K separate sweeps
        // of mu.
        let wsum: f32 = self.weights.iter().sum();
        let mu_scale = 1.0 - coef * wsum;
        self.exec.for_each_shard_mut(&mut self.mu, |_, _, chunk| {
            for v in chunk.iter_mut() {
                *v *= mu_scale;
            }
        });
        for w in self.weights.iter_mut() {
            *w *= coef;
        }
        axpy_k_ctx(&self.exec, &self.weights, dirs, &mut self.mu);
        if self.cfg.renormalize {
            let n = nrm2(&self.mu);
            if n > f32::MIN_POSITIVE {
                scal(self.cfg.init_norm / n, &mut self.mu);
            }
        }
    }

    fn dim(&self) -> usize {
        self.mu.len()
    }

    fn state_bytes(&self) -> usize {
        self.mu.len() * 4 // the O(d) policy mean — the paper's memory claim
    }

    fn name(&self) -> &str {
        "ldsd"
    }

    fn policy_mean(&self) -> Option<&[f32]> {
        Some(&self.mu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{axpy, cosine, dot};

    #[test]
    fn init_norm_respected() {
        let s = LdsdSampler::new(512, 1, LdsdConfig { init_norm: 2.5, ..Default::default() });
        assert!((s.mu_norm() - 2.5).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn zero_init_rejected() {
        let _ = LdsdSampler::new(8, 1, LdsdConfig { init_norm: 0.0, ..Default::default() });
    }

    #[test]
    fn sample_mean_is_mu() {
        let d = 64;
        let mut s = LdsdSampler::new(
            d, 7, LdsdConfig { eps: 0.5, init_norm: 3.0, ..Default::default() },
        );
        let k = 400;
        let mut dirs = vec![0.0f32; k * d];
        s.sample(&mut dirs, k);
        let mut mean = vec![0.0f32; d];
        for i in 0..k {
            axpy(1.0 / k as f32, &dirs[i * d..(i + 1) * d], &mut mean);
        }
        let mu = s.policy_mean().unwrap();
        let cos = cosine(&mean, mu);
        assert!(cos > 0.95, "empirical mean should align with mu, cos={cos}");
    }

    #[test]
    fn gamma_zero_keeps_policy_fixed() {
        // LDSD with gamma_mu = 0 must behave as a frozen-mean sampler —
        // observe() is a no-op on mu.
        let d = 32;
        let mut s = LdsdSampler::new(
            d, 3, LdsdConfig { gamma_mu: 0.0, ..Default::default() },
        );
        let mu0 = s.policy_mean().unwrap().to_vec();
        let k = 5;
        let mut dirs = vec![0.0f32; k * d];
        s.sample(&mut dirs, k);
        let losses = vec![1.0f64, 2.0, 3.0, 4.0, 5.0];
        s.observe(&dirs, &losses, k);
        assert_eq!(s.policy_mean().unwrap(), &mu0[..]);
    }

    #[test]
    fn policy_moves_toward_low_loss_direction() {
        // Construct losses that are lowest for directions aligned with a
        // target t; after many updates mu should rotate toward t.
        let d = 16;
        let mut s = LdsdSampler::new(
            d,
            11,
            LdsdConfig { eps: 1.0, gamma_mu: 0.05, ..Default::default() },
        );
        let mut target = vec![0.0f32; d];
        target[0] = 1.0;
        let k = 8;
        let mut dirs = vec![0.0f32; k * d];
        let cos_before = cosine(s.policy_mean().unwrap(), &target).abs();
        for _ in 0..300 {
            s.sample(&mut dirs, k);
            // loss decreases with alignment: f = -<v, t>
            let losses: Vec<f64> = (0..k)
                .map(|i| -dot(&dirs[i * d..(i + 1) * d], &target) as f64)
                .collect();
            s.observe(&dirs, &losses, k);
        }
        let cos_after = cosine(s.policy_mean().unwrap(), &target);
        assert!(
            cos_after > 0.9 && cos_after > cos_before,
            "cos before {cos_before}, after {cos_after}"
        );
    }

    #[test]
    fn paper_sign_moves_away_from_low_loss() {
        // reward_sign = +1 (the literal printed update) must do the
        // opposite: mu drifts toward HIGH loss directions.
        let d = 16;
        let mut s = LdsdSampler::new(
            d,
            11,
            LdsdConfig {
                eps: 1.0,
                gamma_mu: 0.05,
                reward_sign: 1.0,
                ..Default::default()
            },
        );
        let mut target = vec![0.0f32; d];
        target[0] = 1.0;
        let k = 8;
        let mut dirs = vec![0.0f32; k * d];
        for _ in 0..300 {
            s.sample(&mut dirs, k);
            let losses: Vec<f64> = (0..k)
                .map(|i| -dot(&dirs[i * d..(i + 1) * d], &target) as f64)
                .collect();
            s.observe(&dirs, &losses, k);
        }
        let cos_after = cosine(s.policy_mean().unwrap(), &target);
        assert!(cos_after < -0.5, "expected anti-alignment, cos={cos_after}");
    }

    #[test]
    fn k1_observe_is_noop() {
        let d = 8;
        let mut s = LdsdSampler::new(d, 2, LdsdConfig::default());
        let mu0 = s.policy_mean().unwrap().to_vec();
        let mut dirs = vec![0.0f32; d];
        s.sample(&mut dirs, 1);
        s.observe(&dirs, &[1.0], 1);
        assert_eq!(s.policy_mean().unwrap(), &mu0[..]);
    }

    #[test]
    fn renormalize_keeps_norm() {
        let d = 32;
        let mut s = LdsdSampler::new(
            d,
            5,
            LdsdConfig {
                renormalize: true,
                init_norm: 1.0,
                gamma_mu: 0.1,
                ..Default::default()
            },
        );
        let k = 4;
        let mut dirs = vec![0.0f32; k * d];
        for step in 0..20 {
            s.sample(&mut dirs, k);
            let losses: Vec<f64> =
                (0..k).map(|i| (i + step) as f64 * 0.1).collect();
            s.observe(&dirs, &losses, k);
            assert!((s.mu_norm() - 1.0).abs() < 1e-4);
        }
    }
}
