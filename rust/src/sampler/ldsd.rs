//! LDSD: the learnable direction-sampling policy (Algorithms 1-2).
//!
//! Directions are drawn from N(mu, eps^2 I); after the K probe losses
//! `f(x + tau v_i)` are observed, the policy mean is updated with the
//! REINFORCE / leave-one-out estimator of Algorithm 2 line 6:
//!
//! ```text
//! g_mu = (1/K) sum_i  w_i * (v_i - mu) / eps^2,
//! w_i  = (K f_i - sum_j f_j) / (K - 1)        (leave-one-out advantage)
//! mu  <- mu + gamma_mu * sign * g_mu
//! ```
//!
//! **Sign note** (DESIGN.md §5): as printed, line 8 (`mu += gamma_mu g_mu`
//! with w_i the loss-advantage) *ascends* E[f(x + tau v)], i.e. steers the
//! policy toward high-loss directions — the opposite of the stated goal of
//! concentrating mass on "empirically useful directions" and of the
//! first-order Algorithm 1, which ascends the alignment reward.  We treat
//! the printed sign as a typo: the default `reward_sign = -1.0` descends
//! the loss (reward = -f).  Set `reward_sign = 1.0` to reproduce the
//! literal paper update; the `fig3` ablation bench sweeps both.

use crate::exec::ExecContext;
use crate::rng::Rng;
use crate::tensor::{axpy_k_ctx, nrm2, scal};

use super::gaussian::{fill_normal_sharded, fill_replay_range};
use super::DirectionSampler;

/// Hyperparameters of the LDSD policy (Algorithm 2 defaults in §A.2).
#[derive(Clone, Debug)]
pub struct LdsdConfig {
    /// Std-dev of the sampling distribution (paper's epsilon; §A.2 uses 1).
    pub eps: f32,
    /// Policy learning rate (paper's gamma_mu; §A.2 uses 1e-3).
    pub gamma_mu: f32,
    /// -1.0 (default): reward = -loss (descend f).  +1.0: literal paper.
    pub reward_sign: f32,
    /// Initial ||mu||; mu0 is random isotropic at this norm.  Theorem 1
    /// excludes mu0 = 0 (saddle of the alignment landscape), so this must
    /// be positive.
    pub init_norm: f32,
    /// Optionally renormalize mu to `init_norm` after each update — the
    /// paper's §3.5 closing remark suggests ||mu|| = 1 as a natural design
    /// choice; we keep it optional and ablate it.
    pub renormalize: bool,
    /// Use the leave-one-out baseline (Algorithm 2).  `false` uses the
    /// plain mean baseline of §3.6.
    pub leave_one_out: bool,
}

impl Default for LdsdConfig {
    fn default() -> Self {
        Self {
            eps: 1.0,
            gamma_mu: 1e-3,
            reward_sign: -1.0,
            init_norm: 1.0,
            renormalize: false,
            leave_one_out: true,
        }
    }
}

/// The learnable direction policy: v ~ N(mu, eps^2 I) with REINFORCE
/// updates of mu from observed probe losses.
pub struct LdsdSampler {
    cfg: LdsdConfig,
    mu: Vec<f32>,
    seed: u64,
    step: u64,
    exec: ExecContext,
    /// scratch for the weighted reduce (kept across steps: zero-alloc loop)
    weights: Vec<f32>,
}

impl LdsdSampler {
    /// Build for dimensionality `d`; mu0 is random isotropic at
    /// `cfg.init_norm` (which must be positive — Theorem 1).
    pub fn new(d: usize, seed: u64, cfg: LdsdConfig) -> Self {
        assert!(cfg.eps > 0.0, "eps must be positive");
        assert!(cfg.init_norm > 0.0, "mu0 = 0 is a saddle (Theorem 1)");
        let mut rng = Rng::new(seed);
        let mut mu = vec![0.0f32; d];
        rng.fill_normal(&mut mu);
        let n = nrm2(&mu);
        if n > 0.0 {
            scal(cfg.init_norm / n, &mut mu);
        }
        Self { cfg, mu, seed, step: 0, exec: ExecContext::serial(), weights: Vec::new() }
    }

    /// Warm-start the policy mean along a known direction (Lemma 3's
    /// `mu^0 || grad f(x^0)` initialization).
    pub fn set_mean(&mut self, mean: &[f32]) {
        assert_eq!(mean.len(), self.mu.len());
        self.mu.copy_from_slice(mean);
    }

    /// The policy configuration.
    pub fn config(&self) -> &LdsdConfig {
        &self.cfg
    }

    /// Current ||mu||.
    pub fn mu_norm(&self) -> f32 {
        nrm2(&self.mu)
    }

    /// Compute the REINFORCE advantage weights scaled by the policy
    /// coefficient into `self.weights` and return the multiplicative mu
    /// scale of the update, or `None` when `k < 2` (no baseline possible).
    /// Shared by the materialized and replayed observe paths so both apply
    /// bit-identical updates.
    fn update_weights(&mut self, losses: &[f64], k: usize) -> Option<f32> {
        if k < 2 {
            return None;
        }
        let sum: f64 = losses.iter().sum();
        self.weights.clear();
        for i in 0..k {
            let adv = if self.cfg.leave_one_out {
                (k as f64 * losses[i] - sum) / (k as f64 - 1.0)
            } else {
                losses[i] - sum / k as f64
            };
            self.weights.push(adv as f32);
        }
        let coef = self.cfg.gamma_mu * self.cfg.reward_sign
            / (k as f32 * self.cfg.eps * self.cfg.eps);
        let wsum: f32 = self.weights.iter().sum();
        let mu_scale = 1.0 - coef * wsum;
        for w in self.weights.iter_mut() {
            *w *= coef;
        }
        Some(mu_scale)
    }

    /// Renormalize mu to `init_norm` if the config asks for it.
    fn maybe_renormalize(&mut self) {
        if self.cfg.renormalize {
            let n = nrm2(&self.mu);
            if n > f32::MIN_POSITIVE {
                scal(self.cfg.init_norm / n, &mut self.mu);
            }
        }
    }
}

impl DirectionSampler for LdsdSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        let d = self.mu.len();
        assert_eq!(dirs.len(), k * d);
        // shard-parallel z ~ N(0, I) fill, then the affine v = mu + eps z
        // row-parallel — both deterministic for any worker count
        fill_normal_sharded(&self.exec, self.seed, self.step, dirs);
        let eps = self.cfg.eps;
        let mu = &self.mu;
        self.exec.for_each_row_mut(dirs, d, |_, row| {
            for (r, m) in row.iter_mut().zip(mu.iter()) {
                *r = m + eps * *r;
            }
        });
        self.step += 1;
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.exec = ctx;
    }

    fn observe(&mut self, dirs: &[f32], losses: &[f64], k: usize) {
        let d = self.mu.len();
        assert_eq!(dirs.len(), k * d);
        assert_eq!(losses.len(), k);
        // mu += gamma_mu * sign * (1/K) sum_i w_i (v_i - mu) / eps^2
        // with (v_i - mu) = dirs_i - mu, i.e.
        //   mu_new = (1 - coef * wsum) * mu + coef * sum_i w_i dirs_i.
        // Both baselines make the advantages sum to zero analytically
        // (wsum ~ 0), but we keep the exact form: scale mu first, then
        // accumulate the direction contributions — reusing the estimator's
        // probe matrix in one fused blocked pass (`axpy_k_ctx`, shard-
        // parallel on the installed context) instead of K separate sweeps
        // of mu.
        let mu_scale = match self.update_weights(losses, k) {
            Some(s) => s,
            None => return, // k < 2: no baseline is possible, skip the update
        };
        self.exec.for_each_shard_mut(&mut self.mu, |_, _, chunk| {
            for v in chunk.iter_mut() {
                *v *= mu_scale;
            }
        });
        axpy_k_ctx(&self.exec, &self.weights, dirs, &mut self.mu);
        self.maybe_renormalize();
    }

    fn supports_replay(&self) -> bool {
        true
    }

    fn advance_step(&mut self) {
        self.step += 1;
    }

    fn fill_row_range(
        &self,
        k: usize,
        row: usize,
        col0: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        debug_assert!(self.step > 0, "fill_row_range before any sample/advance");
        let d = self.mu.len();
        // replay the z ~ N(0, 1) cell draws, then the same elementwise
        // affine v = mu + eps z the materialized fill applies
        fill_replay_range(
            self.exec.shard_len(),
            self.seed,
            self.step - 1,
            k * d,
            row * d + col0,
            out,
            scratch,
        );
        let eps = self.cfg.eps;
        for (j, v) in out.iter_mut().enumerate() {
            *v = self.mu[col0 + j] + eps * *v;
        }
    }

    fn observe_replay(&mut self, losses: &[f64], k: usize) {
        assert_eq!(losses.len(), k);
        let mu_scale = match self.update_weights(losses, k) {
            Some(s) => s,
            None => return,
        };
        // Streamed form of `observe`: per mu shard, regenerate the K
        // direction pieces from the *pre-update* mu (the affine transform
        // is elementwise, so a shard only needs its own mu values), scale
        // the shard, then accumulate rows in row order — per element the
        // exact sequence of operations `observe` applies, so the learned
        // mean is bitwise identical.  Peak probe state per worker is the
        // (K + 1)-shard block, tracked for the memory acceptance test.
        let d = self.mu.len();
        let sl = self.exec.shard_len();
        // per-row block stride and substream staging, clamped to the
        // actual geometry so a small d (LoRA subspaces) never allocates
        // full shard_len-sized scratch per worker
        let bl = sl.min(d.max(1));
        let stl = sl.min((k * d).max(1));
        let seed = self.seed;
        let step = self.step - 1;
        let eps = self.cfg.eps;
        let weights = std::mem::take(&mut self.weights);
        let exec = self.exec.clone();
        exec.for_each_shard_mut_scratch(
            &mut self.mu,
            || {
                (
                    crate::metrics::TrackedBuf::zeroed(k * bl),
                    crate::metrics::TrackedBuf::zeroed(stl),
                )
            },
            |scratch, _, start, mub| {
                let (block, stage) = scratch;
                let len = mub.len();
                for (i, wi) in weights.iter().enumerate() {
                    if *wi == 0.0 {
                        continue; // axpy_k skips zero rows; match it
                    }
                    let piece = &mut block[i * bl..i * bl + len];
                    fill_replay_range(sl, seed, step, k * d, i * d + start, piece, stage);
                    for (j, v) in piece.iter_mut().enumerate() {
                        *v = mub[j] + eps * *v;
                    }
                }
                for v in mub.iter_mut() {
                    *v *= mu_scale;
                }
                for (i, wi) in weights.iter().enumerate() {
                    if *wi == 0.0 {
                        continue;
                    }
                    let piece = &block[i * bl..i * bl + len];
                    // fused, matching the fma_axpy kernel that observe()
                    // runs via axpy_k_ctx (tensor::lanes contract)
                    for (m, v) in mub.iter_mut().zip(piece.iter()) {
                        *m = wi.mul_add(*v, *m);
                    }
                }
            },
        );
        self.weights = weights;
        self.maybe_renormalize();
    }

    fn step_label(&self) -> u64 {
        self.step
    }

    fn restore_state(
        &mut self,
        step: u64,
        policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        let mean = policy_mean.ok_or_else(|| {
            anyhow::anyhow!("ldsd: snapshot restore needs the policy mean")
        })?;
        if mean.len() != self.mu.len() {
            anyhow::bail!(
                "ldsd: snapshot policy mean holds {} f32, expected {}",
                mean.len(),
                self.mu.len()
            );
        }
        self.mu.copy_from_slice(mean);
        self.step = step;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.mu.len()
    }

    fn state_bytes(&self) -> usize {
        self.mu.len() * 4 // the O(d) policy mean — the paper's memory claim
    }

    fn name(&self) -> &str {
        "ldsd"
    }

    fn policy_mean(&self) -> Option<&[f32]> {
        Some(&self.mu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{axpy, cosine, dot};

    #[test]
    fn init_norm_respected() {
        let s = LdsdSampler::new(512, 1, LdsdConfig { init_norm: 2.5, ..Default::default() });
        assert!((s.mu_norm() - 2.5).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn zero_init_rejected() {
        let _ = LdsdSampler::new(8, 1, LdsdConfig { init_norm: 0.0, ..Default::default() });
    }

    #[test]
    fn sample_mean_is_mu() {
        let d = 64;
        let mut s = LdsdSampler::new(
            d, 7, LdsdConfig { eps: 0.5, init_norm: 3.0, ..Default::default() },
        );
        let k = 400;
        let mut dirs = vec![0.0f32; k * d];
        s.sample(&mut dirs, k);
        let mut mean = vec![0.0f32; d];
        for i in 0..k {
            axpy(1.0 / k as f32, &dirs[i * d..(i + 1) * d], &mut mean);
        }
        let mu = s.policy_mean().unwrap();
        let cos = cosine(&mean, mu);
        assert!(cos > 0.95, "empirical mean should align with mu, cos={cos}");
    }

    #[test]
    fn gamma_zero_keeps_policy_fixed() {
        // LDSD with gamma_mu = 0 must behave as a frozen-mean sampler —
        // observe() is a no-op on mu.
        let d = 32;
        let mut s = LdsdSampler::new(
            d, 3, LdsdConfig { gamma_mu: 0.0, ..Default::default() },
        );
        let mu0 = s.policy_mean().unwrap().to_vec();
        let k = 5;
        let mut dirs = vec![0.0f32; k * d];
        s.sample(&mut dirs, k);
        let losses = vec![1.0f64, 2.0, 3.0, 4.0, 5.0];
        s.observe(&dirs, &losses, k);
        assert_eq!(s.policy_mean().unwrap(), &mu0[..]);
    }

    #[test]
    fn policy_moves_toward_low_loss_direction() {
        // Construct losses that are lowest for directions aligned with a
        // target t; after many updates mu should rotate toward t.
        let d = 16;
        let mut s = LdsdSampler::new(
            d,
            11,
            LdsdConfig { eps: 1.0, gamma_mu: 0.05, ..Default::default() },
        );
        let mut target = vec![0.0f32; d];
        target[0] = 1.0;
        let k = 8;
        let mut dirs = vec![0.0f32; k * d];
        let cos_before = cosine(s.policy_mean().unwrap(), &target).abs();
        for _ in 0..300 {
            s.sample(&mut dirs, k);
            // loss decreases with alignment: f = -<v, t>
            let losses: Vec<f64> = (0..k)
                .map(|i| -dot(&dirs[i * d..(i + 1) * d], &target) as f64)
                .collect();
            s.observe(&dirs, &losses, k);
        }
        let cos_after = cosine(s.policy_mean().unwrap(), &target);
        assert!(
            cos_after > 0.9 && cos_after > cos_before,
            "cos before {cos_before}, after {cos_after}"
        );
    }

    #[test]
    fn paper_sign_moves_away_from_low_loss() {
        // reward_sign = +1 (the literal printed update) must do the
        // opposite: mu drifts toward HIGH loss directions.
        let d = 16;
        let mut s = LdsdSampler::new(
            d,
            11,
            LdsdConfig {
                eps: 1.0,
                gamma_mu: 0.05,
                reward_sign: 1.0,
                ..Default::default()
            },
        );
        let mut target = vec![0.0f32; d];
        target[0] = 1.0;
        let k = 8;
        let mut dirs = vec![0.0f32; k * d];
        for _ in 0..300 {
            s.sample(&mut dirs, k);
            let losses: Vec<f64> = (0..k)
                .map(|i| -dot(&dirs[i * d..(i + 1) * d], &target) as f64)
                .collect();
            s.observe(&dirs, &losses, k);
        }
        let cos_after = cosine(s.policy_mean().unwrap(), &target);
        assert!(cos_after < -0.5, "expected anti-alignment, cos={cos_after}");
    }

    #[test]
    fn ldsd_replay_bitwise_matches_sample() {
        let d = 233; // misaligned with shard_len on purpose
        let k = 4;
        let ctx = crate::exec::ExecContext::new(1).with_shard_len(64);
        let mk = || {
            let mut s = LdsdSampler::new(d, 13, LdsdConfig { eps: 0.7, ..Default::default() });
            s.set_exec(ctx.clone());
            s
        };
        let mut mat = mk();
        let mut dirs = vec![0.0f32; k * d];
        mat.sample(&mut dirs, k);
        let mut rep = mk();
        rep.advance_step();
        let mut scratch = vec![0.0f32; 64];
        for (row, col0, len) in [(0usize, 0usize, d), (3, 100, 64), (1, 230, 3)] {
            let mut piece = vec![0.0f32; len];
            rep.fill_row_range(k, row, col0, &mut piece, &mut scratch);
            for (i, v) in piece.iter().enumerate() {
                assert_eq!(v.to_bits(), dirs[row * d + col0 + i].to_bits());
            }
        }
    }

    #[test]
    fn observe_replay_bitwise_matches_observe() {
        // the streamed policy update must walk the identical mu trajectory
        let d = 500;
        let k = 5;
        for threads in [1usize, 4] {
            let ctx = crate::exec::ExecContext::new(threads).with_shard_len(96);
            let mk = || {
                let mut s = LdsdSampler::new(d, 21, LdsdConfig::default());
                s.set_exec(ctx.clone());
                s
            };
            let mut mat = mk();
            let mut rep = mk();
            let mut dirs = vec![0.0f32; k * d];
            for step in 0..6 {
                mat.sample(&mut dirs, k);
                rep.advance_step();
                let losses: Vec<f64> =
                    (0..k).map(|i| ((i * 3 + step) % 7) as f64 * 0.2 - 0.5).collect();
                mat.observe(&dirs, &losses, k);
                rep.observe_replay(&losses, k);
                for (a, b) in mat.policy_mean().unwrap().iter().zip(rep.policy_mean().unwrap()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "mu diverged (t={threads})");
                }
            }
        }
    }

    #[test]
    fn k1_observe_is_noop() {
        let d = 8;
        let mut s = LdsdSampler::new(d, 2, LdsdConfig::default());
        let mu0 = s.policy_mean().unwrap().to_vec();
        let mut dirs = vec![0.0f32; d];
        s.sample(&mut dirs, 1);
        s.observe(&dirs, &[1.0], 1);
        assert_eq!(s.policy_mean().unwrap(), &mu0[..]);
    }

    #[test]
    fn restore_state_continues_identically() {
        // snapshot (step label + mu) after a few learning steps; a twin
        // restored from it must sample the same directions and walk the
        // same mu trajectory bit for bit
        let d = 64;
        let k = 4;
        let mut a = LdsdSampler::new(d, 31, LdsdConfig::default());
        let mut dirs = vec![0.0f32; k * d];
        for step in 0..5 {
            a.sample(&mut dirs, k);
            let losses: Vec<f64> = (0..k).map(|i| ((i + step) % 3) as f64).collect();
            a.observe(&dirs, &losses, k);
        }
        let (step_label, mu) = (a.step_label(), a.policy_mean().unwrap().to_vec());
        assert_eq!(step_label, 5);
        let mut b = LdsdSampler::new(d, 31, LdsdConfig::default());
        b.restore_state(step_label, Some(&mu)).unwrap();
        let mut da = vec![0.0f32; k * d];
        let mut db = vec![0.0f32; k * d];
        for step in 0..3 {
            a.sample(&mut da, k);
            b.sample(&mut db, k);
            for (x, y) in da.iter().zip(db.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "directions diverged");
            }
            let losses: Vec<f64> = (0..k).map(|i| (i * step) as f64 * 0.1).collect();
            a.observe(&da, &losses, k);
            b.observe(&db, &losses, k);
            for (x, y) in a.policy_mean().unwrap().iter().zip(b.policy_mean().unwrap()) {
                assert_eq!(x.to_bits(), y.to_bits(), "mu diverged");
            }
        }
        // restoring without a mean is an error for a learnable policy
        assert!(b.restore_state(1, None).is_err());
    }

    #[test]
    fn renormalize_keeps_norm() {
        let d = 32;
        let mut s = LdsdSampler::new(
            d,
            5,
            LdsdConfig {
                renormalize: true,
                init_norm: 1.0,
                gamma_mu: 0.1,
                ..Default::default()
            },
        );
        let k = 4;
        let mut dirs = vec![0.0f32; k * d];
        for step in 0..20 {
            s.sample(&mut dirs, k);
            let losses: Vec<f64> =
                (0..k).map(|i| (i + step) as f64 * 0.1).collect();
            s.observe(&dirs, &losses, k);
            assert!((s.mu_norm() - 1.0).abs() < 1e-4);
        }
    }
}
