//! Gradient-alignment diagnostics: the paper's C^t = <v̄, ∇f̄>^2 and its
//! expectation E[C^t | F^{t-1}] (Figs. 1-2, Lemma 2).

use crate::rng::Rng;
use crate::tensor::{cosine, nrm2};

/// Monte-Carlo estimate of E[ <v̄, ḡ>^2 ] for v ~ N(mu, eps^2 I).
/// This is the landscape function of Fig. 1 evaluated at one (mu, g).
pub fn expected_alignment_mc(
    mu: &[f32],
    grad: &[f32],
    eps: f32,
    n_samples: usize,
    seed: u64,
) -> f64 {
    assert_eq!(mu.len(), grad.len());
    let d = mu.len();
    let gn = nrm2(grad) as f64;
    if gn <= f64::from(f32::MIN_POSITIVE) {
        return 0.0;
    }
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; d];
    let mut acc = 0.0f64;
    for _ in 0..n_samples {
        rng.fill_normal(&mut v);
        for (vi, mi) in v.iter_mut().zip(mu.iter()) {
            *vi = mi + eps * *vi;
        }
        let c = cosine(&v, grad) as f64;
        acc += c * c;
    }
    acc / n_samples as f64
}

/// Running statistics of the realized alignment cos(g_est, grad f) along a
/// training trajectory (the Fig. 2 left panel series).
#[derive(Clone, Debug, Default)]
pub struct AlignmentTracker {
    /// All recorded cosines in order.
    pub series: Vec<f32>,
}

impl AlignmentTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record cos(estimate, true_grad) and return it.
    pub fn record(&mut self, estimate: &[f32], true_grad: &[f32]) -> f32 {
        let c = cosine(estimate, true_grad);
        self.series.push(c);
        c
    }

    /// Most recently recorded alignment.
    pub fn last(&self) -> Option<f32> {
        self.series.last().copied()
    }

    /// Mean of the last `n` recorded alignments.
    pub fn tail_mean(&self, n: usize) -> f32 {
        if self.series.is_empty() {
            return 0.0;
        }
        let start = self.series.len().saturating_sub(n);
        let tail = &self.series[start..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Corollary 1: for mu = 0 the expected alignment is exactly 1/d.
    #[test]
    fn zero_mean_alignment_is_one_over_d() {
        for d in [16usize, 64, 256] {
            let mu = vec![0.0f32; d];
            let mut g = vec![0.0f32; d];
            g[0] = 1.0;
            let c = expected_alignment_mc(&mu, &g, 1.0, 20_000, 42);
            let expect = 1.0 / d as f64;
            assert!(
                (c - expect).abs() < 0.35 * expect + 2e-4,
                "d={d}: mc {c} vs 1/d {expect}"
            );
        }
    }

    /// Aligned mu with small eps gives alignment near 1 — the O(1) regime
    /// of Lemma 2.
    #[test]
    fn aligned_mean_alignment_near_one() {
        let d = 128;
        let mut mu = vec![0.0f32; d];
        mu[0] = 1.0;
        let mut g = vec![0.0f32; d];
        g[0] = 2.0;
        let c = expected_alignment_mc(&mu, &g, 0.01, 2_000, 7);
        assert!(c > 0.98, "c = {c}");
    }

    /// Orthogonal mu with tiny eps gives alignment near 0 (the saddle
    /// valley of Fig. 1).
    #[test]
    fn orthogonal_mean_alignment_near_zero() {
        let d = 128;
        let mut mu = vec![0.0f32; d];
        mu[1] = 1.0;
        let mut g = vec![0.0f32; d];
        g[0] = 1.0;
        let c = expected_alignment_mc(&mu, &g, 0.01, 2_000, 7);
        assert!(c < 0.02, "c = {c}");
    }

    #[test]
    fn tracker_tail_mean() {
        let mut t = AlignmentTracker::new();
        let g = [1.0f32, 0.0];
        t.record(&[1.0, 0.0], &g);
        t.record(&[0.0, 1.0], &g);
        t.record(&[1.0, 0.0], &g);
        assert_eq!(t.series.len(), 3);
        assert!((t.tail_mean(2) - 0.5).abs() < 1e-6);
        assert_eq!(t.last(), Some(1.0));
    }
}
