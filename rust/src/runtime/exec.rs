//! Executable wrapper + argument marshalling for PJRT execution.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// A host-side view of one executable argument.
///
/// Shapes follow the artifact manifest; scalars are rank-0.
#[derive(Clone, Copy, Debug)]
pub enum ArgValue<'a> {
    /// f32 array view with dims.
    F32(&'a [f32], &'a [usize]),
    /// i32 array view with dims.
    I32(&'a [i32], &'a [usize]),
    /// rank-0 f32.
    ScalarF32(f32),
}

/// A device-resident buffer (wrapper so callers never touch xla types).
pub struct DeviceBuffer {
    pub(crate) buf: xla::PjRtBuffer,
    /// Element count of the uploaded array.
    pub elements: usize,
}

/// One argument for the hot-path entry point: either already on device or a
/// host view to upload for this call.
pub enum Arg<'a> {
    /// Pre-uploaded device buffer (no transfer this call).
    Device(&'a DeviceBuffer),
    /// Host view uploaded for this call only.
    Host(ArgValue<'a>),
}

pub(crate) fn upload_f32(
    client: &xla::PjRtClient,
    data: &[f32],
    dims: &[usize],
) -> Result<DeviceBuffer> {
    let buf = client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("uploading f32{dims:?}: {e:?}"))?;
    Ok(DeviceBuffer { buf, elements: data.len() })
}

pub(crate) fn upload_i32(
    client: &xla::PjRtClient,
    data: &[i32],
    dims: &[usize],
) -> Result<DeviceBuffer> {
    let buf = client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("uploading i32{dims:?}: {e:?}"))?;
    Ok(DeviceBuffer { buf, elements: data.len() })
}

/// A compiled artifact.  All artifact graphs return a tuple (jax lowering
/// uses `return_tuple=True`), so outputs decompose into flat f32 vectors.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    /// Artifact name (runtime cache key), used in error messages.
    pub name: String,
}

impl Executable {
    pub(crate) fn compile(
        client: &xla::PjRtClient,
        path: &Path,
        name: &str,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {name}: {e:?}"))?;
        Ok(Self { exe, client: client.clone(), name: name.to_string() })
    }

    /// Execute with host arguments only; returns each tuple element as a
    /// flat f32 vector.
    pub fn run(&self, args: &[ArgValue<'_>]) -> Result<Vec<Vec<f32>>> {
        let wrapped: Vec<Arg<'_>> = args.iter().map(|a| Arg::Host(*a)).collect();
        self.run_with_device(&wrapped)
    }

    /// Hot-path execute: mix of device-resident and host arguments.
    pub fn run_with_device(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        // Temporary uploads must outlive the execute call.
        let mut temps: Vec<DeviceBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::new(); // index into temps or marker
        const DEVICE: usize = usize::MAX;
        for a in args {
            match a {
                Arg::Device(_) => order.push(DEVICE),
                Arg::Host(h) => {
                    let t = match h {
                        ArgValue::F32(data, dims) => {
                            upload_f32(&self.client, data, dims)?
                        }
                        ArgValue::I32(data, dims) => {
                            upload_i32(&self.client, data, dims)?
                        }
                        ArgValue::ScalarF32(x) => {
                            upload_f32(&self.client, &[*x], &[])?
                        }
                    };
                    order.push(temps.len());
                    temps.push(t);
                }
            }
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut ti = 0usize;
        for (a, o) in args.iter().zip(order.iter()) {
            match a {
                Arg::Device(d) => bufs.push(&d.buf),
                Arg::Host(_) => {
                    bufs.push(&temps[*o].buf);
                    ti += 1;
                }
            }
        }
        debug_assert_eq!(ti, temps.len());
        let outs = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let first = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: no output buffers", self.name))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: output download: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: output is not a tuple: {e:?}", self.name))?;
        parts
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                p.to_vec::<f32>().map_err(|e| {
                    anyhow!("{}: output {i} is not f32: {e:?}", self.name)
                })
            })
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("collecting outputs of {}", self.name))
    }
}
