//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate.  The interchange
//! format is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see /opt/xla-example).
//!
//! Design notes:
//! * [`Runtime`] owns the PJRT CPU client; [`Executable`]s are compiled
//!   once and cached by artifact name ([`Runtime::load`] is idempotent).
//! * Arguments go host->device through [`ArgValue`] views (no copies on
//!   the rust side beyond the PJRT transfer itself).
//! * For the hot loop, [`Executable::run_with_device`] accepts
//!   pre-uploaded [`DeviceBuffer`]s so large constants (model parameters,
//!   frozen LoRA bases) are transferred once per update, not per probe.

mod exec;

#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

#[cfg(not(feature = "pjrt"))]
use xla_stub as xla;

pub use exec::{Arg, ArgValue, DeviceBuffer, Executable};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// Shared PJRT client + compiled-executable cache.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

struct RuntimeInner {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at the artifact directory.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            inner: Arc::new(RuntimeInner {
                client,
                dir: artifact_dir.as_ref().to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    /// The artifact directory this runtime loads from.
    pub fn artifact_dir(&self) -> &Path {
        &self.inner.dir
    }

    /// PJRT platform name ("cpu", ...).
    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached; concurrent calls compile once).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        {
            let cache = self.inner.cache.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
        }
        let path = self.inner.dir.join(format!("{name}.hlo.txt"));
        let exe = Arc::new(
            Executable::compile(&self.inner.client, &path, name)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        let mut cache = self.inner.cache.lock().unwrap();
        Ok(cache.entry(name.to_string()).or_insert(exe).clone())
    }

    /// Upload a host f32 array to the device (kept resident until dropped).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
        exec::upload_f32(&self.inner.client, data, dims)
    }

    /// Upload a host i32 array to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
        exec::upload_i32(&self.inner.client, data, dims)
    }

    pub(crate) fn client(&self) -> &xla::PjRtClient {
        &self.inner.client
    }

    /// Names of artifacts currently compiled into the cache.
    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.inner.cache.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}
