//! Inert stand-in for the external `xla` bindings crate (used when the
//! `pjrt` cargo feature is off, which is the default).
//!
//! The real PJRT path needs `xla` (XLA/PJRT FFI bindings), which is not
//! vendorable in an offline build.  This stub mirrors exactly the API
//! surface `runtime/` touches with *uninhabited* types: every constructor
//! returns [`XlaError`], so the whole crate type-checks and the non-PJRT
//! stack (closed-form oracles, training loops, benches) runs normally,
//! while [`PjRtClient::cpu`] fails with a descriptive message at runtime.
//!
//! With `--features pjrt` this module is compiled out and the plain `xla::`
//! paths in `runtime/` resolve to the real extern crate (which must then be
//! added to rust/Cargo.toml).

use std::path::Path;

/// Error produced by every stub entry point.
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend not compiled in: build with `--features pjrt` and the \
         `xla` dependency to execute AOT artifacts"
            .to_string(),
    )
}

/// Uninhabited stand-in for `xla::PjRtClient`.
#[derive(Clone, Debug)]
pub enum PjRtClient {}

/// Uninhabited stand-in for `xla::PjRtDevice`.
#[derive(Debug)]
pub enum PjRtDevice {}

/// Uninhabited stand-in for `xla::PjRtBuffer`.
#[derive(Debug)]
pub enum PjRtBuffer {}

/// Uninhabited stand-in for `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub enum PjRtLoadedExecutable {}

/// Uninhabited stand-in for `xla::HloModuleProto`.
#[derive(Debug)]
pub enum HloModuleProto {}

/// Uninhabited stand-in for `xla::XlaComputation`.
#[derive(Debug)]
pub enum XlaComputation {}

/// Uninhabited stand-in for `xla::Literal`.
#[derive(Debug)]
pub enum Literal {}

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, XlaError> {
        match *self {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        match *self {}
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, XlaError> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match *proto {}
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        match *self {}
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        match *self {}
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_missing_backend() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_hlo_loader_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
