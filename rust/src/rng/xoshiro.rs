//! xoshiro256++ — general-purpose stream for samplers/optimizers.

use super::splitmix::SplitMix64;

/// The xoshiro256++ generator (Blackman & Vigna), 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed the full 256-bit state from a 64-bit seed via SplitMix64,
    /// as recommended by the xoshiro authors.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_short_cycles_and_nonzero() {
        let mut r = Xoshiro256::seeded(1);
        let first = r.next_u64();
        let mut repeated = false;
        for _ in 0..100_000 {
            if r.next_u64() == first {
                repeated = true;
            }
        }
        // A 2^256-period generator repeating a value occasionally is fine;
        // repeating the full starting value immediately is not.
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(1);
        assert_eq!(a.next_u64(), b.next_u64());
        let _ = repeated;
    }

    #[test]
    fn different_seeds_different_streams() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
