//! SplitMix64 — the data-pipeline ABI generator.
//!
//! Bit-identical to `python/compile/corpus.py::SplitMix64`: the synthetic
//! corpus is generated statelessly from (seed, example-index) on both sides
//! of the language boundary and golden-tested for equality.

/// Weyl-sequence increment (2^64 / golden ratio) shared with python.
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The SplitMix64 generator (Steele et al.), 64-bit state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start a stream at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits — matches python `next_f64`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference values computed with the python implementation:
    /// SplitMix64(0).next_u64() x4 and SplitMix64(0x5EED) x2.
    #[test]
    fn matches_python_stream() {
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
        assert_eq!(r.next_u64(), 0xF88B_B8A8_724C_81EC);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(0x5EED);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
