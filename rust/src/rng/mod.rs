//! Deterministic random-number substrate (replaces the `rand` crate).
//!
//! Two generators:
//! * [`SplitMix64`] — the corpus/data ABI generator.  Must stay
//!   bit-identical to `python/compile/corpus.py::SplitMix64`; the golden
//!   tests in `rust/tests/` pin this.
//! * [`Xoshiro256`] — the general-purpose stream used by samplers and
//!   optimizers (seeded from SplitMix64 per the xoshiro authors'
//!   recommendation).
//!
//! Gaussian variates come from [`Normal`], a Box–Muller transform with a
//! cached spare, so direction sampling needs one generator state and no
//! allocation.

mod normal;
mod splitmix;
mod xoshiro;

pub use normal::Normal;
pub use splitmix::{SplitMix64, GOLDEN_GAMMA};
pub use xoshiro::Xoshiro256;

/// Convenience: a seeded xoshiro stream with Gaussian support.
#[derive(Clone, Debug)]
pub struct Rng {
    core: Xoshiro256,
    normal: Normal,
}

impl Rng {
    /// Seed a new stream (full 256-bit state derived via SplitMix64).
    pub fn new(seed: u64) -> Self {
        Self { core: Xoshiro256::seeded(seed), normal: Normal::new() }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.core.next_u64()
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.core.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.core.next_u64() % n
    }

    /// Standard normal.
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let core = &mut self.core;
        self.normal.sample(|| core.next_u64())
    }

    /// Fill `out` with iid N(0, 1) samples (f32).
    ///
    /// Hot path: FT-mode LDSD draws K x d normals per step (6.6M for
    /// roberta_mini), so this runs a tight pairwise Box–Muller loop with
    /// one `sin_cos` per two outputs instead of going through the cached-
    /// spare scalar path (§Perf in EXPERIMENTS.md).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        const TWO_PI: f64 = 2.0 * std::f64::consts::PI;
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        let mut chunks = out.chunks_exact_mut(2);
        for pair in &mut chunks {
            let u1 = ((self.core.next_u64() >> 11) as f64 + 1.0) * SCALE;
            let u2 = (self.core.next_u64() >> 11) as f64 * SCALE;
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (TWO_PI * u2).sin_cos();
            pair[0] = (r * c) as f32;
            pair[1] = (r * s) as f32;
        }
        if let [last] = chunks.into_remainder() {
            *last = self.normal() as f32;
        }
    }

    /// Derive an independent child stream (for per-trial seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut mixer = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(GOLDEN_GAMMA));
        Rng::new(mixer.next_u64())
    }
}

/// Deterministic per-(seed, step, shard) substream for shard-parallel
/// sampling: two SplitMix64 hops mix the step and shard tags into the
/// base seed, and the result seeds an independent xoshiro stream.
///
/// The samplers key one substream per (step, shard) cell of their probe-
/// matrix fill, with shard boundaries fixed by
/// [`crate::exec::ExecContext::shard_len`] — the draw for every element is
/// a pure function of (seed, step, shard, offset), independent of worker
/// count and schedule, which is what makes parallel sampling bitwise
/// reproducible (DESIGN.md §9).
pub fn substream(seed: u64, step: u64, shard: u64) -> Rng {
    let mut outer = SplitMix64::new(seed ^ step.wrapping_mul(GOLDEN_GAMMA));
    let mixed = outer.next_u64();
    let mut inner = SplitMix64::new(mixed ^ shard.wrapping_mul(GOLDEN_GAMMA));
    Rng::new(inner.next_u64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_bound() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 17, 1000] {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn substreams_deterministic_and_distinct_per_cell() {
        let draw = |seed, step, shard| -> Vec<u64> {
            let mut r = substream(seed, step, shard);
            (0..4).map(|_| r.next_u64()).collect()
        };
        // pure function of the cell
        assert_eq!(draw(7, 3, 2), draw(7, 3, 2));
        // any coordinate change moves the stream
        assert_ne!(draw(7, 3, 2), draw(8, 3, 2));
        assert_ne!(draw(7, 4, 2), draw(7, 3, 2));
        assert_ne!(draw(7, 3, 1), draw(7, 3, 2));
        // neighbouring (step, shard) cells don't alias each other
        assert_ne!(draw(7, 0, 1), draw(7, 1, 0));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
