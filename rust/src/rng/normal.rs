//! Box–Muller Gaussian sampling with a cached spare variate.

/// Box–Muller transform state (caches the second variate of each pair).
#[derive(Clone, Debug, Default)]
pub struct Normal {
    spare: Option<f64>,
}

impl Normal {
    /// New transform state with no cached spare.
    pub fn new() -> Self {
        Self { spare: None }
    }

    /// Draw one standard-normal sample, pulling u64s from `next`.
    #[inline]
    pub fn sample<F: FnMut() -> u64>(&mut self, mut next: F) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0, 1] to keep ln() finite; u2 in [0, 1)
        let u1 = ((next() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64);
        let u2 = (next() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    #[test]
    fn finite_and_symmetricish() {
        let mut sm = SplitMix64::new(3);
        let mut n = Normal::new();
        let mut pos = 0usize;
        let total = 100_000;
        for _ in 0..total {
            let z = n.sample(|| sm.next_u64());
            assert!(z.is_finite());
            if z > 0.0 {
                pos += 1;
            }
        }
        let frac = pos as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.01, "positive fraction {frac}");
    }

    #[test]
    fn spare_is_used() {
        let mut sm = SplitMix64::new(9);
        let mut n = Normal::new();
        let mut draws = 0usize;
        let _a = n.sample(|| {
            draws += 1;
            sm.next_u64()
        });
        let _b = n.sample(|| {
            draws += 1;
            sm.next_u64()
        });
        assert_eq!(draws, 2, "second sample must come from the cached spare");
    }
}
