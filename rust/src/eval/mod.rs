//! Accuracy evaluation: the AOT `logits` artifact ([`Evaluator`]) and the
//! host-side MLP/transformer forwards ([`MlpEvaluator`],
//! [`TransformerEvaluator`]), behind one [`AccuracyEval`] interface the
//! trainer scores through.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ModelEntry, TrainMode};
use crate::data::{Batch, Corpus};
use crate::model::mlp::{forward_example, MlpSpec, MlpState};
use crate::model::transformer::{self, TransformerSpec, TransformerState};
use crate::oracle::hash_features;
use crate::runtime::{Arg, DeviceBuffer, Executable, Runtime};

/// Test-set accuracy scoring, abstracted over the backend so the trainer
/// works with both the PJRT logits artifact and host-side forward-only
/// oracles (the MLP) — see [`crate::train::Trainer::run`].
pub trait AccuracyEval {
    /// Accuracy of `trainable` over `n_batches` test batches of `corpus`.
    fn accuracy(&self, trainable: &[f32], corpus: &Corpus, n_batches: usize) -> Result<f64>;
}

/// Evaluates test-set accuracy for one (model, mode) pair.  Holds its own
/// frozen-base device buffer (LoRA mode) so evaluation never perturbs the
/// training oracle's state.
pub struct Evaluator {
    rt: Runtime,
    exe: Arc<Executable>,
    entry: ModelEntry,
    mode: TrainMode,
    base_dev: Option<DeviceBuffer>,
}

impl Evaluator {
    /// Compile the logits artifact and stage the frozen base (LoRA mode).
    pub fn new(rt: &Runtime, entry: &ModelEntry, mode: TrainMode) -> Result<Self> {
        let exe = rt.load(&entry.artifact(mode, "logits"))?;
        let base_dev = match mode {
            TrainMode::Ft => None,
            TrainMode::Lora => {
                let base = crate::oracle::read_params_bin(
                    &rt.artifact_dir().join(&entry.params_file),
                    entry.d_ft,
                )?;
                Some(
                    rt.upload_f32(&base, &[entry.d_ft])
                        .context("uploading eval base params")?,
                )
            }
        };
        Ok(Self { rt: rt.clone(), exe, entry: entry.clone(), mode, base_dev })
    }

    /// Accuracy of `trainable` over `n_batches` eval-batch test batches.
    pub fn accuracy(
        &self,
        trainable: &[f32],
        corpus: &Corpus,
        n_batches: usize,
    ) -> Result<f64> {
        let s = self.entry.shapes;
        let d_expect = self.entry.d_trainable(self.mode);
        if trainable.len() != d_expect {
            bail!(
                "trainable len {} != expected {d_expect} for {} {}",
                trainable.len(), self.entry.name, self.mode.as_str()
            );
        }
        let t_dev = self.rt.upload_f32(trainable, &[trainable.len()])?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let batch = corpus.test_batch(bi as u64, s.eval_batch);
            let logits = self.logits(&t_dev, &batch)?;
            for (b, &label) in batch.labels.iter().enumerate() {
                let row = &logits[b * s.n_classes..(b + 1) * s.n_classes];
                if argmax(row) == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Raw logits for one eval batch (row-major [eval_batch, n_classes]).
    pub fn logits(&self, t_dev: &DeviceBuffer, batch: &Batch) -> Result<Vec<f32>> {
        let s = self.entry.shapes;
        if batch.batch != s.eval_batch || batch.seq != s.seq {
            bail!(
                "eval batch shape [{}, {}] != artifact [{}, {}]",
                batch.batch, batch.seq, s.eval_batch, s.seq
            );
        }
        let ids = self.rt.upload_i32(&batch.ids, &[batch.batch, batch.seq])?;
        let mask = self.rt.upload_f32(&batch.mask, &[batch.batch, batch.seq])?;
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(4);
        if let Some(bd) = &self.base_dev {
            args.push(Arg::Device(bd));
        }
        args.push(Arg::Device(t_dev));
        args.push(Arg::Device(&ids));
        args.push(Arg::Device(&mask));
        let out = self.exe.run_with_device(&args)?;
        Ok(out.into_iter().next().unwrap_or_default())
    }
}

impl AccuracyEval for Evaluator {
    fn accuracy(&self, trainable: &[f32], corpus: &Corpus, n_batches: usize) -> Result<f64> {
        Evaluator::accuracy(self, trainable, corpus, n_batches)
    }
}

/// Host-side accuracy evaluation for the forward-only MLP oracle: hashed
/// bag-of-token features, one forward per test example, argmax over the
/// logits.  No artifacts or runtime needed.
pub struct MlpEvaluator {
    spec: MlpSpec,
    eval_batch: usize,
}

impl MlpEvaluator {
    /// Build for an MLP architecture and a test-batch size.
    pub fn new(spec: MlpSpec, eval_batch: usize) -> Self {
        Self { spec, eval_batch: eval_batch.max(1) }
    }
}

impl AccuracyEval for MlpEvaluator {
    fn accuracy(&self, trainable: &[f32], corpus: &Corpus, n_batches: usize) -> Result<f64> {
        if trainable.len() != self.spec.dim() {
            bail!(
                "mlp eval: trainable len {} != spec dim {}",
                trainable.len(),
                self.spec.dim()
            );
        }
        let in_dim = self.spec.in_dim;
        let mut state = MlpState::new(&self.spec);
        let mut row = vec![0.0f32; in_dim];
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let batch = corpus.test_batch(bi as u64, self.eval_batch);
            for b in 0..batch.batch {
                hash_features(
                    &batch.ids[b * batch.seq..(b + 1) * batch.seq],
                    &batch.mask[b * batch.seq..(b + 1) * batch.seq],
                    in_dim,
                    &mut row,
                );
                let logits = forward_example(&self.spec, trainable, &row, &mut state);
                if argmax(logits) == batch.labels[b] as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

/// Host-side accuracy evaluation for the transformer oracle: one forward
/// per test example, argmax over the logits.  Holds its own frozen base
/// clone (LoRA mode) so evaluation never perturbs the training oracle's
/// state; in FT mode the trainable vector *is* the base and the stored
/// copy is unused.
pub struct TransformerEvaluator {
    spec: TransformerSpec,
    mode: TrainMode,
    /// Frozen base vector (consulted in LoRA mode only).
    base: Vec<f32>,
    eval_batch: usize,
}

impl TransformerEvaluator {
    /// Build for an architecture, mode, frozen base and test-batch size.
    pub fn new(
        spec: TransformerSpec,
        mode: TrainMode,
        base: Vec<f32>,
        eval_batch: usize,
    ) -> Result<Self> {
        if base.len() != spec.d_ft() {
            bail!(
                "transformer eval: base holds {} f32, spec wants d_ft {}",
                base.len(),
                spec.d_ft()
            );
        }
        Ok(Self { spec, mode, base, eval_batch: eval_batch.max(1) })
    }
}

impl AccuracyEval for TransformerEvaluator {
    fn accuracy(&self, trainable: &[f32], corpus: &Corpus, n_batches: usize) -> Result<f64> {
        let d_expect = match self.mode {
            TrainMode::Ft => self.spec.d_ft(),
            TrainMode::Lora => self.spec.d_lora(),
        };
        if trainable.len() != d_expect {
            bail!(
                "transformer eval: trainable len {} != expected {d_expect} for mode {}",
                trainable.len(),
                self.mode.as_str()
            );
        }
        if corpus.spec.seq > self.spec.max_seq {
            bail!(
                "transformer eval: corpus seq {} exceeds max_seq {}",
                corpus.spec.seq,
                self.spec.max_seq
            );
        }
        let mut state = TransformerState::new(&self.spec);
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let batch = corpus.test_batch(bi as u64, self.eval_batch);
            for b in 0..batch.batch {
                let ids = &batch.ids[b * batch.seq..(b + 1) * batch.seq];
                let mask = &batch.mask[b * batch.seq..(b + 1) * batch.seq];
                let logits = match self.mode {
                    TrainMode::Ft => transformer::forward_example(
                        &self.spec, trainable, None, ids, mask, &mut state,
                    ),
                    TrainMode::Lora => transformer::forward_example(
                        &self.spec,
                        &self.base,
                        Some(trainable),
                        ids,
                        mask,
                        &mut state,
                    ),
                };
                if argmax(logits) == batch.labels[b] as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

/// Index of the largest element (first wins on ties).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 0.9]), 1);
        assert_eq!(argmax(&[3.0, -1.0, 2.0]), 0);
        assert_eq!(argmax(&[1.0]), 0);
    }

    #[test]
    fn argmax_ties_pick_first() {
        assert_eq!(argmax(&[0.5, 0.5]), 0);
    }

    #[test]
    fn transformer_evaluator_scores_in_unit_interval() {
        use crate::data::corpus::CorpusSpec;
        use crate::model::Pool;
        let spec =
            TransformerSpec::new(64, 16, 2, 2, 32, 8, 2, false, Pool::Cls, 2).unwrap();
        let base = spec.init_base(1);
        let lora = spec.init_lora(1, Some(&base));
        let ev =
            TransformerEvaluator::new(spec.clone(), TrainMode::Lora, base.clone(), 8)
                .unwrap();
        let corpus_spec = CorpusSpec {
            vocab: 64,
            seq: 8,
            lexicon: 16,
            min_len: 4,
            signal_min: 1,
            signal_max: 3,
            ..CorpusSpec::default_mini()
        };
        let corpus = Corpus::new(corpus_spec).unwrap();
        let acc = ev.accuracy(&lora, &corpus, 2).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        // size mismatches fail loudly
        assert!(ev.accuracy(&base, &corpus, 1).is_err());
        // pure function: same trainable, same score
        let again = ev.accuracy(&lora, &corpus, 2).unwrap();
        assert_eq!(acc.to_bits(), again.to_bits());
        // a too-long corpus sequence is rejected up front
        let long = Corpus::new(CorpusSpec {
            vocab: 64,
            lexicon: 16,
            ..CorpusSpec::default_mini()
        })
        .unwrap();
        assert!(ev.accuracy(&lora, &long, 1).is_err());
    }

    #[test]
    fn mlp_evaluator_scores_in_unit_interval() {
        use crate::data::corpus::CorpusSpec;
        use crate::model::mlp::{Activation, MlpSpec};
        let spec = MlpSpec::new(16, vec![8], 2, Activation::Tanh).unwrap();
        let ev = MlpEvaluator::new(spec.clone(), 8);
        let corpus = Corpus::new(CorpusSpec::default_mini()).unwrap();
        let acc = ev.accuracy(&spec.init_params(1), &corpus, 2).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        // size mismatches fail loudly
        assert!(ev.accuracy(&[0.0; 3], &corpus, 1).is_err());
        // the same params always score the same (pure function)
        let again = ev.accuracy(&spec.init_params(1), &corpus, 2).unwrap();
        assert_eq!(acc.to_bits(), again.to_bits());
    }
}
