//! Minimal JSON substrate (replaces serde_json): recursive-descent parser
//! and writer for the artifact manifest, golden files and report output.
//!
//! Scope: full JSON grammar with f64 numbers, UTF-8 strings with the
//! standard escapes, no trailing commas, no comments.  Numbers are stored
//! as f64; integer accessors check exactness.

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::{to_string_canonical, to_string_pretty};

use std::collections::BTreeMap;

/// A parsed JSON value (numbers stored as f64, objects key-sorted).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (deterministic key order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns a descriptive error — manifest loading wants
    /// hard failures with context, not silent `None`s.
    pub fn field(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Exact non-negative integer value, if any.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Exact non-negative integer value as u64, if any.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object view, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a JSON array of numbers into f32s.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        arr.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    /// Flatten a (possibly nested) JSON array of numbers into f32s,
    /// row-major.
    pub fn to_f32_vec_nested(&self) -> Option<Vec<f32>> {
        let mut out = Vec::new();
        fn rec(j: &Json, out: &mut Vec<f32>) -> Option<()> {
            match j {
                Json::Num(x) => {
                    out.push(*x as f32);
                    Some(())
                }
                Json::Arr(v) => {
                    for e in v {
                        rec(e, out)?;
                    }
                    Some(())
                }
                _ => None,
            }
        }
        rec(self, &mut out)?;
        Some(out)
    }

    /// Like [`Json::to_f32_vec_nested`] but truncating to i32.
    pub fn to_i32_vec_nested(&self) -> Option<Vec<i32>> {
        let f = self.to_f32_vec_nested()?;
        Some(f.into_iter().map(|x| x as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().to_f32_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\n"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        let text = to_string_pretty(&v);
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested_flatten() {
        let v = parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.to_f32_vec_nested().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v.to_i32_vec_nested().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn integer_accessors_reject_fractions() {
        let v = parse("1.5").unwrap();
        assert_eq!(v.as_usize(), None);
        let v = parse("7").unwrap();
        assert_eq!(v.as_usize(), Some(7));
    }

    #[test]
    fn field_error_has_context() {
        let v = parse("{}").unwrap();
        assert!(v.field("missing").unwrap_err().contains("missing"));
    }
}
