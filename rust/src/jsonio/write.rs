//! JSON writer (pretty, deterministic key order via BTreeMap).

use super::Json;

/// Serialize with 1-space indentation and sorted object keys.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

/// Canonical serialization: compact (no whitespace), deterministically
/// key-ordered (objects are `BTreeMap`s), with the same number and string
/// encodings as [`to_string_pretty`].  Equal values always serialize to
/// identical bytes, which is what makes content hashes over JSON stable —
/// spec hashes and store-object identities are computed over this form
/// (DESIGN.md §16).
pub fn to_string_canonical(v: &Json) -> String {
    let mut out = String::new();
    write_canonical(v, &mut out);
    out
}

fn write_canonical(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_canonical(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_canonical(val, out);
            }
            out.push('}');
        }
    }
}

fn indent(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push(' ');
    }
}

fn write_value(v: &Json, level: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                indent(level + 1, out);
                write_value(item, level + 1, out);
            }
            out.push('\n');
            indent(level, out);
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                indent(level + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_value(val, level + 1, out);
            }
            out.push('\n');
            indent(level, out);
            out.push('}');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; clamp deterministically and loudly.
        out.push_str("null");
        return;
    }
    if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn writes_integers_without_exponent() {
        assert_eq!(to_string_pretty(&Json::Num(1321986.0)), "1321986");
    }

    #[test]
    fn escapes_control_chars() {
        let s = to_string_pretty(&Json::Str("a\u{0001}b".into()));
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\u{0001}b".into()));
    }

    #[test]
    fn roundtrip_deep() {
        let mut m = BTreeMap::new();
        m.insert("x".into(), Json::Arr(vec![Json::Num(1.5), Json::Null]));
        let v = Json::Obj(m);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn canonical_golden_bytes() {
        // pins the canonical encoding: compact separators, sorted keys,
        // pretty-writer number/string formats
        let mut inner = BTreeMap::new();
        inner.insert("z".into(), Json::Num(3.0));
        inner.insert("a".into(), Json::Str("x\ny".into()));
        let mut m = BTreeMap::new();
        m.insert("b".into(), Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(true)]));
        m.insert("a".into(), Json::Obj(inner));
        let v = Json::Obj(m);
        assert_eq!(
            to_string_canonical(&v),
            r#"{"a":{"a":"x\ny","z":3},"b":[1.5,null,true]}"#
        );
    }

    #[test]
    fn canonical_is_byte_stable_across_roundtrip_and_key_order() {
        // insertion order must not matter (BTreeMap), and parsing the
        // canonical text back must re-serialize to the identical bytes
        let mut m1 = BTreeMap::new();
        m1.insert("k1".into(), Json::Num(1321986.0));
        m1.insert("k2".into(), Json::Str("v".into()));
        let mut m2 = BTreeMap::new();
        m2.insert("k2".into(), Json::Str("v".into()));
        m2.insert("k1".into(), Json::Num(1321986.0));
        let (a, b) = (to_string_canonical(&Json::Obj(m1)), to_string_canonical(&Json::Obj(m2)));
        assert_eq!(a, b);
        let reparsed = parse(&a).unwrap();
        assert_eq!(to_string_canonical(&reparsed), a);
        // and it agrees with the pretty writer after reparse
        assert_eq!(parse(&to_string_pretty(&reparsed)).unwrap(), reparsed);
    }

    #[test]
    fn canonical_empty_containers() {
        assert_eq!(to_string_canonical(&Json::Obj(BTreeMap::new())), "{}");
        assert_eq!(to_string_canonical(&Json::Arr(vec![])), "[]");
    }
}
