//! Recursive-descent JSON parser.

use std::collections::BTreeMap;

use super::Json;

/// Parse failure with byte position.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset into the source where parsing failed.
    pub pos: usize,
    /// What was expected / found.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing data is an error).
pub fn parse(src: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let code = self.hex4()?;
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((code - 0xD800) << 10)
                                + (low - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0),
                       ("-2.5e-2", -0.025)] {
            assert_eq!(parse(s).unwrap(), Json::Num(v), "{s}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }
}
