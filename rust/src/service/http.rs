//! Minimal vendored HTTP/1.1 over [`std::net`] — just enough protocol
//! for the coordinator/worker wire (DESIGN.md §17): one request per
//! connection (`connection: close`), explicit `content-length` framing
//! (no chunked encoding), JSON or raw-byte bodies.  The parser is
//! generic over [`std::io::Read`] so malformed-request and partial-body
//! behaviour is unit-testable against in-memory cursors without a
//! socket.
//!
//! No new dependencies: this module is the transport the service
//! subsystem runs on inside the container's std-only toolchain.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{to_string_canonical, Json};

/// Hard cap on the request/response head (request line + headers).
/// Anything larger is a malformed or hostile peer and fails parsing.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Hard cap on a message body.  Store objects (curve blobs, outcome
/// manifests, parameter images) stay far below this.
pub const MAX_BODY_BYTES: usize = 256 * 1024 * 1024;

/// Per-connection socket read/write timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Accept-loop poll interval while the listener is idle.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method, verbatim (`GET`, `POST`).
    pub method: String,
    /// Request path, verbatim (no query parsing — routes are exact).
    pub path: String,
    /// Header fields, names lowercased, values trimmed.
    pub headers: BTreeMap<String, String>,
    /// Request body (empty when no `content-length` was sent).
    pub body: Vec<u8>,
}

/// One HTTP response to serialize.
#[derive(Clone, Debug)]
pub struct Response {
    /// Status code (200, 400, 404, 409, 500).
    pub status: u16,
    /// `content-type` header value.
    pub content_type: String,
    /// Response body.
    pub body: Vec<u8>,
}

impl Response {
    /// A 200 response carrying canonical JSON.
    pub fn json(j: &Json) -> Response {
        Response {
            status: 200,
            content_type: "application/json".to_string(),
            body: format!("{}\n", to_string_canonical(j)).into_bytes(),
        }
    }

    /// A 200 response carrying raw bytes (store objects).
    pub fn bytes(body: Vec<u8>) -> Response {
        Response {
            status: 200,
            content_type: "application/octet-stream".to_string(),
            body,
        }
    }

    /// An error response with a JSON `{"error": ...}` body.
    pub fn error(status: u16, msg: &str) -> Response {
        let mut m = BTreeMap::new();
        m.insert("error".to_string(), Json::Str(msg.to_string()));
        Response {
            status,
            content_type: "application/json".to_string(),
            body: format!("{}\n", to_string_canonical(&Json::Obj(m))).into_bytes(),
        }
    }
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read one framed message: accumulate the head up to `\r\n\r\n`, then
/// exactly `content-length` body bytes.  Returns the raw head text and
/// the body.  Errors name the failure mode (truncated head, oversized
/// head, partial body) so the server can answer 400 with a cause.
fn read_framed<R: Read>(stream: &mut R) -> Result<(String, Vec<u8>)> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let head_len = loop {
        if let Some(pos) = head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("message head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut tmp).context("reading message head")?;
        if n == 0 {
            bail!("connection closed mid-head (truncated message)");
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_len])
        .map_err(|_| anyhow!("message head is not UTF-8"))?
        .to_string();
    let body_len = content_length(&head)?;
    if body_len > MAX_BODY_BYTES {
        bail!("declared body of {body_len} bytes exceeds the {MAX_BODY_BYTES}-byte cap");
    }
    let mut body = buf[head_len + 4..].to_vec();
    while body.len() < body_len {
        let n = stream.read(&mut tmp).context("reading message body")?;
        if n == 0 {
            bail!(
                "connection closed mid-body: got {} of {} declared bytes (partial body)",
                body.len(),
                body_len
            );
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(body_len);
    Ok((head, body))
}

/// Parse the `content-length` header out of a raw message head
/// (0 when absent, error when present but non-numeric).
fn content_length(head: &str) -> Result<usize> {
    for line in head.split("\r\n").skip(1) {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            let v = value.trim();
            return v
                .parse::<usize>()
                .map_err(|_| anyhow!("malformed content-length '{v}'"));
        }
    }
    Ok(0)
}

/// Parse one HTTP request from a stream.  Generic over [`Read`] so the
/// malformed/partial-body paths are testable with in-memory cursors.
pub fn read_request<R: Read>(stream: &mut R) -> Result<Request> {
    let (head, body) = read_framed(stream)?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => bail!("malformed request line '{request_line}'"),
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version '{version}'");
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            bail!("malformed header line '{line}'");
        };
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    Ok(Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body,
    })
}

/// Serialize a response onto a stream (`connection: close` framing).
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    let reason = match resp.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        500 => "Internal Server Error",
        _ => "Status",
    };
    write!(
        w,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        resp.status,
        reason,
        resp.content_type,
        resp.body.len()
    )
    .context("writing response head")?;
    w.write_all(&resp.body).context("writing response body")?;
    w.flush().context("flushing response")?;
    Ok(())
}

/// Parse one HTTP response from a stream: `(status, body)`.
pub fn read_response<R: Read>(stream: &mut R) -> Result<(u16, Vec<u8>)> {
    let (head, body) = read_framed(stream)?;
    let status_line = head.split("\r\n").next().unwrap_or("");
    let mut parts = status_line.split(' ');
    let (version, code) = match (parts.next(), parts.next()) {
        (Some(v), Some(c)) => (v, c),
        _ => bail!("malformed status line '{status_line}'"),
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version '{version}'");
    }
    let status = code
        .parse::<u16>()
        .map_err(|_| anyhow!("malformed status code '{code}'"))?;
    Ok((status, body))
}

/// The request handler a server dispatches each parsed request through.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A polling single-listener HTTP server: nonblocking accept loop with a
/// shared stop flag (graceful shutdown), one thread per connection, one
/// request per connection.
pub struct HttpServer {
    listener: TcpListener,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind a listener (e.g. `127.0.0.1:0` for an OS-assigned port).
    pub fn bind(addr: &str) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(HttpServer {
            listener,
            addr,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves an OS-assigned port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared stop flag: set it true and `serve` returns after its
    /// next poll tick.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept connections until the stop flag is raised, dispatching each
    /// request through `handler`.  Parse failures answer 400 with the
    /// parse error; handler panics are confined to their connection
    /// thread.
    pub fn serve(&self, handler: Handler) {
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let h = Arc::clone(&handler);
                    std::thread::spawn(move || handle_connection(stream, h));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
    }
}

/// Serve one connection: parse, dispatch, answer, close.
fn handle_connection(mut stream: TcpStream, handler: Handler) {
    // Accepted sockets can inherit the listener's nonblocking mode on
    // some platforms; this connection uses blocking reads with timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let resp = match read_request(&mut stream) {
        Ok(req) => handler(&req),
        Err(e) => Response::error(400, &format!("{e:#}")),
    };
    let _ = write_response(&mut stream, &resp);
}

/// One client request/response exchange against `addr` (`host:port`):
/// connect, send, read `(status, body)`, close.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    )
    .with_context(|| format!("sending {method} {path}"))?;
    stream
        .write_all(body)
        .with_context(|| format!("sending {method} {path} body"))?;
    stream.flush().context("flushing request")?;
    read_response(&mut stream).with_context(|| format!("reading {method} {path} response"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req_bytes(head: &str, body: &[u8]) -> Vec<u8> {
        let mut v = head.as_bytes().to_vec();
        v.extend_from_slice(body);
        v
    }

    #[test]
    fn parses_a_well_formed_post() {
        let body = br#"{"k":1}"#;
        let raw = req_bytes(
            &format!(
                "POST /api/v1/lease HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
                body.len()
            ),
            body,
        );
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/api/v1/lease");
        assert_eq!(req.headers.get("content-type").map(String::as_str), Some("application/json"));
        assert_eq!(req.body, body);
    }

    #[test]
    fn get_without_content_length_has_empty_body() {
        let raw = req_bytes("GET /api/v1/ping HTTP/1.1\r\n\r\n", b"");
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_malformed_request_line() {
        for raw in [
            "GARBAGE\r\n\r\n".to_string(),
            "GET\r\n\r\n".to_string(),
            "GET /x HTTP/1.1 extra\r\n\r\n".to_string(),
            "GET nopath HTTP/1.1\r\n\r\n".to_string(),
            "GET /x SPDY/9\r\n\r\n".to_string(),
        ] {
            let err = read_request(&mut Cursor::new(raw.into_bytes())).unwrap_err();
            let msg = format!("{err}");
            assert!(
                msg.contains("malformed request line") || msg.contains("unsupported protocol"),
                "unexpected error: {msg}"
            );
        }
    }

    #[test]
    fn rejects_partial_body() {
        // declares 10 bytes, delivers 4, then EOF
        let raw = req_bytes("POST /x HTTP/1.1\r\ncontent-length: 10\r\n\r\n", b"only");
        let err = read_request(&mut Cursor::new(raw)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("partial body"), "unexpected error: {msg}");
        assert!(msg.contains("4 of 10"), "unexpected error: {msg}");
    }

    #[test]
    fn rejects_truncated_head_and_oversized_head() {
        let err = read_request(&mut Cursor::new(b"POST /x HTT".to_vec())).unwrap_err();
        assert!(format!("{err}").contains("truncated"));

        let mut huge = b"GET /x HTTP/1.1\r\n".to_vec();
        huge.extend_from_slice("x-pad: ".as_bytes());
        huge.extend_from_slice(&vec![b'a'; MAX_HEAD_BYTES + 64]);
        let err = read_request(&mut Cursor::new(huge)).unwrap_err();
        assert!(format!("{err}").contains("exceeds"));
    }

    #[test]
    fn rejects_bad_content_length_and_bad_header() {
        let raw = req_bytes("POST /x HTTP/1.1\r\ncontent-length: soon\r\n\r\n", b"");
        let err = read_request(&mut Cursor::new(raw)).unwrap_err();
        assert!(format!("{err}").contains("malformed content-length"));

        let raw = req_bytes("POST /x HTTP/1.1\r\nnocolonhere\r\n\r\n", b"");
        let err = read_request(&mut Cursor::new(raw)).unwrap_err();
        assert!(format!("{err}").contains("malformed header line"));
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::bytes(vec![1, 2, 3, 4, 5]);
        let mut wire = Vec::new();
        write_response(&mut wire, &resp).unwrap();
        let (status, body) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, vec![1, 2, 3, 4, 5]);

        let err = Response::error(409, "spec hash mismatch");
        let mut wire = Vec::new();
        write_response(&mut wire, &err).unwrap();
        let (status, body) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 409);
        assert!(String::from_utf8(body).unwrap().contains("spec hash mismatch"));
    }
}
