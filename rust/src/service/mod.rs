//! Distributed seed-sync training service: a coordinator/worker pair
//! over HTTP/JSON (DESIGN.md §17).
//!
//! Zero-order training ships *seeds and outcomes*, not gradients: a
//! trial is fully described by its wire [`crate::coordinator::TrialSpec`]
//! (schema-versioned, canonical-JSON — the same encoding its spec hash
//! is computed over), and its result by a content-addressed outcome
//! record plus two curve blobs.  That makes distribution nearly free —
//! the coordinator ([`server::Coordinator`]) is a lease queue keyed by
//! canonical spec hash, and workers ([`worker::run_worker`]) are plain
//! polling clients that run trials through the exact single-process
//! grid path and push the resulting objects back.  Identity does the
//! heavy lifting:
//!
//! - **byte-identity**: a farmed grid's merged report is byte-identical
//!   to the single-process run, because each worker runs the same
//!   deterministic trainer on the same spec and the report is assembled
//!   from bit-exact stored outcomes
//!   ([`crate::coordinator::deterministic_report`]);
//! - **fault tolerance**: a worker killed mid-trial just lets its lease
//!   expire — the trial re-queues, and the grid state only ever sees
//!   completed records (submission is idempotent, keyed by spec hash);
//! - **warm starts**: re-serving a finished grid answers every trial
//!   from `grid.lock.json` + the store with zero training steps.
//!
//! The transport ([`http`]) is a minimal vendored HTTP/1.1 over
//! [`std::net`] — no new dependencies — and the protocol ([`proto`])
//! stamps every message with the wire schema version so mismatched
//! builds fail loudly.  Work is leased at two granularities: whole
//! trials, and loss-evaluation shards ([`worker::eval_shard_losses`])
//! that split one evaluation of a parameter image across test-batch
//! ranges.

pub mod http;
pub mod proto;
pub mod server;
pub mod worker;

pub use server::{Coordinator, CoordinatorConfig, ServiceStats};
pub use worker::{eval_shard_losses, run_worker, WorkerConfig, WorkerReport};
