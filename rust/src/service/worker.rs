//! The worker client: polls a coordinator for leases, runs trials
//! through the local grid machinery ([`crate::coordinator::run_local_trial`]),
//! evaluates loss shards, and ships results back (DESIGN.md §17).
//!
//! Every RPC goes through a bounded-retry loop with exponential backoff,
//! so a coordinator mid-restart does not kill the worker.  A finished
//! trial's outcome record (and its curve blobs) is pushed into the
//! coordinator's store *before* the outcome is submitted — record last —
//! so the coordinator never observes a record whose blob closure is
//! incomplete.  The worker trains inside its own directory with
//! checkpointing + resume on, which is what makes a kill mid-trial safe:
//! nothing of the coordinator's grid state is touched until the
//! completed record lands.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::http;
use super::proto::{self, LeaseReply};
use crate::coordinator::wire::{jhex64, jnum, jstr};
use crate::coordinator::{resolved_spec_hash, run_local_trial, OracleSpec, TrialSpec};
use crate::data::Corpus;
use crate::exec::ExecContext;
use crate::jsonio::{parse, to_string_canonical, Json};
use crate::model::mlp::MlpSpec;
use crate::oracle::{MlpOracle, Oracle, TransformerOracle};
use crate::snapshot::CheckpointConfig;
use crate::store::{GridLock, Store};

/// How a worker runs.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Coordinator address (`host:port`).
    pub connect: String,
    /// Worker-local directory: per-trial checkpoints plus the local blob
    /// store (`<dir>/store`).
    pub dir: PathBuf,
    /// Shard-parallel threads (0: `ZO_THREADS`, else core count).
    pub threads: usize,
    /// Idle-poll interval between lease requests.
    pub poll: Duration,
    /// RPC retries before giving up on the coordinator.
    pub retries: u32,
    /// Initial retry backoff (doubles per attempt, capped at 5 s).
    pub backoff: Duration,
    /// Stop after this many leases (None: run until the queue is done).
    /// The fault-injection tests use it to kill a worker mid-grid.
    pub max_leases: Option<u64>,
}

impl WorkerConfig {
    /// A worker against `connect` working out of `dir`, with the default
    /// cadence (50 ms poll, 4 retries, 100 ms initial backoff).
    pub fn new(connect: impl Into<String>, dir: impl Into<PathBuf>) -> WorkerConfig {
        WorkerConfig {
            connect: connect.into(),
            dir: dir.into(),
            threads: 0,
            poll: Duration::from_millis(50),
            retries: 4,
            backoff: Duration::from_millis(100),
            max_leases: None,
        }
    }
}

/// What a worker did before exiting.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    /// Trials run to completion and submitted.
    pub trials_run: u64,
    /// Loss-evaluation shards computed and submitted.
    pub evals_run: u64,
    /// Trials that errored locally (reported to the coordinator).
    pub errors: u64,
}

/// Run the worker loop until the coordinator reports the queue done (or
/// `max_leases` is hit).
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerReport> {
    std::fs::create_dir_all(&cfg.dir)
        .with_context(|| format!("creating worker dir {}", cfg.dir.display()))?;
    let store = Store::open(cfg.dir.join("store"));
    let exec = ExecContext::resolve(cfg.threads);
    let mut report = WorkerReport::default();
    let mut leases = 0u64;
    loop {
        if let Some(max) = cfg.max_leases {
            if leases >= max {
                break;
            }
        }
        let reply = rpc_json(cfg, "POST", proto::P_LEASE, proto::message(vec![]))?;
        match LeaseReply::from_json(&reply)? {
            LeaseReply::Idle { done } => {
                if done {
                    break;
                }
                std::thread::sleep(cfg.poll);
            }
            LeaseReply::Trial {
                lease_id,
                index,
                sync,
                spec,
                ..
            } => {
                leases += 1;
                sync_objects(cfg, &store, &sync)?;
                let spec_hash = resolved_spec_hash(&spec);
                match run_leased_trial(cfg, &exec, &spec, &spec_hash) {
                    Ok(rec_hash) => {
                        push_closure(cfg, &store, &rec_hash)?;
                        rpc_json(
                            cfg,
                            "POST",
                            proto::P_OUTCOME,
                            proto::message(vec![
                                ("kind", jstr("trial")),
                                ("index", jnum(index)),
                                ("lease_id", jhex64(lease_id)),
                                ("spec_hash", jstr(&spec_hash)),
                                ("outcome", jstr(&rec_hash)),
                            ]),
                        )?;
                        report.trials_run += 1;
                    }
                    Err(e) => {
                        report.errors += 1;
                        rpc_json(
                            cfg,
                            "POST",
                            proto::P_OUTCOME,
                            proto::message(vec![
                                ("kind", jstr("trial")),
                                ("index", jnum(index)),
                                ("lease_id", jhex64(lease_id)),
                                ("spec_hash", jstr(&spec_hash)),
                                ("error", jstr(&format!("{e:#}"))),
                            ]),
                        )?;
                    }
                }
            }
            LeaseReply::Eval {
                index,
                sync,
                spec,
                params,
                b0,
                b1,
                ..
            } => {
                leases += 1;
                sync_objects(cfg, &store, &sync)?;
                let blob = store.get(&params)?;
                let xs = proto::bytes_to_f32s(&blob)?;
                let losses = eval_shard_losses(&spec, &xs, b0, b1)?;
                let encoded: Vec<Json> = losses
                    .iter()
                    .map(|l| jstr(&format!("{:016x}", l.to_bits())))
                    .collect();
                rpc_json(
                    cfg,
                    "POST",
                    proto::P_OUTCOME,
                    proto::message(vec![
                        ("kind", jstr("eval")),
                        ("index", jnum(index)),
                        ("losses", Json::Arr(encoded)),
                    ]),
                )?;
                report.evals_run += 1;
            }
        }
    }
    Ok(report)
}

/// Run one leased trial in the worker's directory (checkpointing +
/// resume on, store at `<dir>/store`) and return the store hash of the
/// completed outcome record — read back from the worker's own
/// `grid.lock.json` pin, which [`run_local_trial`] wrote under exactly
/// this spec hash.
fn run_leased_trial(
    cfg: &WorkerConfig,
    exec: &ExecContext,
    spec: &TrialSpec,
    spec_hash: &str,
) -> Result<String> {
    let mut spec = spec.clone();
    // leased specs never carry a checkpoint policy (it is worker-local,
    // deliberately off the wire); pin it to this worker's directory
    spec.checkpoint = Some(CheckpointConfig {
        dir: Some(cfg.dir.to_string_lossy().into_owned()),
        every: 0,
        resume: true,
        max_run_steps: 0,
        store_dir: None,
    });
    run_local_trial("artifacts", &spec, exec)?;
    let entry = GridLock::load(&cfg.dir)
        .get(spec_hash)
        .cloned()
        .ok_or_else(|| {
            anyhow!(
                "trial '{}' finished but left no grid-lock pin for {spec_hash}",
                spec.id
            )
        })?;
    Ok(entry.outcome)
}

/// Pull the listed objects from the coordinator into the local store
/// (skipping ones already present), verifying content addresses.
fn sync_objects(cfg: &WorkerConfig, store: &Store, hashes: &[String]) -> Result<()> {
    for h in hashes {
        if store.contains(h) {
            continue;
        }
        let bytes = rpc_bytes(cfg, &format!("{}/{h}", proto::P_STORE_OBJ))?;
        let got = store.put(&bytes)?;
        ensure!(
            &got == h,
            "synced object hash mismatch: coordinator sent {got} for {h}"
        );
    }
    Ok(())
}

/// Push an outcome record's closure (curve blobs, then the record
/// itself, last) into the coordinator's store, skipping objects the
/// coordinator already has.
fn push_closure(cfg: &WorkerConfig, store: &Store, rec_hash: &str) -> Result<()> {
    let manifest = store.get(rec_hash)?;
    let text = std::str::from_utf8(&manifest)
        .map_err(|_| anyhow!("outcome record {rec_hash} is not UTF-8"))?;
    let j = parse(text).map_err(|e| anyhow!("outcome record {rec_hash}: {e}"))?;
    // blobs first, record last: hashes[0] is the record, so push the
    // reversed list and the coordinator never sees a dangling record
    let mut hashes = vec![rec_hash.to_string()];
    if let Some(Json::Obj(blobs)) = j.get("blobs") {
        for v in blobs.values() {
            if let Some(h) = v.as_str() {
                hashes.push(h.to_string());
            }
        }
    }
    let listed: Vec<Json> = hashes.iter().map(|h| jstr(h)).collect();
    let reply = rpc_json(
        cfg,
        "POST",
        proto::P_STORE_HAVE,
        proto::message(vec![("hashes", Json::Arr(listed))]),
    )?;
    let missing = proto::gstrs(&reply, "missing")?;
    for h in hashes.iter().rev() {
        if !missing.iter().any(|m| m == h) {
            continue;
        }
        let bytes = store.get(h)?;
        let reply = rpc_raw(cfg, "POST", proto::P_STORE_OBJ, "application/octet-stream", &bytes)?;
        let stored = proto::gstr(&reply, "hash")?;
        ensure!(
            stored == h.as_str(),
            "coordinator stored {stored} for pushed object {h}"
        );
    }
    Ok(())
}

/// Losses of `spec`'s oracle at the parameter image `params` over test
/// batches `b0..b1` — the eval-shard kernel, shared by workers and the
/// in-process tests.  Bitwise-deterministic: the oracle is rebuilt from
/// the spec's init seed, the image is installed verbatim, and each batch
/// is evaluated with a zero probe direction (`f(x)` exactly).
pub fn eval_shard_losses(spec: &TrialSpec, params: &[f32], b0: u64, b1: u64) -> Result<Vec<f64>> {
    ensure!(b0 <= b1, "eval shard has b0 {b0} > b1 {b1}");
    match &spec.oracle {
        OracleSpec::Pjrt => {
            bail!("eval shards need a host-side oracle (PJRT trials are not shardable)")
        }
        OracleSpec::Mlp(m) => {
            let corpus = Corpus::new(m.corpus.clone())?;
            let mspec = MlpSpec::new(
                m.in_dim,
                m.hidden.clone(),
                m.corpus.n_classes as usize,
                m.activation,
            )?;
            let oracle = MlpOracle::from_seed(mspec, m.init_seed);
            shard_losses(oracle, &corpus, m.eval_batch, b0, b1, params)
        }
        OracleSpec::Transformer(t) => {
            let corpus = Corpus::new(t.corpus.clone())?;
            let tspec = t.model_spec()?;
            let oracle = TransformerOracle::from_seed(tspec, spec.mode, t.init_seed);
            shard_losses(oracle, &corpus, t.eval_batch, b0, b1, params)
        }
    }
}

fn shard_losses<O: Oracle>(
    mut oracle: O,
    corpus: &Corpus,
    eval_batch: usize,
    b0: u64,
    b1: u64,
    params: &[f32],
) -> Result<Vec<f64>> {
    ensure!(
        oracle.dim() == params.len(),
        "parameter image holds {} values but the oracle dimension is {}",
        params.len(),
        oracle.dim()
    );
    oracle.update_params(&mut |p: &mut [f32]| p.copy_from_slice(params))?;
    let zero = vec![0.0f32; params.len()];
    let mut out = Vec::with_capacity((b1 - b0) as usize);
    for bi in b0..b1 {
        oracle.set_batch(&corpus.test_batch(bi, eval_batch))?;
        out.push(oracle.loss_dir(&zero, 0.0)?);
    }
    Ok(out)
}

/// One JSON RPC with bounded retry: non-200 answers become errors
/// carrying the response body (the coordinator's error JSON).
fn rpc_json(cfg: &WorkerConfig, method: &str, path: &str, body: Json) -> Result<Json> {
    let payload = format!("{}\n", to_string_canonical(&body));
    rpc_raw(cfg, method, path, "application/json", payload.as_bytes())
}

/// GET raw bytes (store objects) with bounded retry.
fn rpc_bytes(cfg: &WorkerConfig, path: &str) -> Result<Vec<u8>> {
    let (status, body) = rpc(cfg, "GET", path, "application/octet-stream", &[])?;
    if status != 200 {
        bail!(
            "GET {path}: coordinator answered {status}: {}",
            String::from_utf8_lossy(&body).trim()
        );
    }
    Ok(body)
}

/// Send a request and parse the JSON reply, with bounded retry.
fn rpc_raw(
    cfg: &WorkerConfig,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<Json> {
    let (status, reply) = rpc(cfg, method, path, content_type, body)?;
    let text = String::from_utf8_lossy(&reply);
    if status != 200 {
        bail!("{method} {path}: coordinator answered {status}: {}", text.trim());
    }
    parse(text.as_ref()).map_err(|e| anyhow!("{method} {path}: bad JSON reply: {e}"))
}

/// The transport-level exchange: bounded retries with exponential
/// backoff on connection failures (a coordinator mid-restart), capped at
/// 5 s per wait.
fn rpc(
    cfg: &WorkerConfig,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut delay = cfg.backoff;
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..=cfg.retries {
        match http::http_request(&cfg.connect, method, path, content_type, body) {
            Ok(r) => return Ok(r),
            Err(e) => {
                last = Some(e);
                if attempt < cfg.retries {
                    std::thread::sleep(delay);
                    delay = delay.saturating_mul(2).min(Duration::from_secs(5));
                }
            }
        }
    }
    Err(last
        .unwrap_or_else(|| anyhow!("unreachable: no attempt ran"))
        .context(format!(
            "{method} {path}: coordinator at {} unreachable after {} attempts",
            cfg.connect,
            cfg.retries + 1
        )))
}
