//! The coordinator: an HTTP/JSON work queue that farms grid trials and
//! loss-evaluation shards out to workers (DESIGN.md §17).
//!
//! The queue holds [`TrialSpec`]s keyed by canonical spec hash — the
//! same identity `grid.lock.json` warm-starts use — so a trial a prior
//! run already completed is served from the store with zero training
//! steps, an outcome submitted twice (requeued lease whose original
//! worker also finished) is accepted idempotently, and a worker killed
//! mid-trial simply lets its lease expire and the trial re-queues.
//! Success is *content*-keyed (any valid spec-hash-stamped record is
//! accepted regardless of lease); failure is *lease*-keyed (only the
//! current leaseholder can mark a trial failed), so a stale worker's
//! error can never poison a trial another worker is re-running.
//!
//! Shutdown persists the queue (`queue.json`, the wire grid format) so a
//! restarted coordinator resumes exactly where it stopped.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::http::{Handler, HttpServer, Request, Response};
use super::proto::{self, LeaseReply};
use crate::coordinator::wire::{self, jhex64, jnum, jobj, jstr};
use crate::coordinator::{resolved_spec_hash, storage_label_static, TrialResult, TrialSpec};
use crate::jsonio::{parse, to_string_canonical, Json};
use crate::snapshot;
use crate::store::{GridLock, LockEntry, Store};

/// Where a queued trial stands.
#[derive(Clone, Debug)]
enum TrialStatus {
    /// Waiting for a worker.
    Pending,
    /// Handed to a worker; re-queues if not finished by `deadline`.
    Leased { lease: u64, deadline: Instant },
    /// Finished: `outcome` is the store hash of the outcome record;
    /// `cached` means it was served from a warm-start pin, no training.
    Done { outcome: String, cached: bool },
    /// The current leaseholder reported a terminal error.
    Failed { error: String },
}

/// One queued trial.
#[derive(Clone, Debug)]
struct TrialState {
    spec: TrialSpec,
    hash: String,
    status: TrialStatus,
}

/// Where a queued loss-evaluation shard stands.  Shard results are
/// deterministic, so submission is content-keyed and leases carry only
/// the requeue deadline.
#[derive(Clone, Debug)]
enum EvalStatus {
    Pending,
    Leased { deadline: Instant },
    Done { losses: Vec<f64> },
}

/// One queued loss-evaluation shard: `spec`'s oracle at the stored
/// parameter image, over test batches `b0..b1`.
#[derive(Clone, Debug)]
struct EvalJob {
    spec: TrialSpec,
    params: String,
    b0: u64,
    b1: u64,
    status: EvalStatus,
}

/// Queue counters (observable via the status route and [`Coordinator::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Leases handed out (trials + eval shards).
    pub leases_granted: u64,
    /// Expired leases returned to the queue.
    pub requeues: u64,
    /// Fresh outcomes accepted.
    pub outcomes_accepted: u64,
    /// Idempotent duplicate submissions (already-done jobs).
    pub duplicates: u64,
    /// Submissions rejected (hash mismatch, missing record).
    pub rejected: u64,
    /// Trials served from a warm-start pin at enqueue time.
    pub cached_on_enqueue: u64,
    /// Store objects pushed by workers.
    pub store_pushes: u64,
    /// Store objects pulled by workers.
    pub store_pulls: u64,
}

#[derive(Default)]
struct State {
    trials: Vec<TrialState>,
    evals: Vec<EvalJob>,
    next_lease: u64,
    stats: ServiceStats,
}

struct Inner {
    dir: PathBuf,
    store: Store,
    lease_timeout: Duration,
    stop: Arc<AtomicBool>,
    state: Mutex<State>,
}

/// How to stand up a [`Coordinator`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Listen address (`127.0.0.1:0` picks a free port).
    pub addr: String,
    /// Grid directory: holds `grid.lock.json`, `queue.json`, and the
    /// shared blob store (`<dir>/store`).
    pub dir: PathBuf,
    /// How long a lease stays exclusive before the work re-queues.
    pub lease_timeout: Duration,
}

impl CoordinatorConfig {
    /// Loopback coordinator on an OS-assigned port with the default
    /// 60 s lease timeout.
    pub fn loopback(dir: impl Into<PathBuf>) -> CoordinatorConfig {
        CoordinatorConfig {
            addr: "127.0.0.1:0".to_string(),
            dir: dir.into(),
            lease_timeout: Duration::from_secs(60),
        }
    }
}

/// A running coordinator: the HTTP listener thread plus the in-process
/// handle used to enqueue work and collect results.
pub struct Coordinator {
    inner: Arc<Inner>,
    addr: SocketAddr,
    serve_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind the listener, start serving, and resume any persisted queue
    /// left behind by a previous coordinator in the same directory.
    pub fn bind(cfg: CoordinatorConfig) -> Result<Coordinator> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating coordinator dir {}", cfg.dir.display()))?;
        let http = HttpServer::bind(&cfg.addr)?;
        let addr = http.addr();
        let inner = Arc::new(Inner {
            store: Store::open(cfg.dir.join("store")),
            dir: cfg.dir,
            lease_timeout: cfg.lease_timeout,
            stop: http.stop_flag(),
            state: Mutex::new(State::default()),
        });
        let route_inner = Arc::clone(&inner);
        let handler: Handler = Arc::new(move |req| handle(&route_inner, req));
        let serve_thread = std::thread::spawn(move || http.serve(handler));
        let c = Coordinator {
            inner,
            addr,
            serve_thread: Some(serve_thread),
        };
        c.resume_queue()?;
        Ok(c)
    }

    /// The bound listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// A snapshot of the queue counters.
    pub fn stats(&self) -> ServiceStats {
        self.inner.state.lock().unwrap().stats
    }

    /// Enqueue trials.  Idempotent by canonical spec hash: a spec whose
    /// hash is already queued is skipped, and one a previous run pinned
    /// in `grid.lock.json` (with its record still in the store) is
    /// marked done immediately — the warm-start path, zero training
    /// steps.  Returns how many landed pre-completed.
    pub fn enqueue(&self, specs: Vec<TrialSpec>) -> Result<usize> {
        let cached = self.inner.enqueue(specs)?;
        Ok(cached)
    }

    /// Split a loss evaluation into leased shards: `spec`'s oracle at
    /// the parameter image `params`, test batches `0..batches` in chunks
    /// of `shard`.  Returns the number of shards queued.
    pub fn enqueue_eval(
        &self,
        spec: &TrialSpec,
        params: &[f32],
        batches: u64,
        shard: u64,
    ) -> Result<usize> {
        ensure!(shard > 0, "eval shard size must be >= 1");
        let hash = self.inner.store.put(&proto::f32s_to_bytes(params))?;
        let mut st = self.inner.state.lock().unwrap();
        let mut n = 0;
        let mut b0 = 0;
        while b0 < batches {
            let b1 = (b0 + shard).min(batches);
            st.evals.push(EvalJob {
                spec: spec.clone(),
                params: hash.clone(),
                b0,
                b1,
                status: EvalStatus::Pending,
            });
            n += 1;
            b0 = b1;
        }
        Ok(n)
    }

    /// The concatenated per-batch losses once every eval shard is done
    /// (shards sorted by batch range), else `None`.
    pub fn eval_losses(&self) -> Option<Vec<f64>> {
        let st = self.inner.state.lock().unwrap();
        if st.evals.is_empty() {
            return None;
        }
        let mut shards: Vec<(u64, &[f64])> = Vec::with_capacity(st.evals.len());
        for job in &st.evals {
            match &job.status {
                EvalStatus::Done { losses } => shards.push((job.b0, losses)),
                _ => return None,
            }
        }
        shards.sort_by_key(|(b0, _)| *b0);
        Some(shards.into_iter().flat_map(|(_, l)| l.iter().copied()).collect())
    }

    /// Block until every queued trial is terminal (done or failed), then
    /// return results in queue order — the same shape [`crate::coordinator::run_grid`]
    /// produces, so [`crate::coordinator::deterministic_report`] applies
    /// directly.
    pub fn run_until_done(&self, poll: Duration) -> Result<Vec<Result<TrialResult>>> {
        loop {
            {
                let st = self.inner.state.lock().unwrap();
                let all_terminal = st.trials.iter().all(|t| {
                    matches!(
                        t.status,
                        TrialStatus::Done { .. } | TrialStatus::Failed { .. }
                    )
                });
                if all_terminal {
                    break;
                }
            }
            std::thread::sleep(poll);
        }
        self.results()
    }

    /// Current results in queue order.  Unfinished trials come back as
    /// errors; callers that want completion first use [`Coordinator::run_until_done`].
    pub fn results(&self) -> Result<Vec<Result<TrialResult>>> {
        let st = self.inner.state.lock().unwrap();
        let mut out = Vec::with_capacity(st.trials.len());
        for t in &st.trials {
            out.push(match &t.status {
                TrialStatus::Done { outcome, cached } => {
                    trial_result(&self.inner.store, t, outcome, *cached)
                }
                TrialStatus::Failed { error } => Err(anyhow!("{error}")),
                _ => Err(anyhow!("trial '{}' is not finished", t.spec.id)),
            });
        }
        Ok(out)
    }

    /// Graceful shutdown: persist the queue, stop the listener, join the
    /// serve thread.  Safe to call once; `Drop` covers the non-graceful
    /// path.
    pub fn shutdown(&mut self) -> Result<()> {
        self.inner.persist_queue()?;
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.serve_thread.take() {
            let _ = h.join();
        }
        Ok(())
    }

    fn resume_queue(&self) -> Result<usize> {
        let path = self.inner.dir.join("queue.json");
        if !path.exists() {
            return Ok(0);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading persisted queue {}", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let specs = wire::grid_from_json(&j)?;
        let n = specs.len();
        self.inner.enqueue(specs)?;
        Ok(n)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.serve_thread.take() {
            let _ = h.join();
        }
    }
}

/// Materialize a [`TrialResult`] from a stored outcome record.
fn trial_result(
    store: &Store,
    t: &TrialState,
    outcome_hash: &str,
    cached: bool,
) -> Result<TrialResult> {
    let rec = snapshot::outcome_from_store(store, outcome_hash)
        .with_context(|| format!("loading outcome record {outcome_hash}"))?;
    let session_oracle_calls = if cached { 0 } else { rec.outcome.oracle_calls };
    Ok(TrialResult {
        spec_id: t.spec.id.clone(),
        probe_storage: storage_label_static(&rec.probe_storage),
        probe_peak_bytes: 0,
        cached,
        session_oracle_calls,
        outcome: rec.outcome,
    })
}

impl Inner {
    fn enqueue(&self, specs: Vec<TrialSpec>) -> Result<usize> {
        let lock = GridLock::load(&self.dir);
        let mut cached = 0;
        {
            let mut st = self.state.lock().unwrap();
            for spec in specs {
                let hash = resolved_spec_hash(&spec);
                if st.trials.iter().any(|t| t.hash == hash) {
                    continue;
                }
                let status = match lock.get(&hash) {
                    Some(entry)
                        if snapshot::outcome_from_store(&self.store, &entry.outcome).is_ok() =>
                    {
                        cached += 1;
                        st.stats.cached_on_enqueue += 1;
                        TrialStatus::Done {
                            outcome: entry.outcome.clone(),
                            cached: true,
                        }
                    }
                    _ => TrialStatus::Pending,
                };
                st.trials.push(TrialState { spec, hash, status });
            }
        }
        self.persist_queue()?;
        Ok(cached)
    }

    /// Persist the queued specs as a wire grid file (atomic rename) so a
    /// restarted coordinator re-enqueues the same work.
    fn persist_queue(&self) -> Result<()> {
        let specs: Vec<TrialSpec> = {
            let st = self.state.lock().unwrap();
            st.trials.iter().map(|t| t.spec.clone()).collect()
        };
        let text = format!("{}\n", to_string_canonical(&wire::grid_to_json(&specs)));
        let tmp = self.dir.join("queue.json.tmp");
        let path = self.dir.join("queue.json");
        std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        Ok(())
    }

    /// Return expired leases to the queue, then hand out the first
    /// pending job (eval shards before trials — they are short and
    /// unblock a waiting aggregation).
    fn grant_lease(&self) -> LeaseReply {
        let now = Instant::now();
        let timeout_ms = self.lease_timeout.as_millis() as u64;
        let mut st = self.state.lock().unwrap();
        let state = &mut *st;
        let mut requeued = 0u64;
        for t in state.trials.iter_mut() {
            if let TrialStatus::Leased { deadline, .. } = &t.status {
                if *deadline <= now {
                    t.status = TrialStatus::Pending;
                    requeued += 1;
                }
            }
        }
        for j in state.evals.iter_mut() {
            if let EvalStatus::Leased { deadline, .. } = &j.status {
                if *deadline <= now {
                    j.status = EvalStatus::Pending;
                    requeued += 1;
                }
            }
        }
        state.stats.requeues += requeued;

        if let Some(i) = state
            .evals
            .iter()
            .position(|j| matches!(j.status, EvalStatus::Pending))
        {
            state.next_lease += 1;
            state.stats.leases_granted += 1;
            let lease = state.next_lease;
            let deadline = now + self.lease_timeout;
            let job = &mut state.evals[i];
            job.status = EvalStatus::Leased { deadline };
            return LeaseReply::Eval {
                lease_id: lease,
                index: i,
                timeout_ms,
                sync: vec![job.params.clone()],
                spec: job.spec.clone(),
                params: job.params.clone(),
                b0: job.b0,
                b1: job.b1,
            };
        }

        if let Some(i) = state
            .trials
            .iter()
            .position(|t| matches!(t.status, TrialStatus::Pending))
        {
            state.next_lease += 1;
            state.stats.leases_granted += 1;
            let lease = state.next_lease;
            let deadline = now + self.lease_timeout;
            let t = &mut state.trials[i];
            t.status = TrialStatus::Leased { lease, deadline };
            return LeaseReply::Trial {
                lease_id: lease,
                index: i,
                timeout_ms,
                sync: Vec::new(),
                spec: t.spec.clone(),
            };
        }

        let done = state.trials.iter().all(|t| {
            matches!(
                t.status,
                TrialStatus::Done { .. } | TrialStatus::Failed { .. }
            )
        }) && state
            .evals
            .iter()
            .all(|j| matches!(j.status, EvalStatus::Done { .. }));
        LeaseReply::Idle { done }
    }

    /// Accept a trial outcome: the record must already be in the
    /// coordinator store and be stamped with the trial's spec hash.
    /// Duplicates (already-done trials) are accepted idempotently.
    fn submit_trial(&self, j: &Json) -> Result<Response> {
        let idx = proto::gnum(j, "index")?;
        let spec_hash = proto::gstr(j, "spec_hash")?;

        if let Some(err) = j.get("error").and_then(Json::as_str) {
            let lease_id = proto::ghex(j, "lease_id")?;
            let mut st = self.state.lock().unwrap();
            let n = st.trials.len();
            ensure!(idx < n, "trial index {idx} out of range (queue has {n})");
            ensure!(
                st.trials[idx].hash == spec_hash,
                "spec hash {spec_hash} does not match queued trial {idx}"
            );
            // failure is lease-keyed: only the current leaseholder may
            // fail a trial, so a stale worker's error cannot poison a
            // re-run already under way
            let current = matches!(
                st.trials[idx].status,
                TrialStatus::Leased { lease, .. } if lease == lease_id
            );
            if current {
                let msg = format!("worker error on '{}': {err}", st.trials[idx].spec.id);
                st.trials[idx].status = TrialStatus::Failed { error: msg };
            } else {
                st.stats.rejected += 1;
            }
            return Ok(Response::json(&proto::message(vec![
                ("ok", Json::Bool(true)),
                ("accepted", Json::Bool(current)),
            ])));
        }

        let rec_hash = proto::gstr(j, "outcome")?;
        // success is content-keyed: validate the record against the
        // store before touching queue state, and accept it regardless of
        // which lease produced it
        let rec = match snapshot::outcome_from_store(&self.store, rec_hash) {
            Ok(rec) => rec,
            Err(e) => {
                self.state.lock().unwrap().stats.rejected += 1;
                return Ok(Response::error(
                    409,
                    &format!("outcome record {rec_hash} is not in the coordinator store (push it before submitting): {e:#}"),
                ));
            }
        };
        if rec.spec_hash.as_deref() != Some(spec_hash) {
            self.state.lock().unwrap().stats.rejected += 1;
            return Ok(Response::error(
                409,
                &format!("record {rec_hash} is not stamped with spec hash {spec_hash}"),
            ));
        }

        let mut st = self.state.lock().unwrap();
        let n = st.trials.len();
        ensure!(idx < n, "trial index {idx} out of range (queue has {n})");
        if st.trials[idx].hash != spec_hash {
            st.stats.rejected += 1;
            return Ok(Response::error(
                409,
                &format!("spec hash {spec_hash} does not match queued trial {idx}"),
            ));
        }
        let duplicate = matches!(st.trials[idx].status, TrialStatus::Done { .. });
        if duplicate {
            st.stats.duplicates += 1;
        } else {
            let entry = LockEntry {
                outcome: rec_hash.to_string(),
                id: st.trials[idx].spec.id.clone(),
                label: rec.outcome.label.clone(),
            };
            // pin under the state lock so concurrent submissions
            // serialize their read-modify-write of grid.lock.json
            GridLock::record(&self.dir, spec_hash, &entry)?;
            st.trials[idx].status = TrialStatus::Done {
                outcome: rec_hash.to_string(),
                cached: false,
            };
            st.stats.outcomes_accepted += 1;
        }
        Ok(Response::json(&proto::message(vec![
            ("ok", Json::Bool(true)),
            ("duplicate", Json::Bool(duplicate)),
        ])))
    }

    /// Accept an eval-shard outcome (idempotent on duplicates).
    fn submit_eval(&self, j: &Json) -> Result<Response> {
        let idx = proto::gnum(j, "index")?;
        let losses: Vec<f64> = proto::gstrs(j, "losses")?
            .iter()
            .map(|s| {
                u64::from_str_radix(s, 16)
                    .map(f64::from_bits)
                    .map_err(|_| anyhow!("loss entry '{s}' is not a hex f64 bit pattern"))
            })
            .collect::<Result<_>>()?;
        let mut st = self.state.lock().unwrap();
        let n = st.evals.len();
        ensure!(idx < n, "eval index {idx} out of range (queue has {n})");
        let expected = (st.evals[idx].b1 - st.evals[idx].b0) as usize;
        ensure!(
            losses.len() == expected,
            "eval shard {idx} expects {expected} losses, got {}",
            losses.len()
        );
        let duplicate = matches!(st.evals[idx].status, EvalStatus::Done { .. });
        if duplicate {
            st.stats.duplicates += 1;
        } else {
            st.evals[idx].status = EvalStatus::Done { losses };
            st.stats.outcomes_accepted += 1;
        }
        Ok(Response::json(&proto::message(vec![
            ("ok", Json::Bool(true)),
            ("duplicate", Json::Bool(duplicate)),
        ])))
    }

    fn status_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let (mut pending, mut leased, mut done, mut failed) = (0usize, 0usize, 0usize, 0usize);
        for t in &st.trials {
            match t.status {
                TrialStatus::Pending => pending += 1,
                TrialStatus::Leased { .. } => leased += 1,
                TrialStatus::Done { .. } => done += 1,
                TrialStatus::Failed { .. } => failed += 1,
            }
        }
        proto::message(vec![
            ("trials", jnum(st.trials.len())),
            ("pending", jnum(pending)),
            ("leased", jnum(leased)),
            ("done", jnum(done)),
            ("failed", jnum(failed)),
            ("evals", jnum(st.evals.len())),
            ("leases_granted", jhex64(st.stats.leases_granted)),
            ("requeues", jhex64(st.stats.requeues)),
            ("outcomes_accepted", jhex64(st.stats.outcomes_accepted)),
            ("duplicates", jhex64(st.stats.duplicates)),
            ("rejected", jhex64(st.stats.rejected)),
            ("cached_on_enqueue", jhex64(st.stats.cached_on_enqueue)),
        ])
    }

    fn results_json(&self) -> Result<Json> {
        let st = self.state.lock().unwrap();
        let mut rows = Vec::with_capacity(st.trials.len());
        for t in &st.trials {
            let (status, outcome, cached) = match &t.status {
                TrialStatus::Pending => ("pending", Json::Null, false),
                TrialStatus::Leased { .. } => ("leased", Json::Null, false),
                TrialStatus::Failed { error } => ("failed", jstr(error), false),
                TrialStatus::Done { outcome, cached } => {
                    let rec = snapshot::outcome_from_store(&self.store, outcome)
                        .with_context(|| format!("loading outcome record {outcome}"))?;
                    ("done", rec.outcome.to_json(), *cached)
                }
            };
            rows.push(jobj(vec![
                ("id", jstr(&t.spec.id)),
                ("spec_hash", jstr(&t.hash)),
                ("status", jstr(status)),
                ("cached", Json::Bool(cached)),
                ("outcome", outcome),
            ]));
        }
        Ok(proto::message(vec![("rows", Json::Arr(rows))]))
    }
}

/// Route one request.  Errors become 400s with the error chain as the
/// body, so a worker's log names the actual failure.
fn handle(inner: &Arc<Inner>, req: &Request) -> Response {
    match route(inner, req) {
        Ok(resp) => resp,
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

fn route(inner: &Arc<Inner>, req: &Request) -> Result<Response> {
    let obj_prefix = format!("{}/", proto::P_STORE_OBJ);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", proto::P_PING) => Ok(Response::json(&proto::message(vec![(
            "service",
            jstr("zo-coordinator"),
        )]))),
        ("POST", proto::P_LEASE) => Ok(Response::json(&inner.grant_lease().to_json())),
        ("POST", proto::P_ENQUEUE) => {
            let j = body_json(&req.body)?;
            let specs = wire::grid_from_json(&j)?;
            let total = specs.len();
            let cached = inner.enqueue(specs)?;
            Ok(Response::json(&proto::message(vec![
                ("ok", Json::Bool(true)),
                ("total", jnum(total)),
                ("cached", jnum(cached)),
            ])))
        }
        ("POST", proto::P_OUTCOME) => {
            let j = body_json(&req.body)?;
            wire::check_schema(&j)?;
            match proto::gstr(&j, "kind")? {
                "trial" => inner.submit_trial(&j),
                "eval" => inner.submit_eval(&j),
                other => bail!("unknown outcome kind '{other}'"),
            }
        }
        ("POST", proto::P_EVAL_ENQUEUE) => {
            let j = body_json(&req.body)?;
            wire::check_schema(&j)?;
            let spec = TrialSpec::from_json(
                j.get("spec").ok_or_else(|| anyhow!("eval enqueue missing 'spec'"))?,
            )?;
            let params_hash = proto::gstr(&j, "params")?;
            let batches = proto::ghex(&j, "batches")?;
            let shard = proto::ghex(&j, "shard")?;
            ensure!(shard > 0, "eval shard size must be >= 1");
            ensure!(
                inner.store.contains(params_hash),
                "parameter image {params_hash} is not in the coordinator store (push it first)"
            );
            let mut st = inner.state.lock().unwrap();
            let mut n = 0;
            let mut b0 = 0;
            while b0 < batches {
                let b1 = (b0 + shard).min(batches);
                st.evals.push(EvalJob {
                    spec: spec.clone(),
                    params: params_hash.to_string(),
                    b0,
                    b1,
                    status: EvalStatus::Pending,
                });
                n += 1;
                b0 = b1;
            }
            Ok(Response::json(&proto::message(vec![
                ("ok", Json::Bool(true)),
                ("shards", jnum(n)),
            ])))
        }
        ("POST", proto::P_STORE_HAVE) => {
            let j = body_json(&req.body)?;
            wire::check_schema(&j)?;
            let hashes = proto::gstrs(&j, "hashes")?;
            let missing: Vec<Json> = hashes
                .iter()
                .filter(|h| !inner.store.contains(h))
                .map(|h| jstr(h))
                .collect();
            Ok(Response::json(&proto::message(vec![(
                "missing",
                Json::Arr(missing),
            )])))
        }
        ("POST", proto::P_STORE_OBJ) => {
            let hash = inner.store.put(&req.body)?;
            inner.state.lock().unwrap().stats.store_pushes += 1;
            Ok(Response::json(&proto::message(vec![(
                "hash",
                jstr(&hash),
            )])))
        }
        ("GET", p) if p.starts_with(obj_prefix.as_str()) => {
            let hash = &p[obj_prefix.len()..];
            if !inner.store.contains(hash) {
                return Ok(Response::error(404, &format!("no object {hash}")));
            }
            let bytes = inner.store.get(hash)?;
            inner.state.lock().unwrap().stats.store_pulls += 1;
            Ok(Response::bytes(bytes))
        }
        ("GET", proto::P_STATUS) => Ok(Response::json(&inner.status_json())),
        ("GET", proto::P_RESULTS) => Ok(Response::json(&inner.results_json()?)),
        ("POST", proto::P_SHUTDOWN) => {
            inner.persist_queue()?;
            inner.stop.store(true, Ordering::SeqCst);
            Ok(Response::json(&proto::message(vec![(
                "ok",
                Json::Bool(true),
            )])))
        }
        _ => Ok(Response::error(
            404,
            &format!("no route {} {}", req.method, req.path),
        )),
    }
}

/// Parse a request body as JSON.
fn body_json(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("request body is not UTF-8"))?;
    parse(text).map_err(|e| anyhow!("request body is not valid JSON: {e}"))
}
