//! The versioned coordinator/worker wire protocol (DESIGN.md §17):
//! route constants, the lease-reply message, and the JSON field getters
//! the two endpoints share.  Every JSON message carries the wire schema
//! version ([`crate::coordinator::wire::WIRE_SCHEMA_VERSION`]) and is
//! rejected on mismatch, so a coordinator and worker from different
//! builds fail loudly instead of silently misinterpreting each other.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::wire::{self, jhex64, jnum, jobj, jstr};
use crate::coordinator::TrialSpec;
use crate::jsonio::Json;

/// Ping/identify: GET, answers the schema version.
pub const P_PING: &str = "/api/v1/ping";
/// Enqueue trials: POST, body is a wire grid file.
pub const P_ENQUEUE: &str = "/api/v1/enqueue";
/// Lease work: POST, answers a [`LeaseReply`].
pub const P_LEASE: &str = "/api/v1/lease";
/// Submit an outcome (trial or eval shard): POST.
pub const P_OUTCOME: &str = "/api/v1/outcome";
/// Enqueue loss-evaluation shards: POST.
pub const P_EVAL_ENQUEUE: &str = "/api/v1/eval/enqueue";
/// Store negotiation: POST a hash list, answers the missing subset.
pub const P_STORE_HAVE: &str = "/api/v1/store/have";
/// Store objects: POST raw bytes to push; GET `<prefix>/<hash>` to pull.
pub const P_STORE_OBJ: &str = "/api/v1/store/obj";
/// Queue status counters: GET.
pub const P_STATUS: &str = "/api/v1/status";
/// Completed results (wire outcomes per trial): GET.
pub const P_RESULTS: &str = "/api/v1/results";
/// Graceful shutdown: POST, persists the queue and stops the listener.
pub const P_SHUTDOWN: &str = "/api/v1/shutdown";

/// Required string field.
pub fn gstr<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
    j.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing or non-string field '{k}'"))
}

/// Required `{:016x}` hex-encoded u64 field.
pub fn ghex(j: &Json, k: &str) -> Result<u64> {
    let s = gstr(j, k)?;
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("field '{k}' is not a hex u64: '{s}'"))
}

/// Required numeric (usize) field.
pub fn gnum(j: &Json, k: &str) -> Result<usize> {
    j.get(k)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing or non-numeric field '{k}'"))
}

/// Required string-array field.
pub fn gstrs(j: &Json, k: &str) -> Result<Vec<String>> {
    let arr = j
        .get(k)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing or non-array field '{k}'"))?;
    arr.iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("field '{k}' holds a non-string element"))
        })
        .collect()
}

/// A schema-stamped message with the given extra fields.
pub fn message(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("schema", jhex64(wire::WIRE_SCHEMA_VERSION))];
    pairs.extend(fields);
    jobj(pairs)
}

/// What the coordinator answers a lease request with.
#[derive(Clone, Debug)]
pub enum LeaseReply {
    /// Nothing to hand out right now.  `done` means every queued job is
    /// terminal — the worker can exit instead of polling again.
    Idle {
        /// True when the queue is fully terminal.
        done: bool,
    },
    /// One full training trial.
    Trial {
        /// Lease token; quote it back when submitting.
        lease_id: u64,
        /// Queue index of the trial; quote it back when submitting.
        index: usize,
        /// Lease duration in ms — unfinished work past this is re-leased.
        timeout_ms: u64,
        /// Store objects to sync before starting (may be empty).
        sync: Vec<String>,
        /// The trial to run.
        spec: TrialSpec,
    },
    /// One loss-evaluation shard: evaluate `spec`'s oracle at the
    /// parameter image `params` over test batches `b0..b1`.
    Eval {
        /// Lease token; quote it back when submitting.
        lease_id: u64,
        /// Queue index of the shard; quote it back when submitting.
        index: usize,
        /// Lease duration in ms — unfinished work past this is re-leased.
        timeout_ms: u64,
        /// Store objects to sync before starting (includes `params`).
        sync: Vec<String>,
        /// The trial whose oracle defines the loss.
        spec: TrialSpec,
        /// Store hash of the f32 little-endian parameter image.
        params: String,
        /// First test-batch index (inclusive).
        b0: u64,
        /// Last test-batch index (exclusive).
        b1: u64,
    },
}

impl LeaseReply {
    /// Wire encoding (schema-stamped).
    pub fn to_json(&self) -> Json {
        match self {
            LeaseReply::Idle { done } => {
                message(vec![("kind", jstr("idle")), ("done", Json::Bool(*done))])
            }
            LeaseReply::Trial {
                lease_id,
                index,
                timeout_ms,
                sync,
                spec,
            } => message(vec![
                ("kind", jstr("trial")),
                ("lease_id", jhex64(*lease_id)),
                ("index", jnum(*index)),
                ("timeout_ms", jhex64(*timeout_ms)),
                ("sync", Json::Arr(sync.iter().map(|h| jstr(h)).collect())),
                ("spec", spec.to_json()),
            ]),
            LeaseReply::Eval {
                lease_id,
                index,
                timeout_ms,
                sync,
                spec,
                params,
                b0,
                b1,
            } => message(vec![
                ("kind", jstr("eval")),
                ("lease_id", jhex64(*lease_id)),
                ("index", jnum(*index)),
                ("timeout_ms", jhex64(*timeout_ms)),
                ("sync", Json::Arr(sync.iter().map(|h| jstr(h)).collect())),
                ("spec", spec.to_json()),
                ("params", jstr(params)),
                ("b0", jhex64(*b0)),
                ("b1", jhex64(*b1)),
            ]),
        }
    }

    /// Decode a wire lease reply, validating the schema stamp.
    pub fn from_json(j: &Json) -> Result<LeaseReply> {
        wire::check_schema(j)?;
        match gstr(j, "kind")? {
            "idle" => Ok(LeaseReply::Idle {
                done: j.get("done").and_then(Json::as_bool).unwrap_or(false),
            }),
            "trial" => Ok(LeaseReply::Trial {
                lease_id: ghex(j, "lease_id")?,
                index: gnum(j, "index")?,
                timeout_ms: ghex(j, "timeout_ms")?,
                sync: gstrs(j, "sync")?,
                spec: TrialSpec::from_json(
                    j.get("spec").ok_or_else(|| anyhow!("lease reply missing 'spec'"))?,
                )?,
            }),
            "eval" => Ok(LeaseReply::Eval {
                lease_id: ghex(j, "lease_id")?,
                index: gnum(j, "index")?,
                timeout_ms: ghex(j, "timeout_ms")?,
                sync: gstrs(j, "sync")?,
                spec: TrialSpec::from_json(
                    j.get("spec").ok_or_else(|| anyhow!("lease reply missing 'spec'"))?,
                )?,
                params: gstr(j, "params")?.to_string(),
                b0: ghex(j, "b0")?,
                b1: ghex(j, "b1")?,
            }),
            other => bail!("unknown lease-reply kind '{other}'"),
        }
    }
}

/// Pack an f32 slice as little-endian bytes (parameter-image blobs).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Unpack a little-endian f32 blob (must be a multiple of 4 bytes).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("parameter blob of {} bytes is not a whole number of f32s", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
