//! bench-gate: the CI benchmark-regression gate.
//!
//! Diffs a current `BENCH_*.json` (written by `make bench-smoke` via
//! `BENCH_JSON=<path>`) against the committed baseline and fails —
//! nonzero exit — when any gated row got slower (or grew its peak
//! probe-state bytes) beyond the threshold, or disappeared.
//!
//!     bench-gate --baseline rust/benches/BENCH_baseline.json \
//!                --current BENCH_current.json \
//!                [--threshold 0.20] [--bytes-threshold 0.20]
//!                [--gate loss_k,axpy_k,probe_combine,mlp,mem/]
//!                [--ab-max-ratio 0.67] [--ab-prefix lanes/]
//!                [--ab-specs lanes/:scalar:wide:0.67,gemm/:reference:blocked:0.5]
//!
//! `--threshold` bounds the (noisy, hardware-dependent) ns/op ratios;
//! `--bytes-threshold` bounds the deterministic peak-byte ratios and can
//! be held much tighter.  `--ab-max-ratio` additionally enforces the
//! intra-run scalar-vs-wide speedup on every `--ab-prefix` row pair
//! (`<prefix><stem>_scalar` / `_wide`): both arms come from the same
//! run, so the bound is hardware-portable and needs no stored anchor
//! (0 disables the check).  `--ab-specs` generalizes that to any number
//! of slow/fast row families, each with its own suffix pair and bound
//! (`prefix:slow:fast:ratio[,...]`, suffixes without the leading
//! underscore) — it is how the GEMM engine's `_reference`/`_blocked`
//! speedup is enforced (DESIGN.md §15), and runs in addition to the
//! legacy `--ab-prefix` pairing.
//!
//! Every failing row is reported in one invocation — the gate collects
//! all regressions, A/B violations and missing rows before exiting
//! nonzero — and each table row prints the bound it was held to next to
//! the observed ratio.
//!
//! With `--store-dir DIR`, a green gate archives the current report into
//! the content-addressed store and pins it in the store's
//! `bench.lock.json` under `--store-label` (default "current") — the
//! audit trail of exactly which gated report byte-set passed
//! (DESIGN.md §16).
//!
//! Regenerate the baseline on the reference runner with
//! `make bench-baseline` and commit it (see DESIGN.md §12).

use anyhow::{bail, Context, Result};

use zo_ldsd::bench::regression::{ab_gate, ab_gate_suffixed, gate, parse_ab_specs, parse_rows};
use zo_ldsd::cli::Args;
use zo_ldsd::report::Table;

fn main() {
    if let Err(e) = run() {
        eprintln!("bench-gate: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[])?;
    args.reject_unknown(
        &[
            "baseline",
            "current",
            "threshold",
            "bytes-threshold",
            "gate",
            "ab-max-ratio",
            "ab-prefix",
            "ab-specs",
            "store-dir",
            "store-label",
        ],
        &[],
    )?;
    let baseline_path = args.require("baseline")?.to_string();
    let current_path = args.require("current")?.to_string();
    let threshold = args.get_f64("threshold", 0.20)?;
    let bytes_threshold = args.get_f64("bytes-threshold", threshold)?;
    let ab_max_ratio = args.get_f64("ab-max-ratio", 0.0)?;
    let ab_prefix = args.get_or("ab-prefix", "lanes/").to_string();
    let ab_specs = parse_ab_specs(args.get_or("ab-specs", ""))?;
    let gates_raw = args
        .get_or("gate", "loss_k,axpy_k,probe_combine,mlp,mem/")
        .to_string();
    let gates: Vec<&str> = gates_raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();

    let baseline = parse_rows(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )?;
    let current_text = std::fs::read_to_string(&current_path)
        .with_context(|| format!("reading current {current_path}"))?;
    let current = parse_rows(&current_text)?;

    let report = gate(&baseline, &current, threshold, bytes_threshold, &gates);
    println!(
        "bench-gate: {} gated row(s) compared against {baseline_path} \
         (ns +{:.0}%, bytes +{:.0}%, gates: {gates_raw})",
        report.compared,
        threshold * 100.0,
        bytes_threshold * 100.0
    );
    for m in &report.missing {
        println!("  MISSING from current run: {m}");
    }
    if !report.regressions.is_empty() {
        let mut t = Table::new(
            "bench regressions",
            &["row", "metric", "baseline", "current", "ratio", "limit"],
        );
        for r in &report.regressions {
            let limit = match r.metric {
                "peak_bytes" => bytes_threshold,
                _ => threshold,
            };
            t.row(vec![
                r.name.clone(),
                r.metric.to_string(),
                format!("{:.1}", r.baseline),
                format!("{:.1}", r.current),
                format!("{:.2}x", r.ratio),
                format!("<= {:.2}x", 1.0 + limit),
            ]);
        }
        t.print();
    }

    // intra-run scalar-vs-wide speedup (hardware-portable: both arms are
    // measured in the same run, so no stored anchor is involved)
    let ab = if ab_max_ratio > 0.0 {
        let ab = ab_gate(&current, &ab_prefix, ab_max_ratio);
        println!(
            "bench-gate: {} A/B pair(s) checked (prefix {ab_prefix}, wide <= {lim:.2}x scalar)",
            ab.compared,
            lim = ab_max_ratio
        );
        if !ab.violations.is_empty() {
            let mut t = Table::new(
                "A/B speedup violations",
                &["scalar row", "scalar ns", "wide ns", "ratio", "limit"],
            );
            for v in &ab.violations {
                t.row(vec![
                    v.scalar.clone(),
                    format!("{:.1}", v.scalar_ns),
                    if v.wide_ns.is_nan() {
                        "MISSING".to_string()
                    } else {
                        format!("{:.1}", v.wide_ns)
                    },
                    format!("{:.2}x", v.ratio),
                    format!("<= {ab_max_ratio:.2}x"),
                ]);
            }
            t.print();
        }
        ab
    } else {
        Default::default()
    };

    // suffixed A/B families (--ab-specs): same intra-run portability as
    // the lane pairing, with per-family suffixes and bounds
    let mut spec_violations = 0usize;
    for spec in &ab_specs {
        let rep = ab_gate_suffixed(
            &current,
            &spec.prefix,
            &spec.slow_suffix,
            &spec.fast_suffix,
            spec.max_ratio,
        );
        println!(
            "bench-gate: {} A/B pair(s) checked (prefix {}, *{} <= {:.2}x *{})",
            rep.compared, spec.prefix, spec.fast_suffix, spec.max_ratio, spec.slow_suffix,
        );
        if !rep.violations.is_empty() {
            let mut t = Table::new(
                "A/B speedup violations",
                &["slow row", "slow ns", "fast ns", "ratio", "limit"],
            );
            for v in &rep.violations {
                t.row(vec![
                    v.scalar.clone(),
                    format!("{:.1}", v.scalar_ns),
                    if v.wide_ns.is_nan() {
                        "MISSING".to_string()
                    } else {
                        format!("{:.1}", v.wide_ns)
                    },
                    format!("{:.2}x", v.ratio),
                    format!("<= {:.2}x", spec.max_ratio),
                ]);
            }
            t.print();
        }
        spec_violations += rep.violations.len();
    }

    if !report.is_green() || !ab.is_green() || spec_violations > 0 {
        bail!(
            "{} regression(s), {} missing gated row(s), {} A/B violation(s)",
            report.regressions.len(),
            report.missing.len(),
            ab.violations.len() + spec_violations
        );
    }
    println!("bench-gate: green");
    // archive the exact report bytes that passed: store object + lockfile
    // pin, so the audit trail dedups across identical re-runs
    if let Some(dir) = args.get("store-dir") {
        let store = zo_ldsd::store::Store::open(dir);
        let hash = store.put(current_text.as_bytes())?;
        let label = args.get_or("store-label", "current");
        zo_ldsd::store::BenchLock::record(store.root(), label, &hash)?;
        println!("bench-gate: archived gated report as {hash} (label '{label}')");
    }
    Ok(())
}
