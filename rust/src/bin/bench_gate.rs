//! bench-gate: the CI benchmark-regression gate.
//!
//! Diffs a current `BENCH_*.json` (written by `make bench-smoke` via
//! `BENCH_JSON=<path>`) against the committed baseline and fails —
//! nonzero exit — when any gated row got slower (or grew its peak
//! probe-state bytes) beyond the threshold, or disappeared.
//!
//!     bench-gate --baseline rust/benches/BENCH_baseline.json \
//!                --current BENCH_current.json \
//!                [--threshold 0.20] [--bytes-threshold 0.20]
//!                [--gate loss_k,axpy_k,probe_combine,mlp,mem/]
//!
//! `--threshold` bounds the (noisy, hardware-dependent) ns/op ratios;
//! `--bytes-threshold` bounds the deterministic peak-byte ratios and can
//! be held much tighter.
//!
//! Regenerate the baseline on the reference runner with
//! `make bench-baseline` and commit it (see DESIGN.md §12).

use anyhow::{bail, Context, Result};

use zo_ldsd::bench::regression::{gate, parse_rows};
use zo_ldsd::cli::Args;
use zo_ldsd::report::Table;

fn main() {
    if let Err(e) = run() {
        eprintln!("bench-gate: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[])?;
    args.reject_unknown(
        &["baseline", "current", "threshold", "bytes-threshold", "gate"],
        &[],
    )?;
    let baseline_path = args.require("baseline")?.to_string();
    let current_path = args.require("current")?.to_string();
    let threshold = args.get_f64("threshold", 0.20)?;
    let bytes_threshold = args.get_f64("bytes-threshold", threshold)?;
    let gates_raw = args
        .get_or("gate", "loss_k,axpy_k,probe_combine,mlp,mem/")
        .to_string();
    let gates: Vec<&str> = gates_raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();

    let baseline = parse_rows(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )?;
    let current = parse_rows(
        &std::fs::read_to_string(&current_path)
            .with_context(|| format!("reading current {current_path}"))?,
    )?;

    let report = gate(&baseline, &current, threshold, bytes_threshold, &gates);
    println!(
        "bench-gate: {} gated row(s) compared against {baseline_path} \
         (ns +{:.0}%, bytes +{:.0}%, gates: {gates_raw})",
        report.compared,
        threshold * 100.0,
        bytes_threshold * 100.0
    );
    for m in &report.missing {
        println!("  MISSING from current run: {m}");
    }
    if !report.regressions.is_empty() {
        let mut t = Table::new(
            "bench regressions",
            &["row", "metric", "baseline", "current", "ratio"],
        );
        for r in &report.regressions {
            t.row(vec![
                r.name.clone(),
                r.metric.to_string(),
                format!("{:.1}", r.baseline),
                format!("{:.1}", r.current),
                format!("{:.2}x", r.ratio),
            ]);
        }
        t.print();
    }
    if !report.is_green() {
        bail!(
            "{} regression(s), {} missing gated row(s)",
            report.regressions.len(),
            report.missing.len()
        );
    }
    println!("bench-gate: green");
    Ok(())
}
