//! bench-gate: the CI benchmark-regression gate.
//!
//! A thin argv wrapper over [`zo_ldsd::bench::regression::gate_cli`] —
//! the same driver behind the `zo bench-gate` subcommand, kept as a
//! standalone binary so existing CI invocations keep working.
//!
//!     bench-gate --baseline rust/benches/BENCH_baseline.json \
//!                --current BENCH_current.json \
//!                [--threshold 0.20] [--bytes-threshold 0.20]
//!                [--gate loss_k,axpy_k,probe_combine,mlp,mem/]
//!                [--ab-max-ratio 0.67] [--ab-prefix lanes/]
//!                [--ab-specs lanes/:scalar:wide:0.67,gemm/:reference:blocked:0.5]
//!                [--store-dir DIR] [--store-label L]
//!
//! `--threshold` bounds the (noisy, hardware-dependent) ns/op ratios;
//! `--bytes-threshold` bounds the deterministic peak-byte ratios and can
//! be held much tighter.  `--ab-max-ratio` additionally enforces the
//! intra-run scalar-vs-wide speedup on every `--ab-prefix` row pair,
//! and `--ab-specs` generalizes that to any number of slow/fast row
//! families (`prefix:slow:fast:ratio[,...]`).  With `--store-dir`, a
//! green gate archives the gated report into the content-addressed
//! store under `--store-label` (DESIGN.md §12, §16).
//!
//! Regenerate the baseline on the reference runner with
//! `make bench-baseline` and commit it (see DESIGN.md §12).

use anyhow::Result;

use zo_ldsd::bench::regression::gate_cli;
use zo_ldsd::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("bench-gate: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[])?;
    args.reject_unknown(
        &[
            "baseline",
            "current",
            "threshold",
            "bytes-threshold",
            "gate",
            "ab-max-ratio",
            "ab-prefix",
            "ab-specs",
            "store-dir",
            "store-label",
        ],
        &[],
    )?;
    gate_cli(&args)
}
