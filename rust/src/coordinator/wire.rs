//! Versioned wire schema for trial specs and outcomes (DESIGN.md §17).
//!
//! One encoding, three consumers: the canonical spec hash
//! ([`super::spec_hash`]), grid spec files (`zo-ldsd grid emit|run`), and
//! the coordinator/worker HTTP service ([`crate::service`]) all speak the
//! same canonical JSON — wire identity *is* cache identity, so an
//! outcome computed by a remote worker slots straight into
//! `grid.lock.json` warm-start on the coordinator.
//!
//! Encoding rules (inherited from the spec-hash encoding of DESIGN.md
//! §16): floats travel as IEEE-754 bit patterns in fixed-width hex
//! (`f32` → 8 hex digits, `f64` → 16), `u64` counters as 16-digit hex,
//! small structural counts as JSON numbers.  Objects are
//! [`BTreeMap`]-backed, so [`to_string_canonical`] emits sorted keys and
//! the bytes are stable across builds and platforms.
//!
//! Every top-level message carries `"schema"`: a reader rejects versions
//! it does not speak instead of guessing.  Checkpoint policy is
//! deliberately *not* on the wire — where a worker snapshots is
//! deployment-local configuration, not trial identity, and
//! [`TrialSpec::from_json`] leaves it `None` for the receiver to fill in.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::TrainMode;
use crate::data::corpus::CorpusSpec;
use crate::jsonio::Json;
use crate::model::mlp::Activation;
use crate::model::{LoraTargets, Pool};
use crate::sampler::LdsdConfig;
use crate::train::{
    EstimatorKind, GemmMode, ParamStoreMode, ProbeDispatch, ProbeStorage, SamplerKind,
    ShuffleSpec, TrainConfig, TrainOutcome,
};

use super::{MlpTrial, OracleSpec, TransformerTrial, TrialSpec};

/// Version stamped into (and required from) every wire message.
pub const WIRE_SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// canonical encoders (shared with the spec hash in `coordinator::spec_hash`)

/// Build a JSON object from literal key/value pairs.
pub fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Owned-string JSON value.
pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

/// Small structural count as a JSON number.
pub fn jnum(n: usize) -> Json {
    Json::Num(n as f64)
}

/// `u64` as 16-digit zero-padded hex (exact at any magnitude — JSON
/// numbers lose integers past 2^53).
pub fn jhex64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// `f32` as its IEEE-754 bit pattern in 8 hex digits.
pub fn jf32(x: f32) -> Json {
    Json::Str(format!("{:08x}", x.to_bits()))
}

/// `f64` as its IEEE-754 bit pattern in 16 hex digits.
pub fn jf64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

pub(super) fn jsampler(s: &SamplerKind) -> Json {
    match s {
        SamplerKind::Gaussian => jobj(vec![("kind", jstr("gaussian"))]),
        SamplerKind::Sphere => jobj(vec![("kind", jstr("sphere"))]),
        SamplerKind::Coordinate => jobj(vec![("kind", jstr("coordinate"))]),
        SamplerKind::Ldsd(c) => jobj(vec![
            ("kind", jstr("ldsd")),
            ("eps", jf32(c.eps)),
            ("gamma_mu", jf32(c.gamma_mu)),
            ("reward_sign", jf32(c.reward_sign)),
            ("init_norm", jf32(c.init_norm)),
            ("renormalize", Json::Bool(c.renormalize)),
            ("leave_one_out", Json::Bool(c.leave_one_out)),
        ]),
    }
}

pub(super) fn jestimator(e: &EstimatorKind) -> Json {
    match e {
        EstimatorKind::CentralK1(s) => {
            jobj(vec![("kind", jstr("central_k1")), ("sampler", jsampler(s))])
        }
        EstimatorKind::ForwardAvg { k, sampler } => jobj(vec![
            ("kind", jstr("forward_avg")),
            ("k", jnum(*k)),
            ("sampler", jsampler(sampler)),
        ]),
        EstimatorKind::BestOfK { k, sampler } => jobj(vec![
            ("kind", jstr("bestofk")),
            ("k", jnum(*k)),
            ("sampler", jsampler(sampler)),
        ]),
    }
}

pub(super) fn jcorpus(c: &CorpusSpec) -> Json {
    jobj(vec![
        ("vocab", jhex64(c.vocab)),
        ("seq", jnum(c.seq)),
        ("n_classes", jhex64(c.n_classes)),
        ("lexicon", jhex64(c.lexicon)),
        ("min_len", jhex64(c.min_len)),
        ("signal_min", jhex64(c.signal_min)),
        ("signal_max", jhex64(c.signal_max)),
        ("contra", jf64(c.contra)),
        ("noise", jf64(c.noise)),
        ("seed", jhex64(c.seed)),
    ])
}

// ---------------------------------------------------------------------------
// canonical decoders

fn field<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow!("missing field '{k}'"))
}

fn fstr<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
    field(j, k)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{k}' is not a string"))
}

fn fbool(j: &Json, k: &str) -> Result<bool> {
    field(j, k)?
        .as_bool()
        .ok_or_else(|| anyhow!("field '{k}' is not a bool"))
}

fn fnum(j: &Json, k: &str) -> Result<usize> {
    field(j, k)?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{k}' is not a count"))
}

fn fhex64(j: &Json, k: &str) -> Result<u64> {
    let s = fstr(j, k)?;
    u64::from_str_radix(s, 16).with_context(|| format!("field '{k}': bad hex u64 '{s}'"))
}

fn ff32(j: &Json, k: &str) -> Result<f32> {
    let s = fstr(j, k)?;
    let bits = u32::from_str_radix(s, 16)
        .with_context(|| format!("field '{k}': bad f32 bit pattern '{s}'"))?;
    Ok(f32::from_bits(bits))
}

fn ff64(j: &Json, k: &str) -> Result<f64> {
    let s = fstr(j, k)?;
    let bits = u64::from_str_radix(s, 16)
        .with_context(|| format!("field '{k}': bad f64 bit pattern '{s}'"))?;
    Ok(f64::from_bits(bits))
}

/// Check the `"schema"` stamp on a wire message against what this build
/// speaks.
pub fn check_schema(j: &Json) -> Result<()> {
    let v = fhex64(j, "schema").context("wire message has no schema stamp")?;
    if v != WIRE_SCHEMA_VERSION {
        bail!(
            "wire schema {v} not supported (this build speaks {WIRE_SCHEMA_VERSION})"
        );
    }
    Ok(())
}

fn sampler_from_json(j: &Json) -> Result<SamplerKind> {
    match fstr(j, "kind")? {
        "gaussian" => Ok(SamplerKind::Gaussian),
        "sphere" => Ok(SamplerKind::Sphere),
        "coordinate" => Ok(SamplerKind::Coordinate),
        "ldsd" => Ok(SamplerKind::Ldsd(LdsdConfig {
            eps: ff32(j, "eps")?,
            gamma_mu: ff32(j, "gamma_mu")?,
            reward_sign: ff32(j, "reward_sign")?,
            init_norm: ff32(j, "init_norm")?,
            renormalize: fbool(j, "renormalize")?,
            leave_one_out: fbool(j, "leave_one_out")?,
        })),
        other => bail!("unknown sampler kind '{other}'"),
    }
}

fn estimator_from_json(j: &Json) -> Result<EstimatorKind> {
    let sampler = sampler_from_json(field(j, "sampler")?)?;
    match fstr(j, "kind")? {
        "central_k1" => Ok(EstimatorKind::CentralK1(sampler)),
        "forward_avg" => Ok(EstimatorKind::ForwardAvg { k: fnum(j, "k")?, sampler }),
        "bestofk" => Ok(EstimatorKind::BestOfK { k: fnum(j, "k")?, sampler }),
        other => bail!("unknown estimator kind '{other}'"),
    }
}

fn corpus_from_json(j: &Json) -> Result<CorpusSpec> {
    Ok(CorpusSpec {
        vocab: fhex64(j, "vocab")?,
        seq: fnum(j, "seq")?,
        n_classes: fhex64(j, "n_classes")?,
        lexicon: fhex64(j, "lexicon")?,
        min_len: fhex64(j, "min_len")?,
        signal_min: fhex64(j, "signal_min")?,
        signal_max: fhex64(j, "signal_max")?,
        contra: ff64(j, "contra")?,
        noise: ff64(j, "noise")?,
        seed: fhex64(j, "seed")?,
    })
}

// ---------------------------------------------------------------------------
// OracleSpec

impl OracleSpec {
    /// Canonical wire encoding.  Field-for-field the oracle identity the
    /// spec hash covers (the PJRT variant adds the manifest model name at
    /// the [`TrialSpec`] level, since the name lives there).
    pub fn to_json(&self) -> Json {
        match self {
            OracleSpec::Pjrt => jobj(vec![("kind", jstr("pjrt"))]),
            OracleSpec::Mlp(m) => jobj(vec![
                ("kind", jstr("mlp")),
                (
                    "hidden",
                    Json::Arr(m.hidden.iter().map(|h| jnum(*h)).collect()),
                ),
                ("activation", jstr(m.activation.label())),
                ("in_dim", jnum(m.in_dim)),
                ("corpus", jcorpus(&m.corpus)),
                ("init_seed", jhex64(m.init_seed)),
                ("eval_batch", jnum(m.eval_batch)),
            ]),
            OracleSpec::Transformer(t) => jobj(vec![
                ("kind", jstr("transformer")),
                ("layers", jnum(t.layers)),
                ("heads", jnum(t.heads)),
                ("d_model", jnum(t.d_model)),
                ("d_ff", jnum(t.d_ff)),
                ("lora_rank", jnum(t.lora_rank)),
                ("lora_targets", jstr(&t.lora_targets.label())),
                ("causal", Json::Bool(t.causal)),
                ("pool", jstr(t.pool.label())),
                ("corpus", jcorpus(&t.corpus)),
                ("init_seed", jhex64(t.init_seed)),
                ("eval_batch", jnum(t.eval_batch)),
            ]),
        }
    }

    /// Decode the wire encoding produced by [`OracleSpec::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        match fstr(j, "kind")? {
            "pjrt" => Ok(OracleSpec::Pjrt),
            "mlp" => {
                let hidden = field(j, "hidden")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("field 'hidden' is not an array"))?
                    .iter()
                    .map(|h| h.as_usize().ok_or_else(|| anyhow!("bad hidden width")))
                    .collect::<Result<Vec<usize>>>()?;
                Ok(OracleSpec::Mlp(MlpTrial {
                    hidden,
                    activation: Activation::parse(fstr(j, "activation")?)?,
                    in_dim: fnum(j, "in_dim")?,
                    corpus: corpus_from_json(field(j, "corpus")?)?,
                    init_seed: fhex64(j, "init_seed")?,
                    eval_batch: fnum(j, "eval_batch")?,
                }))
            }
            "transformer" => Ok(OracleSpec::Transformer(TransformerTrial {
                layers: fnum(j, "layers")?,
                heads: fnum(j, "heads")?,
                d_model: fnum(j, "d_model")?,
                d_ff: fnum(j, "d_ff")?,
                lora_rank: fnum(j, "lora_rank")?,
                lora_targets: LoraTargets::parse(fstr(j, "lora_targets")?)?,
                causal: fbool(j, "causal")?,
                pool: Pool::parse(fstr(j, "pool")?)?,
                corpus: corpus_from_json(field(j, "corpus")?)?,
                init_seed: fhex64(j, "init_seed")?,
                eval_batch: fnum(j, "eval_batch")?,
            })),
            other => bail!("unknown oracle kind '{other}'"),
        }
    }
}

// ---------------------------------------------------------------------------
// TrainConfig

fn config_to_json(cfg: &TrainConfig) -> Json {
    let shuffle = match &cfg.shuffle {
        Some(s) => jobj(vec![("n_train", jhex64(s.n_train))]),
        None => Json::Null,
    };
    jobj(vec![
        ("estimator", jestimator(&cfg.estimator)),
        ("optimizer", jstr(&cfg.optimizer)),
        ("lr", jf32(cfg.lr)),
        ("tau", jf32(cfg.tau)),
        ("budget", jhex64(cfg.budget)),
        ("eval_every", jhex64(cfg.eval_every)),
        ("eval_batches", jnum(cfg.eval_batches)),
        ("cosine_schedule", Json::Bool(cfg.cosine_schedule)),
        ("seed", jhex64(cfg.seed)),
        ("probe_dispatch", jstr(cfg.probe_dispatch.label())),
        ("probe_storage", jstr(cfg.probe_storage.label())),
        ("shuffle", shuffle),
        ("param_store", jstr(cfg.param_store.label())),
        ("gemm", jstr(cfg.gemm.label())),
    ])
}

fn config_from_json(j: &Json) -> Result<TrainConfig> {
    let shuffle = match field(j, "shuffle")? {
        Json::Null => None,
        s => Some(ShuffleSpec { n_train: fhex64(s, "n_train")? }),
    };
    let param = fstr(j, "param_store")?;
    let gemm = fstr(j, "gemm")?;
    Ok(TrainConfig {
        estimator: estimator_from_json(field(j, "estimator")?)?,
        optimizer: fstr(j, "optimizer")?.to_string(),
        lr: ff32(j, "lr")?,
        tau: ff32(j, "tau")?,
        budget: fhex64(j, "budget")?,
        eval_every: fhex64(j, "eval_every")?,
        eval_batches: fnum(j, "eval_batches")?,
        cosine_schedule: fbool(j, "cosine_schedule")?,
        seed: fhex64(j, "seed")?,
        probe_dispatch: ProbeDispatch::parse(fstr(j, "probe_dispatch")?)?,
        probe_storage: ProbeStorage::parse(fstr(j, "probe_storage")?)?,
        checkpoint: Default::default(),
        shuffle,
        param_store: ParamStoreMode::parse(param)
            .ok_or_else(|| anyhow!("unknown param store '{param}'"))?,
        gemm: GemmMode::parse(gemm).ok_or_else(|| anyhow!("unknown gemm mode '{gemm}'"))?,
    })
}

// ---------------------------------------------------------------------------
// TrialSpec

/// Encode an optional per-trial override as its label or `null`.
fn jopt(label: Option<&str>) -> Json {
    match label {
        Some(l) => jstr(l),
        None => Json::Null,
    }
}

impl TrialSpec {
    /// The one constructor path for programmatic specs: identity fields
    /// only, every per-trial override `None`, checkpoint policy left to
    /// the runner.  Grids and the service build specs here (or through
    /// [`TrialSpec::from_json`], which feeds the same fields) instead of
    /// ad-hoc struct literals, so a new field shows up in exactly one
    /// place.
    pub fn new(id: &str, model: &str, mode: TrainMode, config: TrainConfig, oracle: OracleSpec) -> Self {
        let eval_batches = config.eval_batches;
        TrialSpec {
            id: id.to_string(),
            model: model.to_string(),
            mode,
            config,
            eval_batches,
            probe_dispatch: None,
            probe_storage: None,
            param_store: None,
            gemm: None,
            checkpoint: None,
            oracle,
        }
    }

    /// Canonical wire encoding, `"schema"`-stamped.  Checkpoint policy is
    /// not serialized (worker-local; see module docs).
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("schema", jhex64(WIRE_SCHEMA_VERSION)),
            ("id", jstr(&self.id)),
            ("model", jstr(&self.model)),
            ("mode", jstr(self.mode.as_str())),
            ("config", config_to_json(&self.config)),
            ("eval_batches", jnum(self.eval_batches)),
            ("probe_dispatch", jopt(self.probe_dispatch.map(|d| d.label()))),
            ("probe_storage", jopt(self.probe_storage.map(|s| s.label()))),
            ("param_store", jopt(self.param_store.map(|p| p.label()))),
            ("gemm", jopt(self.gemm.map(|g| g.label()))),
            ("oracle", self.oracle.to_json()),
        ])
    }

    /// Decode the wire encoding produced by [`TrialSpec::to_json`],
    /// rejecting schema versions this build does not speak.
    pub fn from_json(j: &Json) -> Result<Self> {
        check_schema(j)?;
        let opt = |k: &str| -> Result<Option<&str>> {
            match field(j, k)? {
                Json::Null => Ok(None),
                v => Ok(Some(v.as_str().ok_or_else(|| {
                    anyhow!("field '{k}' is neither null nor a string")
                })?)),
            }
        };
        let probe_dispatch = opt("probe_dispatch")?.map(ProbeDispatch::parse).transpose()?;
        let probe_storage = opt("probe_storage")?.map(ProbeStorage::parse).transpose()?;
        let param_store = opt("param_store")?
            .map(|s| ParamStoreMode::parse(s).ok_or_else(|| anyhow!("unknown param store '{s}'")))
            .transpose()?;
        let gemm = opt("gemm")?
            .map(|s| GemmMode::parse(s).ok_or_else(|| anyhow!("unknown gemm mode '{s}'")))
            .transpose()?;
        Ok(TrialSpec {
            id: fstr(j, "id")?.to_string(),
            model: fstr(j, "model")?.to_string(),
            mode: TrainMode::parse(fstr(j, "mode")?)?,
            config: config_from_json(field(j, "config")?)?,
            eval_batches: fnum(j, "eval_batches")?,
            probe_dispatch,
            probe_storage,
            param_store,
            gemm,
            checkpoint: None,
            oracle: OracleSpec::from_json(field(j, "oracle")?)?,
        })
    }
}

// ---------------------------------------------------------------------------
// TrainOutcome

fn jcurve(curve: &[(u64, f64)]) -> Json {
    Json::Arr(
        curve
            .iter()
            .map(|(calls, v)| Json::Arr(vec![jhex64(*calls), jf64(*v)]))
            .collect(),
    )
}

fn curve_from_json(j: &Json) -> Result<Vec<(u64, f64)>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("curve is not an array"))?;
    arr.iter()
        .map(|p| {
            let pair = p.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                anyhow!("curve point is not a [calls, value] pair")
            })?;
            let calls = pair[0]
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| anyhow!("bad curve calls"))?;
            let v = pair[1]
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .map(f64::from_bits)
                .ok_or_else(|| anyhow!("bad curve value bits"))?;
            Ok((calls, v))
        })
        .collect()
}

impl TrainOutcome {
    /// Canonical wire encoding, `"schema"`-stamped; curves and floats as
    /// bit patterns, so a decode is bit-exact.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("schema", jhex64(WIRE_SCHEMA_VERSION)),
            ("loss_curve", jcurve(&self.loss_curve)),
            ("acc_curve", jcurve(&self.acc_curve)),
            ("final_accuracy", jf64(self.final_accuracy)),
            ("best_accuracy", jf64(self.best_accuracy)),
            ("steps", jhex64(self.steps)),
            ("oracle_calls", jhex64(self.oracle_calls)),
            ("wall_seconds", jf64(self.wall_seconds)),
            ("label", jstr(&self.label)),
            ("completed", Json::Bool(self.completed)),
        ])
    }

    /// Decode the wire encoding produced by [`TrainOutcome::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        check_schema(j)?;
        Ok(TrainOutcome {
            loss_curve: curve_from_json(field(j, "loss_curve")?)?,
            acc_curve: curve_from_json(field(j, "acc_curve")?)?,
            final_accuracy: ff64(j, "final_accuracy")?,
            best_accuracy: ff64(j, "best_accuracy")?,
            steps: fhex64(j, "steps")?,
            oracle_calls: fhex64(j, "oracle_calls")?,
            wall_seconds: ff64(j, "wall_seconds")?,
            label: fstr(j, "label")?.to_string(),
            completed: fbool(j, "completed")?,
        })
    }
}

// ---------------------------------------------------------------------------
// grid spec files

/// Encode a whole grid as a `"schema"`-stamped spec file
/// (`{"schema": ..., "trials": [...]}`) — the `zo-ldsd grid emit` output
/// and `grid run` / `serve --specs` input.
pub fn grid_to_json(specs: &[TrialSpec]) -> Json {
    jobj(vec![
        ("schema", jhex64(WIRE_SCHEMA_VERSION)),
        ("trials", Json::Arr(specs.iter().map(|s| s.to_json()).collect())),
    ])
}

/// Decode a grid spec file produced by [`grid_to_json`].
pub fn grid_from_json(j: &Json) -> Result<Vec<TrialSpec>> {
    check_schema(j)?;
    field(j, "trials")?
        .as_arr()
        .ok_or_else(|| anyhow!("field 'trials' is not an array"))?
        .iter()
        .enumerate()
        .map(|(i, t)| TrialSpec::from_json(t).with_context(|| format!("trial #{i}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::spec_hash;
    use super::*;
    use crate::jsonio::{parse, to_string_canonical};

    fn sample_specs() -> Vec<TrialSpec> {
        let corpus = CorpusSpec { vocab: 64, seq: 8, ..CorpusSpec::default_mini() };
        let mlp = OracleSpec::Mlp(MlpTrial {
            hidden: vec![8, 4],
            activation: Activation::Relu,
            in_dim: 16,
            corpus: corpus.clone(),
            init_seed: 3,
            eval_batch: 8,
        });
        let tfm = OracleSpec::Transformer(TransformerTrial {
            layers: 2,
            heads: 2,
            d_model: 16,
            d_ff: 32,
            lora_rank: 2,
            lora_targets: LoraTargets::qv(),
            causal: true,
            pool: Pool::Last,
            corpus,
            init_seed: 7,
            eval_batch: 16,
        });
        let mut shuffled = TrainConfig::gaussian_2fwd("zo_sgd", 0.02, 64);
        shuffled.shuffle = Some(ShuffleSpec { n_train: 4096 });
        let mut a = TrialSpec::new(
            "wire/mlp",
            "mlp",
            TrainMode::Ft,
            TrainConfig::algorithm2("zo_adamm", 1e-3, 120),
            mlp,
        );
        a.probe_storage = Some(ProbeStorage::Streamed);
        a.gemm = Some(GemmMode::Reference);
        let b = TrialSpec::new("wire/tfm", "tfm", TrainMode::Lora, shuffled, tfm);
        vec![a, b]
    }

    #[test]
    fn trial_spec_roundtrip_preserves_spec_hash() {
        for spec in sample_specs() {
            let j = spec.to_json();
            // canonical text is stable through a parse/re-encode cycle
            let text = to_string_canonical(&j);
            let back = TrialSpec::from_json(&parse(&text).unwrap()).unwrap();
            assert_eq!(text, to_string_canonical(&back.to_json()));
            // wire identity == cache identity: the decoded spec hashes
            // identically, so a remote outcome slots into the grid lock
            assert_eq!(
                spec_hash(&spec, &spec.config),
                spec_hash(&back, &back.config),
                "spec '{}' must keep its hash across the wire",
                spec.id
            );
            assert_eq!(spec.id, back.id);
            assert_eq!(spec.eval_batches, back.eval_batches);
            assert_eq!(spec.probe_storage, back.probe_storage);
            assert_eq!(spec.gemm, back.gemm);
            assert!(back.checkpoint.is_none(), "checkpoint policy must not travel");
        }
    }

    #[test]
    fn grid_file_roundtrip() {
        let specs = sample_specs();
        let text = format!("{}\n", to_string_canonical(&grid_to_json(&specs)));
        let back = grid_from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), specs.len());
        for (a, b) in specs.iter().zip(&back) {
            assert_eq!(
                to_string_canonical(&a.to_json()),
                to_string_canonical(&b.to_json())
            );
        }
    }

    #[test]
    fn outcome_roundtrip_is_bit_exact() {
        let out = TrainOutcome {
            // PI has a messy bit pattern (not representable in short
            // decimal) — proves bit-exactness survives the hex encoding
            loss_curve: vec![(2, 0.75), (4, std::f64::consts::PI)],
            acc_curve: vec![(4, 0.5)],
            final_accuracy: 0.8125,
            best_accuracy: 0.875,
            steps: 24,
            oracle_calls: 120,
            wall_seconds: 1.5,
            label: "bestofk5/ldsd+zo_sgd".into(),
            completed: true,
        };
        let back = TrainOutcome::from_json(&out.to_json()).unwrap();
        assert_eq!(out.final_accuracy.to_bits(), back.final_accuracy.to_bits());
        assert_eq!(out.loss_curve, back.loss_curve);
        assert_eq!(out.acc_curve, back.acc_curve);
        assert_eq!(out.label, back.label);
        assert_eq!(out.steps, back.steps);
        assert!(back.completed);
    }

    #[test]
    fn unknown_schema_version_is_rejected() {
        let spec = &sample_specs()[0];
        let mut j = spec.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), jhex64(WIRE_SCHEMA_VERSION + 1));
        }
        let err = TrialSpec::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("schema"), "{err}");
        let missing = jobj(vec![("id", jstr("x"))]);
        assert!(TrialSpec::from_json(&missing).is_err());
    }
}
