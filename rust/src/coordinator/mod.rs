//! Trial coordinator: schedules grids of training runs across a worker
//! pool and aggregates results (Table 1 / Fig. 3 machinery).
//!
//! PJRT clients are not `Send`, so each worker *creates its own
//! [`Runtime`]* inside the thread; trials are chunked so one worker
//! amortizes its artifact compilation over its whole chunk.
//!
//! Parallelism is budgeted through one shared [`ExecContext`]: trial-level
//! workers come from the context's pool (created once, reused across
//! grids — no per-grid pool churn), and each trial receives a
//! [`ExecContext::partition`]ed shard-level context so total concurrency
//! stays at the caller's budget instead of multiplying against it.
//!
//! Trials choose their workload through [`OracleSpec`]: the PJRT
//! transformer (needs artifacts + runtime) or the forward-only MLP
//! classifier (host-side, artifact-free; DESIGN.md §12).  Grids may mix
//! both — runtime/manifest failures only fail the trials that needed
//! them.
//!
//! Grids are elastic (DESIGN.md §11): with a checkpoint directory
//! configured, every trial snapshots into its own subdirectory and all
//! trials of a grid share one content-addressed store under the grid
//! base (DESIGN.md §16).  A killed grid resumed with
//! [`crate::snapshot::CheckpointConfig::resume`] warm-starts by hash
//! identity: each trial's *canonical spec hash* ([`spec_hash`], SHA-256
//! over the canonical-JSON identity of the resolved configuration) is
//! looked up in the grid's `grid.lock.json`, which pins spec hash →
//! outcome-record object.  A hit short-circuits the trial with zero
//! training steps; any change to a hashed field changes the hash, so
//! staleness detection is exact (the old label/seed/budget field
//! comparison survives only as the fallback for legacy records without a
//! spec hash).  Mixed or reordered re-run grids still hit — identity is
//! the hash, not the position or directory name.  In-flight trials
//! continue bitwise-identically from their newest valid snapshot.

pub mod wire;

use anyhow::{anyhow, Result};

use std::collections::BTreeMap;

use crate::config::{Manifest, TrainMode};
use crate::data::corpus::CorpusSpec;
use crate::data::Corpus;
use crate::eval::{AccuracyEval, Evaluator, MlpEvaluator, TransformerEvaluator};
use crate::exec::ExecContext;
use crate::jsonio::{to_string_canonical, Json};
use crate::metrics::probe_tracker;
use crate::model::mlp::{Activation, MlpSpec};
use crate::model::{LoraTargets, Pool, TransformerSpec};
use crate::oracle::{MlpOracle, Oracle, PjrtOracle, TransformerOracle};
use crate::runtime::Runtime;
use crate::snapshot::{self, CheckpointConfig};
use crate::store::{sha256_hex, GridLock, LockEntry};
use crate::train::{
    GemmMode, ParamStoreMode, ProbeDispatch, ProbeStorage, TrainConfig, TrainOutcome,
    Trainer,
};
use wire::{jestimator, jf32, jhex64, jnum, jobj, jstr};

/// The forward-only MLP trial configuration: architecture, featurizer
/// width, the corpus it trains on, and the parameter-init seed.
#[derive(Clone, Debug)]
pub struct MlpTrial {
    /// Hidden-layer widths (`--hidden 64,64`).
    pub hidden: Vec<usize>,
    /// Hidden activation (`--activation tanh|relu`).
    pub activation: Activation,
    /// Hashed bag-of-token feature width (`--in-dim`).
    pub in_dim: usize,
    /// The corpus the oracle trains and evaluates on.
    pub corpus: CorpusSpec,
    /// Seed for the deterministic parameter init.
    pub init_seed: u64,
    /// Test-batch size for accuracy evaluation.
    pub eval_batch: usize,
}

/// The host-side transformer trial configuration: architecture + LoRA
/// subspace geometry, the corpus it trains on, and the init seed.  The
/// trainable subspace (FT or LoRA) comes from [`TrialSpec::mode`];
/// vocab, sequence length and class count come from the corpus so the
/// model always matches its data.
#[derive(Clone, Debug)]
pub struct TransformerTrial {
    /// Transformer depth (`--layers`).
    pub layers: usize,
    /// Attention heads (`--heads`; must divide `d_model`).
    pub heads: usize,
    /// Hidden width (`--d-model`).
    pub d_model: usize,
    /// MLP-block hidden width (`--d-ff`).
    pub d_ff: usize,
    /// LoRA adapter rank (`--lora-rank`).
    pub lora_rank: usize,
    /// Which attention projections carry adapters (`--lora-targets`).
    pub lora_targets: LoraTargets,
    /// Causal (decoder) vs bidirectional attention.
    pub causal: bool,
    /// Classifier pooling strategy.
    pub pool: Pool,
    /// The corpus the oracle trains and evaluates on.
    pub corpus: CorpusSpec,
    /// Seed for the deterministic base + adapter init.
    pub init_seed: u64,
    /// Test-batch size for accuracy evaluation.
    pub eval_batch: usize,
}

impl TransformerTrial {
    /// The validated [`TransformerSpec`] this trial instantiates
    /// (vocab/seq/classes taken from the corpus).
    pub fn model_spec(&self) -> Result<TransformerSpec> {
        let mut spec = TransformerSpec::new(
            self.corpus.vocab as usize,
            self.d_model,
            self.layers,
            self.heads,
            self.d_ff,
            self.corpus.seq,
            self.corpus.n_classes as usize,
            self.causal,
            self.pool,
            self.lora_rank,
        )?;
        spec.lora_targets = self.lora_targets;
        Ok(spec)
    }
}

/// Which oracle a trial runs against.
#[derive(Clone, Debug, Default)]
pub enum OracleSpec {
    /// The AOT-compiled transformer via PJRT (needs `make artifacts` and
    /// a live runtime).
    #[default]
    Pjrt,
    /// The forward-only MLP classifier — host-side, artifact-free.
    Mlp(MlpTrial),
    /// The host-side transformer + LoRA oracle — artifact-free
    /// (DESIGN.md §13).
    Transformer(TransformerTrial),
}

/// One training run to schedule.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    /// Stable identifier used to match results back to specs.
    pub id: String,
    /// Manifest model name (PJRT trials; the host oracles ignore it).
    pub model: String,
    /// Full fine-tuning or LoRA (PJRT and transformer trials; the MLP
    /// oracle ignores it).
    pub mode: TrainMode,
    /// The training-run configuration.
    pub config: TrainConfig,
    /// Test batches per evaluation point (overrides the config's value).
    pub eval_batches: usize,
    /// Per-trial override of the probe-dispatch mode (None keeps the
    /// config's).  The CLI `train --probe-dispatch` flag flows through
    /// here; grids can use it to A/B fused vs per-probe dispatch without
    /// cloning configs by hand.
    pub probe_dispatch: Option<ProbeDispatch>,
    /// Per-trial override of the probe storage (None keeps the config's).
    /// The CLI `train --probe-storage` flag flows through here; grids can
    /// use it to A/B materialized vs streamed without cloning configs.
    pub probe_storage: Option<ProbeStorage>,
    /// Per-trial override of the parameter-storage mode (None keeps the
    /// config's).  The CLI `train --param-store` flag flows through here;
    /// grids can use it to A/B f32 vs quantized stores without cloning
    /// configs (DESIGN.md §14).
    pub param_store: Option<ParamStoreMode>,
    /// Per-trial override of the GEMM engine (None keeps the config's).
    /// The CLI `train --gemm` flag flows through here; grids can use it
    /// to A/B the blocked engine against the reference loop without
    /// cloning configs (DESIGN.md §15).  Both engines produce identical
    /// bits, so this only moves throughput.
    pub gemm: Option<GemmMode>,
    /// Per-trial override of the checkpoint/resume policy (None keeps the
    /// config's).  Either way, a grid-level checkpoint directory is
    /// rewritten to a per-trial subdirectory (`<dir>/<sanitized id>`)
    /// before the trainer sees it, so trials never clobber each other's
    /// snapshots.
    pub checkpoint: Option<CheckpointConfig>,
    /// The workload this trial evaluates ([`OracleSpec::Pjrt`] by
    /// default).
    pub oracle: OracleSpec,
}

/// Outcome of one scheduled trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    /// The [`TrialSpec::id`] this result belongs to.
    pub spec_id: String,
    /// The training-run outcome.
    pub outcome: TrainOutcome,
    /// The probe storage the run *resolved to* ("materialized" |
    /// "streamed") after the env override, memory budget, and capability
    /// fallbacks — which may differ from what the spec requested.
    pub probe_storage: &'static str,
    /// Measured peak probe-state bytes (probe matrices + streaming
    /// scratch, from [`crate::metrics::probe_tracker`]).  For serial
    /// schedules — [`run_trial`] and one-worker grids — the tracker is
    /// reset at the start of the trial and this is the trial's exact
    /// peak, never inheriting an earlier trial's high-water mark.  The
    /// tracker is process-wide, so concurrent grids cannot attribute
    /// peaks to individual trials; [`run_grid`] then reports the
    /// *grid-wide* peak (one measurement window around the whole grid)
    /// on every result — a shared upper bound rather than a per-trial
    /// number.
    pub probe_peak_bytes: usize,
    /// True when this result was served from a completed-outcome record
    /// (grid warm-start by canonical spec hash) without constructing a
    /// trainer — zero training steps ran in this process for this trial.
    pub cached: bool,
    /// Oracle forward calls actually issued *in this session* for this
    /// trial: 0 for cached results, equal to the outcome's `oracle_calls`
    /// for cold runs, and smaller for snapshot-resumed ones.  This is the
    /// accounting a warm-started grid's "zero training steps" claim is
    /// verified against.
    pub session_oracle_calls: u64,
}

/// Canonical spec hash: SHA-256 over the canonical-JSON identity of a
/// trial's *resolved* configuration (spec overrides already applied).
///
/// Included: everything that changes the training trajectory or what the
/// numbers mean — estimator (with full sampler configuration, float
/// fields as IEEE bit patterns), optimizer, lr/tau, budget/seed,
/// eval cadence, shuffle, probe dispatch (only tolerance-equal across
/// modes, not bitwise), the *effective* param store (the `ZO_PARAM_STORE`
/// env override is resolved into the hash: an env-forced quantized store
/// changes the trajectory, so a false hit would serve wrong numbers),
/// train mode, and the full oracle/model/corpus spec.
///
/// Excluded: bitwise-identical throughput knobs — GEMM engine, probe
/// storage, thread count.  Re-running a grid with different performance
/// settings still warm-starts.
pub fn spec_hash(spec: &TrialSpec, cfg: &TrainConfig) -> String {
    let shuffle = match &cfg.shuffle {
        Some(s) => jobj(vec![("n_train", jhex64(s.n_train))]),
        None => Json::Null,
    };
    // same CONFIGURED > ENV precedence the trainer resolves with, so the
    // hash always names the store the run will actually use
    let param_store = crate::train::requested_param_store(cfg);
    let identity = jobj(vec![
        ("estimator", jestimator(&cfg.estimator)),
        ("optimizer", jstr(&cfg.optimizer)),
        ("lr", jf32(cfg.lr)),
        ("tau", jf32(cfg.tau)),
        ("budget", jhex64(cfg.budget)),
        ("eval_every", jhex64(cfg.eval_every)),
        ("eval_batches", jnum(cfg.eval_batches)),
        ("cosine_schedule", Json::Bool(cfg.cosine_schedule)),
        ("seed", jhex64(cfg.seed)),
        ("probe_dispatch", jstr(cfg.probe_dispatch.label())),
        ("shuffle", shuffle),
        ("param_store", jstr(param_store.label())),
        ("mode", jstr(spec.mode.as_str())),
        ("oracle", joracle(spec)),
    ]);
    sha256_hex(to_string_canonical(&identity).as_bytes())
}

/// The oracle identity the spec hash covers: the wire encoding
/// ([`OracleSpec::to_json`]), with the manifest model name merged in for
/// PJRT trials (the name selects the artifact, so it is identity there;
/// the host oracles ignore it).
fn joracle(spec: &TrialSpec) -> Json {
    match &spec.oracle {
        OracleSpec::Pjrt => {
            jobj(vec![("kind", jstr("pjrt")), ("model", jstr(&spec.model))])
        }
        other => other.to_json(),
    }
}

/// [`spec_hash`] with the spec's own overrides already applied — the hash
/// [`run_trial_measured`] computes after folding `eval_batches` and the
/// per-trial `Some` overrides into the config.  This is the identity the
/// service leases and collects outcomes under, so coordinator and worker
/// agree on it without shipping a resolved config.
pub fn resolved_spec_hash(spec: &TrialSpec) -> String {
    let mut cfg = spec.config.clone();
    cfg.eval_batches = spec.eval_batches;
    if let Some(dispatch) = spec.probe_dispatch {
        cfg.probe_dispatch = dispatch;
    }
    if let Some(storage) = spec.probe_storage {
        cfg.probe_storage = storage;
    }
    if let Some(store) = spec.param_store {
        cfg.param_store = store;
    }
    if let Some(g) = spec.gemm {
        cfg.gemm = g;
    }
    spec_hash(spec, &cfg)
}

/// Render grid results as the deterministic canonical report: one row per
/// `Ok` trial — id, accuracy/steps/oracle-call bit patterns, label,
/// completed — no wall times, no peaks, no cache provenance.  Canonical
/// JSON plus a trailing newline, so any two runs of the same grid are
/// byte-comparable: cold vs warm, single-process vs farmed over workers
/// (the service acceptance check), any thread count or storage mode.
pub fn deterministic_report(results: &[Result<TrialResult>]) -> String {
    let mut rows: Vec<Json> = Vec::new();
    for tr in results.iter().flatten() {
        let mut row = BTreeMap::new();
        row.insert("id".to_string(), Json::Str(tr.spec_id.clone()));
        row.insert(
            "accuracy_bits".to_string(),
            Json::Str(format!("{:016x}", tr.outcome.final_accuracy.to_bits())),
        );
        row.insert(
            "steps".to_string(),
            Json::Str(format!("{:016x}", tr.outcome.steps)),
        );
        row.insert(
            "oracle_calls".to_string(),
            Json::Str(format!("{:016x}", tr.outcome.oracle_calls)),
        );
        row.insert("label".to_string(), Json::Str(tr.outcome.label.clone()));
        row.insert("completed".to_string(), Json::Bool(tr.outcome.completed));
        rows.push(Json::Obj(row));
    }
    let mut root = BTreeMap::new();
    root.insert("rows".to_string(), Json::Arr(rows));
    format!("{}\n", to_string_canonical(&Json::Obj(root)))
}

/// Where a trial persists its completed-outcome record: its private
/// checkpoint subdirectory, the grid base holding `grid.lock.json`, and
/// the canonical spec hash keying the pin.
struct TrialPersist {
    trial_dir: std::path::PathBuf,
    grid_base: std::path::PathBuf,
    spec_hash: String,
}

/// Run one trial on the current thread (used by workers and by the
/// single-threaded CLI path).  `exec` is the shard-level execution context
/// the trial's train loop runs on.  The probe-memory tracker is reset at
/// trial start, so [`TrialResult::probe_peak_bytes`] is this trial's
/// exact peak (serial-schedule measurement; concurrent grids go through
/// [`run_grid`], which measures grid-wide instead).
pub fn run_trial(
    artifact_dir: &str,
    manifest: &Manifest,
    spec: &TrialSpec,
    rt: &Runtime,
    exec: &ExecContext,
) -> Result<TrialResult> {
    run_trial_measured(artifact_dir, Some(manifest), spec, Some(rt), exec, true)
}

/// [`run_trial`] for trials that need no PJRT artifacts or runtime (the
/// MLP oracle path) — the CLI `train --oracle mlp` entry point.  A
/// [`OracleSpec::Pjrt`] spec errors here.
pub fn run_local_trial(
    artifact_dir: &str,
    spec: &TrialSpec,
    exec: &ExecContext,
) -> Result<TrialResult> {
    run_trial_measured(artifact_dir, None, spec, None, exec, true)
}

/// [`run_trial`] with the per-trial probe-memory window made optional:
/// concurrent grid workers pass `measure = false` (a process-wide
/// tracker cannot attribute peaks to one of several live trials — and a
/// mid-grid reset would clamp a neighbour's transient peak away) and let
/// [`run_grid`] bracket the whole grid with one measurement window.
/// `manifest`/`rt` are optional because artifact-free workloads (the MLP
/// oracle) never touch them.
fn run_trial_measured(
    artifact_dir: &str,
    manifest: Option<&Manifest>,
    spec: &TrialSpec,
    rt: Option<&Runtime>,
    exec: &ExecContext,
    measure: bool,
) -> Result<TrialResult> {
    let mut cfg = spec.config.clone();
    cfg.eval_batches = spec.eval_batches;
    if let Some(dispatch) = spec.probe_dispatch {
        cfg.probe_dispatch = dispatch;
    }
    if let Some(storage) = spec.probe_storage {
        cfg.probe_storage = storage;
    }
    if let Some(store) = spec.param_store {
        cfg.param_store = store;
    }
    if let Some(g) = spec.gemm {
        cfg.gemm = g;
    }
    if let Some(ck) = &spec.checkpoint {
        cfg.checkpoint = ck.clone();
    }
    // Rewrite a grid-level checkpoint base to this trial's private
    // subdirectory, defaulting the shared store to `<base>/store` so all
    // trials of the grid dedup into one object set.  A resumed grid
    // warm-starts by canonical spec hash: a `grid.lock.json` pin (or a
    // still-fresh per-trial completed record) short-circuits the trial
    // with zero training steps.
    let mut persist: Option<TrialPersist> = None;
    if let Some(base) = cfg.checkpoint.dir.clone().map(std::path::PathBuf::from) {
        if cfg.checkpoint.store_dir.is_none() {
            cfg.checkpoint.store_dir =
                Some(base.join("store").to_string_lossy().into_owned());
        }
        let tdir = base.join(snapshot::sanitize_id(&spec.id));
        cfg.checkpoint.dir = Some(tdir.to_string_lossy().into_owned());
        let shash = spec_hash(spec, &cfg);
        if cfg.checkpoint.resume {
            let store = snapshot::open_store(&cfg.checkpoint);
            // 1. Lockfile pin — exact hash identity, independent of trial
            //    position or directory naming, so mixed/reordered re-run
            //    grids still hit.
            if let Some(entry) = GridLock::load(&base).get(&shash) {
                if let Some(st) = &store {
                    match snapshot::outcome_from_store(st, &entry.outcome) {
                        Ok(rec) => return Ok(cached_result(spec, rec)),
                        Err(e) => eprintln!(
                            "coordinator: grid.lock.json pins {} for trial \
                             '{}' but the record is unreadable ({e:#}) — \
                             re-running trial",
                            entry.outcome, spec.id,
                        ),
                    }
                }
            }
            // 2. Per-trial completed record (pre-lockfile grids and
            //    legacy v2 records).  A config edit between grid runs
            //    changes the spec hash, so staleness detection is exact —
            //    the trial re-runs instead of silently serving stale
            //    numbers.  (The re-run then hits the same mismatch on any
            //    leftover snapshot via the trainer's fingerprint check,
            //    which errors loudly.)
            if let Some(rec) = snapshot::load_outcome(&tdir, store.as_ref()) {
                let fresh = match &rec.spec_hash {
                    Some(h) => *h == shash,
                    // legacy record without a spec hash: fall back to the
                    // old label/seed/budget field comparison
                    None => {
                        rec.outcome.label
                            == format!("{}+{}", cfg.estimator.label(), cfg.optimizer)
                            && rec.seed == cfg.seed
                            && rec.budget == cfg.budget
                    }
                };
                if fresh {
                    // backfill the lockfile so the next resume hits the
                    // pin directly (best-effort: a failed backfill only
                    // costs the next resume this same record lookup)
                    if let Some(st) = &store {
                        let mut pinned = rec.clone();
                        pinned.spec_hash = Some(shash.clone());
                        if let Ok(hash) = snapshot::outcome_to_store(st, &pinned) {
                            let _ = GridLock::record(
                                &base,
                                &shash,
                                &LockEntry {
                                    outcome: hash,
                                    id: spec.id.clone(),
                                    label: rec.outcome.label.clone(),
                                },
                            );
                        }
                    }
                    return Ok(cached_result(spec, rec));
                }
                eprintln!(
                    "coordinator: completed record in {} does not match this \
                     run's canonical spec hash {shash} — re-running trial",
                    tdir.display(),
                );
            }
        }
        persist = Some(TrialPersist { trial_dir: tdir, grid_base: base, spec_hash: shash });
    }
    let _ = artifact_dir;
    match &spec.oracle {
        OracleSpec::Pjrt => {
            let rt = rt.ok_or_else(|| {
                anyhow!("trial '{}' needs a PJRT runtime (artifacts missing?)", spec.id)
            })?;
            let manifest = manifest.ok_or_else(|| {
                anyhow!("trial '{}' needs the artifact manifest", spec.id)
            })?;
            let entry = manifest.model(&spec.model)?;
            let corpus = Corpus::new(manifest.corpus(&spec.model)?.clone())?;
            let oracle = PjrtOracle::new(rt, entry, spec.mode)?;
            let evaluator = Evaluator::new(rt, entry, spec.mode)?;
            finish_trial(spec, cfg, oracle, &evaluator, corpus, exec, measure, persist.as_ref())
        }
        OracleSpec::Mlp(m) => {
            let corpus = Corpus::new(m.corpus.clone())?;
            let mspec = MlpSpec::new(
                m.in_dim,
                m.hidden.clone(),
                m.corpus.n_classes as usize,
                m.activation,
            )?;
            let oracle = MlpOracle::from_seed(mspec.clone(), m.init_seed);
            let evaluator = MlpEvaluator::new(mspec, m.eval_batch);
            finish_trial(spec, cfg, oracle, &evaluator, corpus, exec, measure, persist.as_ref())
        }
        OracleSpec::Transformer(t) => {
            let corpus = Corpus::new(t.corpus.clone())?;
            let tspec = t.model_spec()?;
            let oracle = TransformerOracle::from_seed(tspec.clone(), spec.mode, t.init_seed);
            let evaluator = TransformerEvaluator::new(
                tspec,
                spec.mode,
                oracle.base().to_vec(),
                t.eval_batch,
            )?;
            finish_trial(spec, cfg, oracle, &evaluator, corpus, exec, measure, persist.as_ref())
        }
    }
}

/// The oracle-generic tail of one trial: build the trainer on the trial's
/// shard-level context, run it against the evaluator, and persist the
/// completed-outcome record (store object + lockfile pin + `completed/`
/// mirror).
#[allow(clippy::too_many_arguments)]
fn finish_trial<O: Oracle>(
    spec: &TrialSpec,
    cfg: TrainConfig,
    oracle: O,
    evaluator: &dyn AccuracyEval,
    corpus: Corpus,
    exec: &ExecContext,
    measure: bool,
    persist: Option<&TrialPersist>,
) -> Result<TrialResult> {
    // per-trial probe-memory window: without this reset, every trial
    // after the first reported the run's cumulative high-water mark
    // instead of its own peak
    if measure {
        probe_tracker().reset();
    }
    // (cfg moves into the trainer; keep the identity fields the completed
    // record is stamped with, and open the store before the move)
    let (cfg_seed, cfg_budget) = (cfg.seed, cfg.budget);
    let store = snapshot::open_store(&cfg.checkpoint);
    let mut trainer = Trainer::with_exec(cfg, oracle, corpus, exec.clone())?;
    let probe_storage = trainer.estimator().probes().label();
    let outcome = trainer.run(Some(evaluator))?;
    let session_oracle_calls = trainer.oracle().oracle_calls();
    let probe_peak_bytes = if measure { probe_tracker().peak() } else { 0 };
    if outcome.completed {
        if let (Some(p), Some(store)) = (persist, &store) {
            // persist the finished trial as a store object and pin its
            // spec hash in the grid lockfile, so any future re-run of
            // this spec — same grid or a reordered one — warm-starts
            let rec = snapshot::OutcomeRecord {
                outcome: outcome.clone(),
                probe_storage: probe_storage.to_string(),
                seed: cfg_seed,
                budget: cfg_budget,
                spec_hash: Some(p.spec_hash.clone()),
            };
            let hash = snapshot::write_outcome(&p.trial_dir, store, &rec)?;
            GridLock::record(
                &p.grid_base,
                &p.spec_hash,
                &LockEntry {
                    outcome: hash,
                    id: spec.id.clone(),
                    label: outcome.label.clone(),
                },
            )?;
        }
    }
    Ok(TrialResult {
        spec_id: spec.id.clone(),
        outcome,
        probe_storage,
        probe_peak_bytes,
        cached: false,
        session_oracle_calls,
    })
}

/// Build the short-circuit result for a warm-start hit: the stored
/// outcome with `cached = true` and zero session oracle calls (the
/// zero-training-steps evidence grid reports key on).
fn cached_result(spec: &TrialSpec, rec: snapshot::OutcomeRecord) -> TrialResult {
    TrialResult {
        spec_id: spec.id.clone(),
        outcome: rec.outcome,
        probe_storage: storage_label_static(&rec.probe_storage),
        probe_peak_bytes: 0,
        cached: true,
        session_oracle_calls: 0,
    }
}

/// Map a stored probe-storage label back onto the static strings
/// [`TrialResult::probe_storage`] carries.
pub(crate) fn storage_label_static(label: &str) -> &'static str {
    match label {
        "streamed" => "streamed",
        "auto" => "auto",
        _ => "materialized",
    }
}

/// The Table-1 bench workload as wire-constructable specs: the synthetic
/// SST-2 stand-in corpus under a small causal decoder with rank-4 q/v
/// adapters, the three sampling schemes per optimizer (`full` adds the
/// plain-SGD and Adam arms).  One builder — routed through
/// [`TrialSpec::new`], the single wire constructor path — feeds the
/// `table1_sst2` bench, `zo grid emit`, and the service byte-identity
/// tests, so every consumer schedules the identical grid.  `smoke`
/// selects the CI evaluation width (2 test batches instead of 8).
pub fn table1_grid(budget: u64, full: bool, smoke: bool) -> Vec<TrialSpec> {
    let corpus = CorpusSpec {
        vocab: 256,
        seq: 16,
        lexicon: 32,
        min_len: 8,
        signal_min: 2,
        signal_max: 4,
        ..CorpusSpec::default_mini()
    };
    let trial = TransformerTrial {
        layers: 2,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        lora_rank: 4,
        lora_targets: LoraTargets::qv(),
        causal: true,
        pool: Pool::Last,
        corpus,
        init_seed: 7,
        eval_batch: 64,
    };
    let label = trial
        .model_spec()
        .expect("the static table1 architecture is valid")
        .label();
    let optimizers: &[(&str, f32)] = if full {
        &[("zo_sgd", 0.02), ("zo_sgd_plain", 0.02), ("zo_adamm", 1e-3)]
    } else {
        &[("zo_sgd", 0.02)]
    };
    let mut specs = Vec::new();
    for (optimizer, lr) in optimizers {
        for (method, mut cfg) in [
            ("gauss_2fwd", TrainConfig::gaussian_2fwd(optimizer, *lr, budget)),
            ("gauss_6fwd", TrainConfig::gaussian_6fwd(optimizer, *lr, budget)),
            ("alg2", TrainConfig::algorithm2(optimizer, *lr, budget)),
        ] {
            cfg.eval_batches = if smoke { 2 } else { 8 };
            specs.push(TrialSpec::new(
                &format!("{label}/lora/{optimizer}/{method}"),
                &label,
                TrainMode::Lora,
                cfg,
                OracleSpec::Transformer(trial.clone()),
            ));
        }
    }
    specs
}

/// Run a batch of trials on the shared execution context.  Trial-level
/// workers come from `exec`'s pool (reused across grids); each trial gets
/// a partitioned shard-level context so the two levels share one worker
/// budget.  Results come back in spec order; per-trial failures are
/// isolated into `Err` strings.  Runtime/manifest initialization failures
/// only fail the PJRT trials that needed them — artifact-free (MLP)
/// trials in the same grid still run.  Probe-memory peaks are exact per
/// trial on one-worker grids and grid-wide (stamped on every result)
/// otherwise — see [`TrialResult::probe_peak_bytes`].
pub fn run_grid(
    artifact_dir: &str,
    specs: Vec<TrialSpec>,
    exec: &ExecContext,
) -> Vec<Result<TrialResult>> {
    let workers = exec.threads().max(1).min(specs.len().max(1));
    let pool = exec.pool();
    let shard_exec = exec.partition(workers);
    // Probe-memory measurement: with one worker, trials are serial and
    // each gets its own exact per-trial window; with several, the
    // process-wide tracker cannot attribute peaks per trial, so one
    // grid-wide window brackets the whole grid and its peak is stamped
    // on every result below (a shared upper bound).
    let per_trial_peaks = workers <= 1;
    if !per_trial_peaks {
        probe_tracker().reset();
    }
    // chunk specs round-robin so each worker compiles its artifacts once
    let mut chunks: Vec<Vec<(usize, TrialSpec)>> = vec![Vec::new(); workers];
    for (i, spec) in specs.into_iter().enumerate() {
        chunks[i % workers].push((i, spec));
    }
    let dir = artifact_dir.to_string();
    let chunk_results = pool.scope_map(chunks, move |chunk| {
        let mut out: Vec<(usize, Result<TrialResult, String>)> = Vec::new();
        // one runtime + manifest per worker thread, built only when the
        // chunk actually contains a PJRT trial (an all-MLP grid never
        // pays for client init or a manifest parse); failures are kept
        // as errors so artifact-free trials in the chunk still run
        let needs_runtime = chunk
            .iter()
            .any(|(_, s)| matches!(s.oracle, OracleSpec::Pjrt));
        let rt = if needs_runtime {
            Runtime::new(&dir)
        } else {
            Err(anyhow!("no PJRT trial in this chunk"))
        };
        let manifest = if needs_runtime {
            Manifest::load(&dir)
        } else {
            Err(anyhow!("no PJRT trial in this chunk"))
        };
        for (i, spec) in chunk {
            let r = match (&spec.oracle, &rt, &manifest) {
                (OracleSpec::Pjrt, Err(e), _) => Err(format!("runtime init: {e:#}")),
                (OracleSpec::Pjrt, _, Err(e)) => Err(format!("manifest load: {e:#}")),
                _ => run_trial_measured(
                    &dir,
                    manifest.as_ref().ok(),
                    &spec,
                    rt.as_ref().ok(),
                    &shard_exec,
                    per_trial_peaks,
                )
                .map_err(|e| format!("{e:#}")),
            };
            out.push((i, r));
        }
        out
    });
    // flatten, restore order
    let mut indexed: Vec<(usize, Result<TrialResult, String>)> = Vec::new();
    for c in chunk_results {
        match c {
            Ok(items) => indexed.extend(items),
            Err(panic_msg) => {
                // a whole worker chunk panicked; surface it once
                indexed.push((usize::MAX, Err(panic_msg)));
            }
        }
    }
    indexed.sort_by_key(|(i, _)| *i);
    let grid_peak = if per_trial_peaks { 0 } else { probe_tracker().peak() };
    indexed
        .into_iter()
        .map(|(_, r)| {
            r.map(|mut tr| {
                if !per_trial_peaks {
                    tr.probe_peak_bytes = grid_peak;
                }
                tr
            })
            .map_err(|e| anyhow!(e))
        })
        .collect()
}

/// Accuracy aggregation across seed-replicated specs with an explicit
/// sample count: an empty result slice yields `n = 0` and `None` stats
/// instead of NaNs that would propagate into grid summaries (and turn
/// into `null` in report JSON).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccuracyAggregate {
    /// Number of results aggregated.
    pub n: usize,
    /// Mean final accuracy (None when `n == 0`).
    pub mean: Option<f64>,
    /// Sample standard deviation (None when `n == 0`; 0 for `n == 1`).
    pub std: Option<f64>,
}

impl AccuracyAggregate {
    /// Render as `mean ± std (n)` or `n=0` for tables.
    pub fn display(&self) -> String {
        match (self.mean, self.std) {
            (Some(m), Some(s)) => format!("{m:.4} ± {s:.4} (n={})", self.n),
            _ => "n=0".to_string(),
        }
    }
}

/// Mean/std aggregation of final accuracy across seed-replicated specs.
/// Empty input reports `n = 0` explicitly rather than NaN stats.
pub fn aggregate_accuracy(results: &[&TrialResult]) -> AccuracyAggregate {
    if results.is_empty() {
        return AccuracyAggregate::default();
    }
    let accs: Vec<f64> = results.iter().map(|r| r.outcome.final_accuracy).collect();
    AccuracyAggregate {
        n: accs.len(),
        mean: Some(crate::metrics::mean(&accs)),
        std: Some(crate::metrics::stddev(&accs)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_mean_std() {
        let mk = |acc: f64| TrialResult {
            spec_id: "s".into(),
            outcome: TrainOutcome { final_accuracy: acc, ..Default::default() },
            probe_storage: "materialized",
            probe_peak_bytes: 0,
            cached: false,
            session_oracle_calls: 0,
        };
        let a = mk(0.8);
        let b = mk(0.9);
        let agg = aggregate_accuracy(&[&a, &b]);
        assert_eq!(agg.n, 2);
        assert!((agg.mean.unwrap() - 0.85).abs() < 1e-12);
        assert!(agg.std.unwrap() > 0.0);
        assert!(agg.display().contains("n=2"));
    }

    #[test]
    fn aggregate_empty_reports_n_zero_not_nan() {
        let agg = aggregate_accuracy(&[]);
        assert_eq!(agg.n, 0);
        assert_eq!(agg.mean, None);
        assert_eq!(agg.std, None);
        assert_eq!(agg.display(), "n=0");
    }

    #[test]
    fn spec_hash_tracks_identity_not_throughput() {
        use crate::train::TrainConfig;
        let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", 0.05, 120);
        cfg.eval_every = 0;
        let spec = TrialSpec {
            id: "hash/test".into(),
            model: "mlp".into(),
            mode: TrainMode::Ft,
            config: cfg.clone(),
            eval_batches: 1,
            probe_dispatch: None,
            probe_storage: None,
            param_store: None,
            gemm: None,
            checkpoint: None,
            oracle: OracleSpec::Mlp(MlpTrial {
                hidden: vec![8],
                activation: Activation::Tanh,
                in_dim: 16,
                corpus: CorpusSpec::default_mini(),
                init_seed: 1,
                eval_batch: 8,
            }),
        };
        let base = spec_hash(&spec, &cfg);
        assert_eq!(base.len(), 64);
        assert_eq!(base, spec_hash(&spec, &cfg), "hash must be deterministic");

        // throughput knobs are excluded: bitwise-identical trajectories
        let mut perf = cfg.clone();
        perf.gemm = GemmMode::Reference;
        perf.probe_storage = ProbeStorage::Streamed;
        assert_eq!(base, spec_hash(&spec, &perf));

        // identity fields are included: any change must miss
        let mut seed = cfg.clone();
        seed.seed = 7;
        assert_ne!(base, spec_hash(&spec, &seed));
        let mut lr = cfg.clone();
        lr.lr *= 2.0;
        assert_ne!(base, spec_hash(&spec, &lr));
        let mut dispatch = cfg.clone();
        dispatch.probe_dispatch = ProbeDispatch::PerProbe;
        assert_ne!(base, spec_hash(&spec, &dispatch));
        let mode = TrialSpec { mode: TrainMode::Lora, ..spec.clone() };
        assert_ne!(base, spec_hash(&mode, &cfg));
        let mut oracle_seed = spec.clone();
        if let OracleSpec::Mlp(m) = &mut oracle_seed.oracle {
            m.init_seed = 2;
        }
        assert_ne!(base, spec_hash(&oracle_seed, &cfg));
    }

    #[test]
    fn mlp_trial_runs_without_artifacts() {
        use crate::train::TrainConfig;
        let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", 0.05, 120);
        cfg.eval_every = 0;
        let spec = TrialSpec {
            id: "mlp/test".into(),
            model: "mlp".into(),
            mode: TrainMode::Ft,
            config: cfg,
            eval_batches: 1,
            probe_dispatch: None,
            probe_storage: None,
            param_store: None,
            gemm: None,
            checkpoint: None,
            oracle: OracleSpec::Mlp(MlpTrial {
                hidden: vec![8],
                activation: Activation::Tanh,
                in_dim: 16,
                corpus: CorpusSpec::default_mini(),
                init_seed: 1,
                eval_batch: 8,
            }),
        };
        let result =
            run_local_trial("no-artifacts-dir", &spec, &ExecContext::new(2)).unwrap();
        assert_eq!(result.spec_id, "mlp/test");
        assert!(result.outcome.completed);
        assert_eq!(result.outcome.oracle_calls, 120);
        assert!((0.0..=1.0).contains(&result.outcome.final_accuracy));
        // PJRT trials refuse the artifact-free entry point
        let pjrt = TrialSpec { oracle: OracleSpec::Pjrt, ..spec };
        let err = run_local_trial("no-artifacts-dir", &pjrt, &ExecContext::new(1))
            .unwrap_err();
        assert!(err.to_string().contains("PJRT"), "{err}");
    }

    #[test]
    fn transformer_trial_runs_without_artifacts() {
        use crate::train::TrainConfig;
        let mut cfg = TrainConfig::algorithm2("zo_sgd_plain", 0.05, 60);
        cfg.eval_every = 0;
        let corpus = CorpusSpec {
            vocab: 64,
            seq: 8,
            lexicon: 16,
            min_len: 4,
            signal_min: 1,
            signal_max: 3,
            ..CorpusSpec::default_mini()
        };
        let trial = TransformerTrial {
            layers: 2,
            heads: 2,
            d_model: 16,
            d_ff: 32,
            lora_rank: 2,
            lora_targets: LoraTargets::qv(),
            causal: false,
            pool: Pool::Cls,
            corpus,
            init_seed: 1,
            eval_batch: 8,
        };
        let spec = TrialSpec {
            id: "tfm/test".into(),
            model: "transformer".into(),
            mode: TrainMode::Lora,
            config: cfg,
            eval_batches: 1,
            probe_dispatch: None,
            probe_storage: None,
            param_store: None,
            gemm: None,
            checkpoint: None,
            oracle: OracleSpec::Transformer(trial),
        };
        let result =
            run_local_trial("no-artifacts-dir", &spec, &ExecContext::new(2)).unwrap();
        assert_eq!(result.spec_id, "tfm/test");
        assert!(result.outcome.completed);
        assert_eq!(result.outcome.oracle_calls, 60);
        assert!((0.0..=1.0).contains(&result.outcome.final_accuracy));
    }
}
